"""Benchmark harness — one function per paper table/figure (deliverable d).

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Emits ``name,value,derived`` CSV lines per benchmark plus a summary.  Quick
mode (default) shrinks rounds/clients so the whole suite runs on a laptop
CPU in minutes; ``--full`` approaches the paper's settings.

The figure/table sweeps (fig3–fig6, table2) are driven by the declarative
sweep registry in ``repro.experiments`` — the same grids the
``python -m repro.launch.sweep`` CLI runs — so sweep definitions live in one
place; this file only adds presentation (CSV lines, rounds-to-target).
Each registry-driven bench also writes its ``BENCH_feddif_<sweep>.json``
artifact under ``benchmarks/results/``.

Paper artifacts covered:
  fig2_convergence      IID-distance & diffusion-efficiency convergence
                        (analytical Eq. 30 vs experimental)
  fig3_alpha_sweep      accuracy / diffusion rounds / comms vs Dirichlet α
  fig4_epsilon_sweep    minimum tolerable IID distance ε
  fig5_qos_sweep        minimum tolerable QoS γ_min
  fig6_tasks            ML-task sweep (logistic/svm/fcn/lstm/cnn)
  table1_accuracy       FedDif vs baselines, accuracy after T rounds
  table2_comm_eff       sub-frames / transmitted models to target accuracy
  fig_async_sweep       sync vs buffered-async engines (fig_async registry)
  async_throughput      buffered-async vs barrier: virtual time-to-target
  kernels_microbench    flash-attn / stc / ssm-scan op timings (XLA path)
  roofline_summary      aggregates benchmarks/results dry-run JSONs
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, "src")


EXECUTOR = "host"      # set by --executor; stamped on every registry sweep
PLANNER = "host"       # set by --planner; stamped on every registry sweep
ENGINE = None          # set by --engine; an EngineSpec preset name that wins
                       # over EXECUTOR/PLANNER on every cell when given


def _fl(strategy, alpha=1.0, rounds=6, clients=8, task="fcn", **kw):
    from repro.fl import ExperimentSpec, FLConfig, run_experiment
    kw.setdefault("executor", EXECUTOR)
    kw.setdefault("engine", ENGINE)
    # the jax planner does not model underlay CUE interference
    kw.setdefault("planner", "host" if kw.get("underlay") else PLANNER)
    spec = ExperimentSpec(
        task=task, alpha=alpha, num_samples=4000,
        fl=FLConfig(strategy=strategy, rounds=rounds, num_clients=clients,
                    num_models=clients, seed=0, **kw))
    return run_experiment(spec)


def fig2_convergence(full: bool):
    """Fig. 2: IID distance converges to 0 with diffusion; per-α mixing."""
    import jax.numpy as jnp
    from repro.core import dol as D
    rows = []
    for alpha in ([0.1, 0.5, 1.0, 100.0] if full else [0.1, 1.0]):
        rng = np.random.default_rng(0)
        c, iters = 10, 30
        er = []
        dol = jnp.zeros((c,))
        chain = 0.0
        for k in range(iters):
            dsi = rng.dirichlet(np.ones(c) * alpha).astype(np.float32)
            size = float(rng.integers(100, 500))
            dol, chain = D.update_dol(dol, chain, jnp.asarray(dsi), size)
            er.append(float(D.iid_distance(dol)))
        rows.append((alpha, er[0], er[4], er[-1]))
        print(f"fig2_convergence,alpha={alpha},iid_k1={er[0]:.4f},"
              f"iid_k5={er[4]:.4f},iid_k{iters}={er[-1]:.4f}")
    return rows


def _run_registry_sweep(bench_name: str, sweep_name: str, full: bool):
    """Drive one registry sweep; print per-cell CSV lines; write artifact."""
    from repro.experiments import run_sweep
    art = run_sweep(sweep_name, smoke=not full, seeds=(0,),
                    executor=EXECUTOR, planner=PLANNER,
                    engine_preset=ENGINE)
    for c in art["cells"]:
        curve = np.mean(np.asarray(c["accuracy"]), axis=0)
        print(f"{bench_name},{c['label']},engine={c['engine']},"
              f"acc={float(np.max(curve)):.4f},"
              f"dif_rounds={np.mean(c['diffusion_rounds']):.1f},"
              f"subframes={c['comm']['subframes']},"
              f"models={c['comm']['transmitted_models']},"
              f"bandwidth_hz_s={c['comm']['pusch_bandwidth_hz_s']:.3e},"
              f"sec={c['wall_clock_s']:.0f}", flush=True)
    return art


def fig3_alpha_sweep(full: bool):
    _run_registry_sweep("fig3_alpha_sweep", "fig3_alpha", full)


def fig4_epsilon_sweep(full: bool):
    _run_registry_sweep("fig4_epsilon_sweep", "fig4_epsilon", full)


def fig5_qos_sweep(full: bool):
    _run_registry_sweep("fig5_qos_sweep", "fig5_gamma_min", full)


def fig6_tasks(full: bool):
    _run_registry_sweep("fig6_tasks", "fig6_tasks", full)


def fig_async_sweep(full: bool):
    """fig_async registry sweep: buffered-async vs barrier-on-the-event-
    queue (both arms share the straggler/link-delay model and 5% churn)."""
    _run_registry_sweep("fig_async_sweep", "fig_async", full)


def fig_scenarios_sweep(full: bool):
    """fig_scenarios registry sweep: strategy × wireless-world scenario
    (static / mobile / multicell / energy_capped) — accuracy plus the
    ledger (incl. TX joules) per cell."""
    _run_registry_sweep("fig_scenarios_sweep", "fig_scenarios", full)


def world_step(full: bool):
    """Steady-state throughput of the vmapped world transition — the pure
    ``channels.world.step`` pytree update the mobile planner folds into its
    jitted while_loop — plus the host/jax static-placement parity flag.
    Writes ``BENCH_world_step.json`` (gated in benchmarks/budgets.json)."""
    import jax
    import jax.numpy as jnp
    from repro.channels.topology import CellTopology
    from repro.channels.world import WorldConfig, init_world, step
    from repro.experiments.artifacts import write_bench_json

    n = 256 if full else 64
    batch = 64
    cfg = WorldConfig.for_scenario("mobile")
    topo = CellTopology(num_pues=n)
    rng = np.random.default_rng(0)
    worlds = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[init_world(cfg, topo, np.random.default_rng([0, i]), n)
          for i in range(batch)])

    stepper = jax.jit(jax.vmap(
        lambda w: step(w, step_m=cfg.step_m)))
    worlds = jax.block_until_ready(stepper(worlds))   # compile
    iters = 200 if full else 50
    t0 = time.time()
    w = worlds
    for _ in range(iters):
        w = stepper(w)
    jax.block_until_ready(w)
    dt = time.time() - t0
    steps_per_s = batch * iters / dt

    # Host/jax twin parity on the polar placement transform (the seam the
    # static scenario's bit-identity rests on).
    r = 250.0 * np.sqrt(rng.uniform(size=n))
    theta = rng.uniform(0.0, 2 * np.pi, size=n)
    host = CellTopology.positions_from_polar(r, theta, xp=np)
    dev = CellTopology.positions_from_polar(jnp.asarray(r),
                                            jnp.asarray(theta), xp=jnp)
    parity_ok = bool(np.allclose(host, np.asarray(dev), atol=1e-5))

    record = {"steps_per_s": float(steps_per_s), "parity_ok": parity_ok,
              "batch": batch, "num_clients": n, "iters": iters}
    print(f"world_step,vmapped_{batch}x{n},{steps_per_s:.0f},steps_per_s,"
          f"parity_ok={parity_ok}", flush=True)
    write_bench_json("world_step", record)


def async_throughput(full: bool):
    """Buffered-async round plane throughput (the PR-9 tentpole headline).

    Two arms of the same event-driven executor on the same cell — fedavg at
    fleet scale under lognormal compute stragglers and channel-drawn D2D/
    uplink link delays (the ``async`` / ``async_barrier`` EngineSpec
    presets):

    * ``async_barrier``: K = all — every server tick waits for the slowest
      arrival, i.e. the classic synchronous round on the virtual clock;
    * ``async``: FedBuff-style buffering — aggregate the first
      K = 0.5·M arrivals per tick with the staleness discount
      ``alpha/(1+s)^beta``, park the rest in the buffer.

    Both arms replay identical schedules, so their Eq.-15 ledgers are
    asserted bit-identical — the *only* difference is when the virtual
    clock advances.  Headline numbers: ``speedup_time_to_target``
    (virtual seconds to the shared target accuracy, barrier/buffered;
    budget-gated ≥ 1.5x at N ≥ 256) and arrivals aggregated per virtual
    second.  Emits ``BENCH_async_throughput.json``."""
    from repro.experiments.artifacts import write_bench_json
    from repro.fl import ExperimentSpec, FLConfig, run_experiment

    n = 256 if full else 64
    rounds = 6 if full else 4
    samples = 5 * n          # comm/straggler-dominated regime: tiny shards

    def run_arm(preset):
        spec = ExperimentSpec(
            task="fcn", alpha=0.5, num_samples=samples,
            fl=FLConfig(strategy="fedavg", rounds=rounds, num_clients=n,
                        num_models=n, seed=0, topology_seed=0,
                        eval_every=1, engine=preset))
        t0 = time.time()
        r = run_experiment(spec)
        dt = time.time() - t0
        h = r.history
        vfinal = float(h.virtual_s[-1])
        arrivals = int(np.sum(h.arrivals))
        print(f"async_throughput,engine={preset},clients={n},"
              f"rounds={rounds},sec={dt:.1f},virtual_s={vfinal:.2f},"
              f"arrivals={arrivals},"
              f"arrivals_per_vs={arrivals / max(vfinal, 1e-9):.2f},"
              f"acc={max(h.accuracy):.4f},"
              f"mean_staleness={np.mean(h.staleness):.2f},"
              f"ticks={len(h.virtual_s)}", flush=True)
        return r, {"engine": preset, "wall_clock_s": dt,
                   "virtual_s": vfinal, "arrivals": arrivals,
                   "arrivals_per_vs": arrivals / max(vfinal, 1e-9),
                   "peak_acc": float(max(h.accuracy)),
                   "mean_staleness": float(np.mean(h.staleness)),
                   "ticks": len(h.virtual_s)}

    r_barrier, arm_barrier = run_arm("async_barrier")
    r_async, arm_async = run_arm("async")
    ledger_parity = (r_barrier.ledger.as_dict() == r_async.ledger.as_dict())
    assert ledger_parity, \
        "both arms replay identical schedules; Eq.-15 ledgers must agree"

    # Shared target both arms reach: just under the weaker arm's peak.
    target = 0.98 * min(arm_barrier["peak_acc"], arm_async["peak_acc"])
    tta_barrier = r_barrier.time_to_accuracy(target)
    tta_async = r_async.time_to_accuracy(target)
    speedup = float(tta_barrier) / max(float(tta_async), 1e-9)
    record = {
        "clients": n, "rounds": rounds, "num_samples": samples,
        "arms": {"async_barrier": arm_barrier, "async": arm_async},
        "ledger_parity": ledger_parity,
        "target_acc": target,
        "time_to_target_barrier_vs": tta_barrier,
        "time_to_target_async_vs": tta_async,
        "speedup_time_to_target": speedup,
        "throughput_gain": (arm_async["arrivals_per_vs"]
                            / max(arm_barrier["arrivals_per_vs"], 1e-9)),
        "max_wall_clock_s": max(arm_barrier["wall_clock_s"],
                                arm_async["wall_clock_s"]),
    }
    write_bench_json("async_throughput", record)
    print(f"async_throughput,clients={n},target_acc={target:.4f},"
          f"tta_barrier_vs={tta_barrier:.2f},tta_async_vs={tta_async:.2f},"
          f"speedup_time_to_target={speedup:.2f}x,"
          f"throughput_gain={record['throughput_gain']:.2f}x,"
          f"ledger_parity={ledger_parity}", flush=True)


def table1_accuracy(full: bool):
    rounds = 25 if full else 6
    for strat in ["fedavg", "tthf", "stc", "fedswap", "feddif"]:
        r = _fl(strat, alpha=1.0, rounds=rounds)
        print(f"table1_accuracy,strategy={strat},"
              f"acc={max(r.accuracy):.4f},final={r.accuracy[-1]:.4f}",
              flush=True)


def table2_comm_eff(full: bool):
    """Sub-frames / transmitted models until target accuracy (the paper's
    80 % CNN target, rescaled to this synthetic task).  The grid comes from
    the ``table2_strategies`` registry entry (incl. d2d_random_walk)."""
    art = _run_registry_sweep("table2_comm_eff", "table2_strategies", full)
    cells = {c["strategy"]: c for c in art["cells"]}
    base = cells.get("fedavg")
    if base is None:
        return
    base_curve = np.mean(np.asarray(base["accuracy"]), axis=0)
    target = float(np.max(base_curve))   # baseline peak = target (Sec. VI-A)
    print(f"table2_comm_eff,target_acc={target:.4f},source=fedavg_peak")
    for strat, c in cells.items():
        curve = np.mean(np.asarray(c["accuracy"]), axis=0)
        hit = next((i + 1 for i, a in enumerate(curve) if a >= target), None)
        frac = (hit / len(curve)) if hit else 1.0   # ledger is cumulative
        comm = c["comm"]
        print(f"table2_comm_eff,strategy={strat},"
              f"rounds_to_target={hit if hit else 'n/a'},"
              f"subframes={int(comm['subframes']*frac)},"
              f"models={int(comm['transmitted_models']*frac)},"
              f"bits={comm['transmitted_bits']*frac:.3e}", flush=True)


def planner_speedup(full: bool):
    """Control-plane hot path: sequential host planner (Python while +
    O(n³) Hungarian per diffusion round) vs the batched jax planner (one
    vmapped device call planning every cell × round; Bertsekas auction in
    lax.while_loop).  ≥8 concurrent cells at N=20 clients; asserts plan
    *equivalence* (identical round/hop counts and total Eq.-17 decrement —
    exact hop lists are reported but may differ on Eq.-38 ties) and emits
    BENCH_planner_speedup.json."""
    from repro.core import DiffusionPlanner, DiffusionState
    from repro.core.planner import (decode_plan, plan_round_inputs,
                                    plan_rounds_batched)
    from repro.experiments.artifacts import write_bench_json

    n = m = 20
    c = 10
    n_cells = 16 if full else 8
    rounds_per_cell = 2
    max_rounds = 24

    def build_cell(cell_idx):
        rng = np.random.default_rng(cell_idx)
        dsi = rng.dirichlet(np.ones(c) * 0.5, n).astype(np.float32)
        sizes = rng.integers(200, 800, n).astype(np.float64)
        return dsi, sizes

    def init_state(dsi, sizes):
        state = DiffusionState.init(m, n, c)
        for mi in range(m):
            state.record_training(mi, mi % n, dsi[mi % n],
                                  float(sizes[mi % n]))
        return state

    planner = DiffusionPlanner(epsilon=0.04, max_rounds=max_rounds)
    jplanner = DiffusionPlanner(epsilon=0.04, max_rounds=max_rounds,
                                mode="jax")
    cells = [build_cell(i) for i in range(n_cells)]
    topo = planner.topology

    # ---- host loop: one sequential auction loop per cell × round --------
    t0 = time.time()
    host_plans = []
    for i, (dsi, sizes) in enumerate(cells):
        for t in range(rounds_per_cell):
            rng = np.random.default_rng([i, t])
            pos = topo.sample_positions(rng, n)
            host_plans.append(planner.plan_communication_round(
                init_state(dsi, sizes), dsi, sizes, rng, positions=pos))
    host_s = time.time() - t0

    # ---- batched jax: all cells × rounds in one device call -------------
    def batch_inputs():
        items = []
        for i, (dsi, sizes) in enumerate(cells):
            for t in range(rounds_per_cell):
                rng = np.random.default_rng([i, t])
                pos = topo.sample_positions(rng, n)
                inp, g64 = plan_round_inputs(jplanner, init_state(dsi, sizes),
                                             dsi, sizes, rng, positions=pos)
                items.append((inp, g64))
        return items

    t0 = time.time()
    items = batch_inputs()
    outs = plan_rounds_batched([inp for inp, _ in items], metric="w1_norm",
                               allow_retraining=False)
    jax_cold_s = time.time() - t0            # includes compile
    t0 = time.time()
    items = batch_inputs()
    outs = plan_rounds_batched([inp for inp, _ in items], metric="w1_norm",
                               allow_retraining=False)
    jax_plans = [decode_plan(o, num_models=m, gamma_seq64=g64,
                             model_bits=jplanner.auction.model_bits)
                 for o, (_, g64) in zip(outs, items)]
    jax_s = time.time() - t0                 # steady state (compile cached)

    # Equivalence: identical round/hop structure and identical total
    # IID-distance decrement.  Exact hop lists can differ when several
    # matchings tie on Eq.-38 total weight (Hungarian and auction break
    # ties differently; at N=20 a few rounds do tie) — reported, but not a
    # failure.  Strict hop-list parity is asserted at the default config
    # in tests/test_planner_jax.py.
    hops_equal = all(
        [(h.model, h.src, h.dst, h.round_index) for h in ph.hops]
        == [(h.model, h.src, h.dst, h.round_index) for h in pj.hops]
        for ph, pj in zip(host_plans, jax_plans))
    plans_equivalent = all(
        ph.num_rounds == pj.num_rounds and len(ph.hops) == len(pj.hops)
        and abs(sum(h.decrement for h in ph.hops)
                - sum(h.decrement for h in pj.hops))
        <= 1e-6 * max(sum(h.decrement for h in ph.hops), 1e-12)
        for ph, pj in zip(host_plans, jax_plans))
    speedup = host_s / max(jax_s, 1e-9)
    record = {
        "clients": n, "models": m, "cells": n_cells,
        "rounds_per_cell": rounds_per_cell, "max_diffusion_rounds": max_rounds,
        "host_s": host_s, "jax_s": jax_s, "jax_cold_s": jax_cold_s,
        "speedup": speedup, "hops_equal": hops_equal,
        "plans_equivalent": plans_equivalent,
        "total_hops": sum(len(p.hops) for p in host_plans),
    }
    write_bench_json("planner_speedup", record)
    print(f"planner_speedup,cells={n_cells},clients={n},"
          f"host_s={host_s:.2f},jax_s={jax_s:.2f},"
          f"jax_cold_s={jax_cold_s:.2f},speedup={speedup:.2f}x,"
          f"hops_equal={hops_equal},plans_equivalent={plans_equivalent}",
          flush=True)
    assert plans_equivalent, \
        "host and jax planners must produce equivalent plans"
    assert speedup > 1.0, "batched jax planner should beat the host loop"


def executor_speedup(full: bool):
    """RoundSchedule executor seam: same cell, host vs fleet data plane.

    The schedule (and therefore the ledger) is identical by construction;
    the fleet executor replaces the per-client Python loop (one jitted call
    per client per batch, with a host sync per step) by one vmapped call per
    batch over the whole client-stacked fleet — the wall-clock gap is pure
    dispatch/sync overhead and grows with fleet size."""
    from repro.fl import ExperimentSpec, FLConfig, run_experiment
    clients = 32 if full else 20
    rounds = 4 if full else 3
    rows = {}
    for executor in ("host", "fleet"):
        spec = ExperimentSpec(
            task="fcn", alpha=1.0, num_samples=6000,
            fl=FLConfig(strategy="feddif", rounds=rounds,
                        num_clients=clients, num_models=clients, seed=0,
                        topology_seed=0, executor=executor))
        t0 = time.time()
        r = run_experiment(spec)
        dt = time.time() - t0
        rows[executor] = (dt, r)
        print(f"executor_speedup,executor={executor},clients={clients},"
              f"rounds={rounds},sec={dt:.1f},acc={max(r.accuracy):.4f},"
              f"subframes={r.ledger.subframes}", flush=True)
    host_t, host_r = rows["host"]
    fleet_t, fleet_r = rows["fleet"]
    assert host_r.ledger.as_dict() == fleet_r.ledger.as_dict(), \
        "executors must charge identical schedules"
    speedup = host_t / max(fleet_t, 1e-9)
    from repro.experiments.artifacts import write_bench_json
    write_bench_json("executor_speedup", {
        "clients": clients, "rounds": rounds,
        "host_s": host_t, "fleet_s": fleet_t, "speedup": speedup,
        "ledger_identical": True,
    })
    print(f"executor_speedup,speedup={speedup:.2f}x,"
          f"ledger_identical=True", flush=True)


def fleet_scaling(full: bool):
    """Large-N data planes: ``fleet`` (single-device client-stacked vmap) vs
    ``sharded`` (shard_map over the 2-D ``("clients", "model")`` mesh) at
    growing N, with the ``host`` reference run at the smallest N for
    three-way bit-identical ledger parity and a ``sharded`` arm with
    ``shard_overlap="off"`` at the largest N isolating the fused
    comm/compute-overlapped round plane's win over the op-by-op plane.
    Schedules/ledgers are executor-independent by construction, so the
    comparison signal is the **data plane's** steady-state wall-clock —
    ``FLResult.round_wall_s`` with the first (compile) round dropped; the
    shared host control plane (planner, schedule build) is excluded by
    construction.  Up to N=256 the task is the paper's CNN under FedDif;
    at N≥1024 the Hungarian auction control plane is O(N³), so the data
    plane is exercised with the auction-free ``d2d_random_walk`` diffusion
    on the FCN, with the per-client shard pinned small so the round is
    comm-dominated (the fleet-scale regime the overlap targets).  Run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=K`` for a K-device
    CPU mesh (``main()`` forces K=2 when this bench runs standalone; CI's
    mesh2d job uses K=8); on one device the planes are the same program
    and the speedup checks are skipped (also skipped by the budget gate
    via ``device_count``).  Also emits the per-phase wall-clock breakdown
    (train / hop_collective / mix / plan — from a short profiled op-by-op
    rerun, since the fused round cannot be sub-timed) and the
    :mod:`benchmarks.roofline` readout for one round at the largest N
    (achieved FLOP/s and wire bytes vs the machine's measured GEMM peak).
    Emits ``BENCH_fleet_scaling.json``.
    """
    import jax
    from benchmarks.roofline import fl_round_roofline, measure_machine_peak
    from repro.experiments.artifacts import write_bench_json
    from repro.fl import ExperimentSpec, FLConfig, run_experiment
    from repro.fl.experiment import load_experiment_data, spec_model_bits

    n_devices = len(jax.devices())
    sizes = (20, 64, 256, 1024) if full else (20, 64)
    # Small-N arms run 4 rounds; the N≥1024 arms run 6.  The fused sharded
    # plane compiles one program per round *signature*; signature
    # normalization (step-count padding + hop-wave bucketing, see
    # ``ShardedFleetExecutor``) bounds steady state to two signatures, but
    # their compiles can land as late as rounds 1 and 3 — with fewer
    # rounds min(round_wall_s[1:]) would report a compile, not steady
    # state.  min (not mean) is the steady statistic: forced multi-device
    # CPU meshes oversubscribe the host and collective rendezvous can
    # stall a round by whole seconds, on either plane.
    rounds = 4
    big_rounds = 6
    big_n = max(sizes)

    def make_spec(n, executor, rounds=None, **fl_kw):
        # experiment.py trains on the test_frac side of the split, so this
        # is ~40 train samples (2–3 batches) per client up to N=150.  At
        # N≥1024 the per-client shard is pinned small (5 rows/client): at
        # fleet scale the round is comm-dominated — D2D hop traffic, not
        # local SGD, sets the wall-clock — which is the regime the
        # overlapped plane exists for (and the one the roofline reports).
        task = "cnn" if n <= 256 else "fcn"
        strategy = "feddif" if n <= 256 else "d2d_random_walk"
        if rounds is None:
            rounds = 4 if n <= 256 else big_rounds
        return ExperimentSpec(
            task=task, alpha=0.5,
            num_samples=min(200 * n, 30000) if n <= 256 else 5 * n,
            fl=FLConfig(strategy=strategy, rounds=rounds, num_clients=n,
                        num_models=n, seed=0, topology_seed=0,
                        max_diffusion_rounds=6 if n <= 256 else 3,
                        executor=executor, **fl_kw))

    arms = []
    for n in sizes:
        if n == sizes[0]:
            arms.append((n, "host", make_spec(n, "host")))
        arms.append((n, "fleet", make_spec(n, "fleet")))
        arms.append((n, "sharded", make_spec(n, "sharded")))
    arms.append((big_n, "sharded_off",
                 make_spec(big_n, "sharded", shard_overlap="off")))

    cells, ledgers, results = [], {}, {}
    for n, label, spec in arms:
        t0 = time.time()
        r = run_experiment(spec)
        dt = time.time() - t0
        steady = min(r.round_wall_s[1:])
        ledgers[(n, label)] = r.ledger.as_dict()
        results[(n, label)] = r
        cells.append({"clients": n, "executor": label,
                      "task": spec.task, "strategy": spec.fl.strategy,
                      "wall_clock_s": dt, "round_s": steady,
                      "acc": max(r.accuracy),
                      "subframes": r.ledger.subframes})
        print(f"fleet_scaling,clients={n},executor={label},"
              f"sec={dt:.1f},round_s={steady:.2f},"
              f"acc={max(r.accuracy):.4f},"
              f"subframes={r.ledger.subframes}", flush=True)
    n0 = sizes[0]
    ledger_parity = (ledgers[(n0, "host")] == ledgers[(n0, "fleet")]
                     == ledgers[(n0, "sharded")])
    assert ledger_parity, "host/fleet/sharded must charge identical ledgers"
    assert all(ledgers[(n, "fleet")] == ledgers[(n, "sharded")]
               for n in sizes), "fleet/sharded ledgers must agree at every N"
    assert ledgers[(big_n, "sharded_off")] == ledgers[(big_n, "sharded")], \
        "overlap on/off must charge the identical schedule"
    by = {(c["clients"], c["executor"]): c["round_s"] for c in cells}
    speedups = {n: by[(n, "fleet")] / max(by[(n, "sharded")], 1e-9)
                for n in sizes}
    overlap_speedup = (by[(big_n, "sharded_off")]
                       / max(by[(big_n, "sharded")], 1e-9))

    # --- per-phase breakdown (satellite of the overlap work): a short
    # profiled rerun on the op-by-op plane — the fused round is one device
    # call and cannot be sub-timed — so overlap wins are attributable to
    # phases, not just end-to-end deltas.
    phases = {}
    for label in ("fleet", "sharded"):
        spec = make_spec(big_n, label, rounds=2, profile_phases=True)
        r = run_experiment(spec)
        ph = r.phase_s[-1] if r.phase_s else {}
        phases[label] = {k: round(v, 4) for k, v in sorted(ph.items())}
        print(f"fleet_scaling,phase_breakdown,executor={label},"
              f"clients={big_n}," +
              ",".join(f"{k}_s={v:.3f}" for k, v in sorted(ph.items())),
              flush=True)

    # --- roofline readout for one steady round at the largest N on the
    # overlapped sharded arm: analytic FLOPs/bytes (Eq. 15 ledger terms)
    # vs the machine's measured GEMM peak.
    spec = make_spec(big_n, "sharded")
    big_arm_rounds = spec.fl.rounds
    _, _, part, _ = load_experiment_data(spec, with_loaders=False)
    r = results[(big_n, "sharded")]
    led = ledgers[(big_n, "sharded")]
    hops = float(np.mean(r.diffusion_rounds))
    roofline = fl_round_roofline(
        param_count=spec_model_bits(spec) / spec.fl.bits_per_param,
        train_rows=float(np.sum(part.data_sizes)) * (1.0 + hops),
        clients=big_n,
        d2d_models=(led["transmitted_models"] - led["uplink_models"])
        / big_arm_rounds,
        uldl_models=(led["uplink_models"] + led["downlink_models"])
        / big_arm_rounds,
        round_s=by[(big_n, "sharded")],
        bits_per_param=spec.fl.bits_per_param,
        peak_flops=measure_machine_peak())
    print(f"fleet_scaling,roofline,clients={big_n},"
          f"achieved_gflops={roofline['achieved_flops']/1e9:.2f},"
          f"peak_gflops={roofline['machine_peak_flops']/1e9:.2f},"
          f"utilization={roofline['utilization']:.4f},"
          f"wire_mb_per_round={roofline['round_bytes_moved']/1e6:.1f}",
          flush=True)

    record = {
        "device_count": n_devices, "host_cpus": os.cpu_count() or 1,
        "sizes": list(sizes), "rounds": rounds,
        "big_n_rounds": big_arm_rounds,
        "cells": cells, "ledger_parity": ledger_parity,
        "speedup_by_n": {str(n): s for n, s in speedups.items()},
        "speedup_at_scale": speedups[big_n], "scale_n": big_n,
        "overlap_speedup": overlap_speedup, "overlap_scale_n": big_n,
        "phases": phases,
        "roofline": roofline,
        "max_wall_clock_s": max(c["wall_clock_s"] for c in cells),
    }
    write_bench_json("fleet_scaling", record)
    print(f"fleet_scaling,devices={n_devices},"
          f"steady_speedup_n{big_n}={speedups[big_n]:.2f}x,"
          f"overlap_speedup_n{big_n}={overlap_speedup:.2f}x,"
          f"ledger_parity={ledger_parity}", flush=True)
    if speedups[big_n] <= 0.85 and n_devices > 1:
        # check_budgets (benchmarks/budgets.json) is the regression gate;
        # the in-bench hard failure is scoped to the topology the 0.85
        # floor was calibrated on — a forced 2-device CPU mesh with at
        # least 2 host cores behind it.  With forced devices oversubscribing
        # a single core there is no parallelism to win, only dispatch and
        # collective-rendezvous overhead to pay (fleet's single-device vmap
        # pays neither), so the comparison reports instead of aborting the
        # benches queued after this one.
        msg = (f"sharded far behind fleet at N={big_n} on a "
               f"{n_devices}-device mesh (got {speedups[big_n]:.2f}x)")
        if (n_devices == 2 and jax.default_backend() == "cpu"
                and (os.cpu_count() or 1) >= 2):
            raise AssertionError(msg)
        print(f"fleet_scaling,WARNING,{msg}", flush=True)


def lm_hops(full: bool):
    """FedDif-over-LMs hop-payload bench (the adapter hop plane).

    Three payload arms on the small LoRA transformer (``task="lm"``) under
    FedDif: ``full_f32`` (adapter view off — every D2D hop moves the whole
    fp32 model), ``adapter_f32`` (hops move only the trainable LoRA
    adapter, base broadcast once at round 0) and ``adapter_int8`` (adapter
    hops additionally cross the wire int8-packed via the
    ``quant_pack``/``quant_unpack`` kernel pair).  Each arm runs on all
    three executors — host / fleet / sharded — and their Eq.-15 ledgers
    must be *bit-identical per arm*; the ledger's ``transmitted_bits`` must
    also decompose exactly into
    ``uplinks·view_f32_bits + d2d_hops·hop_bits`` with the analytic
    ``spec_adapter_bits`` figures, so the measured wire volume and the
    analytic payload model cannot drift apart.  Headline numbers:
    bytes-per-hop per arm, the full_f32/adapter_int8 payload reduction
    (budget-gated ≥ 50x), the int8-vs-f32 accuracy gap (≤ 2 pts absolute)
    and the steady-round wall-clock (``min(round_wall_s[1:])`` on the
    fleet plane) per arm.  The roofline readout reports the int8 arm with
    ``d2d_bits`` so the bytes side reflects the packed wire.  Emits
    ``BENCH_lm_hops.json``."""
    import dataclasses

    import jax
    from benchmarks.roofline import fl_round_roofline, measure_machine_peak
    from repro.experiments.artifacts import write_bench_json
    from repro.fl import ExperimentSpec, FLConfig, run_experiment
    from repro.fl.experiment import spec_adapter_bits, spec_model_bits

    n_devices = len(jax.devices())
    clients = 8
    rounds = 6 if full else 3
    samples = 4096 if full else 1536

    def make_spec(executor, adapter_hops, hop_quant):
        return ExperimentSpec(
            task="lm", alpha=0.5, dim=32, num_samples=samples,
            adapter_hops=adapter_hops,
            fl=FLConfig(strategy="feddif", rounds=rounds,
                        num_clients=clients, num_models=clients, seed=0,
                        topology_seed=0, max_diffusion_rounds=4,
                        executor=executor, hop_quant=hop_quant))

    # arm -> (adapter_hops, hop_quant); full_f32 is the no-view baseline.
    arms = {"full_f32": (False, "none"),
            "adapter_f32": (True, "none"),
            "adapter_int8": (True, "int8")}
    executors = ("host", "fleet", "sharded")

    cells = []
    arm_stats = {}
    ledger_parity = True
    ledger_bits_match = True
    for arm, (adapter_hops, hop_quant) in arms.items():
        spec0 = make_spec("host", adapter_hops, hop_quant)
        hop_bits = spec_adapter_bits(spec0)          # what one D2D hop moves
        view_f32_bits = spec_adapter_bits(           # what one uplink moves
            dataclasses.replace(
                spec0, fl=dataclasses.replace(spec0.fl, hop_quant="none")))
        ledgers, results = {}, {}
        for executor in executors:
            spec = make_spec(executor, adapter_hops, hop_quant)
            t0 = time.time()
            r = run_experiment(spec)
            dt = time.time() - t0
            ledgers[executor] = r.ledger.as_dict()
            results[executor] = r
            steady = min(r.round_wall_s[1:])
            cells.append({"arm": arm, "executor": executor,
                          "wall_clock_s": dt, "round_s": steady,
                          "acc": max(r.accuracy),
                          "subframes": r.ledger.subframes,
                          "transmitted_bits": r.ledger.transmitted_bits})
            print(f"lm_hops,arm={arm},executor={executor},sec={dt:.1f},"
                  f"round_s={steady:.2f},acc={max(r.accuracy):.4f},"
                  f"bits={r.ledger.transmitted_bits:.3e}", flush=True)
        parity = (ledgers["host"] == ledgers["fleet"] == ledgers["sharded"])
        ledger_parity &= parity
        led = ledgers["host"]
        d2d_hops = led["transmitted_models"] - led["uplink_models"]
        expected = (led["uplink_models"] * view_f32_bits
                    + d2d_hops * hop_bits)
        bits_match = bool(np.isclose(led["transmitted_bits"], expected,
                                     rtol=1e-9, atol=0.0))
        ledger_bits_match &= bits_match
        arm_stats[arm] = {
            "hop_bits": hop_bits, "bytes_per_hop": hop_bits / 8.0,
            "view_f32_bits": view_f32_bits, "d2d_hops": d2d_hops,
            "uplink_models": led["uplink_models"],
            "downlink_models": led["downlink_models"],
            "transmitted_bits": led["transmitted_bits"],
            "acc": max(results["host"].accuracy),
            "round_s": min(results["fleet"].round_wall_s[1:]),
            "ledger_parity": parity, "ledger_bits_match": bits_match,
        }
        print(f"lm_hops,arm={arm},bytes_per_hop={hop_bits / 8.0:.0f},"
              f"d2d_hops={d2d_hops},ledger_parity={parity},"
              f"ledger_bits_match={bits_match}", flush=True)
    assert ledger_parity, \
        "host/fleet/sharded must charge identical ledgers per arm"
    assert ledger_bits_match, \
        "measured transmitted_bits must match the analytic payload model"

    reduction_int8 = (arm_stats["full_f32"]["hop_bits"]
                      / arm_stats["adapter_int8"]["hop_bits"])
    reduction_f32 = (arm_stats["full_f32"]["hop_bits"]
                     / arm_stats["adapter_f32"]["hop_bits"])
    acc_gap = abs(arm_stats["adapter_int8"]["acc"]
                  - arm_stats["adapter_f32"]["acc"])
    assert reduction_int8 >= 50.0, \
        f"int8 adapter hops must be >=50x smaller (got {reduction_int8:.1f}x)"

    # Roofline for one steady int8-arm round: d2d_bits carries the packed
    # wire so bytes-moved reflects what the transport actually ships.
    spec = make_spec("fleet", True, "int8")
    st = arm_stats["adapter_int8"]
    roofline = fl_round_roofline(
        param_count=spec_model_bits(spec) / spec.fl.bits_per_param,
        train_rows=float(samples) * (1.0 - spec.test_frac),
        clients=clients,
        d2d_models=st["d2d_hops"] / rounds,
        uldl_models=(st["uplink_models"] + st["downlink_models"]) / rounds,
        round_s=st["round_s"],
        bits_per_param=spec.fl.bits_per_param,
        d2d_bits=st["hop_bits"],
        peak_flops=measure_machine_peak())

    record = {
        "device_count": n_devices, "host_cpus": os.cpu_count() or 1,
        "clients": clients, "rounds": rounds, "num_samples": samples,
        "cells": cells, "arms": arm_stats,
        "ledger_parity": ledger_parity,
        "ledger_bits_match": ledger_bits_match,
        "payload_reduction_int8": reduction_int8,
        "payload_reduction_f32": reduction_f32,
        "acc_gap_int8_vs_f32": acc_gap,
        "roofline": roofline,
        "max_wall_clock_s": max(c["wall_clock_s"] for c in cells),
    }
    write_bench_json("lm_hops", record)
    print(f"lm_hops,payload_reduction_int8={reduction_int8:.1f}x,"
          f"payload_reduction_f32={reduction_f32:.1f}x,"
          f"acc_gap={acc_gap:.4f},ledger_parity={ledger_parity},"
          f"ledger_bits_match={ledger_bits_match}", flush=True)


def kernel_data_plane(full: bool):
    """FL diffusion data-plane kernels (kernels/diffusion.py): parity of
    the Pallas bodies (interpret mode) against the reference twins, and the
    measurable XLA-side win — the planner's fused bid contraction vs the
    (M, N, C) broadcast composite it replaces.  The mix/aggregate flat
    kernel is timed for the record (its one-HBM-pass claim is a TPU
    property; on CPU the dispatcher keeps the per-leaf chain, which is
    also timed here as the baseline)."""
    import jax
    import jax.numpy as jnp
    from repro.core.dol import iid_distance_candidates
    from repro.experiments.artifacts import write_bench_json
    from repro.kernels import ops
    from repro.kernels.diffusion import dol_bid_scores_xla_fused

    rng = np.random.default_rng(0)
    reps, trials = (10, 8) if full else (5, 5)

    def timeit(f, *args):
        # min over trials: robust to scheduler noise on shared CI cores
        jax.block_until_ready(f(*args))
        best = float("inf")
        for _ in range(trials):
            t0 = time.time()
            for _ in range(reps):
                jax.block_until_ready(f(*args))
            best = min(best, (time.time() - t0) / reps)
        return best

    # --- planner bid tensor: broadcast composite vs fused contraction ---
    m, n, c = (512, 8192, 10) if full else (256, 4096, 10)
    dol = jnp.asarray(rng.dirichlet(np.ones(c), size=m), jnp.float32)
    chain = jnp.asarray(rng.integers(1, 500, size=m), jnp.float32)
    dsi = jnp.asarray(rng.dirichlet(np.ones(c), size=n), jnp.float32)
    sizes = jnp.asarray(rng.integers(1, 300, size=n), jnp.float32)
    composite = jax.jit(lambda *a: iid_distance_candidates(*a))
    fused = jax.jit(dol_bid_scores_xla_fused)
    bids_parity = bool(np.allclose(np.asarray(composite(dol, chain, dsi,
                                                        sizes)),
                                   np.asarray(fused(dol, chain, dsi,
                                                    sizes)), atol=2e-5))
    t_comp = timeit(composite, dol, chain, dsi, sizes)
    t_fused = timeit(fused, dol, chain, dsi, sizes)
    bids_speedup = t_comp / max(t_fused, 1e-9)
    print(f"kernel_data_plane,dol_bids,M={m},N={n},C={c},"
          f"composite_us={t_comp*1e6:.0f},fused_us={t_fused*1e6:.0f},"
          f"speedup={bids_speedup:.2f}x", flush=True)

    # --- mix/aggregate: per-leaf chain (ref) vs flat kernel pass ---
    cc = 64 if full else 32
    params = {"l1": jnp.asarray(rng.normal(size=(cc, 784, 64)), jnp.float32),
              "b1": jnp.asarray(rng.normal(size=(cc, 64)), jnp.float32),
              "l2": jnp.asarray(rng.normal(size=(cc, 64, 10)), jnp.float32),
              "b2": jnp.asarray(rng.normal(size=(cc, 10)), jnp.float32)}
    w = jnp.asarray(rng.random((cc, cc)), jnp.float32)
    chain_fn = jax.jit(lambda p, w: ops.mix_aggregate_tree(
        p, w, implementation="ref"))
    t_mix_ref = timeit(chain_fn, params, w)
    # interpret-mode parity of the fused pass (not timed: interpret is a
    # correctness vehicle, not a performance mode)
    fused_tree = ops.mix_aggregate_tree(params, w,
                                        implementation="pallas_interpret")
    mix_parity = all(
        np.allclose(np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5)
        for a, b in zip(jax.tree.leaves(chain_fn(params, w)),
                        jax.tree.leaves(fused_tree)))
    # stc hop compression parity on the same stacked fleet
    refp = jax.tree.map(lambda x: x[0], params)
    mask = jnp.asarray(rng.random(cc) < 0.5)
    from repro.distributed.fedshard import masked_stc_compress
    stc_ref = masked_stc_compress(params, refp, mask, 0.01,
                                  implementation="ref")
    stc_pal = masked_stc_compress(params, refp, mask, 0.01,
                                  implementation="pallas_interpret")
    stc_parity = all(
        np.allclose(np.asarray(a), np.asarray(b), atol=1e-6)
        for a, b in zip(jax.tree.leaves(stc_ref), jax.tree.leaves(stc_pal)))
    parity_ok = bool(bids_parity and mix_parity and stc_parity)
    print(f"kernel_data_plane,mix_ref_us={t_mix_ref*1e6:.0f},"
          f"parity_ok={parity_ok}", flush=True)
    write_bench_json("kernel_data_plane", {
        "bids_m": m, "bids_n": n, "bids_c": c,
        "bids_composite_s": t_comp, "bids_fused_s": t_fused,
        "bids_speedup": bids_speedup,
        "mix_clients": cc, "mix_ref_s": t_mix_ref,
        "parity_ok": parity_ok,
    })


def kernels_microbench(full: bool):
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    key = jax.random.PRNGKey(0)
    shapes = [(1, 512, 4, 64)] if not full else [(1, 512, 4, 64),
                                                 (2, 2048, 8, 64)]
    for shp in shapes:
        q = jax.random.normal(key, shp, jnp.float32)
        f = jax.jit(lambda a: ops.flash_attention(a, a, a,
                                                  implementation="xla"))
        f(q).block_until_ready()
        t0 = time.time()
        for _ in range(5):
            f(q).block_until_ready()
        us = (time.time() - t0) / 5 * 1e6
        print(f"kernels_microbench,flash_attention_xla_{shp},{us:.0f},"
              f"us_per_call")
    x = jax.random.normal(key, (1 << 20,), jnp.float32)
    g = jax.jit(lambda a: ops.stc_compress(a, 0.01, implementation="xla"))
    g(x).block_until_ready()
    t0 = time.time()
    for _ in range(5):
        g(x).block_until_ready()
    print(f"kernels_microbench,stc_compress_xla_1M,"
          f"{(time.time()-t0)/5*1e6:.0f},us_per_call")
    da = jnp.exp(-jax.random.uniform(key, (2, 1024, 128, 16)))
    h = jax.jit(lambda a: ops.ssm_scan(a, a, implementation="xla"))
    h(da).block_until_ready()
    t0 = time.time()
    for _ in range(5):
        h(da).block_until_ready()
    print(f"kernels_microbench,ssm_scan_xla_2x1024x128x16,"
          f"{(time.time()-t0)/5*1e6:.0f},us_per_call")


def roofline_summary(full: bool):
    import glob
    import json
    from benchmarks.roofline import analyze
    files = sorted(glob.glob("benchmarks/results/dryrun_*.json"))
    if not files:
        print("roofline_summary,no_results,0,run repro.launch.dryrun first")
        return
    ok = err = skip = 0
    for path in files:
        rec = json.load(open(path))
        st = rec.get("status")
        ok += st == "ok"
        err += st == "error"
        skip += st == "skipped"
        row = analyze(rec)
        if row:
            print(f"roofline_summary,{row['arch']}/{row['shape']}/"
                  f"{row['mesh']},{row['dominant']},"
                  f"c={row['t_compute_s']:.2e}s m={row['t_memory_s']:.2e}s "
                  f"x={row['t_collective_s']:.2e}s "
                  f"useful={row['useful_flop_ratio']:.2f}")
    print(f"roofline_summary,totals,ok={ok},err={err} skip={skip}")


def appendix_scenarios(full: bool):
    """Appendix C: fully-decentralized (Fig 7), probability distances
    (Fig 8), re-trainable FedDif (Fig 10), underlay D2D (Fig 12)."""
    rounds = 12 if full else 4
    base = _fl("feddif", alpha=0.5, rounds=rounds)
    print(f"appendixC,scenario=baseline,acc={max(base.accuracy):.4f},"
          f"subframes={base.ledger.subframes}")
    gossip = _fl("gossip", alpha=0.5, rounds=rounds)
    print(f"appendixC,scenario=fully_decentralized,"
          f"acc={max(gossip.accuracy):.4f},"
          f"subframes={gossip.ledger.subframes}")
    for metric in ["kld", "jsd"]:
        r = _fl("feddif", alpha=0.5, rounds=rounds, metric=metric)
        print(f"appendixC,scenario=metric_{metric},"
              f"acc={max(r.accuracy):.4f},"
              f"dif_rounds={np.mean(r.diffusion_rounds):.1f}")
    retr = _fl("feddif", alpha=0.5, rounds=rounds, allow_retraining=True,
               max_diffusion_rounds=12)
    print(f"appendixC,scenario=retrainable,acc={max(retr.accuracy):.4f},"
          f"dif_rounds={np.mean(retr.diffusion_rounds):.1f},"
          f"subframes={retr.ledger.subframes}")
    under = _fl("feddif", alpha=0.5, rounds=rounds, underlay=True)
    print(f"appendixC,scenario=underlay,acc={max(under.accuracy):.4f},"
          f"subframes={under.ledger.subframes} "
          f"(vs overlay {base.ledger.subframes})")


BENCHES = [fig2_convergence, fig3_alpha_sweep, fig4_epsilon_sweep,
           fig5_qos_sweep, fig6_tasks, fig_async_sweep, fig_scenarios_sweep,
           async_throughput, table1_accuracy, table2_comm_eff,
           planner_speedup, executor_speedup, fleet_scaling, lm_hops,
           kernel_data_plane, world_step, appendix_scenarios,
           kernels_microbench, roofline_summary]


def check_budgets(budgets_path: str = "benchmarks/budgets.json") -> int:
    """Perf-regression gate: compare every BENCH artifact named in
    ``benchmarks/budgets.json`` against its budgeted metrics.

    Budget schema — one entry per gate::

        {"<gate>": {"artifact": "BENCH_x.json",
                    "checks": [{"key": "a.b", "min": 1.0, "tolerance": 0.1},
                               {"key": "flag", "equals": true},
                               {"key": "speedup", "min": 1.0,
                                "when": {"key": "device_count", "gte": 2}}]}}

    ``min``/``max`` checks fail when the artifact value crosses the budget
    beyond the relative ``tolerance`` (``value < min·(1−tol)`` resp.
    ``value > max·(1+tol)``); ``equals`` checks are exact.  ``key`` is a
    dotted path into the artifact JSON.  An optional ``when`` guard — one
    condition dict or a list of them, all of which must hold — skips a
    check unless the named artifact fields satisfy every bound given
    (``gte`` and/or ``lte``) — e.g. speedup gates only bind on the exact
    device count and minimum host core count they were calibrated
    against.  A missing artifact is a
    failure — the gate exists so CI cannot silently stop producing the
    number.  Returns a process exit code (0 = within budget).
    """
    import json
    from repro.experiments.artifacts import default_out_dir

    def lookup(art, dotted):
        value = art
        for part in dotted.split("."):
            value = value[part]
        return value

    with open(budgets_path) as f:
        budgets = json.load(f)
    failures = []
    for gate, entry in sorted(budgets.items()):
        path = os.path.join(default_out_dir(), entry["artifact"])
        if not os.path.exists(path):
            failures.append(f"{gate}: missing artifact {path} "
                            f"(did the bench run?)")
            continue
        with open(path) as f:
            art = json.load(f)
        for chk in entry["checks"]:
            conds = chk.get("when")
            if isinstance(conds, dict):
                conds = [conds]
            skip = None
            for cond in conds or ():
                try:
                    guard = lookup(art, cond["key"])
                    if "gte" in cond and not guard >= cond["gte"]:
                        skip = f"{cond['key']}<{cond['gte']}"
                        break
                    if "lte" in cond and not guard <= cond["lte"]:
                        skip = f"{cond['key']}>{cond['lte']}"
                        break
                except (KeyError, TypeError):
                    pass        # guard field absent: check applies
            if skip is not None:
                print(f"budget_skip,{gate},{chk['key']},{skip}",
                      flush=True)
                continue
            try:
                value = lookup(art, chk["key"])
            except (KeyError, TypeError):
                failures.append(f"{gate}: key {chk['key']!r} missing "
                                f"from {path}")
                continue
            tol = float(chk.get("tolerance", 0.0))
            if "equals" in chk and value != chk["equals"]:
                failures.append(f"{gate}: {chk['key']} == {value!r}, "
                                f"budget requires {chk['equals']!r}")
            elif "min" in chk and value < chk["min"] * (1.0 - tol):
                failures.append(f"{gate}: {chk['key']} = {value:.4g} below "
                                f"budget min {chk['min']}·(1−{tol})")
            elif "max" in chk and value > chk["max"] * (1.0 + tol):
                failures.append(f"{gate}: {chk['key']} = {value:.4g} above "
                                f"budget max {chk['max']}·(1+{tol})")
            else:
                print(f"budget_ok,{gate},{chk['key']},{value}", flush=True)
    for f_ in failures:
        print(f"BUDGET REGRESSION: {f_}", flush=True)
    print(f"# check_budgets: {len(failures)} violation(s)", flush=True)
    return 1 if failures else 0


def _force_cpu_mesh_for(bench_names: list) -> None:
    """fleet_scaling / lm_hops need >1 device to mean anything; force a
    2-device CPU mesh when only multi-device benches are selected (CI runs
    them standalone), XLA_FLAGS has no explicit count yet, and jax has not
    been imported (the flag is read at first import).  Full-suite runs are
    left on the real device topology — forcing virtual devices there would
    time every other bench under a configuration its budget was not
    calibrated for; the speedup budget checks are gated on the artifact's
    ``device_count``."""
    flags = os.environ.get("XLA_FLAGS", "")
    if (bench_names and set(bench_names) <= {"fleet_scaling", "lm_hops"}
            and "jax" not in sys.modules
            and "xla_force_host_platform_device_count" not in flags):
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=2").strip()


def main() -> None:
    global EXECUTOR, PLANNER, ENGINE
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--executor", choices=["host", "fleet", "sharded"],
                    default="host",
                    help="FL data plane for the figure/table benches "
                         "(executor_speedup / fleet_scaling always compare)")
    ap.add_argument("--planner", choices=["host", "jax"], default="host",
                    help="FL control plane for the figure/table benches "
                         "(planner_speedup always compares both)")
    ap.add_argument("--engine", default=None,
                    help="EngineSpec preset stamped on every figure/table "
                         "cell (host/fleet/sharded/auto/async/async_barrier)"
                         "; wins over --executor/--planner when given "
                         "(async_throughput always compares async vs "
                         "async_barrier)")
    ap.add_argument("--check-budgets", action="store_true",
                    help="run no benches; gate existing BENCH artifacts "
                         "against benchmarks/budgets.json and exit nonzero "
                         "on regression")
    args = ap.parse_args()
    if args.check_budgets:
        raise SystemExit(check_budgets())
    EXECUTOR = args.executor
    PLANNER = args.planner
    ENGINE = args.engine
    selected = [b.__name__ for b in BENCHES
                if not args.only or args.only in b.__name__]
    _force_cpu_mesh_for(selected)   # must precede any repro/jax import
    if args.engine is not None:
        from repro.fl.engine import ENGINE_PRESETS
        if args.engine not in ENGINE_PRESETS:
            raise SystemExit(f"--engine must be one of "
                             f"{sorted(ENGINE_PRESETS)}")
    t0 = time.time()
    for bench in BENCHES:
        if bench.__name__ not in selected:
            continue
        print(f"# === {bench.__name__} ===", flush=True)
        bench(args.full)
    print(f"# total {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
