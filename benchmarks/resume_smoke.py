"""Resume-smoke driver: kill a durable sweep with SIGTERM, resume it, and
diff the BENCH artifact against an uninterrupted run.

This is the CI face of the durability contract (the pytest face is
``tests/test_resume_orchestration.py``): a real process killed by a real
signal at an arbitrary instant must, after ``--resume``, produce an
artifact bit-identical to a never-killed run modulo the volatile fields
(:func:`repro.experiments.artifacts.strip_volatile`).

    PYTHONPATH=src python -m benchmarks.resume_smoke --workdir resume-out

Exit status: 0 on parity, 1 on divergence or failed cells, 2 on harness
errors (e.g. the sweep finished before the signal landed *and* retrying
still could not interrupt it — parity is still checked in that case).
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SWEEP = "fig3_alpha"


def _cli_args(state: str, out: str, num_samples: int) -> list[str]:
    return [sys.executable, "-m", "repro.launch.sweep",
            "--sweep", SWEEP, "--smoke", "--seeds", "2",
            "--checkpoint-every", "1", "--num-samples", str(num_samples),
            "--state-dir", state, "--out-dir", out]


def _has_committed_checkpoint(state: str) -> bool:
    for _, _, files in os.walk(os.path.join(state, "cells")):
        if any(f.startswith("ckpt_") and f.endswith(".json") for f in files):
            return True
    return False


def _run_interrupted(state: str, out: str, num_samples: int,
                     timeout_s: float) -> bool:
    """Start the sweep, SIGTERM it once durable progress exists.  Returns
    True if the process was actually interrupted mid-run."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.Popen(_cli_args(state, out, num_samples), env=env,
                            cwd=REPO)
    deadline = time.time() + timeout_s
    try:
        while (time.time() < deadline and proc.poll() is None
               and not _has_committed_checkpoint(state)):
            time.sleep(0.05)
        if proc.poll() is None:
            proc.send_signal(signal.SIGTERM)
            proc.wait(timeout=60)
            return True
        return False
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait()


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.resume_smoke",
        description="SIGTERM a durable sweep, resume it, assert the BENCH "
                    "artifact matches an uninterrupted run")
    ap.add_argument("--workdir", default="resume-smoke-out",
                    help="scratch directory for state dirs and artifacts")
    ap.add_argument("--num-samples", type=int, default=400)
    ap.add_argument("--timeout", type=float, default=300.0,
                    help="seconds to wait for the first checkpoint commit")
    args = ap.parse_args(argv)

    wd = os.path.abspath(args.workdir)
    state_kill = os.path.join(wd, "state-killed")
    out_kill = os.path.join(wd, "out-killed")
    state_clean = os.path.join(wd, "state-clean")
    out_clean = os.path.join(wd, "out-clean")
    for d in (state_kill, out_kill, state_clean, out_clean):
        os.makedirs(d, exist_ok=True)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))

    print(f"# resume_smoke: launching durable sweep {SWEEP} "
          f"(will SIGTERM after first checkpoint commit)", flush=True)
    interrupted = _run_interrupted(state_kill, out_kill, args.num_samples,
                                   args.timeout)
    print(f"# resume_smoke: interrupted={interrupted}", flush=True)

    print("# resume_smoke: resuming with --resume", flush=True)
    r = subprocess.run(
        _cli_args(state_kill, out_kill, args.num_samples) + ["--resume"],
        env=env, cwd=REPO)
    if r.returncode != 0:
        print("# resume_smoke: FAIL — resume run exited nonzero",
              file=sys.stderr)
        return 1

    print("# resume_smoke: uninterrupted reference run", flush=True)
    r = subprocess.run(_cli_args(state_clean, out_clean, args.num_samples),
                       env=env, cwd=REPO)
    if r.returncode != 0:
        print("# resume_smoke: FAIL — reference run exited nonzero",
              file=sys.stderr)
        return 2

    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.experiments.artifacts import strip_volatile

    bench = f"BENCH_feddif_{SWEEP}.json"
    with open(os.path.join(out_kill, bench)) as f:
        resumed = json.load(f)
    with open(os.path.join(out_clean, bench)) as f:
        clean = json.load(f)

    manifest = os.path.join(state_kill, "manifest.json")
    print(f"# resume_smoke: manifest {manifest}", flush=True)

    if resumed.get("failed_cells"):
        print(f"# resume_smoke: FAIL — failed cells in resumed artifact: "
              f"{resumed['failed_cells']}", file=sys.stderr)
        return 1
    a = json.dumps(strip_volatile(resumed), sort_keys=True, default=str)
    b = json.dumps(strip_volatile(clean), sort_keys=True, default=str)
    if a != b:
        print("# resume_smoke: FAIL — resumed artifact diverges from the "
              "uninterrupted run", file=sys.stderr)
        return 1
    print("# resume_smoke: PASS — resumed artifact is bit-identical to the "
          "uninterrupted run (volatile fields stripped)", flush=True)
    if not interrupted:
        print("# resume_smoke: note — sweep completed before SIGTERM "
              "landed; parity held but no mid-run kill was exercised",
              flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
