"""Roofline analysis (deliverable g): reads the dry-run JSONs and derives the
three-term roofline per (arch × shape × mesh):

    compute    = HLO_FLOPs / (chips × 197 TFLOP/s)
    memory     = HLO_bytes / (chips × 819 GB/s)
    collective = collective_bytes / (chips × 50 GB/s/link)

HLO FLOPs/bytes come from ``compiled.cost_analysis()`` of the partitioned
module (per-device numbers; dividing global by chips is equivalent).
Collective bytes are the summed output shapes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute in the
partitioned HLO (per-device).  MODEL_FLOPS uses 6·N·D (dense) or
6·N_active·D (MoE) for training, 2·N·D for single forward passes.

Two helpers serve the FL data-plane benches (``fleet_scaling``):
:func:`measure_machine_peak` calibrates this host's achievable fp32 GEMM
FLOP/s (the TPU constants below describe the *target* hardware — a CI CPU
needs its own peak for utilization fractions to mean anything), and
:func:`fl_round_roofline` turns one communication round's analytic FLOP /
bytes-moved model (Eq. 15 communication ledger terms) plus its measured
wall-clock into achieved FLOP/s vs machine peak.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--results DIR] [--csv]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def measure_machine_peak(n: int = 1024, trials: int = 5) -> float:
    """Measured fp32 GEMM FLOP/s of this host (calibration peak).

    One jitted (n, n) @ (n, n) fp32 matmul, best-of-``trials`` — a
    deliberately simple, saturating workload whose 2·n³ FLOP count is
    exact.  Used as the roofline denominator on machines that are not the
    197-TFLOP/s target chip.
    """
    import time

    import jax
    import jax.numpy as jnp
    x = jnp.ones((n, n), jnp.float32)
    f = jax.jit(lambda a: a @ a)
    jax.block_until_ready(f(x))
    best = float("inf")
    for _ in range(trials):
        t0 = time.time()
        jax.block_until_ready(f(x))
        best = min(best, time.time() - t0)
    return 2.0 * n ** 3 / best


def fl_round_roofline(*, param_count: float, train_rows: float,
                      clients: int, d2d_models: float, uldl_models: float,
                      round_s: float, mix_rows: float = 1.0,
                      bits_per_param: int = 32,
                      d2d_bits: float | None = None,
                      peak_flops: float | None = None) -> dict:
    """Roofline readout for ONE FL communication round.

    FLOP model: 6·P per trained sample row (forward 2·P + backward 4·P for
    a dense model of P parameters) plus 2·C·P per mixed/aggregated output
    row (the Eq. 10/11 weighted reduction).  Bytes moved on the wire are
    the Eq.-15 ledger terms — every up/downlink moves one fp32
    P-parameter payload, and each D2D hop moves ``d2d_bits`` when given
    (the int8-packed adapter wire, ``spec_adapter_bits``) else the same
    fp32 payload; without the override the bytes side would overstate
    quantized-arm comm volume 4x+.  ``round_s`` is the measured
    steady-state round wall-clock; ``utilization`` is achieved FLOP/s over
    :func:`measure_machine_peak` (or ``peak_flops``).
    """
    peak = peak_flops if peak_flops is not None else measure_machine_peak()
    flops = (6.0 * param_count * train_rows
             + 2.0 * param_count * clients * mix_rows)
    if d2d_bits is None:
        d2d_bits = param_count * bits_per_param
    moved = (d2d_models * d2d_bits
             + uldl_models * param_count * bits_per_param) / 8.0
    achieved = flops / max(round_s, 1e-9)
    return {
        "machine_peak_flops": peak,
        "round_flops": flops,
        "round_bytes_moved": moved,
        "achieved_flops": achieved,
        "utilization": achieved / max(peak, 1e-9),
        "wire_bytes_per_s": moved / max(round_s, 1e-9),
    }

SHAPE_TOKENS = {
    "train_4k": 256 * 4096,
    "prefill_32k": 32 * 32768,
    "decode_32k": 128 * 1,
    "long_500k": 1 * 1,
}


def analyze(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    chips = rec["chips"]
    # Prefer trip-count-aware HLO accounting (repro.launch.hlo_analysis);
    # fall back to XLA cost_analysis (which undercounts while bodies).
    flops_dev = rec.get("hlo_dot_flops_per_device") or rec["flops_per_device"]
    bytes_dev = rec.get("hlo_hbm_bytes_per_device") \
        or rec["bytes_accessed_per_device"]
    coll = rec.get("hlo_collective_bytes_per_device") \
        or rec.get("collectives", {})
    coll_bytes = sum(v for k, v in coll.items()
                     if isinstance(v, (int, float)) and not k.startswith("_"))
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_bytes / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    tokens = SHAPE_TOKENS.get(rec["shape"], 0)
    n_active = rec.get("active_param_count") or rec.get("param_count") or 0
    mult = {"train_4k": 6.0, "prefill_32k": 2.0,
            "decode_32k": 2.0, "long_500k": 2.0}[rec["shape"]]
    model_flops = mult * n_active * tokens
    hlo_flops_global = flops_dev * chips
    useful = model_flops / hlo_flops_global if hlo_flops_global else 0.0
    # fraction of the dominant-roofline bound actually demanded by useful math
    bound = max(terms.values())
    mfu_bound = (model_flops / (chips * PEAK_FLOPS)) / bound if bound else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": model_flops, "hlo_flops_global": hlo_flops_global,
        "useful_flop_ratio": useful, "roofline_mfu_bound": mfu_bound,
    }


def suggestion(row: dict) -> str:
    d = row["dominant"]
    if d == "compute":
        if row["useful_flop_ratio"] < 0.5:
            return ("cut non-useful FLOPs (remat recompute / unmasked causal "
                    "blocks / dense dispatch)")
        return "compute-bound near useful-FLOP parity: scale batch or chips"
    if d == "memory":
        return ("raise arithmetic intensity: larger per-device batch, bf16 "
                "cache/master split, fuse elementwise chains")
    return ("reduce collective volume: reshard to cut all-gathers, overlap "
            "with compute, or move FSDP gather inside scan")


def load(results_dir: str, mesh_filter: str | None = None) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(results_dir, "dryrun_*.json"))):
        with open(path) as f:
            rec = json.load(f)
        row = analyze(rec)
        if row and (mesh_filter is None or row["mesh"] == mesh_filter):
            rows.append(row)
    return rows


def fmt_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute (s) | memory (s) | collective (s)"
           " | dominant | 6ND/HLO | bottleneck-relief |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_flop_ratio']:.2f} | {suggestion(r)} |")
    return "\n".join(lines)


def compare(dir_a: str, dir_b: str, label_a="baseline", label_b="optimized"):
    ra = {(r["arch"], r["shape"], r["mesh"]): r for r in load(dir_a)}
    rb = {(r["arch"], r["shape"], r["mesh"]): r for r in load(dir_b)}
    print(f"| arch | shape | {label_a} c/m/x (s) | {label_b} c/m/x (s) "
          "| Δ dominant |")
    print("|---|---|---|---|---|")
    for key in sorted(ra):
        if key not in rb:
            continue
        a, b = ra[key], rb[key]
        da = max(a["t_compute_s"], a["t_memory_s"], a["t_collective_s"])
        db = max(b["t_compute_s"], b["t_memory_s"], b["t_collective_s"])
        delta = (db - da) / da * 100 if da else 0.0
        print(f"| {key[0]} | {key[1]} "
              f"| {a['t_compute_s']:.2e}/{a['t_memory_s']:.2e}/"
              f"{a['t_collective_s']:.2e} "
              f"| {b['t_compute_s']:.2e}/{b['t_memory_s']:.2e}/"
              f"{b['t_collective_s']:.2e} | {delta:+.0f}% |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="benchmarks/results")
    ap.add_argument("--mesh", default=None, choices=[None, "16x16", "2x16x16"])
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--compare", nargs=2, metavar=("DIR_A", "DIR_B"),
                    help="side-by-side baseline-vs-optimized table")
    args = ap.parse_args()
    if args.compare:
        compare(*args.compare)
        return
    rows = load(args.results, args.mesh)
    if args.csv:
        print("arch,shape,mesh,t_compute,t_memory,t_collective,dominant,"
              "useful_ratio")
        for r in rows:
            print(f"{r['arch']},{r['shape']},{r['mesh']},"
                  f"{r['t_compute_s']:.6e},{r['t_memory_s']:.6e},"
                  f"{r['t_collective_s']:.6e},{r['dominant']},"
                  f"{r['useful_flop_ratio']:.3f}")
    else:
        print(fmt_table(rows))


if __name__ == "__main__":
    main()
