"""SPMD FedDif runtime: planner control plane + jitted data plane converge."""
import jax.numpy as jnp

from repro.launch.fl_spmd import run_spmd_feddif


def test_spmd_feddif_round_runs_and_improves():
    logs = []
    state, hist = run_spmd_feddif(arch="smollm_360m", clients=4, rounds=3,
                                  seq_len=32, batch=2, seed=0,
                                  log=lambda s: logs.append(s))
    assert len(hist) == 3
    assert hist[-1] < hist[0]            # mean client loss decreases
    assert len(logs) == 3
    # fleet state keeps the client axis
    leaf = next(iter(jnp.asarray(x) for x in
                     __import__("jax").tree.leaves(state.params)))
    assert leaf.shape[0] == 4
