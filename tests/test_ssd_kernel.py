"""Mamba-2 SSD Pallas kernel vs sequential oracle + model-layer scan."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.ssd_scan import ssd_scan_pallas, ssd_scan_ref
from repro.models.ssm import _ssd_chunk_scan


@pytest.mark.parametrize("shape,chunk,bh", [
    ((1, 64, 4, 16, 8), 16, 2),
    ((2, 100, 6, 8, 4), 32, 3),
    ((1, 33, 2, 8, 4), 8, 2),       # padded seq + heads
])
def test_ssd_kernel_matches_sequential_oracle(shape, chunk, bh):
    b, s, h, p, n = shape
    key = jax.random.PRNGKey(0)
    xh = jax.random.normal(key, (b, s, h, p))
    a = -jax.random.uniform(jax.random.PRNGKey(1), (b, s, h)) * 0.5
    bm = jax.random.normal(jax.random.PRNGKey(2), (b, s, n))
    cm = jax.random.normal(jax.random.PRNGKey(3), (b, s, n))
    out = ssd_scan_pallas(xh, a, bm, cm, chunk=chunk, block_h=bh,
                          interpret=True)
    ref = ssd_scan_ref(xh, a, bm, cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5,
                               rtol=1e-4)


def test_model_layer_matches_oracle():
    """The transformer's chunked SSD (_ssd_chunk_scan) implements the same
    recurrence — triangulates kernel, model and oracle."""
    b, s, h, p, n = 1, 64, 4, 16, 8
    key = jax.random.PRNGKey(5)
    xh = jax.random.normal(key, (b, s, h, p))
    a = -jax.random.uniform(jax.random.PRNGKey(6), (b, s, h)) * 0.5
    bm = jax.random.normal(jax.random.PRNGKey(7), (b, s, n))
    cm = jax.random.normal(jax.random.PRNGKey(8), (b, s, n))
    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    y_model, _ = _ssd_chunk_scan(xh, a, bm, cm, h0, 16)
    y_ref = ssd_scan_ref(xh, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_ref),
                               atol=5e-5, rtol=1e-4)


@given(s=st.integers(4, 40), seed=st.integers(0, 30))
@settings(max_examples=8, deadline=None)
def test_ssd_kernel_property(s, seed):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    xh = jax.random.normal(k1, (1, s, 2, 4))
    a = -jax.random.uniform(k2, (1, s, 2)) * 0.3
    bm = jax.random.normal(k3, (1, s, 4))
    cm = jax.random.normal(k4, (1, s, 4))
    out = ssd_scan_pallas(xh, a, bm, cm, chunk=8, block_h=2, interpret=True)
    ref = ssd_scan_ref(xh, a, bm, cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5,
                               rtol=1e-4)
