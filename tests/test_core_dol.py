"""Unit + property tests for the FedDif core math (Sec. III-B, Lemmas 1–2)."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import dol as D


def simplex(draw_c):
    return st.lists(st.floats(0.01, 10.0), min_size=draw_c, max_size=draw_c) \
        .map(lambda v: np.asarray(v, np.float32) / np.sum(v))


@given(p=simplex(8))
@settings(max_examples=50, deadline=None)
def test_iid_distance_nonneg_and_zero_at_uniform(p):
    d = float(D.iid_distance(jnp.asarray(p)))
    assert d >= 0.0
    u = D.uniform_dol(8)
    assert float(D.iid_distance(u)) < 1e-6


@given(p=simplex(10), q=simplex(10),
       s1=st.floats(1.0, 1e4), s2=st.floats(1.0, 1e4))
@settings(max_examples=50, deadline=None)
def test_dol_update_stays_on_simplex(p, q, s1, s2):
    new, size = D.update_dol(jnp.asarray(p), s1, jnp.asarray(q), s2)
    new = np.asarray(new)
    assert abs(new.sum() - 1.0) < 1e-4
    assert (new >= -1e-6).all()
    assert float(size) == pytest.approx(s1 + s2)


def test_dol_update_is_weighted_mixture():
    p = np.array([1.0, 0.0, 0.0], np.float32)
    q = np.array([0.0, 1.0, 0.0], np.float32)
    new, _ = D.update_dol(jnp.asarray(p), 100.0, jnp.asarray(q), 300.0)
    np.testing.assert_allclose(np.asarray(new), [0.25, 0.75, 0.0], atol=1e-6)


def test_optimal_dsi_lemma1_drives_dol_to_uniform():
    """Folding in Lemma-1's optimal DSI must land the DoL exactly on U."""
    rng = np.random.default_rng(0)
    for _ in range(10):
        c = 6
        dol = rng.dirichlet(np.ones(c)).astype(np.float32)
        chain = float(rng.uniform(100, 1000))
        # Corollary 1 feasibility bound
        dmin = float(D.min_feasible_data_size(jnp.asarray(dol), chain))
        di = dmin + float(rng.uniform(10, 100))
        dstar = D.optimal_dsi(jnp.asarray(dol), chain, di)
        dstar_np = np.asarray(dstar)
        assert (dstar_np >= -1e-5).all()       # feasible (Corollary 1)
        assert abs(dstar_np.sum() - 1.0) < 1e-4
        new, _ = D.update_dol(jnp.asarray(dol), chain, dstar, di)
        assert float(D.iid_distance(new)) < 1e-5


def test_closed_form_iid_distance_lemma2():
    """Eq. (30): distance computed from variations matches direct W1."""
    rng = np.random.default_rng(1)
    c = 5
    dol = rng.dirichlet(np.ones(c)).astype(np.float32)
    chain = 500.0
    di = float(D.min_feasible_data_size(jnp.asarray(dol), chain)) + 50.0
    # real-world DSI deviating from optimal by variation phi
    dstar = np.asarray(D.optimal_dsi(jnp.asarray(dol), chain, di))
    phi = rng.normal(0, 1, c).astype(np.float32)
    phi -= phi.mean()  # keep DSI normalized
    real = dstar + phi / di
    new, total = D.update_dol(jnp.asarray(dol), chain, jnp.asarray(real), di)
    direct = float(D.iid_distance(new))
    closed = float(D.closed_form_iid_distance(jnp.asarray(phi), total))
    assert direct == pytest.approx(closed, rel=1e-3, abs=1e-5)


def test_iid_distance_converges_with_diffusion():
    """Lemma 2 asymptotics: mixing many Dirichlet DSIs → distance → 0."""
    rng = np.random.default_rng(2)
    c = 10
    dol = jnp.zeros((c,))
    chain = 0.0
    dist_hist = []
    for k in range(200):
        dsi = rng.dirichlet(np.ones(c) * 0.5).astype(np.float32)
        dol, chain = D.update_dol(dol, chain, jnp.asarray(dsi), 100.0)
        dist_hist.append(float(D.iid_distance(dol)))
    # Lemma-2 rate: distance ~ O(1/k) — expect ~an order of magnitude drop
    assert dist_hist[-1] < dist_hist[0]
    assert dist_hist[-1] < 0.1
    assert dist_hist[-1] < dist_hist[9] / 2


@given(p=simplex(8))
@settings(max_examples=30, deadline=None)
def test_distance_metrics_agree_on_uniform(p):
    for metric in ("w1_norm", "w1_true", "kld", "jsd"):
        u = D.uniform_dol(8)
        assert float(D.iid_distance(u, metric)) < 1e-5
        assert float(D.iid_distance(jnp.asarray(p), metric)) >= -1e-7


def test_entropy_maximized_at_uniform():
    rng = np.random.default_rng(3)
    u = D.uniform_dol(10)
    hu = float(D.entropy(u))
    for _ in range(20):
        p = rng.dirichlet(np.ones(10)).astype(np.float32)
        assert float(D.entropy(jnp.asarray(p))) <= hu + 1e-5


def test_candidates_match_scalar_updates():
    rng = np.random.default_rng(4)
    m_, n_, c = 3, 4, 6
    dol = rng.dirichlet(np.ones(c), m_).astype(np.float32)
    chain = rng.uniform(100, 500, m_).astype(np.float32)
    dsi = rng.dirichlet(np.ones(c), n_).astype(np.float32)
    sizes = rng.uniform(50, 200, n_).astype(np.float32)
    cand = np.asarray(D.iid_distance_candidates(
        jnp.asarray(dol), jnp.asarray(chain), jnp.asarray(dsi),
        jnp.asarray(sizes)))
    for i in range(m_):
        for j in range(n_):
            new, _ = D.update_dol(jnp.asarray(dol[i]), chain[i],
                                  jnp.asarray(dsi[j]), sizes[j])
            assert cand[i, j] == pytest.approx(
                float(D.iid_distance(new)), rel=1e-4, abs=1e-5)
