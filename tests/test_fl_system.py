"""FL system integration: strategies run end-to-end; FedDif beats FedAvg
under non-IID; STC compresses; ledger orderings match the paper's Table II
qualitative structure.  Sizes are kept tiny for CI speed."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation as agg
from repro.data.partitioner import dirichlet_partition
from repro.data.synthetic import gaussian_image_dataset
from repro.fl import (ExperimentSpec, FLConfig, run_experiment,
                      build_task_model, compressed_bits, stc_compress)


def _spec(strategy, rounds=4, alpha=0.3, task="fcn", **kw):
    return ExperimentSpec(
        task=task, alpha=alpha, num_samples=3000,
        fl=FLConfig(strategy=strategy, rounds=rounds, num_clients=6,
                    num_models=6, seed=0, **kw))


@pytest.mark.parametrize("strategy", ["fedavg", "feddif", "fedswap", "stc",
                                      "tthf", "gossip"])
def test_strategy_runs(strategy):
    res = run_experiment(_spec(strategy, rounds=2))
    assert len(res.accuracy) == 2
    assert all(0.0 <= a <= 1.0 for a in res.accuracy)
    assert res.ledger.transmitted_models > 0 or strategy == "gossip"


def test_feddif_beats_fedavg_under_noniid():
    r_avg = run_experiment(_spec("fedavg", rounds=6, alpha=0.2))
    r_dif = run_experiment(_spec("feddif", rounds=6, alpha=0.2))
    assert max(r_dif.accuracy) > max(r_avg.accuracy)


def test_feddif_diffuses_less_when_iid():
    """Fig. 3: with IID data (α→∞) the BS performs (almost) no diffusion —
    comparative claim vs the extreme non-IID setting."""
    res_iid = run_experiment(_spec("feddif", rounds=2, alpha=1000.0,
                                   epsilon=0.04))
    res_non = run_experiment(_spec("feddif", rounds=2, alpha=0.1,
                                   epsilon=0.04))
    assert sum(res_iid.diffusion_rounds) < sum(res_non.diffusion_rounds)


def test_feddif_iid_distance_decreases():
    res = run_experiment(_spec("feddif", rounds=3, alpha=0.3))
    assert res.iid_distance[-1] <= 0.25


def test_stc_cheaper_than_fedavg_per_round():
    r_avg = run_experiment(_spec("fedavg", rounds=2))
    r_stc = run_experiment(_spec("stc", rounds=2))
    assert r_stc.ledger.transmitted_bits < r_avg.ledger.transmitted_bits


def test_fedswap_transmits_more_models_than_feddif():
    """Table II ordering: FedSwap (full diffusion) ≥ FedDif transmissions."""
    r_dif = run_experiment(_spec("feddif", rounds=3))
    r_swp = run_experiment(_spec("fedswap", rounds=3))
    assert r_swp.ledger.transmitted_models >= r_dif.ledger.transmitted_models


def test_stc_compression_semantics():
    tree = {"a": jnp.arange(-50.0, 50.0), "b": jnp.ones((10, 10))}
    out = stc_compress(tree, sparsity=0.1)
    for k in tree:
        assert out[k].shape == tree[k].shape
    bits = compressed_bits(tree, 0.1)
    dense_bits = agg.model_bits(tree, 32)
    assert bits < dense_bits


def test_dirichlet_partition_properties():
    ds = gaussian_image_dataset(2000, 10, 64, seed=0)
    rng = np.random.default_rng(0)
    part = dirichlet_partition(ds.y, 8, alpha=0.2, rng=rng)
    assert part.num_clients == 8
    assert all(len(ix) >= 8 for ix in part.indices)
    # no duplicate assignment
    allidx = np.concatenate(part.indices)
    assert len(allidx) == len(np.unique(allidx))
    # dsi rows are simplex points
    np.testing.assert_allclose(part.dsi.sum(1), 1.0, atol=1e-5)
    # low alpha => high skew: max class share well above uniform
    assert part.dsi.max(1).mean() > 0.3


def test_dirichlet_alpha_controls_skew():
    ds = gaussian_image_dataset(4000, 10, 64, seed=0)
    rng = np.random.default_rng(0)
    skew_low = dirichlet_partition(ds.y, 8, 0.1, rng).dsi.max(1).mean()
    skew_high = dirichlet_partition(ds.y, 8, 100.0, rng).dsi.max(1).mean()
    assert skew_low > skew_high


@pytest.mark.parametrize("task", ["logistic", "svm", "fcn", "lstm", "cnn"])
def test_task_models_learn(task):
    """Every Sec.-VI-A model family fits the synthetic data centrally."""
    ds = gaussian_image_dataset(2000, 10, 64, seed=0)
    model = build_task_model(task)
    params = model.init(jax.random.PRNGKey(0))
    import repro.train.optimizer as O
    opt = O.sgd(0.9)
    st = opt.init(params)

    @jax.jit
    def step(p, s, bx, by):
        loss, g = jax.value_and_grad(
            lambda q: model.loss(q, {"x": bx, "y": by}))(p)
        u, s = opt.update(g, s, p, 0.02)
        return O.apply_updates(p, u), s, loss

    rng = np.random.default_rng(0)
    acc0 = float(model.accuracy(params, ds.x, ds.y))
    for _ in range(100):
        idx = rng.integers(0, len(ds.y), 64)
        params, st, _ = step(params, st, ds.x[idx], ds.y[idx])
    acc1 = float(model.accuracy(params, ds.x, ds.y))
    assert acc1 > acc0 + 0.15, f"{task}: {acc0} -> {acc1}"


def test_divergence_bound_prop1():
    """Prop. 1 numeric sanity: bound grows with K and shrinks as the
    probability distance shrinks."""
    b1 = agg.divergence_bound(0.0, np.array([1.0]), 0.01, 5.0,
                              np.array([1.0]), k=5)
    b2 = agg.divergence_bound(0.0, np.array([1.0]), 0.01, 5.0,
                              np.array([1.0]), k=10)
    b3 = agg.divergence_bound(0.0, np.array([1.0]), 0.01, 5.0,
                              np.array([0.1]), k=10)
    assert b2 > b1 > 0 and b3 < b2


def test_appendix_retrainable_runs():
    """Appendix C-D: dropping constraint 18c still runs end-to-end (the
    paper's point — re-training *eventually* hurts via overfitting/ping-pong
    — needs long horizons; here we check mechanics: the planner actually
    schedules repeat visits and stays bounded by max_diffusion_rounds)."""
    retr = run_experiment(_spec("feddif", rounds=3, alpha=0.3,
                                allow_retraining=True,
                                max_diffusion_rounds=10))
    assert all(r <= 10 for r in retr.diffusion_rounds)
    assert 0.0 <= max(retr.accuracy) <= 1.0
    assert retr.ledger.transmitted_models > 0


def test_appendix_underlay_costs_more_per_hop():
    """Appendix C-F: CUE interference lowers spectral efficiency, so each
    scheduled D2D hop costs more sub-frames (and fewer links pass the QoS
    filter, so fewer hops get scheduled overall)."""
    over = run_experiment(_spec("feddif", rounds=2, alpha=0.5))
    under = run_experiment(_spec("feddif", rounds=2, alpha=0.5,
                                 underlay=True))
    per_hop_over = over.ledger.subframes / max(
        over.ledger.transmitted_models, 1)
    per_hop_under = under.ledger.subframes / max(
        under.ledger.transmitted_models, 1)
    assert per_hop_under >= per_hop_over
    assert under.ledger.transmitted_models <= over.ledger.transmitted_models


def test_metric_variants_still_learn():
    for metric in ("kld", "jsd", "w1_true"):
        r = run_experiment(_spec("feddif", rounds=3, alpha=0.5,
                                 metric=metric))
        assert max(r.accuracy) > 0.25


def test_fedprox_strategies_run_and_track_fedavg():
    """FedProx (weight-regularization family, Sec. II-1) runs standalone and
    composed with FedDif; with small μ it tracks the unregularized runs."""
    base = run_experiment(_spec("fedavg", rounds=2))
    prox = run_experiment(_spec("fedprox", rounds=2))
    assert abs(max(prox.accuracy) - max(base.accuracy)) < 0.1
    dif = run_experiment(_spec("feddif_prox", rounds=2))
    assert max(dif.accuracy) >= max(prox.accuracy) - 0.05
