"""Prefill ≡ decode consistency, chunked-attention vs naive, chunked-CE vs
dense CE, MoE routing invariants — the model-zoo correctness core."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.models import layers as L
from repro.models import transformer as tf
from repro.models.attention import AttnSpec, chunked_attention
from repro.models.moe import MoESpec, init_moe, moe_forward

ARCHS = ["smollm_360m", "qwen3_0_6b", "gemma3_4b", "mixtral_8x22b",
         "falcon_mamba_7b", "zamba2_2_7b", "qwen3_moe_235b_a22b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_equals_decode(arch):
    cfg = dataclasses.replace(get_smoke_config(arch),
                              compute_dtype="float32")
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    b, s = 2, 20
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    x = tf._embed_inputs(params, cfg, {"tokens": toks})
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    hid, _ = tf.forward_hidden(params, cfg, x, pos, remat=False)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    want = (L.unembed_logits(head, hid, jnp.float32) if cfg.tie_embeddings
            else L.dense(head, hid, jnp.float32))
    cache = model.init_cache(params, b, s)
    outs = []
    for t in range(s):
        lg, cache = model.decode_step(params, toks[:, t:t + 1], cache,
                                      jnp.int32(t))
        outs.append(lg[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4,
                               rtol=2e-4)


def test_chunked_attention_matches_naive():
    key = jax.random.PRNGKey(0)
    b, s, kh, g, d = 2, 100, 2, 3, 32
    q = jax.random.normal(key, (b, s, kh, g, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kh, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kh, d))
    spec = AttnSpec(d_model=d * kh * g, num_heads=kh * g, num_kv_heads=kh,
                    head_dim=d, q_chunk=32, kv_chunk=32,
                    compute_dtype=jnp.float32)
    out = chunked_attention(q, k, v, spec)
    scale = 1 / np.sqrt(d)
    s_ = jnp.einsum("bqhgd,bkhd->bhgqk", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), bool))
    s_ = jnp.where(mask[None, None, None], s_, -jnp.inf)
    p = jax.nn.softmax(s_, axis=-1)
    want = jnp.einsum("bhgqk,bkhd->bqhgd", p, v).reshape(b, s, kh * g, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_sliding_window_attention_masks_correctly():
    key = jax.random.PRNGKey(0)
    b, s, kh, g, d, w = 1, 96, 1, 1, 16, 24
    q = jax.random.normal(key, (b, s, kh, g, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kh, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kh, d))
    spec = AttnSpec(d_model=16, num_heads=1, num_kv_heads=1, head_dim=16,
                    window=w, q_chunk=32, kv_chunk=32,
                    compute_dtype=jnp.float32)
    out = chunked_attention(q, k, v, spec)
    scale = 1 / np.sqrt(d)
    s_ = jnp.einsum("bqhgd,bkhd->bhgqk", q, k) * scale
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    mask = (kp <= qp) & (kp > qp - w)
    s_ = jnp.where(mask[None, None, None], s_, -jnp.inf)
    p = jax.nn.softmax(s_, axis=-1)
    want = jnp.einsum("bhgqk,bkhd->bqhgd", p, v).reshape(b, s, 1, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_chunked_cross_entropy_matches_dense():
    key = jax.random.PRNGKey(0)
    b, s, d, v = 2, 17, 8, 50
    hid = jax.random.normal(key, (b, s, d), jnp.float32)
    emb = {"table": jax.random.normal(jax.random.PRNGKey(1), (v, d))}
    y = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, v)
    got = L.chunked_cross_entropy(emb, hid, y, tie=True, chunk=5,
                                  compute_dtype=jnp.float32)
    logits = jnp.einsum("bsd,vd->bsv", hid, emb["table"])
    logz = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, y[..., None], -1)[..., 0]
    want = jnp.mean(logz - gold)
    assert float(got) == pytest.approx(float(want), rel=1e-5)


def test_chunked_cross_entropy_respects_mask():
    key = jax.random.PRNGKey(0)
    b, s, d, v = 1, 8, 4, 11
    hid = jax.random.normal(key, (b, s, d), jnp.float32)
    emb = {"table": jax.random.normal(jax.random.PRNGKey(1), (v, d))}
    y = jnp.zeros((b, s), jnp.int32)
    mask = jnp.zeros((b, s)).at[0, :4].set(1.0)
    got = L.chunked_cross_entropy(emb, hid, y, tie=True, chunk=4, mask=mask,
                                  compute_dtype=jnp.float32)
    got_full = L.chunked_cross_entropy(emb, hid[:, :4], y[:, :4], tie=True,
                                       chunk=4, compute_dtype=jnp.float32)
    assert float(got) == pytest.approx(float(got_full), rel=1e-5)


def test_moe_routing_conservation():
    """Every kept token's output is the prob-weighted sum of its experts'
    outputs; capacity 1.0+ with uniform router keeps ~all tokens."""
    key = jax.random.PRNGKey(0)
    spec = MoESpec(d_model=16, num_experts=4, top_k=2, d_ff_expert=32,
                   capacity_factor=2.0, compute_dtype=jnp.float32)
    p = init_moe(key, spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16), jnp.float32)
    out, aux = moe_forward(p, spec, x)
    assert out.shape == x.shape
    assert jnp.isfinite(out).all() and float(aux) > 0
    # reference dense computation of the same routing
    t = 16
    xt = x.reshape(t, 16)
    logits = xt @ p["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, 2)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    def expert(e, h):
        g = h @ p["w_gate"][e]
        u = h @ p["w_up"][e]
        return (jax.nn.silu(g) * u) @ p["w_down"][e]
    want = jnp.zeros_like(xt)
    for ti in range(t):
        acc = jnp.zeros((16,))
        for j in range(2):
            acc += top_p[ti, j] * expert(int(top_e[ti, j]), xt[ti])
        want = want.at[ti].set(acc)
    np.testing.assert_allclose(np.asarray(out.reshape(t, 16)),
                               np.asarray(want), atol=1e-4, rtol=1e-3)


def test_moe_capacity_drops_overflow():
    key = jax.random.PRNGKey(0)
    spec = MoESpec(d_model=8, num_experts=2, top_k=1, d_ff_expert=16,
                   capacity_factor=0.5, compute_dtype=jnp.float32)
    p = init_moe(key, spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 8), jnp.float32)
    out, _ = moe_forward(p, spec, x)
    # some tokens must be dropped (zero contribution) at cf=0.5
    norms = jnp.linalg.norm(out.reshape(16, 8), axis=-1)
    assert int(jnp.sum(norms == 0.0)) >= 1
