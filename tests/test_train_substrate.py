"""Optimizer / checkpoint / data pipeline substrate tests."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.pipeline import ClientLoader, lm_batches
from repro.data.synthetic import lm_corpus
from repro.models import build_model
from repro.train import (adamw, clip_by_global_norm,
                         constant_lr, cosine_lr, init_train_state,
                         latest_step, make_train_step, restore_checkpoint,
                         save_checkpoint, sgd, warmup_cosine_lr)


def test_sgd_momentum_matches_reference():
    p = {"w": jnp.ones((3,))}
    g = {"w": jnp.full((3,), 2.0)}
    opt = sgd(momentum=0.9)
    s = opt.init(p)
    lr = 0.1
    u1, s = opt.update(g, s, p, lr)
    np.testing.assert_allclose(np.asarray(u1["w"]), -0.2, rtol=1e-6)
    u2, s = opt.update(g, s, p, lr)
    # mu = 0.9*2 + 2 = 3.8 -> update -0.38
    np.testing.assert_allclose(np.asarray(u2["w"]), -0.38, rtol=1e-6)


def test_adamw_first_step_is_lr_sized():
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 0.5)}
    opt = adamw(weight_decay=0.0)
    s = opt.init(p)
    u, s = opt.update(g, s, p, 1e-2)
    np.testing.assert_allclose(np.abs(np.asarray(u["w"])), 1e-2, rtol=1e-3)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_schedules():
    assert float(constant_lr(0.1)(100)) == pytest.approx(0.1)
    c = cosine_lr(1.0, 100)
    assert float(c(0)) == pytest.approx(1.0)
    assert float(c(100)) == pytest.approx(0.0, abs=1e-6)
    w = warmup_cosine_lr(1.0, 10, 110)
    assert float(w(5)) == pytest.approx(0.5)
    assert float(w(10)) == pytest.approx(1.0)


def test_loss_decreases_over_steps():
    cfg = get_smoke_config("smollm_360m")
    model = build_model(cfg)
    opt = sgd()
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(model, opt))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    losses = []
    for _ in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert int(state.step) == 8


def test_checkpoint_roundtrip():
    cfg = get_smoke_config("qwen3_0_6b")
    model = build_model(cfg)
    opt = sgd()
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, state.params, {"note": "test"})
        assert latest_step(d) == 3
        zeros = jax.tree.map(jnp.zeros_like, state.params)
        restored = restore_checkpoint(d, 3, zeros)
        for a, b in zip(jax.tree.leaves(restored),
                        jax.tree.leaves(state.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 0, {"w": jnp.ones((2, 2))})
        with pytest.raises(ValueError):
            restore_checkpoint(d, 0, {"w": jnp.ones((3, 3))})


def test_client_loader_epochs():
    x = np.arange(100, dtype=np.float32)[:, None]
    y = np.arange(100) % 10
    loader = ClientLoader(x, y, batch_size=16, seed=0)
    batches = list(loader.epoch())
    assert len(batches) == 6
    assert all(b["x"].shape == (16, 1) for b in batches)
    # different epochs shuffle differently
    b1 = list(loader.epoch())[0]["x"].ravel()
    b2 = list(loader.epoch())[0]["x"].ravel()
    assert not np.array_equal(b1, b2)


def test_lm_batches_shapes_and_shift():
    toks = lm_corpus(10_000, vocab=100, seed=0)
    it = lm_batches(toks, batch=4, seq_len=32, seed=0)
    b = next(it)
    assert b["tokens"].shape == (4, 32) and b["labels"].shape == (4, 32)
    # labels are the next-token shift of tokens
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_grad_accumulation_matches_single_step():
    """accum_steps=K over a batch must equal one step on the full batch
    (same mean loss/grads up to fp accumulation order)."""
    from repro.train.trainstep import make_train_step, init_train_state
    cfg = get_smoke_config("smollm_360m")
    model = build_model(cfg)
    opt = sgd(momentum=0.0)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    s1 = init_train_state(model, jax.random.PRNGKey(0), opt)
    s2 = init_train_state(model, jax.random.PRNGKey(0), opt)
    step1 = jax.jit(make_train_step(model, opt, remat=False, accum_steps=1,
                                    clip_norm=None))
    step4 = jax.jit(make_train_step(model, opt, remat=False, accum_steps=4,
                                    clip_norm=None))
    stacked = jax.tree.map(
        lambda x: x.reshape((4, 1) + x.shape[1:]), batch)
    s1, m1 = step1(s1, batch)
    s2, m2 = step4(s2, stacked)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-4, rtol=1e-3)
