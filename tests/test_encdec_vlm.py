"""Whisper enc-dec and Pixtral VLM backbone specifics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.models import encdec as ed


def _whisper(fp32=True):
    cfg = get_smoke_config("whisper_base")
    if fp32:
        cfg = dataclasses.replace(cfg, compute_dtype="float32")
    return cfg, build_model(cfg)


def test_whisper_decode_matches_teacher_forcing():
    cfg, model = _whisper()
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    b, s = 2, 12
    frames = jax.random.normal(key, (b, cfg.num_frontend_tokens,
                                     cfg.d_model), jnp.float32)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    enc = ed.encode(params, cfg, frames, remat=False)
    hid = ed._decode_hidden(params, cfg, toks, enc, remat=False)
    from repro.models import layers as L
    want = L.unembed_logits(params["embed"], hid, jnp.float32)
    cache = model.init_cache(params, frames, b, s)
    outs = []
    for t in range(s):
        lg, cache = model.decode_step(params, toks[:, t:t + 1], cache,
                                      jnp.int32(t))
        outs.append(lg[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4,
                               rtol=2e-4)


def test_whisper_encoder_bidirectional():
    """Replacing the second half of the frames must change first-half
    encoder outputs (no causal mask in the encoder).  Note: a CONSTANT
    perturbation would be invisible — pre-LN makes the block shift-
    invariant — so the probe uses fresh random frames."""
    cfg, model = _whisper()
    params = model.init(jax.random.PRNGKey(0))
    t = cfg.num_frontend_tokens
    frames = jax.random.normal(jax.random.PRNGKey(1), (1, t, cfg.d_model),
                               jnp.float32)
    other = jax.random.normal(jax.random.PRNGKey(2), (1, t, cfg.d_model),
                              jnp.float32)
    enc1 = ed.encode(params, cfg, frames, remat=False)
    frames2 = frames.at[:, t // 2:].set(other[:, t // 2:])
    enc2 = ed.encode(params, cfg, frames2, remat=False)
    assert float(jnp.abs(enc1[:, 0] - enc2[:, 0]).max()) > 1e-5


def test_whisper_cross_attention_sees_audio():
    cfg, model = _whisper()
    params = model.init(jax.random.PRNGKey(0))
    b, s = 1, 4
    toks = jnp.ones((b, s), jnp.int32)
    f1 = jax.random.normal(jax.random.PRNGKey(3),
                           (b, cfg.num_frontend_tokens, cfg.d_model),
                           jnp.float32)
    f2 = jax.random.normal(jax.random.PRNGKey(4),
                           (b, cfg.num_frontend_tokens, cfg.d_model),
                           jnp.float32)
    l1 = model.loss(params, {"frames": f1, "tokens": toks, "labels": toks},
                    remat=False)
    l2 = model.loss(params, {"frames": f2, "tokens": toks, "labels": toks},
                    remat=False)
    assert float(l1) != pytest.approx(float(l2), abs=1e-6)


def test_pixtral_patch_prefix_changes_text_loss():
    cfg = get_smoke_config("pixtral_12b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 1, 8
    toks = jnp.ones((b, s), jnp.int32)
    p1 = jnp.zeros((b, cfg.num_frontend_tokens, cfg.d_model), jnp.bfloat16)
    p2 = jnp.ones((b, cfg.num_frontend_tokens, cfg.d_model), jnp.bfloat16)
    l1 = model.loss(params, {"patch_embeddings": p1, "tokens": toks,
                             "labels": toks}, remat=False)
    l2 = model.loss(params, {"patch_embeddings": p2, "tokens": toks,
                             "labels": toks}, remat=False)
    assert float(l1) != pytest.approx(float(l2), abs=1e-6)


def test_pixtral_loss_only_over_text_positions():
    """The VLM loss must be computed on the text suffix (patch positions
    carry no labels)."""
    cfg = get_smoke_config("pixtral_12b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size)
    patches = jax.random.normal(jax.random.PRNGKey(2),
                                (b, cfg.num_frontend_tokens, cfg.d_model),
                                jnp.bfloat16)
    loss = model.loss(params, {"patch_embeddings": patches, "tokens": toks,
                               "labels": toks}, remat=False)
    assert jnp.isfinite(loss)
    # shape contract: hidden sliced to the last `s` positions internally —
    # a mismatched label length would have thrown in chunked CE.
