"""End-to-end dry-run integration: one real 512-device subprocess lowering
(the deliverable-e path), using the cheapest admissible pair."""
import json
import os
import subprocess
import sys
import tempfile

import pytest


@pytest.mark.parametrize("mp", [False, True])
def test_dryrun_subprocess_falcon_long(mp):
    with tempfile.TemporaryDirectory() as out:
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", "falcon_mamba_7b", "--shape", "long_500k",
               "--out", out] + (["--multi-pod"] if mp else [])
        env = dict(os.environ, PYTHONPATH="src")
        r = subprocess.run(cmd, cwd=os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), env=env, capture_output=True,
            text=True, timeout=900)
        assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
        suffix = "512" if mp else "256"
        path = os.path.join(out,
                            f"dryrun_falcon_mamba_7b_long_500k_{suffix}.json")
        rec = json.load(open(path))
        assert rec["status"] == "ok"
        assert rec["chips"] == (512 if mp else 256)
        assert rec["hlo_dot_flops_per_device"] > 0


def test_dryrun_skip_rule():
    """Full-attention archs must skip long_500k with the documented reason."""
    import importlib
    dr = importlib.import_module("repro.launch.dryrun")
    r = dr.lower_one("smollm_360m", "long_500k", multi_pod=False)
    assert r["status"] == "skipped"
    assert "sub-quadratic" in r["reason"]
