"""Matching solvers vs scipy oracle + auction constraint tests (Sec. V).

Covers both Algorithm-1 solvers: the host Kuhn–Munkres oracle and the
jitted Bertsekas ε-scaling auction (`repro.core.matching.auction_assign`).
"""
import numpy as np
import pytest
import scipy.optimize as so

from repro.core.auction import AuctionConfig, run_auction
from repro.core.dol import DiffusionState
from repro.core.matching import (auction_matching, hungarian_min_cost,
                                 max_weight_matching)

# Only the @given property tests need hypothesis; the plain pytest tests
# (auction pair-parity, constraints) must run everywhere, so guard the
# import instead of importorskip-ing the whole module.
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # pragma: no cover
    def given(*_a, **_k):
        return pytest.mark.skip(reason="property tests need hypothesis")

    def settings(*_a, **_k):
        return lambda f: f

    class _St:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _St()


@given(st.integers(1, 8), st.integers(1, 8), st.integers(0, 10_000))
@settings(max_examples=60, deadline=None)
def test_hungarian_matches_scipy(n, m, seed):
    rng = np.random.default_rng(seed)
    cost = rng.normal(size=(n, m))
    r, c = hungarian_min_cost(cost)
    r2, c2 = so.linear_sum_assignment(cost)
    assert cost[r, c].sum() == pytest.approx(cost[r2, c2].sum(), abs=1e-9)


@given(st.integers(1, 8), st.integers(1, 8), st.integers(0, 10_000),
       st.sampled_from([1e-8, 1.0, 1e5]))
@settings(max_examples=40, deadline=None)
def test_auction_matches_scipy_oracle(n, m, seed, scale):
    """Differential test: Bertsekas auction vs linear_sum_assignment.

    The oracle solves the same "match or stay put" problem via a dummy-
    padded square cost matrix restricted to strictly positive weights —
    exactly `max_weight_matching`'s contract.  The auction's total must
    agree to ε-scaling resolution across 7 orders of weight magnitude.
    """
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(n, m)) * scale
    pairs = auction_matching(w)
    # validity: 1-1 over rows and columns, strictly positive weights
    assert len({r for r, _ in pairs}) == len(pairs)
    assert len({c for _, c in pairs}) == len(pairs)
    assert all(w[r, c] > 0 for r, c in pairs)
    # scipy oracle on the dummy-padded square problem
    big = np.zeros((n, m + n))
    big[:, :m] = np.where(w > 0, w, 0.0)
    rr, cc = so.linear_sum_assignment(-big)
    oracle_total = big[rr, cc].sum()
    total = sum(w[r, c] for r, c in pairs)
    assert total == pytest.approx(oracle_total,
                                  rel=1e-4, abs=1e-5 * abs(w).max())


@pytest.mark.parametrize("seed", range(12))
def test_auction_matches_hungarian_pairs(seed):
    """On generic (tie-free) matrices the auction returns the *same pairs*
    as the Hungarian oracle, not just the same total — the property the
    jax planner's hop-list parity rests on."""
    rng = np.random.default_rng(seed)
    n, m = rng.integers(2, 12, 2)
    w = np.where(rng.uniform(size=(n, m)) < 0.6,
                 rng.uniform(size=(n, m)) * 1e-8, 0.0)
    assert auction_matching(w) == max_weight_matching(w)


def test_auction_matching_respects_forbid():
    w = np.ones((3, 3)) + np.arange(9).reshape(3, 3) * 0.1
    forbid = np.zeros((3, 3), bool)
    forbid[0, :] = True
    pairs = auction_matching(w, forbid)
    assert all(mdl != 0 for mdl, _ in pairs)
    assert auction_matching(-np.ones((2, 2))) == []


def test_max_weight_matching_excludes_nonpositive():
    w = np.array([[1.0, 0.0], [-1.0, 0.5]])
    pairs = max_weight_matching(w)
    assert (0, 0) in pairs and (1, 1) in pairs
    w2 = np.array([[-1.0, -2.0], [-3.0, -4.0]])
    assert max_weight_matching(w2) == []


def test_max_weight_matching_respects_forbid():
    w = np.ones((3, 3))
    forbid = np.zeros((3, 3), bool)
    forbid[0, :] = True
    pairs = max_weight_matching(w, forbid)
    assert all(m != 0 for m, _ in pairs)


def _setup_auction(seed=0, n=8, m=6, c=5):
    rng = np.random.default_rng(seed)
    dsi = rng.dirichlet(np.ones(c) * 0.5, n).astype(np.float32)
    sizes = rng.uniform(100, 500, n)
    state = DiffusionState.init(m, n, c)
    for mi in range(m):
        state.record_training(mi, mi % n, dsi[mi % n], float(sizes[mi % n]))
    gains = rng.exponential(1e-7, (n, n))
    snr = gains * 1e9
    mean_snr = np.full((n, n), snr.mean())
    return state, dsi, sizes, gains, mean_snr, snr


def test_auction_respects_constraints():
    state, dsi, sizes, gains, mean_snr, snr = _setup_auction()
    cfg = AuctionConfig(gamma_min=0.5, model_bits=1e5)
    res = run_auction(state, dsi, sizes, gains, mean_snr, snr, cfg)
    seen_pues = set()
    for mdl, pue in res.pairs:
        assert res.decrements[mdl] > 0          # (18b)
        assert not state.visited[mdl, pue]      # (18c)
        assert pue not in seen_pues             # (18d)
        seen_pues.add(pue)
        assert res.bandwidth[mdl] > 0           # Eq. (37) finite
    # Second price never exceeds the winner's own bid.
    for mdl, pue in res.pairs:
        assert res.payments[mdl] <= res.bids[mdl, pue] + 1e-9


def test_auction_bandwidth_budget_18f():
    state, dsi, sizes, gains, mean_snr, snr = _setup_auction()
    cfg_inf = AuctionConfig(gamma_min=0.0, model_bits=1e5)
    full = run_auction(state, dsi, sizes, gains, mean_snr, snr, cfg_inf)
    if len(full.pairs) < 2:
        pytest.skip("need ≥2 feasible pairs for this scenario")
    # budget that admits only the single most efficient transmission
    costs = sorted(full.bandwidth.values())
    cfg_tight = AuctionConfig(gamma_min=0.0, model_bits=1e5,
                              bandwidth_budget=costs[0] * 1.01)
    tight = run_auction(state, dsi, sizes, gains, mean_snr, snr, cfg_tight)
    assert len(tight.pairs) <= len(full.pairs)
    assert sum(tight.bandwidth.values()) <= cfg_tight.bandwidth_budget * 1.001


def test_auction_qos_filter_18e():
    state, dsi, sizes, gains, mean_snr, snr = _setup_auction()
    cfg = AuctionConfig(gamma_min=1e9, model_bits=1e5)   # impossible QoS
    res = run_auction(state, dsi, sizes, gains, mean_snr, snr, cfg)
    assert res.pairs == []
