"""FL diffusion data-plane kernels (kernels/diffusion.py): reference parity
in pallas_interpret and ref modes, dispatch plumbing, and end-to-end
executor/planner parity with the kernels forced on."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.diffusion import (dol_bid_scores_pallas,
                                     dol_bid_scores_xla_fused,
                                     mix_aggregate_pallas, stc_rows_pallas)

RNG = np.random.default_rng(7)


# ------------------------------------------------------------ mix_aggregate

@pytest.mark.parametrize("c,f,g", [
    (8, 1000, 8),        # MixOp: full (C, C) mixing matrix
    (8, 1000, 1),        # Eq.-11 aggregation row
    (20, 257, 20),       # F not lane-aligned
    (5, 64, 3),          # sharded partial: G != C, tiny F
    (64, 50890, 64),     # fcn-sized flattened fleet
])
def test_mix_aggregate_matches_ref(c, f, g):
    x = jnp.asarray(RNG.normal(size=(c, f)), jnp.float32)
    w = jnp.asarray(RNG.random(size=(g, c)), jnp.float32)
    out = ops.mix_aggregate(x, w, implementation="pallas_interpret")
    want = ops.mix_aggregate(x, w, implementation="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_mix_aggregate_client_tiled_accumulate_matches_ref():
    """C > block_c streams client tiles through the revolving accumulator;
    the result must match the single-slab product (and C % block_c != 0
    must be handled by zero padding)."""
    x = jnp.asarray(RNG.normal(size=(90, 700)), jnp.float32)
    w = jnp.asarray(RNG.random(size=(90, 90)), jnp.float32)
    out = mix_aggregate_pallas(x, w, block_c=32, interpret=True)
    want = jnp.einsum("gc,cf->gf", w, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_mix_aggregate_tree_paths_agree():
    """Tree-level dispatch: the per-leaf XLA chain and the flattened Pallas
    pass compute the same mix and the same (squeezed) aggregate."""
    params = {"w": jnp.asarray(RNG.normal(size=(6, 17, 3)), jnp.float32),
              "b": jnp.asarray(RNG.normal(size=(6, 9)), jnp.float32)}
    w_mix = jnp.asarray(RNG.random(size=(6, 6)), jnp.float32)
    w_agg = jnp.asarray(RNG.random(size=(1, 6)), jnp.float32)
    for w, collapse in ((w_mix, False), (w_agg, True)):
        a = ops.mix_aggregate_tree(params, w, collapse=collapse,
                                   implementation="ref")
        b = ops.mix_aggregate_tree(params, w, collapse=collapse,
                                   implementation="pallas_interpret")
        for la, lb, orig in zip(jax.tree.leaves(a), jax.tree.leaves(b),
                                jax.tree.leaves(params)):
            want_shape = (orig.shape[1:] if collapse
                          else (w.shape[0],) + orig.shape[1:])
            assert la.shape == lb.shape == want_shape
            np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                       atol=1e-5, rtol=1e-5)


def test_mix_aggregate_tree_one_slot_mix_stays_stacked():
    """A legitimate one-slot MixOp has w (1, 1) — without collapse the
    client axis must survive on both paths."""
    params = {"w": jnp.asarray(RNG.normal(size=(1, 4, 3)), jnp.float32)}
    w = jnp.ones((1, 1), jnp.float32)
    for impl in ("ref", "pallas_interpret"):
        out = ops.mix_aggregate_tree(params, w, implementation=impl)
        assert out["w"].shape == (1, 4, 3), impl
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   np.asarray(params["w"]), atol=1e-6)


def test_mix_aggregate_ref_is_flat_einsum():
    x = jnp.asarray(RNG.normal(size=(6, 100)), jnp.float32)
    w = jnp.asarray(RNG.random(size=(6, 6)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(ops.mix_aggregate(x, w, implementation="ref")),
        np.asarray(jnp.einsum("gc,cf->gf", w, x)))


# ------------------------------------------------------------------ stc_topk

@pytest.mark.parametrize("c,n,sparsity", [
    (6, 530, 0.05),
    (4, 4096, 0.01),
    (10, 64, 0.1),       # n below one lane tile
    (3, 10000, 0.001),
])
def test_stc_topk_matches_ref(c, n, sparsity):
    x = jnp.asarray(RNG.normal(size=(c, n)), jnp.float32)
    r = jnp.asarray(RNG.normal(size=(n,)), jnp.float32)
    mask = jnp.asarray(RNG.random(c) < 0.6)
    out = ops.stc_topk(x, r, mask, sparsity,
                       implementation="pallas_interpret")
    want = ops.stc_topk(x, r, mask, sparsity, implementation="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-6)


def test_stc_topk_unmasked_rows_bit_identical():
    x = jnp.asarray(RNG.normal(size=(5, 300)), jnp.float32)
    r = jnp.asarray(RNG.normal(size=(300,)), jnp.float32)
    mask = jnp.asarray([False, True, False, True, False])
    out = stc_rows_pallas(x, r, mask, 0.05, interpret=True)
    np.testing.assert_array_equal(np.asarray(out[~np.asarray(mask)]),
                                  np.asarray(x[~np.asarray(mask)]))


def test_stc_topk_sparsity_level():
    x = jnp.asarray(RNG.normal(size=(3, 2048)), jnp.float32)
    r = jnp.zeros((2048,), jnp.float32)
    mask = jnp.ones((3,), bool)
    out = ops.stc_topk(x, r, mask, 0.01, implementation="pallas_interpret")
    for row in np.asarray(out):
        assert int((row != 0).sum()) == max(1, int(2048 * 0.01))


def test_masked_stc_compress_routes_through_ops(monkeypatch):
    """fedshard's hop compression gives the same payload on both paths."""
    from repro.distributed.fedshard import masked_stc_compress
    params = {"w": jnp.asarray(RNG.normal(size=(4, 17, 3)), jnp.float32),
              "b": jnp.asarray(RNG.normal(size=(4, 9)), jnp.float32)}
    refp = {"w": jnp.asarray(RNG.normal(size=(17, 3)), jnp.float32),
            "b": jnp.asarray(RNG.normal(size=(9,)), jnp.float32)}
    mask = jnp.asarray([True, False, True, True])
    host = masked_stc_compress(params, refp, mask, 0.1,
                               implementation="ref")
    kern = masked_stc_compress(params, refp, mask, 0.1,
                               implementation="pallas_interpret")
    for a, b in zip(jax.tree.leaves(host), jax.tree.leaves(kern)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# ------------------------------------------------------------ dol_bid_scores

def _planner_inputs(m, n, c, zero_rows=True):
    dol = jnp.asarray(RNG.dirichlet(np.ones(c), size=m), jnp.float32)
    chain = jnp.asarray(RNG.integers(1, 500, size=m), jnp.float32)
    if zero_rows:   # never-trained model: dol = 0, chain = 0
        dol = dol.at[0].set(0.0)
        chain = chain.at[0].set(0.0)
    dsi = jnp.asarray(RNG.dirichlet(np.ones(c), size=n), jnp.float32)
    sizes = jnp.asarray(RNG.integers(0, 300, size=n), jnp.float32)
    return dol, chain, dsi, sizes


@pytest.mark.parametrize("m,n,c", [(4, 10, 10), (16, 130, 5), (64, 256, 10)])
def test_dol_bid_scores_matches_composite(m, n, c):
    dol, chain, dsi, sizes = _planner_inputs(m, n, c)
    want = ops.dol_bid_scores(dol, chain, dsi, sizes, implementation="ref")
    fused = dol_bid_scores_xla_fused(dol, chain, dsi, sizes)
    out = ops.dol_bid_scores(dol, chain, dsi, sizes,
                             implementation="pallas_interpret")
    np.testing.assert_allclose(np.asarray(fused), np.asarray(want),
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_dol_bid_scores_near_uniform_no_cancellation():
    """As DoLs converge to uniform (dist → 0) the centered expansion must
    not lose precision — the regime every diffusion round ends in."""
    m, n, c = 8, 12, 10
    dol = jnp.full((m, c), 1.0 / c) + jnp.asarray(
        RNG.normal(size=(m, c)) * 1e-4, jnp.float32)
    dol = dol / dol.sum(axis=1, keepdims=True)
    chain = jnp.asarray(RNG.integers(100, 500, size=m), jnp.float32)
    dsi = jnp.full((n, c), 1.0 / c, jnp.float32)
    sizes = jnp.asarray(RNG.integers(50, 100, size=n), jnp.float32)
    want = ops.dol_bid_scores(dol, chain, dsi, sizes, implementation="ref")
    out = ops.dol_bid_scores(dol, chain, dsi, sizes,
                             implementation="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-7)


def test_dol_bid_scores_non_default_metric_falls_back():
    dol, chain, dsi, sizes = _planner_inputs(4, 8, 6)
    for metric in ("kld", "jsd", "w1_true"):
        out = ops.dol_bid_scores(dol, chain, dsi, sizes, metric=metric,
                                 implementation="pallas_interpret")
        want = ref.dol_bid_scores_ref(dol, chain, dsi, sizes, metric)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_dol_bid_scores_vmaps():
    """plan_rounds_batched vmaps the planner over sweep cells — the kernel
    must batch."""
    dols, chains, dsis, sizess = [], [], [], []
    for _ in range(3):
        d, ch, ds, sz = _planner_inputs(4, 10, 10)
        dols.append(d), chains.append(ch), dsis.append(ds), sizess.append(sz)
    stack = map(jnp.stack, (dols, chains, dsis, sizess))
    out = jax.vmap(lambda d, ch, ds, sz: dol_bid_scores_pallas(
        d, ch, ds, sz, interpret=True))(*stack)
    for i in range(3):
        want = ref.dol_bid_scores_ref(dols[i], chains[i], dsis[i],
                                      sizess[i])
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(want),
                                   atol=2e-5)


# ------------------------------------------------------------- dispatch

def test_resolve_accepts_ref_alias(monkeypatch):
    assert ops._resolve("ref") == "xla"
    monkeypatch.setenv("REPRO_KERNELS_IMPL", "ref")
    assert ops._resolve("auto") == "xla"
    monkeypatch.setenv("REPRO_KERNELS_IMPL", "pallas_interpret")
    assert ops._resolve("auto") == "pallas_interpret"
    assert ops._resolve("ref") == "xla"      # explicit arg beats env


# ----------------------------------------------- end-to-end kernel parity

def _spec(strategy, executor, clients=4, rounds=2):
    from repro.fl import ExperimentSpec, FLConfig
    return ExperimentSpec(
        task="fcn", alpha=0.3, num_samples=800,
        fl=FLConfig(strategy=strategy, rounds=rounds, num_clients=clients,
                    num_models=clients, seed=0, topology_seed=3,
                    executor=executor, tthf_cluster_size=2,
                    tthf_global_period=2))


@pytest.mark.parametrize("strategy", ["gossip", "feddif_stc", "tthf"])
def test_fleet_kernel_data_plane_parity(monkeypatch, strategy):
    """Host executor (pure reference) vs fleet executor with every data-
    plane op forced onto the interpreted Pallas kernels: ledgers identical,
    params within the executor-parity tolerance."""
    from repro.fl import run_experiment
    monkeypatch.delenv("REPRO_KERNELS_IMPL", raising=False)
    host = run_experiment(_spec(strategy, "host"))
    monkeypatch.setenv("REPRO_KERNELS_IMPL", "pallas_interpret")
    fleet = run_experiment(_spec(strategy, "fleet"))
    assert host.ledger.as_dict() == fleet.ledger.as_dict()
    for a, b in zip(jax.tree.leaves(host.final_params),
                    jax.tree.leaves(fleet.final_params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-4, rtol=2e-3)


def test_planner_bids_kernel_inside_while_loop(monkeypatch):
    """The jitted round loop (lax.while_loop) with the Pallas bid kernel
    produces the same plan tensors as the reference composite."""
    from repro.core.planner import _plan_rounds, PlanInputs
    m, n, c, r = 3, 6, 5, 4
    dol = jnp.asarray(RNG.dirichlet(np.ones(c), size=m), jnp.float32)
    inp = PlanInputs(
        dol0=dol,
        chain_size0=jnp.asarray(RNG.integers(50, 200, size=m), jnp.float32),
        visited0=jnp.zeros((m, n), bool),
        holder0=jnp.arange(m, dtype=jnp.int32),
        dsi=jnp.asarray(RNG.dirichlet(np.ones(c), size=n), jnp.float32),
        data_sizes=jnp.asarray(RNG.integers(50, 200, size=n), jnp.float32),
        gamma_seq=jnp.asarray(1.0 + RNG.random((r, n, n)), jnp.float32),
        mean_snr=jnp.asarray(10.0 * jnp.ones((n, n)), jnp.float32),
        epsilon=jnp.float32(0.01),
        gamma_min=jnp.float32(0.5),
        outage_max=jnp.float32(0.9),
        bandwidth_budget=jnp.float32(1e9),
        model_bits=jnp.float32(1e5))
    monkeypatch.delenv("REPRO_KERNELS_IMPL", raising=False)
    want = _plan_rounds(inp, metric="w1_norm", allow_retraining=False)
    monkeypatch.setenv("REPRO_KERNELS_IMPL", "pallas_interpret")
    out = _plan_rounds(inp, metric="w1_norm", allow_retraining=False)
    assert int(out.num_rounds) == int(want.num_rounds)
    np.testing.assert_array_equal(np.asarray(out.scheduled),
                                  np.asarray(want.scheduled))
    np.testing.assert_array_equal(np.asarray(out.dst),
                                  np.asarray(want.dst))
    np.testing.assert_allclose(np.asarray(out.weight),
                               np.asarray(want.weight), atol=1e-4)


# ----------------------------------------------------- bid_value_fuse trio

@pytest.mark.parametrize("m,n", [(3, 5), (16, 20), (130, 257)])
def test_bid_value_fuse_pallas_matches_ref(m, n):
    rng = np.random.default_rng(0)
    bids = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    value = jnp.asarray(rng.uniform(size=n), jnp.float32)
    want = ref.bid_value_fuse_ref(bids, value, 0.7)
    from repro.kernels.diffusion import bid_value_fuse_pallas
    got = bid_value_fuse_pallas(bids, value, 0.7, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_bid_value_fuse_weight_zero_is_identity():
    rng = np.random.default_rng(1)
    bids = jnp.asarray(rng.normal(size=(8, 12)), jnp.float32)
    value = jnp.asarray(rng.uniform(size=12), jnp.float32)
    out = ops.bid_value_fuse(bids, value, 0.0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(bids))


def test_bid_value_fuse_ops_dispatch_routes_both_impls():
    rng = np.random.default_rng(2)
    bids = jnp.asarray(rng.normal(size=(6, 9)), jnp.float32)
    value = jnp.asarray(rng.uniform(size=9), jnp.float32)
    a = ops.bid_value_fuse(bids, value, 1.3, implementation="xla")
    b = ops.bid_value_fuse(bids, value, 1.3,
                           implementation="pallas_interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-6)
    # fused sign structure: value in [0,1], w > -1 preserves bid signs
    assert (np.sign(np.asarray(a)) == np.sign(np.asarray(bids))).all()


def test_bid_value_fuse_host_oracle_agrees():
    """The host auction's fusion (numpy) and the kernel trio agree — the
    planner-mode parity the scenario sweeps rely on."""
    from repro.core.auction import fuse_learning_value
    rng = np.random.default_rng(3)
    bids = rng.normal(size=(5, 7))
    value = rng.uniform(size=7)
    host = fuse_learning_value(bids, value, 0.4)
    dev = ops.bid_value_fuse(jnp.asarray(bids, jnp.float32),
                             jnp.asarray(value, jnp.float32), 0.4)
    np.testing.assert_allclose(np.asarray(dev), host.astype(np.float32),
                               rtol=1e-5, atol=1e-6)
