"""Device-resident planner plane: host-vs-jax parity + building blocks.

The acceptance bar for the jax control plane is *identical hop lists*
(model, src, dst, round) to the host numpy oracle on the default feddif
config, plus bit-identical ledger charges end-to-end.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.channels.fading import ChannelModel
from repro.channels.resources import (outage_probability,
                                      outage_probability_jax,
                                      required_bandwidth,
                                      required_bandwidth_jax,
                                      spectral_efficiency,
                                      spectral_efficiency_jax)
from repro.channels.topology import CellTopology
from repro.core import DiffusionPlanner, DiffusionState, PlannerState
from repro.core.diffusion import DiffusionHop, DiffusionPlan, PlanCache


def _mkstate(n, m, c, dsi, sizes):
    state = DiffusionState.init(m, n, c)
    for mi in range(m):
        state.record_training(mi, mi % n, dsi[mi % n], float(sizes[mi % n]))
    return state


def _hoplist(plan):
    return [(h.model, h.src, h.dst, h.round_index) for h in plan.hops]


@pytest.mark.parametrize("seed", range(3))
def test_host_vs_jax_planner_parity_default_config(seed):
    """Default feddif planner knobs (ε=0.04, γ_min=1, N=M=10): both modes
    must emit identical hop lists and identical post-plan states."""
    n = m = c = 10
    rng = np.random.default_rng(seed)
    dsi = rng.dirichlet(np.ones(c) * 0.5, n).astype(np.float32)
    sizes = rng.integers(200, 800, n).astype(np.float64)
    pos = CellTopology().sample_positions(np.random.default_rng(seed + 50), n)

    st_h = _mkstate(n, m, c, dsi, sizes)
    plan_h = DiffusionPlanner().plan_communication_round(
        st_h, dsi, sizes, np.random.default_rng(seed + 7), positions=pos)

    st_j = _mkstate(n, m, c, dsi, sizes)
    plan_j = DiffusionPlanner(mode="jax").plan_communication_round(
        st_j, dsi, sizes, np.random.default_rng(seed + 7), positions=pos)

    assert plan_h.num_rounds == plan_j.num_rounds
    assert _hoplist(plan_h) == _hoplist(plan_j)
    assert plan_h.num_rounds > 0          # a real plan, not a vacuous pass
    for hh, hj in zip(plan_h.hops, plan_j.hops):
        assert hj.gamma == pytest.approx(hh.gamma, rel=0, abs=0)
        assert hj.bandwidth == pytest.approx(hh.bandwidth, rel=0, abs=0)
    np.testing.assert_array_equal(st_h.holder, st_j.holder)
    np.testing.assert_array_equal(st_h.visited, st_j.visited)
    # XLA fuses the Eq.-2 chain inside the jitted loop, so the DoLs may
    # drift by float32 ulps; the *decisions* above must still coincide.
    np.testing.assert_allclose(st_h.dol, st_j.dol, rtol=3e-5, atol=1e-7)
    assert st_h.round_index == st_j.round_index


def test_host_vs_jax_end_to_end_ledger_parity():
    """Full feddif experiment, planner='host' vs 'jax': same accuracy curve
    and a bit-identical ResourceLedger (schedules coincide hop for hop)."""
    from repro.fl.experiment import ExperimentSpec, run_experiment
    from repro.fl.server import FLConfig
    spec = ExperimentSpec(
        task="fcn", alpha=0.5, num_samples=400,
        fl=FLConfig(strategy="feddif", rounds=2, num_clients=4, num_models=4,
                    seed=0, topology_seed=3, max_diffusion_rounds=8))
    r_host = run_experiment(spec)
    spec_j = dataclasses.replace(
        spec, fl=dataclasses.replace(spec.fl, planner="jax"))
    r_jax = run_experiment(spec_j)
    assert r_host.ledger.as_dict() == r_jax.ledger.as_dict()
    assert r_host.accuracy == r_jax.accuracy
    assert r_host.diffusion_rounds == r_jax.diffusion_rounds


def test_batched_preplan_matches_per_round_plans():
    """prepopulate_plan_cache must store plans the per-round jax (and host)
    path reproduces: a sweep run with a pre-populated cache sees zero
    misses and charges the same ledger as an uncached host run."""
    from repro.experiments import run_sweep
    art = run_sweep("fig5_gamma_min", smoke=True, seeds=(0,), out_dir=None,
                    planner="jax", num_samples=300)
    assert art["planner"] == "jax"
    assert art["plan_cache"]["misses"] == 0
    assert art["plan_cache"]["hits"] > 0
    host = run_sweep("fig5_gamma_min", smoke=True, seeds=(0,), out_dir=None,
                     planner="host", num_samples=300)
    for cj, ch in zip(art["cells"], host["cells"]):
        assert cj["comm"] == ch["comm"]
        assert cj["accuracy"] == ch["accuracy"]


def test_channel_jax_twins_match_numpy():
    rng = np.random.default_rng(0)
    topo, chan = CellTopology(), ChannelModel()
    pos = topo.sample_positions(rng, 8)
    dist = topo.pairwise_distances(pos)
    np.testing.assert_allclose(np.asarray(topo.pairwise_distances_jax(pos)),
                               dist, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(chan.large_scale_db_jax(dist)),
                               chan.large_scale_db(dist), rtol=1e-5)
    gains = chan.sample_gains(dist, rng)
    np.testing.assert_allclose(np.asarray(chan.snr_jax(gains)),
                               chan.snr(gains), rtol=1e-5)
    snr = chan.snr(gains)
    np.testing.assert_allclose(np.asarray(spectral_efficiency_jax(snr)),
                               spectral_efficiency(snr), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(required_bandwidth_jax(1e6, spectral_efficiency(snr))),
        required_bandwidth(1e6, spectral_efficiency(snr)), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(outage_probability_jax(1.0, snr)),
                               outage_probability(1.0, snr),
                               rtol=1e-5, atol=1e-9)
    # device-keyed draws: right shape/positivity, deterministic per key
    key = jax.random.PRNGKey(0)
    g1 = chan.sample_gains_jax(key, jnp.asarray(dist))
    g2 = chan.sample_gains_jax(key, jnp.asarray(dist))
    assert g1.shape == dist.shape and bool(jnp.all(g1 > 0))
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
    p1 = topo.sample_positions_jax(key, 8)
    assert p1.shape == (8, 2)
    assert bool(jnp.all(jnp.linalg.norm(p1, axis=-1) <= topo.radius_m + 1e-3))


def test_planner_state_matches_mutable_state():
    """PlannerState.record_training / record_round mirror the mutable
    DiffusionState bookkeeping bit for bit."""
    n, m, c = 5, 4, 6
    rng = np.random.default_rng(1)
    dsi = rng.dirichlet(np.ones(c), n).astype(np.float32)
    sizes = rng.integers(50, 200, n).astype(np.float64)
    host = DiffusionState.init(m, n, c)
    fstate = PlannerState.init(m, n, c)
    for mi in range(m):
        host.record_training(mi, mi % n, dsi[mi % n], float(sizes[mi % n]))
        fstate = fstate.record_training(mi, mi % n, dsi[mi % n],
                                        float(sizes[mi % n]))
    np.testing.assert_allclose(np.asarray(fstate.dol), host.dol, atol=0)
    np.testing.assert_array_equal(np.asarray(fstate.holder), host.holder)
    # one masked round: models 0 and 2 hop
    dst = np.array([3, 0, 4, 0])
    mask = np.array([True, False, True, False])
    fstate2 = fstate.record_round(jnp.asarray(dst), jnp.asarray(mask),
                                  jnp.asarray(dsi), jnp.asarray(sizes))
    for mi in range(m):
        if mask[mi]:
            host.record_training(mi, int(dst[mi]), dsi[dst[mi]],
                                 float(sizes[dst[mi]]))
    np.testing.assert_allclose(np.asarray(fstate2.dol), host.dol, atol=0)
    np.testing.assert_array_equal(np.asarray(fstate2.visited), host.visited)
    np.testing.assert_array_equal(np.asarray(fstate2.holder), host.holder)
    # functional() / update_from round-trip
    host2 = DiffusionState.init(m, n, c)
    host2.update_from(fstate2, rounds_advanced=1)
    np.testing.assert_allclose(host2.dol, host.dol, atol=0)
    assert host2.round_index == 1


def test_as_permutations_keeps_never_hopping_models():
    """Satellite fix: M must come from the planner, not max(h.model)+1 —
    otherwise models that never hop vanish from slot bookkeeping."""
    hop = DiffusionHop(model=0, src=0, dst=2, gamma=1.0, bandwidth=1.0,
                       decrement=0.1, round_index=0)
    plan = DiffusionPlan(hops=[hop], num_rounds=1,
                         final_iid_distance=np.zeros(3),
                         efficiency_per_round=[0.1], num_models=3)
    assert plan.num_models == 3
    perms = plan.as_permutations(3)
    assert len(perms) == 1
    perm, mask = perms[0]
    assert sorted(perm.tolist()) == [0, 1, 2]
    assert mask.tolist() == [False, False, True]
    # explicit override beats the stored value
    perms2 = plan.as_permutations(3, num_models=3)
    assert perms2[0][0].tolist() == perm.tolist()
    # a plan produced by the planner records M even when some models idle
    rng = np.random.default_rng(0)
    n, m, c = 6, 3, 5
    dsi = rng.dirichlet(np.ones(c), n).astype(np.float32)
    sizes = rng.integers(100, 300, n).astype(np.float64)
    state = _mkstate(n, m, c, dsi, sizes)
    p = DiffusionPlanner(epsilon=0.04, max_rounds=4).plan_communication_round(
        state, dsi, sizes, rng)
    assert p.num_models == m


def test_jax_planner_cache_roundtrip():
    """jax plans store/replay through PlanCache like host plans do."""
    n = m = c = 6
    rng = np.random.default_rng(2)
    dsi = rng.dirichlet(np.ones(c), n).astype(np.float32)
    sizes = rng.integers(100, 400, n).astype(np.float64)
    pos = CellTopology().sample_positions(np.random.default_rng(9), n)
    cache = PlanCache()
    key = ("k", 0)
    planner = DiffusionPlanner(mode="jax", max_rounds=8)
    st1 = _mkstate(n, m, c, dsi, sizes)
    plan1 = planner.plan_communication_round(
        st1, dsi, sizes, np.random.default_rng(3), positions=pos,
        cache=cache, cache_key=key)
    st2 = _mkstate(n, m, c, dsi, sizes)
    plan2 = planner.plan_communication_round(
        st2, dsi, sizes, np.random.default_rng(99), positions=pos,
        cache=cache, cache_key=key)        # different rng: must be a replay
    assert cache.hits == 1
    assert _hoplist(plan1) == _hoplist(plan2)
    np.testing.assert_array_equal(st1.holder, st2.holder)
    assert key in cache                    # __contains__ probe, no miss count
    assert cache.stats()["misses"] == 1
