"""Serving engine + sampler + ragged (per-row position) decode tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serving import Request, SamplerConfig, ServingEngine, sample


# ------------------------------------------------------------- sampler

def test_sampler_greedy():
    logits = jnp.asarray([[0.0, 5.0, 1.0], [3.0, 0.0, -1.0]])
    out = sample(jax.random.PRNGKey(0), logits,
                 SamplerConfig(temperature=0.0))
    np.testing.assert_array_equal(np.asarray(out), [1, 0])


def test_sampler_top_k_restricts_support():
    logits = jnp.asarray([[0.0, 10.0, 9.0, -5.0]])
    cfg = SamplerConfig(temperature=1.0, top_k=2)
    draws = {int(sample(jax.random.PRNGKey(s), logits, cfg)[0])
             for s in range(50)}
    assert draws <= {1, 2}


def test_sampler_top_p_restricts_support():
    logits = jnp.asarray([[10.0, 9.5, -10.0, -10.0]])
    cfg = SamplerConfig(temperature=1.0, top_p=0.9)
    draws = {int(sample(jax.random.PRNGKey(s), logits, cfg)[0])
             for s in range(50)}
    assert draws <= {0, 1}


# ------------------------------------------------------- ragged decode

def test_vector_position_decode_matches_scalar():
    cfg = dataclasses.replace(get_smoke_config("qwen3_0_6b"),
                              compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    b, s = 3, 10
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                              cfg.vocab_size)
    cache = model.init_cache(params, b, s)
    ref = []
    for t in range(s):
        lg, cache = model.decode_step(params, toks[:, t:t + 1], cache,
                                      jnp.int32(t))
        ref.append(lg[:, 0])
    ref = jnp.stack(ref, 1)
    # staggered rows decoded with per-row positions
    offsets = np.array([0, 1, 4])
    cache2 = model.init_cache(params, b, s)
    out = jnp.zeros_like(ref)
    for gt in range(s + offsets.max()):
        pos = np.maximum(gt - offsets, 0)
        idx = np.minimum(pos, s - 1)
        xin = jnp.stack([toks[r, idx[r]] for r in range(b)])[:, None]
        lg, cache2 = model.decode_step(params, xin, cache2,
                                       jnp.asarray(pos, jnp.int32))
        for r in range(b):
            p = gt - offsets[r]
            if 0 <= p < s:
                out = out.at[r, p].set(lg[r, 0])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)


# ------------------------------------------------------------- engine

def _engine(num_slots=2, max_seq=32):
    cfg = get_smoke_config("smollm_360m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params, ServingEngine(
        model, params, num_slots=num_slots, max_seq=max_seq,
        sampler=SamplerConfig(temperature=0.0))


def test_engine_completes_more_requests_than_slots():
    cfg, model, params, eng = _engine(num_slots=2)
    rng = np.random.default_rng(0)
    for uid in range(5):
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(0, cfg.vocab_size,
                                               size=4 + uid).astype(np.int32),
                           max_new_tokens=3))
    done = eng.run()
    assert sorted(r.uid for r in done) == [0, 1, 2, 3, 4]
    assert all(len(r.output) == 3 and r.done for r in done)


def test_engine_matches_unbatched_greedy_decode():
    """Slot reuse must not leak state: engine output == standalone greedy."""
    cfg, model, params, eng = _engine(num_slots=2, max_seq=24)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 7, 3)]
    for uid, pr in enumerate(prompts):
        eng.submit(Request(uid=uid, prompt=pr, max_new_tokens=4))
    done = {r.uid: r.output for r in eng.run()}

    for uid, pr in enumerate(prompts):
        cache = model.init_cache(params, 1, 24)
        tok = None
        out = []
        for t in range(len(pr) + 4 - 1):
            x = (jnp.asarray([[pr[t]]], jnp.int32) if t < len(pr)
                 else jnp.asarray([[out[-1]]], jnp.int32))
            lg, cache = model.decode_step(params, x, cache, jnp.int32(t))
            if t >= len(pr) - 1:
                out.append(int(jnp.argmax(lg[0, -1])))
        assert done[uid] == out, f"request {uid} diverged"


def test_engine_rejects_oversized_request():
    cfg, model, params, eng = _engine(max_seq=16)
    with pytest.raises(ValueError):
        eng.submit(Request(uid=0, prompt=np.zeros(20, np.int32),
                           max_new_tokens=4))
