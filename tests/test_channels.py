"""Wireless channel model + resource ledger tests (Sec. III-D, Eq. 39)."""
import numpy as np
import pytest

from repro.channels import (ChannelModel, CellTopology,
                            ResourceLedger, outage_probability,
                            required_bandwidth, spectral_efficiency)


def test_pathloss_monotone_in_distance():
    ch = ChannelModel()
    d = np.array([1.0, 10.0, 100.0, 250.0])
    beta = ch.large_scale_db(d)
    assert (np.diff(beta) < 0).all()


def test_spectral_efficiency_shannon():
    assert spectral_efficiency(np.array(1.0)) == pytest.approx(1.0)
    assert spectral_efficiency(np.array(3.0)) == pytest.approx(2.0)
    assert spectral_efficiency(np.array(0.0)) == pytest.approx(0.0)


def test_required_bandwidth_eq15():
    b = required_bandwidth(1e6, np.array([1.0, 2.0, 0.0]))
    assert b[0] == pytest.approx(1e6)
    assert b[1] == pytest.approx(5e5)
    assert np.isinf(b[2])


def test_outage_probability_eq39():
    # higher mean SNR -> lower outage; gamma_min -> 0 => outage -> 0
    p1 = outage_probability(1.0, 10.0)
    p2 = outage_probability(1.0, 100.0)
    assert 0 <= p2 < p1 < 1
    assert outage_probability(0.0, 10.0) == pytest.approx(0.0)


def test_rayleigh_outage_matches_monte_carlo():
    rng = np.random.default_rng(0)
    mean_snr, gmin = 20.0, 1.5
    h2 = rng.exponential(1.0, 200_000)
    emp = np.mean(np.log2(1 + mean_snr * h2) <= gmin)
    ana = outage_probability(gmin, mean_snr)
    assert emp == pytest.approx(ana, abs=5e-3)


def test_ledger_accounting():
    led = ResourceLedger()
    sf = led.charge_d2d(model_bits=1.8e5, gamma=1.0)   # rate 180 kbit/s
    assert sf == 1000 and led.transmitted_models == 1
    led.charge_uplink(1.8e5, 2.0)
    assert led.uplink_models == 1 and led.subframes == 1500
    led2 = ResourceLedger()
    led2.charge_downlink(1.8e5, 1.0, n_users=10)
    merged = led.merge(led2)
    assert merged.subframes == led.subframes + led2.subframes
    with pytest.raises(ValueError):
        led.charge_d2d(1e5, 0.0)


def test_topology_positions_within_cell():
    topo = CellTopology(radius_m=250.0)
    rng = np.random.default_rng(0)
    pos = topo.sample_positions(rng, 500)
    assert (np.linalg.norm(pos, axis=1) <= 250.0 + 1e-9).all()
    d = topo.pairwise_distances(pos)
    assert d.shape == (500, 500)
    assert (np.diag(d) == 1.0).all()


# ------------------------------------------------- host/jax twin parity

def test_pairwise_distances_host_jax_agree_with_safe_diagonal():
    import jax.numpy as jnp
    topo = CellTopology()
    pos = topo.sample_positions(np.random.default_rng(3), 40)
    d_host = topo.pairwise_distances(pos)
    d_jax = np.asarray(topo.pairwise_distances_jax(jnp.asarray(pos)))
    assert (np.diag(d_host) == 1.0).all()
    assert (np.diag(d_jax) == 1.0).all()
    np.testing.assert_allclose(d_jax, d_host, atol=1e-4)


def test_positions_from_polar_twins_share_the_transform():
    """Feed the SAME polar draws through both array namespaces — any drift
    between the numpy and jnp position math is a direct mismatch here."""
    import jax.numpy as jnp
    rng = np.random.default_rng(9)
    r = 250.0 * np.sqrt(rng.uniform(size=64))
    theta = rng.uniform(0.0, 2 * np.pi, size=64)
    p_np = CellTopology.positions_from_polar(r, theta, np)
    p_jnp = np.asarray(CellTopology.positions_from_polar(
        jnp.asarray(r), jnp.asarray(theta), jnp))
    np.testing.assert_allclose(p_jnp, p_np, atol=1e-4)
    assert (np.linalg.norm(p_np, axis=-1) <= 250.0 + 1e-9).all()


# --------------------------------------------- property tests (hypothesis)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                     # CI installs it; the image may not
    HAVE_HYPOTHESIS = False

    def _identity(f=None, **kw):        # keep the decorators importable
        return f if f is not None else _identity

    given = settings = _identity

    class st:                           # noqa: N801 - stand-in namespace
        floats = staticmethod(lambda *a, **k: None)

needs_hypothesis = pytest.mark.skipif(not HAVE_HYPOTHESIS,
                                      reason="hypothesis not installed")


@needs_hypothesis
@settings(max_examples=50, deadline=None)
@given(r=st.floats(0.0, 250.0), theta=st.floats(0.0, 2 * np.pi))
def test_positions_from_polar_radius_invariant_host_jax(r, theta):
    import jax.numpy as jnp
    p = CellTopology.positions_from_polar(np.array([r]), np.array([theta]))
    assert np.linalg.norm(p[0]) == pytest.approx(r, abs=1e-9 * max(r, 1.0))
    # host/jax twin parity on the SAME polar draw (f32 tolerance)
    pj = np.asarray(CellTopology.positions_from_polar(
        jnp.asarray([r]), jnp.asarray([theta]), jnp))
    np.testing.assert_allclose(pj, p, atol=max(r, 1.0) * 1e-6)


@needs_hypothesis
@settings(max_examples=50, deadline=None)
@given(gmin=st.floats(1e-3, 20.0), snr=st.floats(1e-2, 1e4))
def test_outage_probability_is_a_probability_host_jax(gmin, snr):
    from repro.channels.resources import outage_probability_jax
    p = outage_probability(gmin, snr)
    assert 0.0 <= p <= 1.0
    # monotone: more required rate -> more outage; more SNR -> less
    assert outage_probability(gmin * 2, snr) >= p - 1e-12
    assert outage_probability(gmin, snr * 2) <= p + 1e-12
    assert float(outage_probability_jax(gmin, snr)) == pytest.approx(
        p, abs=1e-6)


@needs_hypothesis
@settings(max_examples=50, deadline=None)
@given(snr=st.floats(0.0, 1e6))
def test_spectral_efficiency_monotone_nonnegative_host_jax(snr):
    from repro.channels.resources import spectral_efficiency_jax
    import jax.numpy as jnp
    g = spectral_efficiency(np.array(snr))
    assert g >= 0.0
    assert spectral_efficiency(np.array(snr + 1.0)) >= g
    assert float(spectral_efficiency_jax(jnp.asarray(snr))) == pytest.approx(
        float(g), rel=1e-5, abs=1e-6)
