"""Wireless channel model + resource ledger tests (Sec. III-D, Eq. 39)."""
import numpy as np
import pytest

from repro.channels import (ChannelModel, CellTopology,
                            ResourceLedger, outage_probability,
                            required_bandwidth, spectral_efficiency)


def test_pathloss_monotone_in_distance():
    ch = ChannelModel()
    d = np.array([1.0, 10.0, 100.0, 250.0])
    beta = ch.large_scale_db(d)
    assert (np.diff(beta) < 0).all()


def test_spectral_efficiency_shannon():
    assert spectral_efficiency(np.array(1.0)) == pytest.approx(1.0)
    assert spectral_efficiency(np.array(3.0)) == pytest.approx(2.0)
    assert spectral_efficiency(np.array(0.0)) == pytest.approx(0.0)


def test_required_bandwidth_eq15():
    b = required_bandwidth(1e6, np.array([1.0, 2.0, 0.0]))
    assert b[0] == pytest.approx(1e6)
    assert b[1] == pytest.approx(5e5)
    assert np.isinf(b[2])


def test_outage_probability_eq39():
    # higher mean SNR -> lower outage; gamma_min -> 0 => outage -> 0
    p1 = outage_probability(1.0, 10.0)
    p2 = outage_probability(1.0, 100.0)
    assert 0 <= p2 < p1 < 1
    assert outage_probability(0.0, 10.0) == pytest.approx(0.0)


def test_rayleigh_outage_matches_monte_carlo():
    rng = np.random.default_rng(0)
    mean_snr, gmin = 20.0, 1.5
    h2 = rng.exponential(1.0, 200_000)
    emp = np.mean(np.log2(1 + mean_snr * h2) <= gmin)
    ana = outage_probability(gmin, mean_snr)
    assert emp == pytest.approx(ana, abs=5e-3)


def test_ledger_accounting():
    led = ResourceLedger()
    sf = led.charge_d2d(model_bits=1.8e5, gamma=1.0)   # rate 180 kbit/s
    assert sf == 1000 and led.transmitted_models == 1
    led.charge_uplink(1.8e5, 2.0)
    assert led.uplink_models == 1 and led.subframes == 1500
    led2 = ResourceLedger()
    led2.charge_downlink(1.8e5, 1.0, n_users=10)
    merged = led.merge(led2)
    assert merged.subframes == led.subframes + led2.subframes
    with pytest.raises(ValueError):
        led.charge_d2d(1e5, 0.0)


def test_topology_positions_within_cell():
    topo = CellTopology(radius_m=250.0)
    rng = np.random.default_rng(0)
    pos = topo.sample_positions(rng, 500)
    assert (np.linalg.norm(pos, axis=1) <= 250.0 + 1e-9).all()
    d = topo.pairwise_distances(pos)
    assert d.shape == (500, 500)
    assert (np.diag(d) == 1.0).all()
