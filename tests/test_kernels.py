"""Per-kernel allclose sweeps against the ref.py pure-jnp oracles, including
hypothesis property tests (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ssm_scan import ssm_scan_pallas

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------ flash attn

@pytest.mark.parametrize("shape,causal,window,dtype", [
    ((2, 128, 4, 64), True, None, jnp.float32),
    ((1, 200, 2, 32), True, None, jnp.float32),
    ((2, 64, 1, 128), False, None, jnp.float32),
    ((1, 256, 2, 64), True, 64, jnp.float32),
    ((1, 130, 3, 64), True, 32, jnp.float32),
    ((2, 128, 4, 64), True, None, jnp.bfloat16),
])
def test_flash_attention_matches_ref(shape, causal, window, dtype):
    b, s, h, d = shape
    q = jax.random.normal(KEY, shape, dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), shape, dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), shape, dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    atol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=atol)


@given(s=st.integers(16, 150), h=st.integers(1, 3),
       d=st.sampled_from([32, 64]), seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_flash_attention_property(s, h, d, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (1, s, h, d), jnp.float32)
    k = jax.random.normal(k2, (1, s, h, d), jnp.float32)
    v = jax.random.normal(k3, (1, s, h, d), jnp.float32)
    out = flash_attention_pallas(q, k, v, block_q=32, block_k=32,
                                 interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-6)


def test_flash_attention_cross_lengths():
    """Sq != Sk (right-aligned decode-style block)."""
    q = jax.random.normal(KEY, (1, 32, 2, 64), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 128, 2, 64), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 128, 2, 64), jnp.float32)
    out = flash_attention_pallas(q, k, v, block_q=32, block_k=64,
                                 interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-6)


# ------------------------------------------------------------ stc

@pytest.mark.parametrize("n,sparsity", [(4096, 0.01), (10_000, 0.05),
                                        (100_000, 0.001), (555, 0.1)])
def test_stc_matches_ref(n, sparsity):
    x = jax.random.normal(KEY, (n,), jnp.float32)
    out = ops.stc_compress(x, sparsity, implementation="pallas_interpret")
    want = ref.stc_compress_ref(x, sparsity)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-6)


def test_stc_sparsity_level():
    x = jax.random.normal(KEY, (8192,), jnp.float32)
    out = ops.stc_compress(x, 0.01, implementation="pallas_interpret")
    nnz = int(jnp.sum(out != 0))
    assert nnz == max(1, int(8192 * 0.01))
    # ternary: all non-zeros share one magnitude
    vals = np.unique(np.abs(np.asarray(out)[np.asarray(out) != 0]))
    assert len(vals) == 1


@given(seed=st.integers(0, 1000), sparsity=st.sampled_from([0.01, 0.1, 0.5]))
@settings(max_examples=10, deadline=None)
def test_stc_property_preserves_sign(seed, sparsity):
    x = jax.random.normal(jax.random.PRNGKey(seed), (2048,), jnp.float32)
    out = np.asarray(ops.stc_compress(x, sparsity,
                                      implementation="pallas_interpret"))
    xn = np.asarray(x)
    nz = out != 0
    assert (np.sign(out[nz]) == np.sign(xn[nz])).all()


# ------------------------------------------------------------ ssm scan

@pytest.mark.parametrize("shape", [(2, 100, 64, 16), (1, 257, 128, 8),
                                   (3, 64, 32, 4)])
def test_ssm_scan_matches_ref(shape):
    b, s, d, n = shape
    da = jnp.exp(-jax.random.uniform(KEY, shape))
    dbx = jax.random.normal(jax.random.PRNGKey(1), shape)
    out = ssm_scan_pallas(da, dbx, chunk=32, block_d=32, interpret=True)
    want = ref.ssm_scan_ref(da, dbx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@given(s=st.integers(4, 80), d=st.sampled_from([8, 16]),
       seed=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_ssm_scan_property(s, d, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    da = jnp.exp(-jax.random.uniform(k1, (1, s, d, 4)))
    dbx = jax.random.normal(k2, (1, s, d, 4))
    out = ssm_scan_pallas(da, dbx, chunk=16, block_d=8, interpret=True)
    want = ref.ssm_scan_ref(da, dbx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5,
                               rtol=1e-5)


def test_ssm_scan_decay_property():
    """With dbx == 0 and constant a, h_t = a^t · h_0-ish (here 0) — states
    stay exactly zero; with da == 1, states are the prefix sums of dbx."""
    s = 32
    dbx = jax.random.normal(KEY, (1, s, 8, 4))
    ones = jnp.ones((1, s, 8, 4))
    out = ssm_scan_pallas(ones, dbx, chunk=8, block_d=8, interpret=True)
    want = jnp.cumsum(dbx, axis=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5,
                               rtol=1e-5)


def test_ops_dispatch_xla_fallback(monkeypatch):
    """On this CPU container, implementation='auto' must use the oracle
    (absent the REPRO_KERNELS_IMPL override CI's pallas-interpret job sets).
    """
    monkeypatch.delenv("REPRO_KERNELS_IMPL", raising=False)
    q = jax.random.normal(KEY, (1, 16, 1, 32), jnp.float32)
    out = ops.flash_attention(q, q, q, implementation="auto")
    want = ref.flash_attention_ref(q, q, q)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-6)


def test_ops_auto_respects_impl_env(monkeypatch):
    """REPRO_KERNELS_IMPL forces what 'auto' resolves to (CI pallas job)."""
    monkeypatch.setenv("REPRO_KERNELS_IMPL", "pallas_interpret")
    q = jax.random.normal(KEY, (1, 32, 2, 32), jnp.float32)
    out = ops.flash_attention(q, q, q, implementation="auto")
    want = ref.flash_attention_ref(q, q, q)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-6)
    monkeypatch.setenv("REPRO_KERNELS_IMPL", "warp")
    with pytest.raises(ValueError, match="REPRO_KERNELS_IMPL"):
        ops.flash_attention(q, q, q, implementation="auto")
