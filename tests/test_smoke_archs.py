"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train step on CPU, asserting output shapes and no NaNs (deliverable f).
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import build_model
from repro.train import init_train_state, make_train_step, sgd

B, S = 2, 32


def _batch(cfg, key):
    batch = {"tokens": jnp.zeros((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.frontend == "vision":
        batch["patch_embeddings"] = jax.random.normal(
            key, (B, cfg.num_frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.num_frontend_tokens, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_reduced_config_bounds(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 4 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    opt = sgd()
    state = init_train_state(model, key, opt)
    batch = _batch(cfg, key)
    step = jax.jit(make_train_step(model, opt))
    state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert jnp.isfinite(loss), f"{arch} produced non-finite loss"
    # a random model on a uniform-ish vocab should start near ln(V)
    assert 0.5 * jnp.log(cfg.vocab_size) < loss < 2.5 * jnp.log(cfg.vocab_size)
    for leaf in jax.tree.leaves(state.params):
        assert jnp.isfinite(leaf).all(), f"{arch} param NaN after step"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    max_seq = 64
    if cfg.family == "audio":
        frames = jax.random.normal(
            key, (B, cfg.num_frontend_tokens, cfg.d_model), jnp.bfloat16)
        cache = model.init_cache(params, frames, B, max_seq)
    else:
        cache = model.init_cache(params, B, max_seq)
    tok = jnp.ones((B, 1), jnp.int32)
    logits, cache2 = model.decode_step(params, tok, cache, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert jnp.isfinite(logits).all()
    logits3, _ = model.decode_step(params, tok, cache2, jnp.int32(1))
    assert jnp.isfinite(logits3).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_configs_match_assignment(arch):
    """The FULL configs carry the exact assigned geometry (exercised only
    via the dry-run — never instantiated here)."""
    cfg = get_config(arch)
    expected = {
        "qwen3_moe_235b_a22b": (94, 4096, 64, 4, 151936),
        "moonshot_v1_16b_a3b": (48, 2048, 16, 16, 163840),
        "gemma3_4b": (34, 2560, 8, 4, 262144),
        "mixtral_8x22b": (56, 6144, 48, 8, 32768),
        "smollm_360m": (32, 960, 15, 5, 49152),
        "pixtral_12b": (40, 5120, 32, 8, 131072),
        "qwen3_0_6b": (28, 1024, 16, 8, 151936),
        "whisper_base": (6, 512, 8, 8, 51865),
        "zamba2_2_7b": (54, 2560, 32, 32, 32000),
        "falcon_mamba_7b": (64, 4096, 1, 1, 65024),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.vocab_size)
    assert got == expected
    if arch == "qwen3_moe_235b_a22b":
        assert (cfg.moe.num_experts, cfg.moe.top_k,
                cfg.moe.d_ff_expert) == (128, 8, 1536)
    if arch == "moonshot_v1_16b_a3b":
        assert (cfg.moe.num_experts, cfg.moe.top_k,
                cfg.moe.d_ff_expert) == (64, 6, 1408)
    if arch == "mixtral_8x22b":
        assert (cfg.moe.num_experts, cfg.moe.top_k,
                cfg.moe.d_ff_expert) == (8, 2, 16384)
    if arch == "zamba2_2_7b":
        assert cfg.ssm.d_state == 64
    if arch == "falcon_mamba_7b":
        assert cfg.ssm.d_state == 16 and cfg.d_ff == 0
    if arch == "gemma3_4b":
        assert cfg.local_global_ratio == 5 and cfg.d_ff == 10240


@pytest.mark.parametrize("arch", ["smollm_360m", "falcon_mamba_7b",
                                  "whisper_base"])
def test_input_specs_shapes(arch):
    from repro.configs import SHAPES
    cfg = get_config(arch)
    model = build_model(cfg)
    for name, shp in SHAPES.items():
        specs = model.input_specs(shp)
        if shp.mode == "decode":
            assert specs["tokens"].shape == (shp.global_batch, 1)
        else:
            assert specs["tokens"].shape == (shp.global_batch, shp.seq_len)
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)
