"""Sweep registry / orchestrator / plan-cache tests (repro.experiments)."""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.core.diffusion import DiffusionPlanner, PlanCache, plan_cache_key
from repro.core.dol import DiffusionState
from repro.experiments import (REGISTRY, SEED_VMAP_STRATEGIES, bench_path,
                               expand_sweep, run_sweep, sweep_names)
from repro.experiments.replicate import (run_replicates_loop,
                                         run_replicates_vmapped)
from repro.fl.experiment import ExperimentSpec
from repro.fl.models import TASK_MODELS
from repro.fl.server import STRATEGIES


# ------------------------------------------------------------------ registry

def test_registry_has_all_paper_sweeps():
    assert set(sweep_names()) >= {"fig3_alpha", "fig4_epsilon",
                                  "fig5_gamma_min", "fig6_tasks",
                                  "table2_strategies"}


@pytest.mark.parametrize("name", sorted(REGISTRY))
@pytest.mark.parametrize("smoke", [True, False])
def test_every_sweep_expands_to_valid_specs(name, smoke):
    cells = expand_sweep(name, smoke=smoke)
    assert cells, name
    labels = [c.label for c in cells]
    assert len(set(labels)) == len(labels), "cell labels must be unique"
    for c in cells:
        assert isinstance(c.spec, ExperimentSpec)
        assert c.spec.fl.strategy in STRATEGIES
        assert c.spec.task in TASK_MODELS
        assert c.spec.alpha > 0
        assert c.spec.fl.rounds >= 1
        assert c.spec.fl.topology_seed is not None
        # The axis value must actually land on the spec.
        got = {"alpha": c.spec.alpha, "epsilon": c.spec.fl.epsilon,
               "gamma_min": c.spec.fl.gamma_min, "task": c.spec.task,
               "strategy": c.spec.fl.strategy,
               "num_clients": c.spec.fl.num_clients,
               "scenario": c.spec.fl.scenario,
               "engine": c.spec.fl.engine}[c.axis]
        assert got == c.value
        if c.axis == "num_clients":   # scaling sweeps keep M = N
            assert c.spec.fl.num_models == c.value


def test_smoke_grid_is_subset_of_full_grid():
    for name in sweep_names():
        d = REGISTRY[name]
        assert set(d.smoke_values) <= set(d.values)


def test_table2_strategy_axis_has_at_least_three_points():
    d = REGISTRY["table2_strategies"]
    assert len(d.values) >= 3
    assert "d2d_random_walk" in d.values
    assert "feddif" in d.values and "fedavg" in d.values


def test_fig7_scaling_targets_large_n_with_churn():
    d = REGISTRY["fig7_scaling"]
    assert d.axis == "num_clients"
    assert max(d.values) >= 256 and max(d.smoke_values) >= 64
    assert d.fl_overrides.get("churn_rate", 0) > 0
    cells = expand_sweep("fig7_scaling", smoke=True, executor="sharded")
    assert all(c.spec.fl.executor == "sharded" for c in cells)
    assert all(c.spec.fl.churn_rate > 0 for c in cells)


def test_fig7_full_grid_drops_auction_strategies_at_large_n():
    """At N ≥ 1024 the Hungarian auction control plane is O(N³); the sweep's
    ``value_strategies`` override keeps only the auction-free strategies
    there while the ≤256 points still compare against feddif."""
    cells = expand_sweep("fig7_scaling", smoke=False)
    by_n = {}
    for c in cells:
        by_n.setdefault(c.value, set()).add(c.strategy)
    assert max(by_n) >= 4096
    for n, strategies in by_n.items():
        if n >= 1024:
            assert "feddif" not in strategies, n
            assert "d2d_random_walk" in strategies, n
        else:
            assert "feddif" in strategies, n


def test_auto_engine_downgrades_sharded_below_crossover():
    """engine="auto" swaps sharded→fleet under the measured N-crossover (the
    mesh dispatch overhead regime) and keeps sharded at/above it; the chosen
    executor lands in the cell record."""
    from repro.experiments.orchestrator import (SHARDED_CROSSOVER_N,
                                                _pick_executor)
    cells = expand_sweep("fig7_scaling", smoke=True, executor="sharded")
    for cell in cells:
        picked = _pick_executor(cell, "auto")
        want = ("fleet" if cell.spec.fl.num_clients < SHARDED_CROSSOVER_N
                else "sharded")
        assert picked.spec.fl.executor == want, cell.label
        # explicit engines leave the user's executor choice alone
        assert _pick_executor(cell, "loop").spec.fl.executor == "sharded"


def test_run_cell_records_downgraded_executor():
    from repro.experiments.orchestrator import run_cell
    cell = next(c for c in expand_sweep(
        "fig7_scaling", smoke=True, executor="sharded", num_samples=400)
        if c.strategy == "fedavg" and c.value == 20)
    cell = dataclasses.replace(
        cell, spec=dataclasses.replace(
            cell.spec, fl=dataclasses.replace(cell.spec.fl, rounds=1)))
    rec = run_cell(cell, seeds=(0,))
    assert rec["executor"] == "fleet"


def test_churned_cells_replicate_on_loop_engine():
    """Churn masks are applied schedule-side in run_federated; the seed_vmap
    engine would skip them, so engine picking must route to the loop."""
    from repro.experiments.orchestrator import _pick_engine
    cell = next(c for c in expand_sweep("fig7_scaling", smoke=True)
                if c.strategy == "fedavg")
    assert _pick_engine(cell, "auto") == "loop"
    with pytest.raises(ValueError, match="churn"):
        run_replicates_vmapped(cell.spec, (0,))


def test_expand_overrides_reach_spec():
    cells = expand_sweep("fig3_alpha", smoke=True, num_samples=123)
    assert all(c.spec.num_samples == 123 for c in cells)


# ---------------------------------------------------------------- plan cache

def _tiny_partition(n=4, c=5, seed=0):
    rng = np.random.default_rng(seed)
    dsi = rng.dirichlet(np.ones(c), size=n).astype(np.float32)
    sizes = rng.integers(50, 100, size=n).astype(np.float64)
    return dsi, sizes


def _seed_state(m, n, dsi, sizes):
    state = DiffusionState.init(m, n, dsi.shape[1])
    for mi in range(m):
        h = int(state.holder[mi])
        state.record_training(mi, h, dsi[h], float(sizes[h]))
    return state


def test_plan_cache_hit_replays_plan_and_state():
    dsi, sizes = _tiny_partition()
    n = m = 4
    cache = PlanCache()
    key = plan_cache_key(7, 0, dsi, sizes, 0.04, 1.0, "w1_norm",
                         extra=(n, m))

    planner = DiffusionPlanner(epsilon=0.04)
    s1 = _seed_state(m, n, dsi, sizes)
    rng1 = np.random.default_rng([7, 0])
    plan1 = planner.plan_communication_round(s1, dsi, sizes, rng1,
                                             cache=cache, cache_key=key)
    assert cache.stats() == {"hits": 0, "misses": 1, "entries": 1}

    s2 = _seed_state(m, n, dsi, sizes)
    rng2 = np.random.default_rng([7, 0])
    plan2 = planner.plan_communication_round(s2, dsi, sizes, rng2,
                                             cache=cache, cache_key=key)
    assert cache.stats()["hits"] == 1
    assert plan2 is plan1                      # replayed, not replanned
    np.testing.assert_array_equal(s1.holder, s2.holder)
    np.testing.assert_allclose(s1.dol, s2.dol)
    np.testing.assert_array_equal(s1.visited, s2.visited)


def test_plan_cache_key_distinguishes_inputs():
    dsi, sizes = _tiny_partition()
    k1 = plan_cache_key(0, 0, dsi, sizes, 0.04, 1.0, "w1_norm")
    assert k1 == plan_cache_key(0, 0, dsi.copy(), sizes.copy(), 0.04, 1.0,
                                "w1_norm")
    assert k1 != plan_cache_key(0, 1, dsi, sizes, 0.04, 1.0, "w1_norm")
    assert k1 != plan_cache_key(0, 0, dsi, sizes, 0.1, 1.0, "w1_norm")
    assert k1 != plan_cache_key(0, 0, dsi, sizes, 0.04, 2.0, "w1_norm")
    dsi2 = dsi.copy()
    dsi2[0, 0] += 0.25
    assert k1 != plan_cache_key(0, 0, dsi2, sizes, 0.04, 1.0, "w1_norm")


def test_plan_cache_lru_eviction():
    dsi, sizes = _tiny_partition()
    cache = PlanCache(max_entries=2)
    planner = DiffusionPlanner(epsilon=0.04)
    for t in range(3):
        key = plan_cache_key(0, t, dsi, sizes, 0.04, 1.0, "w1_norm")
        s = _seed_state(4, 4, dsi, sizes)
        planner.plan_communication_round(s, dsi, sizes,
                                         np.random.default_rng([0, t]),
                                         cache=cache, cache_key=key)
    assert len(cache) == 2


# ------------------------------------------------------- replication engines

def _tiny_cells(name="fig3_alpha"):
    return expand_sweep(name, smoke=True, num_samples=300)


def test_vmapped_and_loop_engines_agree():
    cell = next(c for c in _tiny_cells() if c.strategy == "feddif")
    cache = PlanCache()
    r_v = run_replicates_vmapped(cell.spec, (0,), cache)
    r_l = run_replicates_loop(cell.spec, (0,), cache)
    assert cache.stats()["hits"] >= 1          # loop replayed vmap's plans
    np.testing.assert_allclose(r_v[0].accuracy, r_l[0].accuracy, atol=2e-3)
    assert r_v[0].ledger.as_dict() == r_l[0].ledger.as_dict()


def test_vmapped_engine_rejects_unsupported_strategy():
    cell = next(c for c in _tiny_cells("table2_strategies")
                if c.strategy == "d2d_random_walk")
    assert cell.strategy not in SEED_VMAP_STRATEGIES
    with pytest.raises(ValueError):
        run_replicates_vmapped(cell.spec, (0,))


def test_vmapped_engine_requires_topology_seed():
    cell = next(c for c in _tiny_cells() if c.strategy == "fedavg")
    spec = dataclasses.replace(
        cell.spec, fl=dataclasses.replace(cell.spec.fl, topology_seed=None))
    with pytest.raises(ValueError):
        run_replicates_vmapped(spec, (0,))


def test_replicate_seeds_differ_on_data_plane():
    cell = next(c for c in _tiny_cells() if c.strategy == "fedavg")
    r = run_replicates_vmapped(cell.spec, (0, 1))
    assert r[0].config.seed == 0 and r[1].config.seed == 1
    # Same communication (control plane shared) ...
    assert r[0].ledger.as_dict() == r[1].ledger.as_dict()
    # ... but different models (init seeds differ).
    assert r[0].accuracy != r[1].accuracy


# ----------------------------------------------------------- end-to-end + IO

def test_smallest_sweep_end_to_end_writes_valid_artifact(tmp_path):
    art = run_sweep("fig5_gamma_min", smoke=True, seeds=(0,),
                    out_dir=str(tmp_path), num_samples=300)
    path = bench_path("fig5_gamma_min", str(tmp_path))
    assert art["path"] == path
    on_disk = json.load(open(path))
    assert on_disk["sweep"] == "fig5_gamma_min"
    assert on_disk["axis"] == "gamma_min"
    assert on_disk["mode"] == "smoke"
    assert on_disk["plan_cache"]["misses"] >= 1
    assert len(on_disk["cells"]) == len(REGISTRY["fig5_gamma_min"]
                                        .smoke_values)
    assert on_disk["executor"] == "host"
    for c in on_disk["cells"]:
        assert c["accuracy"] and c["accuracy"][0], "per-seed accuracy curve"
        assert c["summary"]["peak_mean"] is not None
        assert c["comm"]["subframes"] > 0
        assert "pusch_bandwidth_hz_s" in c["comm"]
        assert c["wall_clock_s"] >= 0
        assert c["executor"] == "host"
        # per-cell plan-cache delta (sweep cache efficacy trajectory)
        pc = c["plan_cache"]
        assert set(pc) == {"hits", "misses", "entries"}
        assert pc["hits"] + pc["misses"] >= 1


# ------------------------------------------------- durability: RNG streams

def test_checkpoint_audits_every_rng_stream_position(tmp_path, monkeypatch):
    """The round checkpoint must carry every RNG stream position the run
    consumes: the per-client data-shuffle cursors and the model-seed
    bit-generator state.  (The control-plane and churn streams are stateless
    ``[seed, t, tag]`` draws and need no stored position.)  An interrupted
    run's checkpoint at step k must equal a clean run's checkpoint at the
    same step, byte for byte on these fields."""
    from repro.fl.experiment import run_experiment
    from repro.fl.resume import Preempted, RoundCheckpointer
    from repro.fl.server import FLConfig
    from repro.train import load_metadata, valid_steps

    fl = FLConfig(strategy="feddif", num_clients=4, num_models=4, rounds=3,
                  topology_seed=None, churn_rate=0.25, batch_size=8,
                  checkpoint_every=1, local_epochs=2)
    spec = ExperimentSpec(task="logistic", num_samples=400, fl=fl)

    clean_dir = str(tmp_path / "clean")
    run_experiment(spec, checkpoint_dir=clean_dir)

    killed_dir = str(tmp_path / "killed")
    with monkeypatch.context() as m:
        m.setattr(RoundCheckpointer, "fail_after_save", 1)
        with pytest.raises(Preempted):
            run_experiment(spec, checkpoint_dir=killed_dir)
    run_experiment(spec, checkpoint_dir=killed_dir)

    steps = valid_steps(clean_dir)
    assert steps and steps == valid_steps(killed_dir)
    for step in steps:
        a = load_metadata(clean_dir, step)
        b = load_metadata(killed_dir, step)
        # data-shuffle stream: per-client epoch cursors, advanced by
        # local_epochs per training session — nonzero and exactly restored
        assert a["extra"]["loader_epochs"] == b["extra"]["loader_epochs"]
        assert any(e > 0 for e in a["extra"]["loader_epochs"])
        # model-seed stream: full PCG64 bit-generator state (exact 128-bit
        # ints — JSON carries Python ints losslessly)
        assert a["rng_state"] == b["rng_state"]
        # and the cumulative Eq.-15 ledger
        assert a["ledger"] == b["ledger"]


def test_loader_epoch_cursor_replays_batch_order():
    from repro.data.pipeline import ClientLoader

    x = np.arange(40, dtype=np.float32).reshape(20, 2)
    y = np.arange(20) % 4
    a = ClientLoader(x, y, batch_size=4, seed=11)
    for _ in range(3):
        list(a.epoch())
    assert a.epochs_drawn == 3
    reference = [b["x"].tolist() for b in a.epoch()]

    b = ClientLoader(x, y, batch_size=4, seed=11)
    b.seek(3)                       # resume path repositions the stream
    replay = [bb["x"].tolist() for bb in b.epoch()]
    assert replay == reference


def test_plan_cache_state_dict_roundtrip_replays():
    """PlanCache state_dict/load_state_dict round-trips entries, counters
    and plan contents — the durable sweep's plan_cache.json contract."""
    from repro.core.diffusion import PlanCache

    cache = PlanCache()
    cell = next(c for c in _tiny_cells() if c.strategy == "feddif")
    run_replicates_loop(cell.spec, (0,), cache)
    assert cache.stats()["entries"] >= 1

    state = json.loads(json.dumps(cache.state_dict()))   # disk round-trip
    restored = PlanCache.from_state_dict(state)
    assert restored.stats() == cache.stats()

    # replaying from the restored cache reproduces the identical run
    r_orig = run_replicates_loop(cell.spec, (0,), PlanCache())
    r_rest = run_replicates_loop(cell.spec, (0,), restored)
    assert r_rest[0].accuracy == r_orig[0].accuracy
    assert r_rest[0].ledger == r_orig[0].ledger


# --------------------------------------------- durability: artifact writes

def test_bench_write_is_atomic_under_partial_write(tmp_path, monkeypatch):
    """Kill the writer mid-serialization: the previous artifact must remain
    intact on disk (temp+rename — no torn JSON)."""
    import repro.train.checkpoint as ckpt_mod
    from repro.experiments.artifacts import bench_file, write_bench_json

    write_bench_json("torn", {"generation": 1}, str(tmp_path))
    real_dump = json.dump

    def dying_dump(obj, f, **kw):
        f.write('{"generation": 2, "partial": [1, 2')   # torn bytes
        raise OSError("disk full mid-write")

    with monkeypatch.context() as m:
        m.setattr(ckpt_mod.json, "dump", dying_dump)
        with pytest.raises(OSError):
            write_bench_json("torn", {"generation": 2}, str(tmp_path))

    with open(bench_file("torn", str(tmp_path))) as f:
        assert json.load(f) == {"generation": 1}        # old bytes intact
    assert not [p for p in os.listdir(tmp_path) if p.endswith(".tmp")]
    assert json.dump is real_dump


def test_artifact_always_reports_failed_cells(tmp_path):
    art = run_sweep("fig5_gamma_min", smoke=True, seeds=(0,),
                    out_dir=str(tmp_path), num_samples=300)
    assert art["failed_cells"] == []                    # key always present
    on_disk = json.load(open(bench_path("fig5_gamma_min", str(tmp_path))))
    assert on_disk["failed_cells"] == []


def test_strip_volatile_drops_only_run_dependent_fields(tmp_path):
    from repro.experiments import strip_volatile
    art = run_sweep("fig5_gamma_min", smoke=True, seeds=(0,),
                    out_dir=str(tmp_path), num_samples=300)
    s = strip_volatile(art)
    for k in ("created_unix", "wall_clock_s", "plan_cache", "path"):
        assert k not in s
    for c in s["cells"]:
        assert "wall_clock_s" not in c and "plan_cache" not in c
        assert c["comm"]["subframes"] > 0               # physics retained
    assert s["failed_cells"] == []
