"""Durability tests for repro.train.checkpoint — the substrate the FL sweep
resume path (repro.fl.resume / repro.experiments.durability) rides on.

Covers the contract spelled out in the module docstring: atomic temp+rename
writes, the metadata-JSON commit marker, non-uniform pytree round-trips,
``valid_steps``/``latest_step`` ordering, and ``restore_latest``'s loud
fallback past truncated/corrupt checkpoints (never a silent wrong restore).
"""
import json
import os

import jax
import numpy as np
import pytest

from repro.train import (atomic_write_json, latest_step, load_metadata,
                         restore_checkpoint, restore_latest, save_checkpoint,
                         valid_steps)


def _tree_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return (len(la) == len(lb)
            and all(np.array_equal(np.asarray(x), np.asarray(y))
                    and np.asarray(x).dtype == np.asarray(y).dtype
                    for x, y in zip(la, lb)))


def _mixed_tree():
    """Non-uniform pytree: nested dicts, a list, mixed dtypes, a 0-d leaf."""
    return {
        "params": [{"w": np.arange(12, dtype=np.float32).reshape(3, 4),
                    "b": np.ones(4, np.float64)},
                   {"w": np.full((2, 2), -3, np.int32)}],
        "counters": {"steps": np.array(17, np.int64),
                     "mask": np.array([True, False, True])},
    }


# ------------------------------------------------------------- round-trips

def test_nonuniform_pytree_roundtrip(tmp_path):
    tree = _mixed_tree()
    save_checkpoint(str(tmp_path), 5, tree, metadata={"note": "x"})
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    out = restore_checkpoint(str(tmp_path), 5, like)
    assert _tree_equal(tree, out)
    assert load_metadata(str(tmp_path), 5)["note"] == "x"
    assert load_metadata(str(tmp_path), 5)["step"] == 5


def test_restore_validates_shape_and_structure(tmp_path):
    tree = _mixed_tree()
    save_checkpoint(str(tmp_path), 1, tree)
    bad = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((7,) + x.shape, x.dtype), tree)
    with pytest.raises(ValueError, match="shape"):
        restore_checkpoint(str(tmp_path), 1, bad)
    with pytest.raises(KeyError, match="missing leaf"):
        restore_checkpoint(str(tmp_path), 1, {"other": tree["counters"]})


def test_no_temp_debris_after_saves(tmp_path):
    for step in (1, 2, 3):
        save_checkpoint(str(tmp_path), step, _mixed_tree())
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]


# ------------------------------------------------------- step enumeration

def test_valid_steps_and_latest_step_ordering(tmp_path):
    tree = {"x": np.zeros(2)}
    for step in (3, 10, 2):          # written out of order
        save_checkpoint(str(tmp_path), step, tree)
    assert valid_steps(str(tmp_path)) == [2, 3, 10]
    assert latest_step(str(tmp_path)) == 10
    assert valid_steps(str(tmp_path / "nope")) == []
    assert latest_step(str(tmp_path / "nope")) is None


def test_npz_without_commit_marker_is_invisible(tmp_path):
    """A kill between the npz write and the metadata write leaves an orphan
    npz; valid_steps must not report it and restore_latest must skip it."""
    tree = {"x": np.arange(3.0)}
    save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 2, tree)
    os.remove(tmp_path / "ckpt_00000002.json")     # simulate the torn pair
    assert valid_steps(str(tmp_path)) == [1]
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        tree)
    step, out, _ = restore_latest(str(tmp_path), like)
    assert step == 1 and _tree_equal(tree, out)


# ----------------------------------------------------- corruption fallback

def test_restore_latest_falls_back_past_truncated_npz(tmp_path):
    tree = {"x": np.arange(8.0), "y": {"z": np.ones((2, 2), np.int32)}}
    save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 2, tree)
    npz2 = tmp_path / "ckpt_00000002.npz"
    npz2.write_bytes(npz2.read_bytes()[:40])       # truncate mid-zip
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        tree)
    with pytest.warns(RuntimeWarning, match="unreadable"):
        step, out, meta = restore_latest(str(tmp_path), like)
    assert step == 1
    assert _tree_equal(tree, out)
    assert meta["step"] == 1


def test_restore_latest_falls_back_past_corrupt_metadata(tmp_path):
    tree = {"x": np.arange(4.0)}
    save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 2, tree)
    (tmp_path / "ckpt_00000002.json").write_text("{not json")
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        tree)
    with pytest.warns(RuntimeWarning, match="unreadable"):
        step, out, _ = restore_latest(str(tmp_path), like)
    assert step == 1 and _tree_equal(tree, out)


def test_restore_latest_returns_none_when_nothing_readable(tmp_path):
    like = {"x": jax.ShapeDtypeStruct((2,), np.float32)}
    assert restore_latest(str(tmp_path / "empty"), like) is None
    tree = {"x": np.zeros(2, np.float32)}
    save_checkpoint(str(tmp_path), 1, tree)
    (tmp_path / "ckpt_00000001.npz").write_bytes(b"garbage")
    with pytest.warns(RuntimeWarning, match="unreadable"):
        assert restore_latest(str(tmp_path), like) is None


# ------------------------------------------------------- atomic JSON write

def test_atomic_write_json_roundtrip_and_replace(tmp_path):
    path = str(tmp_path / "doc.json")
    atomic_write_json(path, {"a": 1})
    atomic_write_json(path, {"a": 2, "b": [1, 2, 3]}, indent=2)
    with open(path) as f:
        assert json.load(f) == {"a": 2, "b": [1, 2, 3]}
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]


def test_atomic_write_json_failure_preserves_old_contents(tmp_path):
    """A writer that dies mid-serialization must leave the previous document
    intact — the temp file never replaces the target."""
    path = str(tmp_path / "doc.json")
    atomic_write_json(path, {"good": True})

    class Unserializable:
        pass

    with pytest.raises(TypeError):
        atomic_write_json(path, {"bad": Unserializable()})
    with open(path) as f:
        assert json.load(f) == {"good": True}
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]
