"""Buffered-async round plane tests (repro.fl.async_plane).

Pins the PR-9 contracts:

* **Degeneracy bit-identity** — async with K = everything, zero delays and
  the discount off reproduces the sync host executor exactly (params AND
  Eq.-15 ledger) for fedavg and feddif at N = 20.
* **Staleness-weight normalization** — discounted weights renormalize to 1
  inside the Eq.-11 mean (plain numpy sweeps, no hypothesis).
* **Event-queue determinism** — same seed ⇒ identical event order (virtual
  clock, arrival counts, staleness, curves) across runs and across
  ``--resume``.
* **Kill/resume** — the mid-tick pending buffer rides the commit-marker
  protocol: a preempted buffered run resumes bit-identically.
* **Hop parking** — a hop deadline parks late diffusion hops (training
  skipped) while their wire events stay charged.
* **Population sampling** — deterministic availability-weighted cohorts.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.fl.engine import AsyncSpec, EngineSpec
from repro.fl.experiment import ExperimentSpec, run_experiment
from repro.fl.resume import Preempted, RoundCheckpointer
from repro.fl.server import FLConfig


def _spec(strategy="fedavg", n=4, rounds=2, engine=None, **fl_kw):
    return ExperimentSpec(
        task="fcn", alpha=0.5, num_samples=600,
        fl=FLConfig(strategy=strategy, rounds=rounds, num_clients=n,
                    num_models=n, seed=0, topology_seed=0, eval_every=1,
                    engine=engine, **fl_kw))


def _trees_equal(a, b):
    return all((np.asarray(x) == np.asarray(y)).all()
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


_DEGENERATE = EngineSpec(mode="async", data_plane="host")


# ------------------------------------------------------ degeneracy contract

@pytest.mark.parametrize("strategy", ["fedavg", "feddif"])
def test_degenerate_async_bit_identical_to_host_n20(strategy):
    """K = all, zero delays, discount off, host inner plane ⇒ the async
    event queue replays the sync host executor bit for bit at N = 20."""
    host = run_experiment(_spec(strategy, n=20))
    async_ = run_experiment(_spec(strategy, n=20, engine=_DEGENERATE))
    assert _trees_equal(host.params, async_.params)
    assert host.ledger.as_dict() == async_.ledger.as_dict()
    assert host.accuracy == async_.accuracy
    assert host.history.diffusion_rounds == async_.history.diffusion_rounds
    # degenerate ticks: one per round, everything arrives at t=0, fresh
    assert async_.history.virtual_s == [0.0, 0.0]
    assert all(s == 0.0 for s in async_.history.staleness)


def test_degenerate_async_matches_under_churn():
    """apply_round_churn is shared: the masked schedule degenerates too."""
    host = run_experiment(_spec("fedavg", n=6, churn_rate=0.3))
    async_ = run_experiment(
        _spec("fedavg", n=6, churn_rate=0.3, engine=_DEGENERATE))
    assert _trees_equal(host.params, async_.params)
    assert host.ledger.as_dict() == async_.ledger.as_dict()


def test_async_rejects_persistent_and_delta_strategies():
    for strategy in ("gossip", "stc"):
        with pytest.raises(ValueError, match="buffered-async"):
            run_experiment(_spec(strategy, engine=_DEGENERATE))


# ------------------------------------------------ staleness normalization

def test_discounted_weights_renormalize_to_one():
    """Eq.-11 aggregation of constant trees is exactly that constant no
    matter how weights are discounted — numpy sweep over staleness mixes."""
    from repro.fl.async_plane import _Contribution, _discounted_fedavg

    rng = np.random.default_rng(0)
    b = AsyncSpec(staleness_alpha=0.7, staleness_beta=1.3)
    for trial in range(25):
        k = int(rng.integers(1, 9))
        popped = [
            _Contribution(arrival_s=float(rng.random()), seq=i,
                          round=int(rng.integers(0, 5)), slot=i,
                          weight=float(rng.uniform(0.1, 10.0)),
                          tree={"w": np.full((3,), 7.5, np.float32)})
            for i in range(k)]
        tick = 6
        out, stale = _discounted_fedavg(popped, tick, b)
        np.testing.assert_allclose(np.asarray(out["w"]), 7.5, rtol=1e-6)
        assert stale == np.mean([tick - c.round for c in popped])
        # the discounted weights themselves normalize to 1
        w = np.array([c.weight * b.discount(tick - c.round) for c in popped],
                     np.float64)
        np.testing.assert_allclose((w / w.sum()).sum(), 1.0, rtol=1e-12)


def test_zero_weight_tick_leaves_global_unchanged():
    """Empty Dirichlet shards arrive instantly; a tick popping only
    zero-weight contributions must be a no-op, not a ValueError."""
    from repro.fl.async_plane import _Contribution, _discounted_fedavg

    popped = [_Contribution(arrival_s=0.0, seq=i, round=0, slot=i,
                            weight=0.0, tree={"w": np.ones(2, np.float32)})
              for i in range(3)]
    out, stale = _discounted_fedavg(popped, 1, AsyncSpec())
    assert out is None
    assert stale == 1.0


def test_zero_staleness_discount_is_exactly_unity():
    b = AsyncSpec(staleness_alpha=1.0, staleness_beta=0.9)
    # weight * discount(0) must be bitwise w * 1.0 — the degeneracy proof
    for w in np.random.default_rng(1).uniform(0.01, 100.0, 50):
        assert w * b.discount(0) == w


# ------------------------------------------------- event-queue determinism

def test_event_queue_deterministic_across_runs():
    spec = _spec("fedavg", n=6, rounds=3, engine="async", churn_rate=0.05)
    r1 = run_experiment(spec)
    r2 = run_experiment(spec)
    assert r1.history.virtual_s == r2.history.virtual_s
    assert r1.history.arrivals == r2.history.arrivals
    assert r1.history.staleness == r2.history.staleness
    assert r1.accuracy == r2.accuracy
    assert _trees_equal(r1.params, r2.params)


def test_buffered_async_diverges_from_barrier_but_charges_same_ledger():
    """The two preset arms replay identical schedules — identical Eq.-15
    ledgers — while the buffered arm's virtual clock runs ahead."""
    r_barrier = run_experiment(_spec("fedavg", n=6, rounds=3,
                                     engine="async_barrier"))
    r_async = run_experiment(_spec("fedavg", n=6, rounds=3, engine="async"))
    assert r_barrier.ledger.as_dict() == r_async.ledger.as_dict()
    # barrier ticks advance to the slowest arrival; buffered to the K-th
    assert (r_async.history.virtual_s[0]
            < r_barrier.history.virtual_s[0])
    assert max(r_barrier.history.staleness) == 0.0
    assert max(r_async.history.staleness) > 0.0


# ----------------------------------------------------------- kill / resume

def test_async_kill_resume_bit_identical_with_pending_buffer(
        tmp_path, monkeypatch):
    """Preempt mid-run with buffer_k < N (contributions pending in the
    heap at the checkpoint boundary); the resumed run must be bitwise the
    clean run — params, ledger, virtual clock, curves."""
    eng = EngineSpec(mode="async", buffered=AsyncSpec(
        buffer_k=2, staleness_beta=0.5, delay_scale=0.01, delay_sigma=1.0))

    def mkspec():
        return _spec("fedavg", n=4, rounds=4, engine=eng,
                     checkpoint_every=1)

    clean = run_experiment(mkspec(), checkpoint_dir=str(tmp_path / "clean"))
    killed_dir = str(tmp_path / "killed")
    monkeypatch.setattr(RoundCheckpointer, "fail_after_save", 2)
    with pytest.raises(Preempted):
        run_experiment(mkspec(), checkpoint_dir=killed_dir)
    monkeypatch.setattr(RoundCheckpointer, "fail_after_save", None)
    resumed = run_experiment(mkspec(), checkpoint_dir=killed_dir)
    assert _trees_equal(clean.params, resumed.params)
    assert clean.ledger.as_dict() == resumed.ledger.as_dict()
    assert clean.history.virtual_s == resumed.history.virtual_s
    assert clean.history.arrivals == resumed.history.arrivals
    assert clean.accuracy == resumed.accuracy


def test_resume_refuses_changed_engine(tmp_path):
    """The engine fingerprint joins the checkpoint config guard: resuming
    an async run under different async knobs must be refused."""
    eng = EngineSpec(mode="async", buffered=AsyncSpec(buffer_k=2))
    spec = _spec("fedavg", n=4, rounds=4, engine=eng, checkpoint_every=1)
    d = str(tmp_path / "ck")
    monkey = RoundCheckpointer.fail_after_save
    RoundCheckpointer.fail_after_save = 2
    try:
        with pytest.raises(Preempted):
            run_experiment(spec, checkpoint_dir=d)
    finally:
        RoundCheckpointer.fail_after_save = monkey
    other = dataclasses.replace(
        spec, fl=dataclasses.replace(
            spec.fl, engine=EngineSpec(
                mode="async", buffered=AsyncSpec(buffer_k=3))))
    with pytest.raises(ValueError, match="different config"):
        run_experiment(other, checkpoint_dir=d)


# ------------------------------------------------------------- hop parking

def test_hop_deadline_parks_hops_but_charges_full_wire():
    """A tiny hop deadline parks (almost) every diffusion hop's training
    session, yet the wire events stay charged — the ledgers of the parked
    and unparked runs are identical (Eq. 15: stale airtime is airtime)."""
    base = EngineSpec(mode="async", data_plane="host", buffered=AsyncSpec(
        delay_scale=0.01, delay_sigma=0.5))
    tight = dataclasses.replace(base, buffered=dataclasses.replace(
        base.buffered, hop_deadline_s=1e-9))
    free = run_experiment(_spec("d2d_random_walk", n=6, rounds=2,
                                engine=base))
    parked = run_experiment(_spec("d2d_random_walk", n=6, rounds=2,
                                  engine=tight))
    assert sum(free.history.parked_hops) == 0
    assert sum(parked.history.parked_hops) > 0
    assert free.ledger.as_dict() == parked.ledger.as_dict()
    assert not _trees_equal(free.params, parked.params)


# ------------------------------------------------------------- population

def test_population_cohorts_are_deterministic_and_availability_weighted():
    from repro.fl.population import Population

    pop = Population(size=500, num_shards=10, seed=3)
    a = pop.sample_cohort(t=7, k=20)
    b = pop.sample_cohort(t=7, k=20)
    assert np.array_equal(a.users, b.users)
    assert len(set(a.users.tolist())) == 20          # without replacement
    assert np.array_equal(a.shards, pop.shard_of(a.users))
    assert a.shards.max() < 10 and a.users.max() < 500
    # different ticks draw different cohorts
    c = pop.sample_cohort(t=8, k=20)
    assert not np.array_equal(a.users, c.users)
    # Efraimidis–Spirakis: high-availability users appear more often
    counts = np.zeros(500)
    for t in range(300):
        counts[pop.sample_cohort(t=t, k=20).users] += 1
    hi = pop.availability > np.quantile(pop.availability, 0.8)
    lo = pop.availability < np.quantile(pop.availability, 0.2)
    assert counts[hi].mean() > 2.0 * counts[lo].mean()


def test_population_cohort_run_is_deterministic():
    eng = EngineSpec(mode="async", buffered=AsyncSpec(
        buffer_frac=0.5, delay_scale=0.01, delay_sigma=1.0,
        population=200))
    r1 = run_experiment(_spec("fedavg", n=4, rounds=2, engine=eng))
    r2 = run_experiment(_spec("fedavg", n=4, rounds=2, engine=eng))
    assert _trees_equal(r1.params, r2.params)
    assert r1.accuracy == r2.accuracy
    assert r1.history.virtual_s == r2.history.virtual_s
