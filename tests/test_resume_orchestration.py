"""Kill/resume fault-injection harness for the durable sweep orchestration.

The contract under test (ISSUE: durable, fault-tolerant sweeps): a sweep
killed at **any** round boundary — in-process simulated preemption
(:class:`repro.fl.resume.Preempted`) or a real SIGTERM to a CLI subprocess —
and restarted with ``resume=True`` produces **bit-identical** results to an
uninterrupted run: same Eq.-15 ledger, same accuracy/loss curves, same final
parameters, same BENCH artifact modulo wall-clock
(:func:`repro.experiments.artifacts.strip_volatile`).  And a cell that
*crashes* (raises) is isolated: marked failed in the manifest while the rest
of the grid completes, retried on resume.
"""
import json
import os
import signal
import subprocess
import sys
import time

import jax
import numpy as np
import pytest

from repro.experiments import strip_volatile
from repro.experiments.durability import SweepManifest
from repro.experiments.orchestrator import run_sweep
from repro.fl.experiment import ExperimentSpec, run_experiment
from repro.fl.resume import Preempted, RoundCheckpointer
from repro.fl.server import FLConfig

ROUNDS = 4


def _spec(executor: str, strategy: str = "feddif", **fl_overrides
          ) -> ExperimentSpec:
    kwargs = dict(strategy=strategy, num_clients=4, num_models=4,
                  rounds=ROUNDS, topology_seed=7, executor=executor,
                  checkpoint_every=1, batch_size=8)
    kwargs.update(fl_overrides)
    return ExperimentSpec(task="logistic", num_samples=400,
                          fl=FLConfig(**kwargs))


def _trees_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return (len(la) == len(lb)
            and all(np.array_equal(np.asarray(x), np.asarray(y))
                    for x, y in zip(la, lb)))


def _assert_results_identical(clean, resumed):
    assert clean.accuracy == resumed.accuracy
    assert clean.loss == resumed.loss
    assert clean.ledger == resumed.ledger          # Eq.-15 ledger, exact
    assert clean.diffusion_rounds == resumed.diffusion_rounds
    assert clean.iid_distance == resumed.iid_distance
    assert _trees_equal(clean.final_params, resumed.final_params)


def _run_killed_then_resumed(spec, ckpt_dir, kill_round, monkeypatch):
    """Run the cell, preempting right after round ``kill_round``'s
    checkpoint lands; then resume it to completion."""
    with monkeypatch.context() as m:
        m.setattr(RoundCheckpointer, "fail_after_save", kill_round)
        with pytest.raises(Preempted):
            run_experiment(spec, checkpoint_dir=ckpt_dir)
    return run_experiment(spec, checkpoint_dir=ckpt_dir)


# ------------------------------------------------ experiment-level parity

@pytest.mark.parametrize("executor", ["host", "fleet", "sharded"])
@pytest.mark.parametrize("strategy", ["feddif", "gossip"])
def test_kill_resume_bit_identical(executor, strategy, tmp_path,
                                   monkeypatch):
    """Preempt after the round-2 checkpoint; the resumed run must be
    indistinguishable from one that never died — for the slotless (feddif)
    and persistent-slot (gossip) round structures, on every executor."""
    spec = _spec(executor, strategy)
    clean = run_experiment(spec, checkpoint_dir=str(tmp_path / "clean"))
    resumed = _run_killed_then_resumed(spec, str(tmp_path / "killed"),
                                       kill_round=2, monkeypatch=monkeypatch)
    _assert_results_identical(clean, resumed)


def test_kill_resume_every_boundary(tmp_path, monkeypatch):
    """Every possible kill round (1..rounds-1) resumes bit-identically."""
    spec = _spec("host")
    clean = run_experiment(spec, checkpoint_dir=str(tmp_path / "clean"))
    for k in range(1, ROUNDS):
        resumed = _run_killed_then_resumed(
            spec, str(tmp_path / f"killed{k}"), kill_round=k,
            monkeypatch=monkeypatch)
        _assert_results_identical(clean, resumed)


def test_double_kill_resume(tmp_path, monkeypatch):
    """Die twice (rounds 1 and 3), resume twice — still bit-identical."""
    spec = _spec("host")
    clean = run_experiment(spec, checkpoint_dir=str(tmp_path / "clean"))
    d = str(tmp_path / "killed")
    for k in (1, 3):
        with monkeypatch.context() as m:
            m.setattr(RoundCheckpointer, "fail_after_save", k)
            with pytest.raises(Preempted):
                run_experiment(spec, checkpoint_dir=d)
    resumed = run_experiment(spec, checkpoint_dir=d)
    _assert_results_identical(clean, resumed)


def test_kill_resume_with_stateful_model_rng(tmp_path, monkeypatch):
    """With ``topology_seed=None`` the control plane consumes the *stateful*
    model-seed generator; resume must restore its bit-generator position."""
    spec = _spec("host", topology_seed=None)
    clean = run_experiment(spec, checkpoint_dir=str(tmp_path / "clean"))
    resumed = _run_killed_then_resumed(spec, str(tmp_path / "killed"),
                                       kill_round=2, monkeypatch=monkeypatch)
    _assert_results_identical(clean, resumed)


def test_kill_resume_with_churn(tmp_path, monkeypatch):
    """Churn draws come from the stateless per-round ``[seed, t, tag]``
    stream — a resumed run must reproduce the same dropout masks."""
    spec = _spec("host", churn_rate=0.3)
    clean = run_experiment(spec, checkpoint_dir=str(tmp_path / "clean"))
    resumed = _run_killed_then_resumed(spec, str(tmp_path / "killed"),
                                       kill_round=2, monkeypatch=monkeypatch)
    _assert_results_identical(clean, resumed)


def test_resume_refuses_mismatched_config(tmp_path, monkeypatch):
    spec = _spec("host")
    d = str(tmp_path / "ckpt")
    with monkeypatch.context() as m:
        m.setattr(RoundCheckpointer, "fail_after_save", 2)
        with pytest.raises(Preempted):
            run_experiment(spec, checkpoint_dir=d)
    import dataclasses
    other = dataclasses.replace(
        spec, fl=dataclasses.replace(spec.fl, gamma_min=2.5))
    with pytest.raises(ValueError, match="different config"):
        run_experiment(other, checkpoint_dir=d)


# ------------------------------------------------------ hypothesis property

def test_property_kill_round_parity(tmp_path, monkeypatch):
    """Property: for a randomly drawn (kill round, executor) the resumed run
    equals the clean one."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    clean = {}

    @hyp.settings(max_examples=6, deadline=None,
                  suppress_health_check=[
                      hyp.HealthCheck.function_scoped_fixture])
    @hyp.given(k=st.integers(min_value=1, max_value=ROUNDS - 1),
               executor=st.sampled_from(["host", "fleet"]))
    def prop(k, executor):
        spec = _spec(executor)
        if executor not in clean:
            clean[executor] = run_experiment(
                spec, checkpoint_dir=str(tmp_path / f"clean-{executor}"))
        resumed = _run_killed_then_resumed(
            spec, str(tmp_path / f"killed-{executor}-{k}-{time.time_ns()}"),
            kill_round=k, monkeypatch=monkeypatch)
        _assert_results_identical(clean[executor], resumed)

    prop()


# ------------------------------------------------------ sweep-level parity

def _durable_sweep(out, state, **kw):
    return run_sweep("fig3_alpha", seeds=(0, 1), out_dir=out,
                     state_dir=state, num_samples=400, **kw)


def test_sweep_kill_resume_artifact_parity(tmp_path, monkeypatch):
    """Kill a durable sweep mid-grid (in-process preemption), resume it; the
    BENCH artifact must match an uninterrupted durable run bit-for-bit after
    stripping volatile fields."""
    clean = _durable_sweep(str(tmp_path / "o1"), str(tmp_path / "s1"),
                           checkpoint_every=1)
    with monkeypatch.context() as m:
        m.setattr(RoundCheckpointer, "fail_after_save", 1)
        with pytest.raises(Preempted):
            _durable_sweep(str(tmp_path / "o2"), str(tmp_path / "s2"),
                           checkpoint_every=1)
    resumed = _durable_sweep(str(tmp_path / "o2"), str(tmp_path / "s2"),
                             resume=True)
    assert json.dumps(strip_volatile(clean), sort_keys=True) \
        == json.dumps(strip_volatile(resumed), sort_keys=True)
    assert resumed["failed_cells"] == []
    # the manifest agrees: every cell done
    man = SweepManifest.load(str(tmp_path / "s2"))
    assert all(c["status"] == "done" for c in man.data["cells"].values())


def test_sweep_failure_isolation_and_retry(tmp_path, monkeypatch):
    """A cell whose run *raises* is marked failed and skipped while the rest
    of the grid completes; a later resume retries it and heals the sweep."""
    from repro.experiments import orchestrator

    real_loop = orchestrator.run_replicates_loop
    clean = _durable_sweep(str(tmp_path / "o1"), str(tmp_path / "s1"),
                           checkpoint_every=1)
    poisoned = clean["cells"][0]["label"]

    def flaky(spec, seeds, plan_cache=None, checkpoint_root=None):
        if spec.fl.strategy == clean["cells"][0]["strategy"] \
                and f"alpha={spec.alpha}" in poisoned:
            raise RuntimeError("injected cell crash")
        return real_loop(spec, seeds, plan_cache=plan_cache,
                         checkpoint_root=checkpoint_root)

    with monkeypatch.context() as m:
        m.setattr(orchestrator, "run_replicates_loop", flaky)
        broken = _durable_sweep(str(tmp_path / "o2"), str(tmp_path / "s2"),
                                checkpoint_every=1)
    assert [f["label"] for f in broken["failed_cells"]] == [poisoned]
    assert "injected cell crash" in broken["failed_cells"][0]["error"]
    # the other cells completed despite the crash
    assert len(broken["cells"]) == len(clean["cells"]) - 1
    # resume retries the failed cell and the artifact heals to parity
    healed = _durable_sweep(str(tmp_path / "o2"), str(tmp_path / "s2"),
                            resume=True)
    assert healed["failed_cells"] == []
    assert json.dumps(strip_volatile(clean), sort_keys=True) \
        == json.dumps(strip_volatile(healed), sort_keys=True)


def test_fresh_sweep_refuses_existing_state_dir(tmp_path):
    _durable_sweep(str(tmp_path / "o"), str(tmp_path / "s"),
                   checkpoint_every=1)
    with pytest.raises(FileExistsError, match="resume"):
        _durable_sweep(str(tmp_path / "o"), str(tmp_path / "s"),
                       checkpoint_every=1)


def test_resume_refuses_mismatched_sweep_config(tmp_path):
    _durable_sweep(str(tmp_path / "o"), str(tmp_path / "s"),
                   checkpoint_every=1)
    with pytest.raises(ValueError, match="different configuration"):
        run_sweep("fig3_alpha", seeds=(0, 1, 2),   # seeds changed
                  out_dir=str(tmp_path / "o"), state_dir=str(tmp_path / "s"),
                  num_samples=400, resume=True)


# ------------------------------------------------------- SIGTERM subprocess

@pytest.mark.skipif(not hasattr(signal, "SIGTERM") or os.name != "posix",
                    reason="POSIX signals required")
def test_sigterm_kill_resume_cli(tmp_path):
    """The real thing: SIGTERM a durable CLI sweep mid-run, resume it with
    ``--resume``, and diff the artifact against an uninterrupted run."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(repo, "src"))
    state, out = str(tmp_path / "state"), str(tmp_path / "out")
    args = [sys.executable, "-m", "repro.launch.sweep",
            "--sweep", "fig3_alpha", "--smoke", "--seeds", "2",
            "--checkpoint-every", "1", "--num-samples", "400",
            "--state-dir", state, "--out-dir", out]

    proc = subprocess.Popen(args, env=env, cwd=repo,
                            stdout=subprocess.PIPE, stderr=subprocess.STDOUT)
    try:
        # Wait for durable progress (first committed round checkpoint),
        # then deliver SIGTERM mid-sweep.
        deadline = time.time() + 120
        def committed():
            for root, _, files in os.walk(os.path.join(state, "cells")):
                if any(f.endswith(".json") and f.startswith("ckpt_")
                       for f in files):
                    return True
            return False
        while time.time() < deadline and proc.poll() is None \
                and not committed():
            time.sleep(0.05)
        assert committed(), "no checkpoint ever committed"
        if proc.poll() is None:
            proc.send_signal(signal.SIGTERM)
        proc.wait(timeout=60)
    finally:
        if proc.poll() is None:
            proc.kill()

    r = subprocess.run(args + ["--resume"], env=env, cwd=repo,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr

    clean = run_sweep("fig3_alpha", seeds=(0, 1),
                      out_dir=str(tmp_path / "out-clean"),
                      state_dir=str(tmp_path / "state-clean"),
                      checkpoint_every=1, num_samples=400)
    with open(os.path.join(out, "BENCH_feddif_fig3_alpha.json")) as f:
        resumed = json.load(f)
    assert resumed["failed_cells"] == []
    assert json.dumps(strip_volatile(clean), sort_keys=True, default=str) \
        == json.dumps(strip_volatile(resumed), sort_keys=True, default=str)
