"""SPMD FedDif data plane: the client-stacked diffusion step must agree with
the host-side reference semantics (move → selective train → aggregate)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.distributed.fedshard import (diffuse_params, fleet_aggregate,
                                        make_diffusion_step)
from repro.models import build_model
from repro.train import optimizer as opt_lib
from repro.train.trainstep import TrainState, make_train_step


def _stacked_state(model, opt, n):
    states = []
    for i in range(n):
        params = model.init(jax.random.PRNGKey(i))
        states.append(TrainState(params=params, opt_state=opt.init(params),
                                 step=jnp.zeros((), jnp.int32)))
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


def test_diffuse_params_permutes_client_axis():
    x = {"w": jnp.arange(4.0)[:, None] * jnp.ones((4, 3))}
    perm = jnp.asarray([2, 0, 3, 1])   # slot c receives from perm[c]
    out = diffuse_params(x, perm)
    np.testing.assert_allclose(np.asarray(out["w"][:, 0]), [2.0, 0.0, 3.0, 1.0])


def test_fleet_aggregate_weighted_mean():
    x = {"w": jnp.stack([jnp.full((2,), 1.0), jnp.full((2,), 5.0)])}
    out = fleet_aggregate(x, jnp.asarray([3.0, 1.0]))
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.full((2, 2), 2.0), rtol=1e-6)


def test_diffusion_step_matches_host_reference(monkeypatch):
    # wire_bf16 is intentionally lossy (bf16 on the D2D wire) — disable it
    # for the exact-equivalence check; params_only momentum-restart matches
    # the host reference because both start from zero momentum here.
    monkeypatch.setenv("REPRO_PERF_OPTS",
                       "params_only_diffusion,ce_seqchunk,ce_mask")
    cfg = get_smoke_config("smollm_360m")
    model = build_model(cfg)
    opt = opt_lib.sgd()
    n = 4
    state = _stacked_state(model, opt, n)
    key = jax.random.PRNGKey(42)
    toks = jax.random.randint(key, (n, 2, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=-1)}
    src_of_dst = jnp.asarray([1, 2, 3, 0])
    train_mask = jnp.asarray([True, False, True, True])
    weights = jnp.asarray([1.0, 1.0, 2.0, 1.0])

    dstep = make_diffusion_step(model, opt, remat=False)
    out, metrics = jax.jit(dstep)(state, batch, src_of_dst, train_mask,
                                  weights)

    # host reference: per-client jit step applied after the permutation
    step = make_train_step(model, opt, opt_lib.constant_lr(0.01),
                           remat=False)
    moved = jax.tree.map(lambda x: x[src_of_dst], state)
    refs = []
    for c in range(n):
        st_c = jax.tree.map(lambda x: x[c], moved)
        b_c = jax.tree.map(lambda x: x[c], batch)
        new_c, _ = step(st_c, b_c)
        refs.append(new_c if bool(train_mask[c]) else st_c)
    ref = jax.tree.map(lambda *xs: jnp.stack(xs), *refs)
    ref_params = fleet_aggregate(ref.params, weights)

    for a, b in zip(jax.tree.leaves(out.params),
                    jax.tree.leaves(ref_params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-5, rtol=5e-4)


def test_diffusion_step_no_aggregation_keeps_distinct_models():
    cfg = get_smoke_config("qwen3_0_6b")
    model = build_model(cfg)
    opt = opt_lib.sgd()
    n = 2
    state = _stacked_state(model, opt, n)
    toks = jnp.zeros((n, 1, 8), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    dstep = make_diffusion_step(model, opt, remat=False)
    out, _ = jax.jit(dstep)(state, batch, jnp.asarray([1, 0]),
                            jnp.asarray([True, True]), None)
    w0 = np.asarray(jax.tree.leaves(out.params)[0])
    assert not np.allclose(w0[0], w0[1])   # models stay per-client
