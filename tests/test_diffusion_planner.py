"""Diffusion planner invariants (Algorithm 2 control plane)."""
import numpy as np
import pytest

from repro.core import (DiffusionPlanner, DiffusionState, iid_distance)


def _plan(seed=0, n=10, m=10, c=10, alpha=0.5, epsilon=0.04):
    rng = np.random.default_rng(seed)
    dsi = rng.dirichlet(np.ones(c) * alpha, n).astype(np.float32)
    sizes = rng.integers(200, 800, n).astype(np.float64)
    state = DiffusionState.init(m, n, c)
    for mi in range(m):
        state.record_training(mi, mi % n, dsi[mi % n], sizes[mi % n])
    planner = DiffusionPlanner(epsilon=epsilon)
    plan = planner.plan_communication_round(state, dsi, sizes, rng)
    return plan, state, n, m


@pytest.mark.parametrize("seed", range(5))
def test_no_retraining_constraint_18c(seed):
    plan, state, n, m = _plan(seed)
    visits: dict[int, set] = {mi: {mi % n} for mi in range(m)}
    for h in plan.hops:
        assert h.dst not in visits[h.model], "PUE trained same model twice"
        visits[h.model].add(h.dst)


@pytest.mark.parametrize("seed", range(5))
def test_one_model_per_pue_per_round_18d(seed):
    plan, state, n, m = _plan(seed)
    for k in range(plan.num_rounds):
        dsts = [h.dst for h in plan.hops_in_round(k)]
        assert len(dsts) == len(set(dsts))


@pytest.mark.parametrize("seed", range(5))
def test_positive_decrements_18b(seed):
    plan, *_ = _plan(seed)
    for h in plan.hops:
        assert h.decrement > 0


@pytest.mark.parametrize("seed", range(8))
def test_permutations_bijective(seed):
    plan, state, n, m = _plan(seed)
    for perm, mask in plan.as_permutations(n):
        assert sorted(perm.tolist()) == list(range(n))
        assert mask.sum() <= len(plan.hops)


def test_diffusion_reduces_iid_distance():
    plan, state, n, m = _plan(0)
    start = None
    # recompute initial distance from one-client chains
    rng = np.random.default_rng(0)
    dsi = rng.dirichlet(np.ones(10) * 0.5, n).astype(np.float32)
    start = float(np.mean(iid_distance(np.asarray(dsi))))
    assert float(np.mean(plan.final_iid_distance)) < start


def test_epsilon_halting():
    plan_tight, *_ = _plan(1, epsilon=0.3)
    plan_loose, *_ = _plan(1, epsilon=0.01)
    assert plan_tight.num_rounds <= plan_loose.num_rounds
    assert (plan_tight.final_iid_distance <= 0.3 + 1e-6).all() or \
        plan_tight.num_rounds > 0


def test_max_rounds_cap():
    planner = DiffusionPlanner(epsilon=0.0, max_rounds=2)
    rng = np.random.default_rng(0)
    n = c = 8
    dsi = rng.dirichlet(np.ones(c) * 0.2, n).astype(np.float32)
    sizes = rng.integers(100, 400, n).astype(np.float64)
    state = DiffusionState.init(n, n, c)
    for mi in range(n):
        state.record_training(mi, mi, dsi[mi], sizes[mi])
    plan = planner.plan_communication_round(state, dsi, sizes, rng)
    assert plan.num_rounds <= 2
