"""EngineSpec / RunResult API tests (repro.fl.engine).

The spec is the single engine-selection authority: presets, the legacy
FLConfig-string shim (warns once), auto resolution/downgrade, and the
RunResult legacy surface (flat FLResult attributes + tuple unpacking).
"""
import dataclasses
import warnings

import pytest

import repro.fl.engine as engine_mod
from repro.fl.engine import (ENGINE_PRESETS, AsyncSpec, EngineSpec,
                             SHARDED_CROSSOVER_N, RunHistory, RunResult,
                             engine_fingerprint, resolve_engine)
from repro.fl.server import FLConfig


# ----------------------------------------------------------------- presets

def test_presets_cover_every_mode():
    assert {"host", "fleet", "sharded", "auto", "async",
            "async_barrier"} <= set(ENGINE_PRESETS)
    for name, spec in ENGINE_PRESETS.items():
        spec.validate()


def test_preset_lookup_and_unknown_name():
    assert EngineSpec.preset("fleet").mode == "fleet"
    with pytest.raises(ValueError, match="unknown engine preset"):
        EngineSpec.preset("warp_drive")


def test_async_preset_is_buffered_and_barrier_preset_is_not():
    a = ENGINE_PRESETS["async"].buffered
    b = ENGINE_PRESETS["async_barrier"].buffered
    assert a.buffer_frac is not None and a.staleness_beta > 0
    assert b.buffer_k is None and b.buffer_frac is None
    # same delay model on both arms: the gap isolates buffering
    assert a.delay_scale == b.delay_scale
    assert a.delay_sigma == b.delay_sigma


# ----------------------------------------------------------- resolve order

def test_engine_field_wins_over_legacy_strings():
    cfg = FLConfig(strategy="fedavg", num_clients=4, num_models=4,
                   executor="host", engine="fleet")
    assert resolve_engine(cfg).mode == "fleet"
    cfg = dataclasses.replace(cfg, engine=EngineSpec(mode="host"))
    assert resolve_engine(cfg).mode == "host"


def test_legacy_strings_map_through_shim():
    cfg = FLConfig(strategy="fedavg", num_clients=4, num_models=4,
                   executor="fleet", planner="jax", shard_microbatch=8)
    spec = resolve_engine(cfg)
    assert spec.mode == "fleet"
    assert spec.planner == "jax"
    assert spec.shard_microbatch == 8


def test_bad_engine_type_raises():
    cfg = FLConfig(strategy="fedavg", num_clients=4, num_models=4,
                   engine=42)
    with pytest.raises(TypeError, match="EngineSpec or a preset"):
        resolve_engine(cfg)


def test_shim_warns_once_per_process(monkeypatch):
    monkeypatch.setattr(engine_mod, "_WARNED_LEGACY", False)
    cfg = FLConfig(strategy="fedavg", num_clients=4, num_models=4,
                   executor="fleet")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        EngineSpec.from_config(cfg)
        EngineSpec.from_config(cfg)
    deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(deps) == 1


def test_shim_stays_silent_on_defaults(monkeypatch):
    monkeypatch.setattr(engine_mod, "_WARNED_LEGACY", False)
    cfg = FLConfig(strategy="fedavg", num_clients=4, num_models=4)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        EngineSpec.from_config(cfg)
    assert not [x for x in w if issubclass(x.category, DeprecationWarning)]


# ------------------------------------------------------------- auto logic

def test_auto_resolves_by_size_and_never_touches_explicit_modes():
    assert EngineSpec(mode="auto").auto(4).mode == "fleet"
    for mode in ("host", "fleet", "async"):
        assert EngineSpec(mode=mode).auto(4).mode == mode
        assert EngineSpec(mode=mode).auto(10 ** 6).mode == mode


def test_auto_downgrades_sharded_below_crossover():
    small = EngineSpec(mode="sharded").auto(SHARDED_CROSSOVER_N - 1)
    assert small.mode == "fleet"
    # at/above the crossover an explicit sharded request survives
    big = EngineSpec(mode="sharded").auto(SHARDED_CROSSOVER_N)
    assert big.mode == "sharded"


def test_resolve_engine_keeps_explicit_sharded():
    # Benches deliberately run sharded below the crossover for parity
    # checks; only the orchestrator's engine="auto" path downgrades.
    cfg = FLConfig(strategy="fedavg", num_clients=4, num_models=4,
                   executor="sharded")
    assert resolve_engine(cfg).mode == "sharded"


def test_fingerprint_distinguishes_async_knobs():
    base = FLConfig(strategy="fedavg", num_clients=4, num_models=4)
    f_host = engine_fingerprint(base)
    f_async = engine_fingerprint(dataclasses.replace(base, engine="async"))
    f_barrier = engine_fingerprint(
        dataclasses.replace(base, engine="async_barrier"))
    assert len({f_host, f_async, f_barrier}) == 3
    # and it is stable across calls (the checkpoint guard depends on it)
    assert f_async == engine_fingerprint(
        dataclasses.replace(base, engine="async"))


# --------------------------------------------------------------- AsyncSpec

def test_resolve_k_priority_and_clamping():
    assert AsyncSpec().resolve_k(7) == 7                       # barrier
    assert AsyncSpec(buffer_k=3).resolve_k(7) == 3
    assert AsyncSpec(buffer_k=30).resolve_k(7) == 7            # clamped
    assert AsyncSpec(buffer_frac=0.5).resolve_k(7) == 4        # round(3.5)
    assert AsyncSpec(buffer_k=2, buffer_frac=0.9).resolve_k(7) == 2
    assert AsyncSpec(buffer_frac=0.01).resolve_k(7) == 1       # >= 1


def test_discount_is_one_at_zero_staleness_and_decays():
    b = AsyncSpec(staleness_alpha=1.0, staleness_beta=0.5)
    assert b.discount(0) == 1.0
    assert b.discount(3) < b.discount(1) < b.discount(0)
    # beta=0 turns the discount off entirely
    off = AsyncSpec(staleness_beta=0.0)
    assert off.discount(10) == 1.0


def test_validate_rejects_bad_knobs():
    with pytest.raises(AssertionError):
        AsyncSpec(buffer_k=0).validate()
    with pytest.raises(AssertionError):
        AsyncSpec(buffer_frac=1.5).validate()
    with pytest.raises(AssertionError):
        EngineSpec(mode="warp").validate()


# --------------------------------------------------------------- RunResult

def _result():
    return RunResult.from_histories(
        accuracy=[0.1, 0.5, 0.9], loss=[2.0, 1.0, 0.5], ledger="LEDGER",
        diffusion_rounds=[1, 2, 1], iid_distance=[0.3, 0.2, 0.1],
        final_params={"w": 1}, virtual_s=[1.0, 2.0, 4.0])


def test_runresult_legacy_surface_and_unpacking():
    r = _result()
    assert r.final_params == r.params == {"w": 1}
    assert r.accuracy == [0.1, 0.5, 0.9]
    assert r.ledger == "LEDGER"
    params, ledger, history = r
    assert params == {"w": 1} and ledger == "LEDGER"
    assert isinstance(history, RunHistory)


def test_time_to_accuracy_uses_virtual_clock_when_present():
    r = _result()
    assert r.rounds_to_accuracy(0.5) == 2
    assert r.time_to_accuracy(0.5) == 2.0     # virtual_s[1]
    assert r.time_to_accuracy(0.99) is None
    sync = RunResult.from_histories(
        accuracy=[0.1, 0.5], loss=[1, 1], ledger=None,
        diffusion_rounds=[0, 0], iid_distance=[0, 0])
    assert sync.time_to_accuracy(0.5) == 2.0  # falls back to round index


def test_flresult_alias_is_runresult():
    from repro.fl.server import FLResult
    assert FLResult is RunResult
