"""Sharded fleet executor: three-plane parity at N=64, permutation-table
routing, and churn/straggler schedule dropout.

Tier-1 runs on one CPU device, where the ``("clients",)`` mesh degenerates
to a single shard — the shard_map program, microbatched sessions and
routing-table permute still execute (collectives become identities).  A
subprocess test forces a 2-device CPU mesh so ppermute / psum_scatter /
psum actually cross shards; CI's smokes job additionally drives the fig7
scaling sweep on a 2-device mesh.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core.schedule import (MixOp, PermuteOp, RoundSchedule, TrainOp,
                                 WireEvent, apply_churn)
from repro.fl import ExperimentSpec, FLConfig, run_experiment
from repro.fl.executors import _permutation_tables


def _spec(strategy, executor, clients=64, rounds=2, **kw):
    # experiment.py trains on the test_frac (0.2) side of the split: 100
    # samples/client keeps every Dirichlet shard non-empty at N=64.
    return ExperimentSpec(
        task="fcn", alpha=0.5, num_samples=100 * clients,
        fl=FLConfig(strategy=strategy, rounds=rounds, num_clients=clients,
                    num_models=clients, seed=0, topology_seed=1,
                    max_diffusion_rounds=4, executor=executor, **kw))


# ------------------------------------------------- three-way parity at N=64

@pytest.mark.parametrize("strategy", ["feddif", "fedavg", "feddif_stc",
                                      "gossip"])
def test_host_fleet_sharded_parity_n64(strategy):
    """Host, fleet and sharded planes at N=64: identical ledgers (bitwise —
    charging is schedule-side), matching final accuracy and params.

    feddif_stc and gossip extend the pair through the kernel data plane
    (``kernels/diffusion.py``): STC-compressed hops exercise ``stc_topk``
    and the gossip MixOp exercises ``mix_aggregate`` on all three planes
    (with ``implementation="auto"`` — the reference twins here, the Pallas
    bodies on TPU / under ``REPRO_KERNELS_IMPL``).  The sharded arm forces
    the fused round plane ("auto" would take op-by-op below
    ``FUSED_MIN_CLIENTS``) so the whole-round program is what parity
    certifies.
    """
    results = {ex: run_experiment(_spec(strategy, ex,
                                        **({"shard_overlap": "on"}
                                           if ex == "sharded" else {})))
               for ex in ("host", "fleet", "sharded")}
    host = results["host"]
    for ex in ("fleet", "sharded"):
        r = results[ex]
        assert host.ledger.as_dict() == r.ledger.as_dict(), ex
        assert host.diffusion_rounds == r.diffusion_rounds, ex
        np.testing.assert_allclose(host.accuracy, r.accuracy, atol=0.02,
                                   err_msg=ex)
        for a, b in zip(jax.tree.leaves(host.final_params),
                        jax.tree.leaves(r.final_params)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=5e-4, rtol=5e-3, err_msg=ex)


def test_sharded_microbatches_session():
    """N=64 over shard_microbatch=16 runs the lax.map chunk path (4 chunks
    per shard on one device) and still matches the un-chunked fleet plane."""
    fleet = run_experiment(_spec("fedavg", "fleet", rounds=1))
    shard = run_experiment(_spec("fedavg", "sharded", rounds=1,
                                 shard_microbatch=16))
    assert fleet.ledger.as_dict() == shard.ledger.as_dict()
    for a, b in zip(jax.tree.leaves(fleet.final_params),
                    jax.tree.leaves(shard.final_params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-4, rtol=5e-3)


def test_sharded_runs_every_schedule_op_kind():
    """tthf (MixOp), feddif_stc (compressed PermuteOp) and stc (stc_delta
    aggregation) all execute on the sharded plane."""
    for strategy in ("tthf", "feddif_stc", "stc"):
        res = run_experiment(_spec(strategy, "sharded", clients=8, rounds=1,
                                   shard_overlap="on",
                                   tthf_cluster_size=4, tthf_global_period=1))
        assert len(res.accuracy) == 1
        assert np.all(np.isfinite(np.concatenate(
            [np.asarray(x, np.float32).ravel()
             for x in jax.tree.leaves(res.final_params)])))


def test_sharded_parity_on_multi_device_mesh():
    """Force a 2-device CPU mesh in a subprocess (XLA_FLAGS is read at jax
    import) so the permute ppermutes and the aggregation psum really cross
    shards; host-vs-sharded must still agree."""
    code = """
import numpy as np, jax
assert len(jax.devices()) == 2, jax.devices()
from repro.fl import ExperimentSpec, FLConfig, run_experiment
def spec(executor, **kw):
    return ExperimentSpec(task="fcn", alpha=0.5, num_samples=240,
        fl=FLConfig(strategy="feddif", rounds=1, num_clients=8, num_models=8,
                    seed=0, topology_seed=1, max_diffusion_rounds=3,
                    executor=executor, **kw))
host = run_experiment(spec("host"))
shard = run_experiment(spec("sharded", shard_overlap="on"))
assert host.ledger.as_dict() == shard.ledger.as_dict()
for a, b in zip(jax.tree.leaves(host.final_params),
                jax.tree.leaves(shard.final_params)):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               atol=5e-4, rtol=5e-3)
print("MULTI_DEVICE_PARITY_OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH",
                                                              ""))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MULTI_DEVICE_PARITY_OK" in out.stdout


def test_hop_transport_parity_single_device():
    """``shard_hop_transport`` must not change results — "auto" resolves to
    gather for the tiny FCN, so force the ring plane explicitly and compare
    against gather (identical ledgers, matching params)."""
    runs = {t: run_experiment(_spec("feddif", "sharded", clients=8,
                                    rounds=1, shard_overlap="on",
                                    shard_hop_transport=t))
            for t in ("gather", "ring")}
    assert (runs["gather"].ledger.as_dict()
            == runs["ring"].ledger.as_dict())
    for a, b in zip(jax.tree.leaves(runs["gather"].final_params),
                    jax.tree.leaves(runs["ring"].final_params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-4, rtol=5e-3)


def test_ring_transport_parity_on_multi_device_mesh():
    """Forced ring transport on a 2-device mesh: the fused round's
    double-buffered ppermute shifts really cross shards and must still
    match the host reference."""
    code = """
import numpy as np, jax
assert len(jax.devices()) == 2, jax.devices()
from repro.fl import ExperimentSpec, FLConfig, run_experiment
def spec(executor, **kw):
    return ExperimentSpec(task="fcn", alpha=0.5, num_samples=240,
        fl=FLConfig(strategy="feddif", rounds=2, num_clients=8, num_models=8,
                    seed=0, topology_seed=1, max_diffusion_rounds=3,
                    executor=executor, **kw))
host = run_experiment(spec("host"))
shard = run_experiment(spec("sharded", shard_overlap="on",
                            shard_hop_transport="ring"))
assert host.ledger.as_dict() == shard.ledger.as_dict()
for a, b in zip(jax.tree.leaves(host.final_params),
                jax.tree.leaves(shard.final_params)):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               atol=5e-4, rtol=5e-3)
print("RING_TRANSPORT_PARITY_OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH",
                                                              ""))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "RING_TRANSPORT_PARITY_OK" in out.stdout


# ------------------------------------------------------- permutation tables

@pytest.mark.parametrize("c,k", [(8, 1), (8, 2), (12, 3), (16, 4)])
def test_permutation_tables_route_every_row(c, k):
    """Replaying the send/recv tables in numpy reproduces take(x, perm)."""
    rng = np.random.default_rng(c * 10 + k)
    for _ in range(5):
        perm = rng.permutation(c)
        send, recv = _permutation_tables(perm, k)
        nl = c // k
        x = np.arange(c)
        out = np.full((k, nl + 1), -1)          # per-shard block + trash row
        for shift in range(k):
            for s in range(k):                  # buffer shipped s -> d
                d = (s + shift) % k
                buf = x[s * nl:(s + 1) * nl][send[s, shift]]
                out[d][recv[d, shift]] = buf
        np.testing.assert_array_equal(out[:, :nl].ravel(), x[perm])


# ----------------------------------------------------------- churn dropout

def _toy_schedule(n=6):
    return RoundSchedule(
        num_slots=n,
        ops=[TrainOp(np.ones(n, dtype=bool)),
             PermuteOp(np.roll(np.arange(n), 1), np.ones(n, dtype=bool)),
             MixOp((((0, 1), (1.0, 1.0)),))],
        wire=[WireEvent("downlink", 1e6, 2.0, n)],
        agg=[(i, float(i + 1)) for i in range(n)])


def test_churned_clients_carry_zero_aggregation_weight():
    drop = np.array([False, True, False, False, True, False])
    out = apply_churn(_toy_schedule(), drop)
    w = out.slot_weights()
    assert w[1] == 0.0 and w[4] == 0.0
    assert (w[[0, 2, 3, 5]] > 0).all()
    # dropped slots train nowhere, in plain and permute ops alike
    assert not out.ops[0].train_mask[[1, 4]].any()
    assert not out.ops[1].train_mask[[1, 4]].any()
    # survivors keep training; the permutation itself is untouched
    assert out.ops[0].train_mask[[0, 2, 3, 5]].all()
    np.testing.assert_array_equal(out.ops[1].src_of_dst,
                                  _toy_schedule().ops[1].src_of_dst)
    # stragglers consumed their airtime: wire events unchanged
    assert out.wire == _toy_schedule().wire


def test_churn_noop_cases():
    sched = _toy_schedule()
    assert apply_churn(sched, np.zeros(6, dtype=bool)) is sched
    # dropping everyone would leave nothing to aggregate -> round unchanged
    assert apply_churn(sched, np.ones(6, dtype=bool)) is sched


def test_churn_rate_runs_end_to_end_and_charges_full_schedule():
    """churn_rate > 0 drops training/weights but never the wire: ledgers of
    churned and unchurned runs of one config are identical."""
    base = run_experiment(_spec("fedavg", "host", clients=8, rounds=2))
    churn = run_experiment(_spec("fedavg", "host", clients=8, rounds=2,
                                 churn_rate=0.4))
    assert base.ledger.as_dict() == churn.ledger.as_dict()
    # with 8 clients at 40% for 2 rounds, some client dropped somewhere:
    # the global models must differ
    diff = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(base.final_params),
                        jax.tree.leaves(churn.final_params)))
    assert diff, "churn at 40% should have dropped at least one client"


def test_churn_parity_across_executors():
    """The churn mask is drawn on the control plane, so every executor
    applies the same dropout."""
    runs = {ex: run_experiment(_spec("feddif", ex, clients=8, rounds=2,
                                     churn_rate=0.3))
            for ex in ("host", "fleet", "sharded")}
    host = runs["host"]
    for ex in ("fleet", "sharded"):
        assert host.ledger.as_dict() == runs[ex].ledger.as_dict()
        for a, b in zip(jax.tree.leaves(host.final_params),
                        jax.tree.leaves(runs[ex].final_params)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=5e-4, rtol=5e-3, err_msg=ex)


def test_rejects_unknown_executor_name():
    spec = _spec("fedavg", "warp", clients=4, rounds=1)
    with pytest.raises(AssertionError):
        run_experiment(spec)
