"""Int8 absmax quantization kernels (kernels/quant.py) and the adapter-hop
packing layer over them (repro.fl.adapters): Pallas-vs-ref parity, dispatch
plumbing, the wire-format invariants (idempotence, zero rows, error bound)
and the packed-bits payload accounting the Eq.-15 ledger charges."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.fl.adapters import (QUANT_BLOCK, pack_rows, packed_bits,
                               quant_roundtrip_rows, quant_roundtrip_slot,
                               quant_roundtrip_tree, unpack_rows)
from repro.kernels import ops
from repro.kernels.quant import quant_pack_pallas, quant_unpack_pallas
from repro.kernels.ref import quant_pack_ref, quant_unpack_ref

RNG = np.random.default_rng(11)


def _rows(r, b, zero_row=True):
    x = RNG.normal(size=(r, b)).astype(np.float32) * RNG.uniform(
        0.01, 100.0, size=(r, 1)).astype(np.float32)
    if zero_row:
        x[0] = 0.0
    return jnp.asarray(x)


# --------------------------------------------------------- kernel parity

@pytest.mark.parametrize("r,b", [(1, 512), (7, 512), (16, 128), (3, 8)])
def test_pack_pallas_matches_ref_bitwise(r, b):
    """Same int8 codes and bit-identical fp32 scales on both bodies — the
    wire format cannot depend on which implementation packed it."""
    x = _rows(r, b)
    q_p, s_p = quant_pack_pallas(x, interpret=True)
    q_r, s_r = quant_pack_ref(x)
    np.testing.assert_array_equal(np.asarray(q_p), np.asarray(q_r))
    np.testing.assert_array_equal(np.asarray(s_p), np.asarray(s_r))
    out_p = quant_unpack_pallas(q_p, s_p, interpret=True)
    out_r = quant_unpack_ref(q_r, s_r)
    np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_r))


def test_zero_rows_quantize_to_exact_zero():
    """All-zero rows hit the ε absmax floor and decode to exact zeros —
    padded mesh slots must stay inert through a packed hop."""
    x = jnp.zeros((4, QUANT_BLOCK), jnp.float32)
    q, s = quant_pack_pallas(x, interpret=True)
    np.testing.assert_array_equal(np.asarray(q), 0)
    out = quant_unpack_pallas(q, s, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_roundtrip_error_bounded_by_half_scale():
    x = _rows(9, QUANT_BLOCK, zero_row=False)
    q, s = quant_pack_pallas(x, interpret=True)
    out = quant_unpack_pallas(q, s, interpret=True)
    err = np.abs(np.asarray(out) - np.asarray(x))
    bound = np.asarray(s)[:, None] * (0.5 + 1e-3)
    assert (err <= bound).all()


def test_roundtrip_is_stable():
    """Re-packing a decoded payload (a multi-round diffusion chain: one
    roundtrip per hop) keeps the int8 codes bit-identical; only the scale
    can move by 1 ulp (absmax lands exactly on 127·scale, and
    (127·s)·(1/127) re-rounds), so values stay within 1 ulp relative."""
    x = _rows(5, QUANT_BLOCK)
    once = quant_roundtrip_rows(x)
    twice = quant_roundtrip_rows(once)
    q1, _ = pack_rows(once)
    q2, _ = pack_rows(twice)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice),
                               rtol=1.5e-7, atol=0.0)


def test_pack_vmaps():
    """The fleet/sharded planes pack under vmap (client-stacked batch)."""
    xs = jnp.stack([_rows(4, 128, zero_row=False) for _ in range(3)])
    q_v, s_v = jax.vmap(lambda a: quant_pack_pallas(a, interpret=True))(xs)
    for i in range(3):
        q, s = quant_pack_pallas(xs[i], interpret=True)
        np.testing.assert_array_equal(np.asarray(q_v[i]), np.asarray(q))
        np.testing.assert_array_equal(np.asarray(s_v[i]), np.asarray(s))


# -------------------------------------------------------------- dispatch

def test_ops_dispatch_honors_implementation_and_env(monkeypatch):
    x = _rows(4, 64)
    want_q, want_s = quant_pack_ref(x)
    for impl in ("ref", "xla", "pallas_interpret"):
        q, s = ops.quant_pack(x, implementation=impl)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(want_q))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(want_s))
        out = ops.quant_unpack(q, s, implementation=impl)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(quant_unpack_ref(want_q, want_s)))
    monkeypatch.setenv("REPRO_KERNELS_IMPL", "pallas_interpret")
    q, s = ops.quant_pack(x, implementation="auto")
    np.testing.assert_array_equal(np.asarray(q), np.asarray(want_q))
    monkeypatch.setenv("REPRO_KERNELS_IMPL", "ref")
    q, s = ops.quant_pack(x, implementation="auto")
    np.testing.assert_array_equal(np.asarray(q), np.asarray(want_q))


# ------------------------------------------------- adapter packing layer

def test_pack_rows_pads_to_block_multiple():
    """F not a block multiple: the pad decodes away and padded tail codes
    are zeros (they ride the wire but never perturb the payload)."""
    c, f = 3, QUANT_BLOCK + 37
    flat = _rows(c, f, zero_row=False)
    q, s = pack_rows(flat)
    assert q.shape == (c, 2 * QUANT_BLOCK) and s.shape == (c, 2)
    out = unpack_rows(q, s, f)
    assert out.shape == (c, f)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(quant_roundtrip_rows(flat)))


def test_packed_bits_formula_and_shape_structs():
    """8·block + 32 bits per row-block, computed from shapes alone — the
    same figure whether the template holds arrays or eval_shape structs."""
    tmpl = {"a": jnp.zeros((3, 100)), "b": jnp.zeros((41,))}
    f = 341
    rows = -(-f // QUANT_BLOCK)
    assert packed_bits(tmpl) == float(rows * (8 * QUANT_BLOCK + 32))
    structs = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tmpl)
    assert packed_bits(structs) == packed_bits(tmpl)
    assert packed_bits(tmpl) < 32.0 * f * 2   # beats fp32 well before 4x


def test_slot_and_tree_roundtrips_share_block_layout():
    """HostExecutor decodes slot trees, the stacked planes decode (C, F)
    blocks; identical row-block boundaries mean identical decoded values —
    the cross-executor parity invariant."""
    def tree(k):
        g = np.random.default_rng(k)
        return {"a": jnp.asarray(g.normal(size=(13, 5)), jnp.float32),
                "b": [jnp.asarray(g.normal(size=(700,)), jnp.float32),
                      jnp.asarray(g.normal(size=(2, 3)), jnp.float32)]}
    slots = [tree(i) for i in range(4)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *slots)
    via_tree = quant_roundtrip_tree(stacked)
    for i, slot in enumerate(slots):
        via_slot = quant_roundtrip_slot(slot)
        for a, b in zip(jax.tree.leaves(via_slot),
                        jax.tree.leaves(jax.tree.map(lambda x: x[i],
                                                     via_tree))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
