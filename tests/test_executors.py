"""RoundSchedule / Executor seam: host and fleet data planes must agree.

The schedule is computed once per round from the control plane, so the
ledger totals are *identical* by construction (both executors replay the
same wire events); the trained parameters must agree to vmap-vs-loop float
tolerance.  Every Table-II strategy must run end-to-end on both executors.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.schedule import (MixOp, PermuteOp, RoundSchedule,
                                 WireEvent, charge_schedule,
                                 complete_round_permutation)
from repro.channels.resources import ResourceLedger
from repro.fl import ExperimentSpec, FLConfig, run_experiment
from repro.fl.server import STRATEGIES


def _spec(strategy, executor, rounds=2, clients=5, **kw):
    return ExperimentSpec(
        task="fcn", alpha=0.3, num_samples=1200,
        fl=FLConfig(strategy=strategy, rounds=rounds, num_clients=clients,
                    num_models=clients, seed=0, topology_seed=3,
                    executor=executor, tthf_cluster_size=2,
                    tthf_global_period=2, **kw))


# ------------------------------------------------------------------ schedule

def test_complete_round_permutation_bijects_and_parks():
    # 3 slots; model 0 at slot 0 hops to slot 1 (occupied by model 1).
    src_of_dst, mask, slots = complete_round_permutation(
        [(0, 1)], np.array([0, 1, 2]), 3)
    assert sorted(src_of_dst.tolist()) == [0, 1, 2]
    assert mask.tolist() == [False, True, False]
    assert slots[0] == 1                      # scheduled hop
    assert sorted(slots.tolist()) == [0, 1, 2]  # one model per slot


def test_charge_schedule_replays_every_event_kind():
    led = ResourceLedger()
    sched = RoundSchedule(
        num_slots=2, ops=[],
        wire=[WireEvent("downlink", 1e6, 2.0, 2),
              WireEvent("d2d", 1e6, 1.0),
              WireEvent("uplink", 5e5, 2.0)],
        agg=[(0, 1.0), (1, 1.0)])
    charge_schedule(led, sched)
    assert led.downlink_models == 1
    assert led.uplink_models == 1
    assert led.transmitted_models == 2        # d2d + uplink
    assert led.subframes > 0
    with pytest.raises(ValueError):
        charge_schedule(led, dataclasses.replace(
            sched, wire=[WireEvent("sideways", 1.0, 1.0)]))


def test_mixop_matrix_is_row_stochastic():
    op = MixOp((((0, 2), (3.0, 1.0)),))
    w = op.matrix(4)
    np.testing.assert_allclose(w.sum(axis=1), 1.0, atol=1e-6)
    np.testing.assert_allclose(w[0], [0.75, 0.0, 0.25, 0.0])
    np.testing.assert_allclose(w[1], [0.0, 1.0, 0.0, 0.0])


def test_permuteop_compress_src_mask():
    op = PermuteOp(np.array([2, 0, 1]), np.array([True, False, True]),
                   compress=True)
    # trained dsts 0 and 2 receive from slots 2 and 1.
    assert op.compress_src_mask().tolist() == [False, True, True]


# ----------------------------------------------------- host vs fleet parity

@pytest.mark.parametrize("strategy", ["feddif", "fedavg", "fedswap"])
def test_host_fleet_parity(strategy):
    """Same seed + config: final params allclose, ledgers identical."""
    host = run_experiment(_spec(strategy, "host"))
    fleet = run_experiment(_spec(strategy, "fleet"))
    assert host.ledger.as_dict() == fleet.ledger.as_dict()
    assert host.diffusion_rounds == fleet.diffusion_rounds
    np.testing.assert_allclose(host.iid_distance, fleet.iid_distance,
                               atol=1e-6)
    for a, b in zip(jax.tree.leaves(host.final_params),
                    jax.tree.leaves(fleet.final_params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(host.accuracy, fleet.accuracy, atol=0.05)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_every_strategy_runs_on_fleet_executor(strategy):
    """All 10 Table-II strategies execute on the client-stacked data plane."""
    res = run_experiment(_spec(strategy, "fleet", rounds=1, clients=4))
    assert len(res.accuracy) == 1
    assert 0.0 <= res.accuracy[0] <= 1.0
    assert np.all(np.isfinite(
        np.concatenate([np.asarray(x, np.float32).ravel()
                        for x in jax.tree.leaves(res.final_params)])))


def test_fleet_rejects_unknown_executor():
    with pytest.raises(AssertionError):
        run_experiment(_spec("fedavg", "warp"))


def test_rejects_more_models_than_clients():
    """M ≤ N (constraint 18d): a clear error, not a slot-invariant crash."""
    spec = _spec("feddif", "host", clients=4)
    spec = dataclasses.replace(
        spec, fl=dataclasses.replace(spec.fl, num_models=8))
    with pytest.raises(ValueError, match="num_models"):
        run_experiment(spec)
