"""Trip-count-aware HLO analyzer validated against XLA cost_analysis on
loop-free modules and against hand-computed trip counts on scans."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze_hlo, xla_cost_analysis


def test_matches_cost_analysis_loop_free():
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = jax.jit(lambda x, y: x @ y).lower(a, a).compile()
    r = analyze_hlo(c.as_text())
    assert r["dot_flops"] == pytest.approx(xla_cost_analysis(c)["flops"],
                                           rel=1e-6)


def test_scan_multiplies_by_trip_count():
    def body(h, w):
        return jnp.tanh(h @ w), None

    ws = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    h0 = jax.ShapeDtypeStruct((4, 128), jnp.float32)
    c = jax.jit(lambda h, w: jax.lax.scan(body, h, w)[0]).lower(h0,
                                                                ws).compile()
    r = analyze_hlo(c.as_text())
    assert r["dot_flops"] == pytest.approx(8 * 2 * 4 * 128 * 128, rel=1e-6)


def test_nested_scan_compounds_multipliers():
    def outer(h, w):
        def inner(c2, x):
            return c2 + jnp.sum(x @ x), None
        s, _ = jax.lax.scan(inner, 0.0, w)
        return h + s, None

    ws = jax.ShapeDtypeStruct((5, 3, 16, 16), jnp.float32)
    c = jax.jit(lambda h, w: jax.lax.scan(outer, h, w)[0]).lower(
        jax.ShapeDtypeStruct((), jnp.float32), ws).compile()
    r = analyze_hlo(c.as_text())
    assert r["dot_flops"] == pytest.approx(5 * 3 * 2 * 16 * 16 * 16, rel=1e-6)


def test_hbm_bytes_positive_and_scales():
    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    c1 = jax.jit(lambda x: jnp.tanh(x) * 2).lower(a).compile()
    r1 = analyze_hlo(c1.as_text())
    b = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    c2 = jax.jit(lambda x: jnp.tanh(x) * 2).lower(b).compile()
    r2 = analyze_hlo(c2.as_text())
    assert r2["hbm_bytes"] > r1["hbm_bytes"] > 0


def test_no_collectives_single_device():
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(lambda x, y: x @ y).lower(a, a).compile()
    r = analyze_hlo(c.as_text())
    assert r["total_collective_bytes"] == 0.0
