"""Sharding-rule engine tests: spec shapes match param ranks, divisibility
guard works, and a miniature end-to-end lower on a host mesh succeeds."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_smoke_config
from repro.configs.base import ShapeConfig
from repro.distributed import sharding as sh
from repro.models import build_model


class FakeMesh:
    """Duck-typed mesh with the production geometry, no devices needed."""
    def __init__(self, shape: dict):
        self._shape = shape

    @property
    def shape(self):
        return self._shape

    @property
    def axis_names(self):
        return tuple(self._shape)


PROD = FakeMesh({"data": 16, "model": 16})


def _leaf_iter(params, specs):
    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    return [(sh._path_str(p), leaf, spec)
            for (p, leaf), spec in zip(flat_p, flat_s)]


@pytest.mark.parametrize("arch", ["qwen3_moe_235b_a22b", "mixtral_8x22b",
                                  "falcon_mamba_7b", "zamba2_2_7b",
                                  "whisper_base", "gemma3_4b"])
def test_param_specs_rank_and_divisibility(arch):
    cfg = get_config(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,),
                                                             jnp.uint32))
    specs = sh.param_specs(shapes, cfg, PROD, fsdp=True)
    for pstr, leaf, spec in _leaf_iter(shapes, specs):
        assert len(spec) <= len(leaf.shape), (pstr, spec, leaf.shape)
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = int(np.prod([PROD.shape[a] for a in axes]))
            assert dim % total == 0, (pstr, spec, leaf.shape)


def test_moe_expert_axis_strategy():
    """E=128 (divisible): expert-parallel; E=8 (mixtral): intra-expert TP."""
    cfg_q = get_config("qwen3_moe_235b_a22b")
    model = build_model(cfg_q)
    shapes = jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,),
                                                             jnp.uint32))
    specs = sh.param_specs(shapes, cfg_q, PROD, fsdp=True)
    found = [spec for pstr, leaf, spec in _leaf_iter(shapes, specs)
             if pstr.endswith("moe/w_gate")]
    assert found and all(tuple(s)[1] == "model" for s in found)  # stacked+E

    cfg_m = get_config("mixtral_8x22b")
    model = build_model(cfg_m)
    shapes = jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,),
                                                             jnp.uint32))
    specs = sh.param_specs(shapes, cfg_m, PROD, fsdp=True)
    found = [spec for pstr, leaf, spec in _leaf_iter(shapes, specs)
             if pstr.endswith("moe/w_gate")]
    # leading stacked dim None, E=8 not sharded, F on model
    assert found and all(tuple(s)[1] is None and "model" in tuple(s)
                         for s in found)


def test_whisper_vocab_not_sharded():
    cfg = get_config("whisper_base")
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,),
                                                             jnp.uint32))
    specs = sh.param_specs(shapes, cfg, PROD, fsdp=False)
    for pstr, leaf, spec in _leaf_iter(shapes, specs):
        if pstr == "embed/table":
            assert tuple(spec)[0] is None    # 51865 % 16 != 0 -> dropped


def test_needs_fsdp_heuristic():
    assert sh.needs_fsdp(get_config("qwen3_moe_235b_a22b"))
    assert sh.needs_fsdp(get_config("mixtral_8x22b"))
    assert not sh.needs_fsdp(get_config("smollm_360m"))
    assert not sh.needs_fsdp(get_config("qwen3_0_6b"))


def test_batch_specs_modes():
    shape_tr = ShapeConfig("t", 128, 32, "train")
    batch = {"tokens": jax.ShapeDtypeStruct((32, 128), jnp.int32)}
    spec = sh.batch_specs(batch, shape_tr, PROD)
    assert tuple(spec["tokens"])[0] == "data"
    # tiny batch replicates
    batch1 = {"tokens": jax.ShapeDtypeStruct((1, 128), jnp.int32)}
    spec1 = sh.batch_specs(batch1, shape_tr, PROD)
    assert tuple(spec1["tokens"])[0] is None


def test_cache_specs_seq_sharding():
    cfg = get_config("qwen3_0_6b")
    model = build_model(cfg)
    shape = ShapeConfig("d", 32768, 128, "decode")
    cache = model.cache_specs(shape)
    specs = sh.cache_specs(cache, shape, PROD)
    k_spec = specs["segments"][0]["0_attn"]["k"]
    assert tuple(k_spec)[1] == "data" and tuple(k_spec)[2] == "model"
    # long-context batch=1: seq over (data, model)
    shape_l = ShapeConfig("l", 524288, 1, "decode")
    cache_l = model.cache_specs(shape_l)
    specs_l = sh.cache_specs(cache_l, shape_l, PROD)
    k_spec_l = specs_l["segments"][0]["0_attn"]["k"]
    assert tuple(k_spec_l)[2] == ("data", "model")


def test_end_to_end_lower_on_host_mesh():
    """Real (1-device) mesh: specs must be accepted by jit and compile."""
    from repro.launch.mesh import activate_mesh, make_host_mesh
    from repro.train import optimizer as opt_lib
    from repro.train.trainstep import TrainState, make_train_step
    mesh = make_host_mesh(1, 1)
    cfg = get_smoke_config("smollm_360m")
    model = build_model(cfg)
    opt = opt_lib.sgd()
    with activate_mesh(mesh):
        key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
        state_shapes = jax.eval_shape(
            lambda k: TrainState(params=model.init(k),
                                 opt_state=opt.init(model.init(k)),
                                 step=jnp.zeros((), jnp.int32)), key_spec)
        pspecs = sh.param_specs(state_shapes.params, cfg, mesh, fsdp=False)
        sspecs = sh.state_specs(pspecs, state_shapes.opt_state)
        batch = {"tokens": jax.ShapeDtypeStruct((2, 32), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((2, 32), jnp.int32)}
        bspecs = sh.batch_specs(batch, ShapeConfig("t", 32, 2, "train"), mesh)
        step = make_train_step(model, opt)
        jitted = jax.jit(step, in_shardings=(sh.named(mesh, sspecs),
                                             sh.named(mesh, bspecs)))
        compiled = jitted.lower(state_shapes, batch).compile()
        from repro.launch.hlo_analysis import xla_cost_analysis
        assert xla_cost_analysis(compiled).get("flops", 0) > 0
