"""The adapter hop plane end to end: frozen-base/LoRA views on the "lm"
task, int8-packed PermuteOp wire, cross-executor parity (host / fleet /
sharded, ring and gather transports, fused round plane), the Eq.-15 ledger
decomposition with ``spec_adapter_bits``, and the full-params degenerate
path staying bit-identical for the CNN sweeps."""
import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.fl import ExperimentSpec, FLConfig, run_experiment
from repro.fl.adapters import make_adapter_view, packed_bits
from repro.fl.experiment import spec_adapter_bits, spec_model_bits
from repro.fl.models import build_task_model


def _spec(executor="host", task="lm", hop_quant="int8", adapter_hops=True,
          clients=4, rounds=2, **fl_kw):
    return ExperimentSpec(
        task=task, alpha=0.5, dim=16 if task == "lm" else 64,
        num_samples=640, adapter_hops=adapter_hops,
        fl=FLConfig(strategy="feddif", rounds=rounds, num_clients=clients,
                    num_models=clients, seed=0, topology_seed=1,
                    max_diffusion_rounds=3, executor=executor,
                    hop_quant=hop_quant, **fl_kw))


def _run_forced(code: str, devices: int, timeout: int = 600):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH",
                                                              ""))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


# -------------------------------------------- cross-executor quant parity

def test_host_fleet_sharded_parity_int8_lm():
    """One pack→unpack roundtrip per hop per slot on every plane: ledgers
    bit-identical, adapters within the executor-parity tolerance."""
    host = run_experiment(_spec("host"))
    fleet = run_experiment(_spec("fleet"))
    sharded = run_experiment(_spec("sharded", shard_overlap="on"))
    assert (host.ledger.as_dict() == fleet.ledger.as_dict()
            == sharded.ledger.as_dict())
    assert host.diffusion_rounds == fleet.diffusion_rounds
    for r in (fleet, sharded):
        for a, b in zip(jax.tree.leaves(host.final_params),
                        jax.tree.leaves(r.final_params)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=5e-4, rtol=5e-3)


def test_sharded_transports_and_planes_parity_2_devices():
    """On a real 2-device client mesh the packed wire rides the ring
    ppermute, the gather all_gather and the fused (overlapped) round plane
    — all three must reproduce the fleet reference."""
    code = """
import numpy as np, jax
assert len(jax.devices()) == 2, jax.devices()
from repro.fl import ExperimentSpec, FLConfig, run_experiment
def spec(executor, **kw):
    return ExperimentSpec(task="lm", alpha=0.5, dim=16, num_samples=640,
        fl=FLConfig(strategy="feddif", rounds=2, num_clients=4,
                    num_models=4, seed=0, topology_seed=1,
                    max_diffusion_rounds=3, executor=executor,
                    hop_quant="int8", **kw))
fleet = run_experiment(spec("fleet"))
for label, kw in (("ring_fused", {"shard_overlap": "on",
                                  "shard_hop_transport": "ring"}),
                  ("gather_fused", {"shard_overlap": "on",
                                    "shard_hop_transport": "gather"}),
                  ("op_by_op", {"shard_overlap": "off"})):
    r = run_experiment(spec("sharded", **kw))
    assert fleet.ledger.as_dict() == r.ledger.as_dict(), label
    assert fleet.diffusion_rounds == r.diffusion_rounds, label
    for a, b in zip(jax.tree.leaves(fleet.final_params),
                    jax.tree.leaves(r.final_params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-4, rtol=5e-3, err_msg=label)
print("ADAPTER_INT8_TRANSPORT_PARITY_OK")
"""
    assert "ADAPTER_INT8_TRANSPORT_PARITY_OK" in _run_forced(code, 2)


# --------------------------------------------------- frozen-base property

def test_frozen_base_bit_identical_through_diffusion():
    """Diffusion rounds move only the adapter: the merged full model's base
    leaves are bitwise the round-0 broadcast, while the LoRA leaves moved
    (b is zero-init, so any training shows up there)."""
    spec = _spec("host")
    r = run_experiment(spec)
    model = build_task_model(spec.task, spec.dim, spec.num_classes)
    view = make_adapter_view(model, spec.fl)
    base0, adapter0 = model.split(
        model.init(jax.random.PRNGKey(spec.fl.seed)))
    for a, b in zip(jax.tree.leaves(view.base), jax.tree.leaves(base0)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    full = view.merge_fn(r.final_params)
    base_f, adapter_f = model.split(full)
    for a, b in zip(jax.tree.leaves(base_f), jax.tree.leaves(base0)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    moved = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(adapter_f),
                        jax.tree.leaves(adapter0)))
    assert moved, "training/diffusion must move the adapter"


def test_full_params_tasks_unaffected_by_adapter_flag():
    """No-split tasks get the identity view: adapter_hops on/off is the
    same program — bit-identical ledger AND params (the CNN-sweep
    bit-compat guarantee)."""
    on = run_experiment(_spec("host", task="fcn", hop_quant="none",
                              adapter_hops=True))
    off = run_experiment(_spec("host", task="fcn", hop_quant="none",
                               adapter_hops=False))
    assert on.ledger.as_dict() == off.ledger.as_dict()
    for a, b in zip(jax.tree.leaves(on.final_params),
                    jax.tree.leaves(off.final_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------- ledger accounting

def test_ledger_charges_packed_adapter_bits():
    """transmitted_bits decomposes exactly into uplinks·(adapter fp32) +
    D2D hops·(int8-packed adapter); the round-0 base broadcast adds one
    downlink_models count (not bits-charged as a hop)."""
    spec = _spec("host")
    r = run_experiment(spec)
    led = r.ledger.as_dict()
    hop_bits = spec_adapter_bits(spec)
    view_f32 = spec_adapter_bits(dataclasses.replace(
        spec, fl=dataclasses.replace(spec.fl, hop_quant="none")))
    d2d = led["transmitted_models"] - led["uplink_models"]
    assert d2d > 0, "feddif must schedule D2D hops in this cell"
    expected = led["uplink_models"] * view_f32 + d2d * hop_bits
    np.testing.assert_allclose(led["transmitted_bits"], expected, rtol=1e-9)
    # one extra downlink: the round-0 frozen-base broadcast
    assert led["downlink_models"] == spec.fl.rounds + 1
    full = run_experiment(_spec("host", task="fcn", hop_quant="none",
                                adapter_hops=False))
    assert full.ledger.as_dict()["downlink_models"] == spec.fl.rounds


def test_spec_adapter_bits_relations():
    lm = _spec("host")
    lm_f32 = dataclasses.replace(
        lm, fl=dataclasses.replace(lm.fl, hop_quant="none"))
    full = dataclasses.replace(lm_f32, adapter_hops=False)
    b_int8 = spec_adapter_bits(lm)
    b_f32 = spec_adapter_bits(lm_f32)
    b_full = spec_adapter_bits(full)
    assert b_int8 < b_f32 < b_full
    assert b_full == spec_model_bits(lm)
    assert b_full / b_int8 >= 50.0           # the headline payload claim
    model = build_task_model("lm", lm.dim, lm.num_classes)
    _, adapter = model.split(
        jax.eval_shape(model.init, jax.random.PRNGKey(0)))
    assert b_int8 == packed_bits(adapter)
    # no-split task: spec_adapter_bits degenerates to spec_model_bits
    fcn = _spec("host", task="fcn", hop_quant="none")
    assert spec_adapter_bits(fcn) == spec_model_bits(fcn)


# ---------------------------------------------------------- spec validation

def test_experiment_spec_validates_at_construction():
    with pytest.raises(ValueError, match="unknown task"):
        ExperimentSpec(task="transformer")
    with pytest.raises(ValueError, match="square"):
        ExperimentSpec(task="cnn", dim=60)
    with pytest.raises(ValueError, match="divisible by 8"):
        ExperimentSpec(task="lstm", dim=30)
    with pytest.raises(AssertionError):
        run_experiment(ExperimentSpec(
            task="fcn", num_samples=200,
            fl=FLConfig(rounds=1, num_clients=2, num_models=2,
                        hop_quant="int4")))
