"""World-model tests: static degeneracy, scenarios end-to-end, energy
decomposition, the SNR interference API, and the pure world stepper.

Sizes are kept tiny for CI speed — the `scenario-smoke` job runs exactly
this file plus the fig_scenarios smoke sweep.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.channels.fading import ChannelModel
from repro.channels.resources import (PRB_HZ, TX_POWER_W,
                                      spectral_efficiency)
from repro.channels.topology import CellTopology
from repro.channels.world import (SCENARIOS, DEFAULT_ENERGY_BUDGET_J,
                                  HostWorld, WorldConfig, WorldState,
                                  cell_centers, init_world,
                                  per_client_energy_j,
                                  receiver_interference_w, step)
from repro.fl import ExperimentSpec, FLConfig, run_experiment
from repro.fl.server import _uplink_gamma


def _spec(scenario, strategy="feddif", rounds=3, **fl_kw):
    fl_kw.setdefault("max_diffusion_rounds", 3)
    return ExperimentSpec(
        task="fcn", alpha=0.3, num_samples=1200,
        fl=FLConfig(strategy=strategy, rounds=rounds, num_clients=6,
                    num_models=6, seed=0, topology_seed=11,
                    scenario=scenario, **fl_kw))


# ------------------------------------------------- degeneracy (the contract)

def test_static_world_consumes_exactly_the_legacy_draws():
    """static advance_round + uplink_gamma must consume the same RNG draws
    with the same arithmetic as the pre-world control plane — positions
    and gammas are bit-identical, interference is the python float 0.0."""
    topo, ch, n = CellTopology(num_pues=8), ChannelModel(), 8
    world = HostWorld.create("static", topo, ch, n)
    for t in range(3):
        rng_w = np.random.default_rng([11, t])
        rng_legacy = np.random.default_rng([11, t])
        pos = world.advance_round(rng_w)
        gamma = world.uplink_gamma(rng_w)
        pos_legacy = topo.sample_positions(rng_legacy, n)
        gamma_legacy = _uplink_gamma(ch, pos_legacy, rng_legacy)
        np.testing.assert_array_equal(pos, pos_legacy)
        np.testing.assert_array_equal(gamma, gamma_legacy)
    i = world.interference()
    assert isinstance(i, float) and i == 0.0
    assert not world.has_energy_cap


def test_static_run_bit_identical_to_scenario_default():
    """An explicit scenario="static" run equals the default-config run —
    same params hash, ledger fields, and accuracy curve."""
    res_a = run_experiment(_spec("static"))
    res_b = run_experiment(dataclasses.replace(
        _spec("static"), fl=dataclasses.replace(_spec("static").fl)))
    flat_a = jax.tree.leaves(res_a.params)
    flat_b = jax.tree.leaves(res_b.params)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert res_a.ledger.subframes == res_b.ledger.subframes
    assert res_a.history.accuracy == res_b.history.accuracy


# --------------------------------------------------- scenarios, end to end

@pytest.mark.parametrize("scenario", ["mobile", "multicell", "energy_capped"])
def test_scenario_runs_end_to_end(scenario):
    res = run_experiment(_spec(scenario))
    assert len(res.history.accuracy) >= 1
    assert np.isfinite(res.history.accuracy[-1])
    assert res.ledger.energy_j > 0.0


def test_multicell_interference_lowers_gamma():
    """Co-channel power from the other cells can only shrink SINR, so the
    multicell uplink γ sits below a zero-interference replay of the same
    draws."""
    topo, ch, n = CellTopology(num_pues=12), ChannelModel(), 12
    world = HostWorld.create("multicell", topo, ch, n)
    world.advance_round(np.random.default_rng([3, 0]))
    i_rx = world.interference()
    assert isinstance(i_rx, np.ndarray) and i_rx.shape == (n,)
    assert (i_rx > 0.0).all()
    # per-link broadcast: columns (receivers) carry the interference
    link = world.link_interference()
    assert link.shape == (n, n)
    np.testing.assert_array_equal(link[0], i_rx)
    np.testing.assert_array_equal(link[3], i_rx)


def test_mobile_positions_evolve_and_stay_in_disc():
    topo, ch, n = CellTopology(num_pues=10), ChannelModel(), 10
    world = HostWorld.create("mobile", topo, ch, n)
    p0 = world.advance_round(np.random.default_rng([5, 0])).copy()
    p1 = world.advance_round(np.random.default_rng([5, 1])).copy()
    move = world.cfg.speed_mps * world.cfg.round_s
    d = np.linalg.norm(p1 - p0, axis=-1)
    assert (d > 0.0).any()                      # the world actually moves
    assert (d <= move + 1e-9).all()             # but no faster than v·T
    assert (np.linalg.norm(p1, axis=-1) <= topo.radius_m + 1e-9).all()


def test_energy_cap_masks_training_but_not_wire():
    """Depletion reuses the churn semantics: dropped clients stop training
    and aggregating, but already-scheduled airtime still charges — so a
    partially-depleted capped run diverges in *learning* from the static
    run while both ledgers stay identical (energy_capped consumes the same
    RNG draws as static by construction)."""
    from repro.channels.resources import GAMMA_FLOOR
    from repro.fl.experiment import spec_model_bits
    spec = _spec("static", strategy="fedavg", rounds=4)
    # Replay round 0's uplink γ to pick a budget that splits the cohort:
    # three clients deplete after round 0, three never do.
    topo, ch = CellTopology(num_pues=6), ChannelModel()
    probe = HostWorld.create("energy_capped", topo, ch, 6)
    rng = np.random.default_rng([11, 0])
    probe.advance_round(rng)
    g0 = np.maximum(probe.uplink_gamma(rng), GAMMA_FLOOR)
    e0 = np.sort(TX_POWER_W * spec_model_bits(spec) / (g0 * PRB_HZ))
    budget = float((e0[2] + e0[3]) / 2)

    static = run_experiment(spec)
    capped = run_experiment(_spec("energy_capped", strategy="fedavg",
                                  rounds=4, energy_budget_j=budget))
    assert capped.ledger.subframes == static.ledger.subframes
    assert capped.ledger.transmitted_bits == static.ledger.transmitted_bits
    assert capped.ledger.energy_j == pytest.approx(static.ledger.energy_j)
    assert capped.history.accuracy != static.history.accuracy


def test_energy_cap_all_depleted_falls_back_to_full_round():
    """If depletion would empty the aggregation entirely, the round runs
    unchanged (the apply_churn no-0/0 fallback) — a vanishing budget is
    therefore bit-identical to no budget at all."""
    static = run_experiment(_spec("static", strategy="fedavg", rounds=3))
    capped = run_experiment(_spec("energy_capped", strategy="fedavg",
                                  rounds=3, energy_budget_j=1e-9))
    assert capped.history.accuracy == static.history.accuracy
    assert capped.ledger.subframes == static.ledger.subframes


def test_energy_capped_defaults_budget():
    w = HostWorld.create("energy_capped", CellTopology(), ChannelModel(), 4)
    assert w.cfg.energy_budget_j == DEFAULT_ENERGY_BUDGET_J
    assert w.has_energy_cap
    w.advance_round(np.random.default_rng(0))
    assert not w.depleted().any()
    w.charge_energy(np.full(4, 2 * DEFAULT_ENERGY_BUDGET_J))
    assert w.depleted().all()


# -------------------------------------------------- joules decomposition

def test_ledger_energy_matches_wire_event_decomposition():
    """`ledger.energy_j` must equal the per-client decomposition summed
    over clients: E = P_tx/B · Σ bits/γ over UE-sent wire events — the
    joule analogue of the transmitted-bits decomposition.  Downlink is
    BS-side and charges neither the ledger's joules nor any client."""
    from repro.channels.resources import ResourceLedger
    from repro.core.schedule import RoundSchedule, WireEvent, charge_schedule
    wire = [WireEvent("d2d", 2.4e5, 1.7, src=0),
            WireEvent("d2d", 2.4e5, 0.9, src=2),
            WireEvent("uplink", 2.4e5, 2.2, src=1),
            WireEvent("uplink", 2.4e5, 3.1, src=0),
            WireEvent("downlink", 2.4e5, 1.0, n_users=4, src=-1)]
    sched = RoundSchedule(num_slots=4, ops=[], wire=wire, agg=[])
    ledger = ResourceLedger()
    charge_schedule(ledger, sched)
    per_client = per_client_energy_j(sched, 4, PRB_HZ)
    analytic = sum(TX_POWER_W * ev.bits / (max(ev.gamma, 1e-9) * PRB_HZ)
                   for ev in wire
                   if ev.kind in ("d2d", "uplink") and ev.src >= 0)
    assert per_client.sum() == pytest.approx(analytic, rel=1e-12)
    assert ledger.energy_j == pytest.approx(per_client.sum(), rel=1e-9)
    assert per_client[3] == 0.0                    # never transmitted
    assert per_client[0] > per_client[1] > 0.0     # two events vs one


def test_run_energy_is_positive_and_restores_with_ledger():
    """End-to-end: the static feddif run charges joules alongside bits and
    the value survives in the result ledger."""
    res = run_experiment(_spec("static", rounds=2))
    assert res.ledger.energy_j > 0.0
    assert np.isfinite(res.ledger.energy_j)


# ------------------------------------------------------ snr API migration

def test_snr_interference_w_shim_warns_and_matches():
    ch = ChannelModel()
    gains = np.array([1e-9, 3e-9])
    import repro.channels.fading as fading
    fading._WARNED_INTERFERENCE_W = False
    with pytest.warns(DeprecationWarning, match="interference_w"):
        legacy = ch.snr(gains, interference_w=2e-13)
    np.testing.assert_array_equal(legacy, ch.snr(gains, 2e-13))
    # the new positional arg broadcasts per receiver
    per_rx = ch.snr(gains, np.array([0.0, 1e-12]))
    assert per_rx[1] < ch.snr(gains, 0.0)[1]


# ------------------------------------------------------- the pure stepper

def test_step_is_pure_and_jit_vmap_safe():
    cfg = WorldConfig(scenario="mobile")
    topo = CellTopology(num_pues=5)
    w = init_world(cfg, topo, np.random.default_rng(0), 5)
    w_jax = jax.tree.map(jnp.asarray, w)
    out1 = step(w_jax, jax.random.PRNGKey(0), step_m=cfg.step_m)
    out2 = step(w_jax, jax.random.PRNGKey(0), step_m=cfg.step_m)
    for a, b in zip(jax.tree.leaves(out1), jax.tree.leaves(out2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(out1.t) == int(w_jax.t) + 1
    # vmap over a batch of worlds
    batch = jax.tree.map(lambda x: jnp.stack([x, x]), w_jax)
    keys = jax.random.split(jax.random.PRNGKey(1), 2)
    stepped = jax.jit(jax.vmap(
        lambda wv, k: step(wv, k, step_m=cfg.step_m)))(batch, keys)
    assert stepped.positions.shape == (2, 5, 2)
    # keyless form is deterministic (the planner's in-loop transition)
    det = step(w_jax, None, step_m=cfg.step_m)
    np.testing.assert_array_equal(np.asarray(det.waypoints),
                                  np.asarray(w_jax.waypoints))


def test_host_and_jax_step_agree():
    """One keyless substep through HostWorld's numpy arithmetic and the jnp
    `step()` must land on the same positions (f32 tolerance)."""
    cfg = WorldConfig(scenario="mobile")
    topo = CellTopology(num_pues=16)
    w0 = init_world(cfg, topo, np.random.default_rng(7), 16)
    jax_next = step(jax.tree.map(lambda x: jnp.asarray(x, jnp.float32)
                                 if np.asarray(x).dtype.kind == "f" else
                                 jnp.asarray(x), w0),
                    None, step_m=cfg.step_m)
    delta = w0.waypoints - w0.positions
    d = np.linalg.norm(delta, axis=-1, keepdims=True)
    frac = np.minimum(cfg.step_m, d) / np.maximum(d, 1e-9)
    host_pos = w0.positions + delta * frac
    np.testing.assert_allclose(np.asarray(jax_next.positions), host_pos,
                               atol=1e-3)


def test_receiver_interference_excludes_serving_cell():
    cfg = WorldConfig(scenario="multicell", num_cells=3)
    centers = cell_centers(cfg, 250.0)
    ch = ChannelModel()
    # a UE sitting exactly on its serving center sees only the other cells
    pos = centers[:1].copy()
    i = receiver_interference_w(pos, np.array([0], np.int32), centers, ch)
    d_other = np.linalg.norm(pos[0] - centers[1:], axis=-1)
    beta = 10.0 ** (ch.large_scale_db(np.maximum(d_other, 1.0)) / 10.0)
    assert i[0] == pytest.approx((beta * ch.params.tx_power_w).sum())


# -------------------------------------------------- planner-mode parity

@pytest.mark.parametrize("scenario", ["mobile", "multicell"])
def test_host_and_jax_planner_agree_on_scenario(scenario):
    """The device-resident planner must see the same world as the host
    oracle: identical accuracy curve, ledger, and diffusion activity."""
    from repro.fl.engine import EngineSpec

    def _with_planner(mode):
        spec = _spec(scenario, rounds=2)
        return dataclasses.replace(spec, fl=dataclasses.replace(
            spec.fl, engine=EngineSpec(mode="host", planner=mode)))

    host = run_experiment(_with_planner("host"))
    dev = run_experiment(_with_planner("jax"))
    assert host.history.accuracy == dev.history.accuracy
    assert host.ledger.subframes == dev.ledger.subframes
    assert host.history.diffusion_rounds == dev.history.diffusion_rounds


def test_uncertainty_weight_changes_plans_not_contract():
    """Learning-value bidding perturbs the auction (different diffusion
    chains are allowed) but the run stays finite and charges energy; with
    weight 0 the value probe is never consulted."""
    fused = run_experiment(_spec("static", uncertainty_weight=0.5))
    assert np.isfinite(fused.history.accuracy[-1])
    plain = run_experiment(_spec("static"))
    base = run_experiment(_spec("static", uncertainty_weight=0.0))
    assert plain.history.accuracy == base.history.accuracy
    assert plain.ledger.subframes == base.ledger.subframes


def test_mobile_planner_compiles_once_per_round_signature(monkeypatch):
    """World stepping inside the jitted while_loop must not retrace: a
    4-round mobile run with the device planner traces `_plan_rounds`
    exactly once (shapes and statics are round-invariant)."""
    from repro.core import planner as P
    from repro.fl.engine import EngineSpec

    traces = {"n": 0}
    orig = P._plan_rounds

    def counting(*a, **k):
        traces["n"] += 1
        return orig(*a, **k)

    monkeypatch.setattr(P, "plan_rounds", jax.jit(
        counting, static_argnames=("metric", "allow_retraining",
                                   "mobility", "step_m", "use_value")))
    spec = _spec("mobile", rounds=4)
    spec = dataclasses.replace(spec, fl=dataclasses.replace(
        spec.fl, engine=EngineSpec(mode="host", planner="jax")))
    run_experiment(spec)
    assert traces["n"] == 1
