"""2-D ``("clients", "model")`` mesh executor: factorization, non-divisible
padding, and N=256 parity on a forced 8-device CPU mesh.

Tier-1 runs on one CPU device where ``make_fl_mesh`` degenerates to a
``(1, 1)`` mesh; the multi-device behaviour (model-axis ring shifts, padded
client shards, all_to_all reshards) is exercised in subprocesses that force
``XLA_FLAGS=--xla_force_host_platform_device_count=K`` before the first jax
import — the same topology CI's mesh2d job drives.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.fl import ExperimentSpec, FLConfig, run_experiment
from repro.fl.executors import _chunked_permutation_tables
from repro.launch.mesh import make_fl_mesh


def _run_forced(code: str, devices: int, timeout: int = 600):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH",
                                                              ""))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


# ------------------------------------------------------------ mesh factory

def test_make_fl_mesh_degenerates_on_one_device():
    mesh = make_fl_mesh(64, model=4, max_devices=1)
    assert dict(mesh.shape) == {"clients": 1, "model": 1}
    assert tuple(mesh.axis_names) == ("clients", "model")


def test_make_fl_mesh_factorizes_forced_devices():
    """On an 8-device mesh the model axis takes the largest divisor ≤ the
    request and the client axis the rest, clamped to N."""
    code = """
from repro.launch.mesh import make_fl_mesh
shapes = {
    "m1": dict(make_fl_mesh(64).shape),
    "m2": dict(make_fl_mesh(64, model=2).shape),
    "m3": dict(make_fl_mesh(64, model=3).shape),   # 3 ∤ 8 -> falls back to 2
    "m8": dict(make_fl_mesh(64, model=8).shape),
    "small_n": dict(make_fl_mesh(3).shape),        # never > N client shards
}
assert shapes["m1"] == {"clients": 8, "model": 1}, shapes
assert shapes["m2"] == {"clients": 4, "model": 2}, shapes
assert shapes["m3"] == {"clients": 4, "model": 2}, shapes
assert shapes["m8"] == {"clients": 1, "model": 8}, shapes
assert shapes["small_n"] == {"clients": 3, "model": 1}, shapes
print("MESH_FACTORIZATION_OK")
"""
    assert "MESH_FACTORIZATION_OK" in _run_forced(code, 8, timeout=120)


# ------------------------------------------------- chunked hop routing table

@pytest.mark.parametrize("c,k,chunks", [(8, 2, 2), (16, 4, 2), (12, 2, 3)])
def test_chunked_permutation_tables_route_every_row(c, k, chunks):
    """Replaying the per-chunk send/recv tables in numpy reproduces
    take(x, perm) chunk by chunk — the double-buffered hop's invariant that
    chunk j+1's sends never read rows chunk j already overwrote."""
    rng = np.random.default_rng(c + k + chunks)
    nl, mb = c // k, c // k // chunks
    for _ in range(5):
        perm = rng.permutation(c)
        send, recv = _chunked_permutation_tables(perm, k, chunks)
        x = np.arange(c)
        out = np.full((k, nl), -1)
        for j in range(chunks):
            buf_out = np.full((k, mb + 1), -1)     # chunk block + trash row
            for shift in range(k):
                for s in range(k):
                    d = (s + shift) % k
                    buf = x[s * nl:(s + 1) * nl][send[s, j, shift]]
                    buf_out[d][recv[d, j, shift]] = buf
            out[:, j * mb:(j + 1) * mb] = buf_out[:, :mb]
        np.testing.assert_array_equal(out.ravel(), x[perm])


# --------------------------------------- non-divisible shapes (padded shards)

def test_nondivisible_clients_and_model_axis_keep_parity():
    """N=10 on a 4-device mesh (client axis pads 10→12 slots) and the same
    N with a 2-way model axis (flattened feature count padded to an even
    split) must both reproduce the host plane: identical ledgers, matching
    params — padding slots carry zero aggregation weight and never leak."""
    code = """
import numpy as np, jax
assert len(jax.devices()) == 4, jax.devices()
from repro.fl import ExperimentSpec, FLConfig, run_experiment
def spec(executor, **kw):
    return ExperimentSpec(task="fcn", alpha=0.5, num_samples=1000,
        fl=FLConfig(strategy="feddif", rounds=2, num_clients=10,
                    num_models=10, seed=0, topology_seed=1,
                    max_diffusion_rounds=3, executor=executor, **kw))
host = run_experiment(spec("host"))
for label, kw in (("pad_clients", {"shard_overlap": "on"}),
                  ("pad_model", {"shard_overlap": "on",
                                 "mesh_model_axis": 2})):
    r = run_experiment(spec("sharded", **kw))
    assert host.ledger.as_dict() == r.ledger.as_dict(), label
    assert host.diffusion_rounds == r.diffusion_rounds, label
    for a, b in zip(jax.tree.leaves(host.final_params),
                    jax.tree.leaves(r.final_params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-4, rtol=5e-3, err_msg=label)
print("NONDIVISIBLE_PARITY_OK")
"""
    assert "NONDIVISIBLE_PARITY_OK" in _run_forced(code, 4)


# ------------------------------------------------ N=256 parity on a 2-D mesh

def test_n256_parity_on_2d_mesh_8_devices():
    """The acceptance topology: N=256 on a forced 8-device (4×2) mesh with a
    2-way model axis, overlapped (fused) and op-by-op planes both matching
    the single-device fleet reference bit-for-bit on the ledger."""
    code = """
import numpy as np, jax
assert len(jax.devices()) == 8, jax.devices()
from repro.fl import ExperimentSpec, FLConfig, run_experiment
def spec(executor, **kw):
    return ExperimentSpec(task="fcn", alpha=0.5, num_samples=25600,
        fl=FLConfig(strategy="feddif", rounds=1, num_clients=256,
                    num_models=256, seed=0, topology_seed=1,
                    max_diffusion_rounds=2, executor=executor, **kw))
fleet = run_experiment(spec("fleet"))
for label, kw in (("fused", {"shard_overlap": "on", "mesh_model_axis": 2}),
                  ("op_by_op", {"mesh_model_axis": 2, "shard_overlap": "off"})):
    r = run_experiment(spec("sharded", **kw))
    assert fleet.ledger.as_dict() == r.ledger.as_dict(), label
    assert fleet.diffusion_rounds == r.diffusion_rounds, label
    for a, b in zip(jax.tree.leaves(fleet.final_params),
                    jax.tree.leaves(r.final_params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-4, rtol=5e-3, err_msg=label)
print("N256_MESH2D_PARITY_OK")
"""
    assert "N256_MESH2D_PARITY_OK" in _run_forced(code, 8)


# ------------------------------------------------- single-device 2-D configs

def test_mesh_model_axis_degenerates_cleanly_on_one_device():
    """mesh_model_axis > 1 on a single-device host must be a no-op (the mesh
    clamps to (1, 1)) — same ledger and params as the plain sharded run."""
    def spec(**kw):
        return ExperimentSpec(
            task="fcn", alpha=0.5, num_samples=800,
            fl=FLConfig(strategy="feddif", rounds=1, num_clients=8,
                        num_models=8, seed=0, topology_seed=1,
                        max_diffusion_rounds=2, executor="sharded", **kw))
    base = run_experiment(spec())
    m2 = run_experiment(spec(mesh_model_axis=4))
    assert base.ledger.as_dict() == m2.ledger.as_dict()
    for a, b in zip(jax.tree.leaves(base.final_params),
                    jax.tree.leaves(m2.final_params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-4, rtol=5e-3)
