"""Per-architecture sharding rules (PartitionSpec pytrees).

Scheme (DESIGN.md §5): tensor/expert parallelism over the ``model`` mesh
axis, optional FSDP over ``data``, pure data parallelism for the batch, and
sequence-sharded KV caches for decode.  Rules are matched on the flattened
parameter path; stacked-layer prefixes (``segments/``, ``enc_layers/``,
``dec_layers/``) transparently add a leading replicated dim.

Uneven shardings (e.g. whisper's 51865 vocab over 16) are allowed — XLA SPMD
pads internally.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

__all__ = ["param_specs", "batch_specs", "cache_specs", "state_specs",
           "needs_fsdp", "named", "client_stacked_specs", "client_shardings",
           "fl_stacked_specs", "fl_shardings", "fl_batch_specs",
           "MODEL_AXIS", "DATA_AXIS", "CLIENT_AXIS", "FL_AXES"]

MODEL_AXIS = "model"
DATA_AXIS = "data"
CLIENT_AXIS = "clients"
# The 2-D FL mesh of repro.launch.mesh.make_fl_mesh.  In "train layout" the
# leading client axis shards over the *combined* axis (every device holds an
# equal block of whole clients); the hop plane temporarily re-lays params
# feature-split over MODEL_AXIS (see executors.ShardedFleetExecutor).
FL_AXES = (CLIENT_AXIS, MODEL_AXIS)


def client_stacked_specs(tree: Any) -> Any:
    """PartitionSpec pytree for a *client-stacked* pytree: the leading client
    axis of every leaf is sharded over :data:`CLIENT_AXIS`, everything else
    replicated.  This is the spec family the sharded fleet executor and
    ``repro.launch.fl_spmd --shard-clients`` use — per-client model shards
    stay whole on their shard (FL clients are independent; only diffusion
    hops and the Eq.-11 aggregation cross shards)."""
    return jax.tree.map(lambda _: P(CLIENT_AXIS), tree)


def client_shardings(mesh, tree: Any) -> Any:
    """``NamedSharding`` pytree matching :func:`client_stacked_specs`."""
    return named(mesh, client_stacked_specs(tree))


def fl_stacked_specs(tree: Any) -> Any:
    """Train-layout specs on the 2-D FL mesh: the leading client axis of
    every leaf shards over the combined ``("clients", "model")`` axis —
    each of the ``kc·km`` devices holds an equal block of whole clients.
    The slot count must be padded to a multiple of the mesh size first
    (``ShardedFleetExecutor`` zero-weights the padding slots)."""
    return jax.tree.map(lambda _: P(FL_AXES), tree)


def fl_shardings(mesh, tree: Any) -> Any:
    """``NamedSharding`` pytree matching :func:`fl_stacked_specs`."""
    return named(mesh, fl_stacked_specs(tree))


def fl_batch_specs(tree: Any) -> Any:
    """Specs for a *step-stacked* batch pytree ``(steps, C_pad, ...)``: the
    client axis (dim 1) shards over the combined FL axis, the step axis is
    replicated — the layout the fused round plane streams sessions from."""
    return jax.tree.map(lambda _: P(None, FL_AXES), tree)

# (regex on leaf path, spec factory(shape, fsdp) -> PartitionSpec)
# First match wins.  `d` = the FSDP axis (None when fsdp disabled).
_RULES: list[tuple[str, Any]] = [
    # --- embeddings / heads ---
    (r"embed/table$",        lambda s, d: P(MODEL_AXIS, d)),
    (r"lm_head/w$",          lambda s, d: P(d, MODEL_AXIS)),
    # --- attention ---
    (r"w[qkv]/w$",           lambda s, d: P(d, MODEL_AXIS)),
    (r"wo/w$",               lambda s, d: P(MODEL_AXIS, d)),
    (r"[qk]_norm/scale$",    lambda s, d: P(None)),
    # --- dense MLP (SwiGLU + whisper GELU) ---
    (r"mlp/w_gate/w$",       lambda s, d: P(d, MODEL_AXIS)),
    (r"mlp/w_up/w$",         lambda s, d: P(d, MODEL_AXIS)),
    (r"mlp/w_down/w$",       lambda s, d: P(MODEL_AXIS, d)),
    (r"mlp/w1/w$",           lambda s, d: P(d, MODEL_AXIS)),
    (r"mlp/w2/w$",           lambda s, d: P(MODEL_AXIS, d)),
    # --- MoE (expert-parallel when E % axis == 0, else intra-expert TP) ---
    (r"moe/router/w$",       lambda s, d: P(d, None)),
    (r"moe/w_gate$",         "_moe_in"),
    (r"moe/w_up$",           "_moe_in"),
    (r"moe/w_down$",         "_moe_out"),
    (r"moe/shared/w_gate/w$", lambda s, d: P(d, MODEL_AXIS)),
    (r"moe/shared/w_up/w$",  lambda s, d: P(d, MODEL_AXIS)),
    (r"moe/shared/w_down/w$", lambda s, d: P(MODEL_AXIS, d)),
    # --- Mamba-1 ---
    (r"mamba/in_proj/w$",    lambda s, d: P(d, MODEL_AXIS)),
    (r"mamba/conv_w$",       lambda s, d: P(None, MODEL_AXIS)),
    (r"mamba/conv_b$",       lambda s, d: P(MODEL_AXIS)),
    (r"mamba/x_proj/w$",     lambda s, d: P(MODEL_AXIS, None)),
    (r"mamba/dt_proj/w$",    lambda s, d: P(None, MODEL_AXIS)),
    (r"mamba/dt_proj/b$",    lambda s, d: P(MODEL_AXIS)),
    (r"mamba/a_log$",        lambda s, d: (P(MODEL_AXIS, None) if len(s) == 2
                                           else P(MODEL_AXIS))),
    (r"mamba/d_skip$",       lambda s, d: P(MODEL_AXIS)),
    (r"mamba/out_proj/w$",   lambda s, d: P(MODEL_AXIS, d)),
    # --- Mamba-2 ---
    (r"mamba/w_zx/w$",       lambda s, d: P(d, MODEL_AXIS)),
    (r"mamba/w_bc/w$",       lambda s, d: P(d, None)),
    (r"mamba/w_dt/w$",       lambda s, d: P(d, MODEL_AXIS)),
    (r"mamba/conv_x/w$",     lambda s, d: P(None, MODEL_AXIS)),
    (r"mamba/conv_x/b$",     lambda s, d: P(MODEL_AXIS)),
    (r"mamba/conv_bc/[wb]$", lambda s, d: P(None)),
    (r"mamba/dt_bias$",      lambda s, d: P(MODEL_AXIS)),
    (r"mamba/out_norm/scale$", lambda s, d: P(MODEL_AXIS)),
    # --- norms & scalars ---
    (r"(ln\d?|ln_x|final_norm|enc_norm|dec_norm)/(scale|bias)$",
     lambda s, d: P(None)),
]


def _moe_spec_in(shape, d, model_size):
    e = shape[0]
    if e % model_size == 0:
        return P(MODEL_AXIS, d, None)       # expert parallel
    return P(None, d, MODEL_AXIS)           # intra-expert TP (mixtral: E=8)


def _moe_spec_out(shape, d, model_size):
    e = shape[0]
    if e % model_size == 0:
        return P(MODEL_AXIS, None, d)
    return P(None, MODEL_AXIS, d)


_STACK_PREFIXES = ("segments/", "enc_layers", "dec_layers")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(getattr(p, "name", p)))
    return "/".join(parts)


def needs_fsdp(cfg: ModelConfig, model_size: int = 16,
               hbm_budget_bytes: float = 8e9) -> bool:
    """FSDP over `data` when fp32 params + momentum per TP shard exceed
    half the HBM budget (leaving room for activations)."""
    bytes_per_shard = cfg.param_count() * 8.0 / model_size
    return bytes_per_shard > hbm_budget_bytes / 2


def param_specs(params_or_shapes: Any, cfg: ModelConfig, mesh,
                fsdp: bool | None = None) -> Any:
    """PartitionSpec pytree matching the parameter pytree."""
    model_size = mesh.shape[MODEL_AXIS]
    if fsdp is None:
        fsdp = needs_fsdp(cfg, model_size)
    d = DATA_AXIS if fsdp else None

    axis_sizes = {a: mesh.shape[a] for a in mesh.axis_names}

    def fit(spec: P, shape: tuple) -> P:
        """Drop mesh axes whose size does not divide the dim (jit requires
        exact divisibility for explicit in_shardings — e.g. whisper's 51865
        vocab over 16)."""
        out = []
        for dim, ax in zip(shape, tuple(spec) + (None,) * len(shape)):
            if ax is None:
                out.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = int(np.prod([axis_sizes[a] for a in axes]))
            out.append(ax if dim % total == 0 else None)
        return P(*out)

    def one(path, leaf) -> P:
        pstr = _path_str(path)
        stacked = pstr.startswith("segments/") or "_layers/" in pstr \
            or pstr.startswith(("enc_layers", "dec_layers"))
        shape = tuple(leaf.shape)
        core_shape = shape[1:] if stacked else shape
        for pattern, fn in _RULES:
            if re.search(pattern, pstr):
                if fn == "_moe_in":
                    spec = _moe_spec_in(core_shape, d, model_size)
                elif fn == "_moe_out":
                    spec = _moe_spec_out(core_shape, d, model_size)
                else:
                    spec = fn(core_shape, d)
                if len(spec) > len(core_shape):
                    spec = P(*spec[:len(core_shape)])
                spec = fit(spec, core_shape)
                if stacked:
                    spec = P(None, *spec)
                return spec
        # default: replicate
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(one, params_or_shapes)


def batch_specs(batch: Any, shape_cfg: ShapeConfig, mesh) -> Any:
    """Input batch sharding: batch dim over every data-parallel axis."""
    dp_axes = tuple(a for a in mesh.axis_names if a != MODEL_AXIS)
    dp_size = int(np.prod([mesh.shape[a] for a in dp_axes]))

    def one(path, leaf):
        b = leaf.shape[0]
        if b % dp_size == 0:
            lead = dp_axes if len(dp_axes) > 1 else dp_axes[0]
            return P(lead, *([None] * (len(leaf.shape) - 1)))
        # tiny global batch (long_500k): replicate batch dim
        return P(*([None] * len(leaf.shape)))

    return jax.tree_util.tree_map_with_path(one, batch)


def cache_specs(cache: Any, shape_cfg: ShapeConfig, mesh) -> Any:
    """KV/SSM cache sharding for decode.

    Attention K/V  (layers, B, S, KH, hd): batch over data axes, sequence
    over ``model`` (flash-decoding combine).  When the batch is too small to
    shard (long_500k B=1) the sequence is sharded over (data, model).
    SSM conv/h states: batch over data, channel/head dim over model.
    """
    dp_axes = tuple(a for a in mesh.axis_names if a != MODEL_AXIS)
    dp_size = int(np.prod([mesh.shape[a] for a in dp_axes]))
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    axis_sizes = {a: mesh.shape[a] for a in mesh.axis_names}

    def fit(spec: P, shape: tuple) -> P:
        out = []
        for dim, ax in zip(shape, tuple(spec) + (None,) * len(shape)):
            if ax is None:
                out.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = int(np.prod([axis_sizes[a] for a in axes]))
            out.append(ax if dim % total == 0 else None)
        return P(*out)

    def one(path, leaf):
        pstr = _path_str(path)
        shape = tuple(leaf.shape)
        batch_ok = shape[1] % dp_size == 0 if len(shape) > 1 else False
        if re.search(r"/[kv]$", pstr):      # (L, B, S, KH, hd)
            if batch_ok:
                return fit(P(None, dp, MODEL_AXIS, None, None), shape)
            return fit(P(None, None, (*dp_axes, MODEL_AXIS), None, None),
                       shape)
        if pstr.endswith("/h"):             # mamba1 (L,B,di,N) / m2 (L,B,H,P,N)
            bspec = dp if batch_ok else None
            if len(shape) == 4:
                return fit(P(None, bspec, MODEL_AXIS, None), shape)
            return fit(P(None, bspec, MODEL_AXIS, None, None), shape)
        if "conv" in pstr:                  # (L, B, k-1, C)
            bspec = dp if batch_ok else None
            if pstr.endswith("bc"):
                return fit(P(None, bspec, None, None), shape)
            return fit(P(None, bspec, None, MODEL_AXIS), shape)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(one, cache)


def state_specs(pspecs: Any, opt_state_like: Any) -> Any:
    """TrainState sharding: opt moments mirror param specs; step replicated."""
    from repro.train.trainstep import TrainState

    def opt_map(subtree):
        # opt states are dicts whose leaves mirror params ('mu', 'm', 'v')
        def one(path, leaf):
            return leaf
        return subtree

    # Build opt-state specs by structural recursion: every leaf of the opt
    # state that has the same path suffix as a param gets that param's spec.
    flat_p = {_path_str(p): s for p, s in
              jax.tree_util.tree_flatten_with_path(pspecs)[0]}

    def one(path, leaf):
        pstr = _path_str(path)
        # strip the leading moment name (mu/m/v)
        for prefix in ("mu/", "m/", "v/"):
            if pstr.startswith(prefix):
                suffix = pstr[len(prefix):]
                if suffix in flat_p:
                    return flat_p[suffix]
        if pstr in ("count",):
            return P()
        return P(*([None] * len(getattr(leaf, "shape", ()))))

    ospecs = jax.tree_util.tree_map_with_path(one, opt_state_like)
    return TrainState(params=pspecs, opt_state=ospecs,
                      step=P())


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
