from repro.distributed import sharding
from repro.distributed.fedshard import (make_fleet_train_step,
                                        make_diffusion_step, fleet_aggregate,
                                        diffuse_params)
