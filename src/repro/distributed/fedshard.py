"""SPMD FedDif data plane: client-stacked training, diffusion exchange, and
weighted aggregation as jit-compiled collectives.

Mapping (DESIGN.md §2): FL clients are stacked on a leading axis of every
state/batch leaf, sharded over a *client axis* of the mesh — ``pod`` on the
2×16×16 multi-pod mesh (one client per pod: the faithful pod-scale regime)
or ``data`` on-pod for paper-scale fleets (M ≈ 10 small models).

* local step      = ``jax.vmap(train_step)`` over the client axis
* diffusion hop   = ``take(params, perm, axis=0)`` — XLA lowers the gather
  across the client-sharded axis to a collective-permute, which IS the
  paper's D2D model transmission (Eq. 15's S bits on the wire)
* aggregation     = data-size-weighted mean over the client axis (Eq. 11),
  lowered to an all-reduce
* selective training (auction winners only) = `train_mask` select between
  updated and carried state — FedDif's partial participation.

Relation to the RoundSchedule / Executor seam
---------------------------------------------
This module is a *data plane*, deliberately strategy-agnostic: it executes
whatever ``(src_of_dst, train_mask, weights)`` arrays it is handed and never
consults the auction, the DoL state, or the wireless ledger.  Those arrays
are one op of a :class:`~repro.core.schedule.RoundSchedule` — the IR every
strategy scheduler in ``repro.fl.schedulers`` emits:
:func:`~repro.core.schedule.complete_round_permutation` completes a partial
hop set (FedDif's auction matching, FedSwap's swaps, the random walk's
waves) into the slot bijection consumed here; an all-``True`` mask with an
identity permutation is FedAvg.  ``repro.fl.executors.FleetExecutor`` runs
whole schedules on a client-stacked fleet out of this module's primitives
(vmapped train, :func:`diffuse_params`, :func:`fleet_aggregate`, and
:func:`masked_stc_compress` for the STC-compressed hops of ``stc`` /
``feddif_stc``); ``repro.launch.fl_spmd`` does the same for LM fleets with
:func:`make_diffusion_step`.  Adding a strategy means writing a scheduler —
nothing in this file needs to change.  The same split is what the sweep
orchestrator's plan cache exploits: schedules are pure host-side control
state, replayable across replicate seeds, while this data plane does all
seed-dependent work.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.zoo import Model
from repro.train import optimizer as opt_lib
from repro.train.trainstep import TrainState, make_train_step

Params = Any

# Re-exported for data-plane callers: the flatten/unflatten pair lives with
# the kernels (kernels/diffusion.py) so kernels.ops can use it cycle-free.
from repro.kernels.diffusion import stack_ravel, stack_unravel  # noqa: E402

__all__ = ["make_fleet_train_step", "make_diffusion_step", "fleet_aggregate",
           "diffuse_params", "masked_stc_compress", "stack_ravel",
           "stack_unravel"]


def diffuse_params(params: Params, perm: jax.Array) -> Params:
    """One diffusion round: model in client-slot c moves to slot perm[c].

    ``perm`` is the *destination-major* gather index: new[c] = old[src[c]];
    callers pass ``src_of_dst`` (inverse of the planner's perm).
    """
    return jax.tree.map(lambda x: jnp.take(x, perm, axis=0), params)


def fleet_aggregate(params: Params, weights: jax.Array) -> Params:
    """Eq. (11): weighted FedAvg over the leading client axis -> broadcast
    back to every client slot (the BS broadcast of the next round)."""
    w = weights / jnp.maximum(jnp.sum(weights), 1e-9)

    def one(x):
        avg = jnp.tensordot(w.astype(jnp.float32),
                            x.astype(jnp.float32), axes=(0, 0))
        return jnp.broadcast_to(avg[None], x.shape).astype(x.dtype)

    return jax.tree.map(one, params)


def masked_stc_compress(params: Params, ref: Params, mask: jax.Array,
                        sparsity: float = 0.01,
                        implementation: str = "auto") -> Params:
    """STC-compress selected slots of a client-stacked pytree against ``ref``.

    Slot ``c`` with ``mask[c]`` becomes ``ref + STC(params[c] − ref)`` — the
    paper's compressed D2D payload (the receiver reconstructs the round-start
    global plus the ternarized delta); other slots pass through untouched.
    ``ref`` is unstacked (the broadcast global every PUE already holds).
    Used by the fleet executor for ``stc`` / ``feddif_stc`` hops and uplinks.

    The per-leaf ternarize runs through :func:`repro.kernels.ops.stc_topk`
    (per-row top-k thresholds, as the host path's per-leaf ``top_k``): the
    Pallas kernel on TPU / under ``REPRO_KERNELS_IMPL``, the exact host
    composite otherwise.
    """
    from repro.kernels import ops

    def leaf(x, r):
        c = x.shape[0]
        out = ops.stc_topk(x.reshape(c, -1), r.reshape(-1), mask, sparsity,
                           implementation=implementation)
        return out.reshape(x.shape).astype(x.dtype)

    return jax.tree.map(leaf, params, ref)


def make_fleet_train_step(model: Model, opt: opt_lib.Optimizer,
                          lr: float = 0.01, remat: bool = True):
    """vmapped local update over the leading client axis."""
    step = make_train_step(model, opt, opt_lib.constant_lr(lr), remat=remat)
    return jax.vmap(step)


def make_diffusion_step(model: Model, opt: opt_lib.Optimizer,
                        lr: float = 0.01, remat: bool = True) -> Callable:
    """One full FedDif diffusion round over a client-stacked fleet.

    Args of the returned function:
      state:      TrainState with leading client axis C on every leaf.
      batch:      per-client batches, leading axis C.
      src_of_dst: (C,) int32 — slot c receives the model from src_of_dst[c].
      train_mask: (C,) bool — True where the receiving client trains
                  (auction winners; constraint 18d).
      weights:    (C,) float — chain data sizes for the final aggregation
                  (pass None to skip aggregation — mid-round hop).
    """
    fleet_step = make_fleet_train_step(model, opt, lr, remat)
    from repro.models.layers import perf_opt_enabled
    params_only = perf_opt_enabled("params_only_diffusion")
    wire_bf16 = perf_opt_enabled("wire_bf16")

    def _move(tree, perm):
        if not wire_bf16:
            return diffuse_params(tree, perm)
        # §Perf P3: D2D hops ship bf16 (the paper ships fp32 — Table II
        # charges 32 b/param); master copies stay fp32 locally.  The
        # optimization barrier pins the convert BEFORE the cross-pod gather
        # — without it XLA may legally move the (elementwise) convert to
        # the receiving side and put fp32 on the wire.
        down = jax.tree.map(
            lambda x: x.astype(jnp.bfloat16)
            if x.dtype == jnp.float32 else x, tree)
        down = jax.lax.optimization_barrier(down)
        moved = diffuse_params(down, perm)
        return jax.tree.map(lambda m, ref: m.astype(ref.dtype), moved, tree)

    def diffusion_step(state: TrainState, batch, src_of_dst, train_mask,
                       weights=None):
        # 1. D2D model transmission (collective-permute over client axis).
        #    §Perf P3: the paper's PUSCH payload is the MODEL only — every
        #    hop starts a fresh local SGD session at the receiving PUE
        #    (client.py semantics), so moving the optimizer state wastes
        #    wire bytes; momentum restarts from zero instead.
        if params_only:
            opt_state = jax.tree.map(
                lambda x: jnp.zeros_like(x)
                if x.dtype in (jnp.float32, jnp.bfloat16) else x,
                state.opt_state)
        else:
            opt_state = diffuse_params(state.opt_state, src_of_dst)
        moved = TrainState(
            params=_move(state.params, src_of_dst),
            opt_state=opt_state,
            step=state.step)
        # 2. Local update at the receiving clients.
        trained, metrics = fleet_step(moved, batch)
        # 3. Winners keep the trained model; others carry the received one.
        def select(a, b):
            m = train_mask.reshape((-1,) + (1,) * (a.ndim - 1))
            return jnp.where(m, a, b)
        out = jax.tree.map(select, trained, moved)
        # 4. Optional global aggregation (end of the communication round).
        if weights is not None:
            out = TrainState(params=fleet_aggregate(out.params, weights),
                             opt_state=out.opt_state, step=out.step)
        return out, metrics

    return diffusion_step
