"""End-to-end FL-LM training driver (deliverable b).

Trains an assigned-architecture LM with FedDif over Dirichlet-non-IID client
shards of a synthetic corpus, charging communication to the wireless ledger,
and checkpointing the global model.

    PYTHONPATH=src python -m repro.launch.train --arch smollm_360m --smoke \
        --rounds 8 --clients 4 --steps-per-round 8

``--smoke`` uses the reduced same-family config (CPU-friendly); omit it on
real hardware to train the full config (e.g. the ~360M smollm on a pod).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.data.partitioner import dirichlet_partition
from repro.data.synthetic import class_labels_for_lm, lm_corpus
from repro.fl.server import FLConfig, run_federated
from repro.models import build_model
from repro.train import save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm_360m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU)")
    ap.add_argument("--strategy", default="feddif",
                    choices=["feddif", "fedavg", "fedswap", "stc"])
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--steps-per-round", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--engine", default=None,
                    help="EngineSpec preset (host/fleet/sharded/auto/async/"
                         "async_barrier); default: legacy host loop")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.frontend is not None:
        raise SystemExit(f"{args.arch} needs frontend embeddings; use the "
                         "dry-run for this arch or a text arch here.")
    model = build_model(cfg)
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"(config geometry)")

    # --- data: synthetic corpus, Dirichlet-partitioned by pseudo-class ---
    rng = np.random.default_rng(args.seed)
    corpus = lm_corpus(400_000, vocab=cfg.vocab_size, seed=args.seed)
    n_docs = len(corpus) // args.seq_len
    docs = corpus[:n_docs * args.seq_len].reshape(n_docs, args.seq_len)
    labels = class_labels_for_lm(corpus, 10, args.seq_len)
    held = docs[: max(8, args.batch)]
    docs, labels = docs[len(held):], labels[len(held):]
    part = dirichlet_partition(labels, args.clients, args.alpha, rng)

    def client_epoch(i):
        ix = part.indices[i]

        def gen():
            sel = rng.choice(ix, size=min(len(ix),
                                          args.steps_per_round * args.batch),
                             replace=len(ix) < args.steps_per_round
                             * args.batch)
            out = []
            for s in range(0, len(sel), args.batch):
                chunk = docs[sel[s:s + args.batch]]
                if len(chunk) < args.batch:
                    break
                out.append({
                    "tokens": jnp.asarray(chunk[:, :-1]),
                    "labels": jnp.asarray(chunk[:, 1:]),
                })
            return out
        return gen

    batches = [client_epoch(i) for i in range(args.clients)]
    eval_batch = {"tokens": jnp.asarray(held[:, :-1]),
                  "labels": jnp.asarray(held[:, 1:])}

    @jax.jit
    def _eval_loss(params):
        return model.loss(params, eval_batch, remat=False)

    def eval_fn(params):
        l = float(_eval_loss(params))
        return float(np.exp(-l)), l   # "accuracy" = exp(-loss) proxy

    fl = FLConfig(strategy=args.strategy, num_clients=args.clients,
                  num_models=args.clients, rounds=args.rounds, lr=args.lr,
                  seed=args.seed, engine=args.engine)

    def loss_fn(params, batch):
        return model.loss(params, batch, remat=False)

    t0 = time.time()
    result = run_federated(lambda k: model.init(k), loss_fn, batches,
                           part.dsi, part.data_sizes, eval_fn, fl)
    for i, (a, l) in enumerate(zip(result.accuracy, result.loss)):
        print(f"round {i+1}: eval_loss={l:.4f} "
              f"dif_rounds={result.diffusion_rounds[i]}")
    print(f"ledger: subframes={result.ledger.subframes} "
          f"models={result.ledger.transmitted_models} "
          f"bits={result.ledger.transmitted_bits:.3e} "
          f"({time.time()-t0:.0f}s)")
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.rounds, result.final_params,
                        {"arch": cfg.name, "strategy": args.strategy,
                         "loss_history": result.loss})
        print(f"global model checkpointed to {args.ckpt_dir}")


if __name__ == "__main__":
    main()
