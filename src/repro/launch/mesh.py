"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.  The dry-run entry
point sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before*
any jax import; everything else sees the real device count.

Target hardware (constants used by §Roofline): TPU v5e,
197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "make_clients_mesh",
           "make_fl_mesh", "activate_mesh", "PEAK_FLOPS", "HBM_BW", "ICI_BW",
           "mesh_axes"]

PEAK_FLOPS = 197e12       # bf16 FLOP/s per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link


def _auto_axis_types_kwargs(n: int) -> dict:
    """``axis_types=(Auto,)*n`` where supported.

    ``jax.sharding.AxisType`` only exists on newer jax; on 0.4.x the
    default mesh axis type already IS auto, so omitting the kwarg is
    equivalent — this shim keeps one mesh constructor working on both.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_auto_axis_types_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over the real host devices (CI / smoke tests)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // max(data, 1)))
    return jax.make_mesh((data, model), ("data", "model"),
                         **_auto_axis_types_kwargs(2))


def make_clients_mesh(num_clients: int, max_devices: int | None = None):
    """1-D ``("clients",)`` mesh for client-sharded FL data planes.

    Uses the largest available device count that divides ``num_clients`` so
    every shard carries an equal block of client slots (the sharded executor
    requires an even split).  On a single-device host this degenerates to a
    1-device mesh — same program, no collectives on the wire.  Drive CPU
    multi-device runs with ``XLA_FLAGS=--xla_force_host_platform_device_count=K``
    set before the first jax import.
    """
    n = len(jax.devices())
    if max_devices is not None:
        n = max(1, min(n, max_devices))
    k = max(d for d in range(1, n + 1) if num_clients % d == 0)
    return jax.make_mesh((k,), ("clients",), **_auto_axis_types_kwargs(1))


def make_fl_mesh(num_clients: int, model: int = 1,
                 max_devices: int | None = None):
    """2-D ``("clients", "model")`` mesh for the overlapped FL data plane.

    ``model`` is the requested model-axis size: during diffusion hops the
    flattened per-client parameter block is split feature-wise over
    ``"model"`` (each ring-shift ``ppermute`` then moves only ``F/model``
    bytes per link) while client slots shard over ``"clients"``; outside the
    hops the leading client axis is sharded over the *combined*
    ``("clients", "model")`` axis, so every device trains an equal block of
    clients.  Unlike :func:`make_clients_mesh` there is no divisibility
    requirement on ``num_clients`` — the executor pads the slot axis
    (zero-weighted padding slots) to the mesh size.

    The model axis is clamped to a divisor of the device count; remaining
    devices land on ``"clients"``.  On one device this degenerates to a
    ``(1, 1)`` mesh — same program, no collectives on the wire.
    """
    n = len(jax.devices())
    if max_devices is not None:
        n = max(1, min(n, max_devices))
    km = max(d for d in range(1, max(1, min(model, n)) + 1) if n % d == 0)
    # Never more client shards than clients — padding a 4096-device mesh to
    # N=20 would be absurd; excess devices simply sit out of the mesh.
    kc = min(n // km, max(1, num_clients))
    return jax.make_mesh((kc, km), ("clients", "model"),
                         **_auto_axis_types_kwargs(2))


def activate_mesh(mesh):
    """Context manager making ``mesh`` the ambient mesh.

    ``jax.set_mesh`` on new jax; on 0.4.x the ``Mesh`` object itself is the
    context manager that enters the mesh context.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def mesh_axes(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)
