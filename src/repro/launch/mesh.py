"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.  The dry-run entry
point sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before*
any jax import; everything else sees the real device count.

Target hardware (constants used by §Roofline): TPU v5e,
197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "make_clients_mesh",
           "PEAK_FLOPS", "HBM_BW", "ICI_BW", "mesh_axes"]

PEAK_FLOPS = 197e12       # bf16 FLOP/s per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link


def _auto(n: int):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over the real host devices (CI / smoke tests)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // max(data, 1)))
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=_auto(2))


def make_clients_mesh(num_clients: int, max_devices: int | None = None):
    """1-D ``("clients",)`` mesh for client-sharded FL data planes.

    Uses the largest available device count that divides ``num_clients`` so
    every shard carries an equal block of client slots (the sharded executor
    requires an even split).  On a single-device host this degenerates to a
    1-device mesh — same program, no collectives on the wire.  Drive CPU
    multi-device runs with ``XLA_FLAGS=--xla_force_host_platform_device_count=K``
    set before the first jax import.
    """
    n = len(jax.devices())
    if max_devices is not None:
        n = max(1, min(n, max_devices))
    k = max(d for d in range(1, n + 1) if num_clients % d == 0)
    # No axis_types: jax.sharding.AxisType is missing on older jax (0.4.x)
    # and the default (Auto) is what we want everywhere.
    return jax.make_mesh((k,), ("clients",))


def mesh_axes(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)
