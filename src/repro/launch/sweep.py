"""Paper-figure sweep CLI — reproduce a figure/table with one command.

    PYTHONPATH=src python -m repro.launch.sweep --sweep fig3_alpha --smoke
    PYTHONPATH=src python -m repro.launch.sweep --sweep all --full --seeds 3
    PYTHONPATH=src python -m repro.launch.sweep --list

Durable mode (kill-safe, bit-identical resume)::

    python -m repro.launch.sweep --sweep fig3_alpha --checkpoint-every 1
    # ... SIGTERM / crash / power loss ...
    python -m repro.launch.sweep --sweep fig3_alpha --resume

Expands the named entry of the sweep registry
(:mod:`repro.experiments.registry`), runs every cell with multi-seed
replication (seed axis vmapped on the data plane where the strategy allows,
process loop otherwise; diffusion plans cached across seeds), and writes a
``BENCH_feddif_<sweep>.json`` artifact with per-cell accuracy curves, the
Eq.-15 cumulative PUSCH bandwidth, sub-frame counts and wall-clock.
Artifacts land in the repo-wide BENCH directory
(``$REPRO_BENCH_DIR`` or ``benchmarks/results/`` — see
``repro.experiments.artifacts.default_out_dir``) unless ``--out-dir`` says
otherwise.  ``benchmarks/run.py`` drives the same registry — definitions
live in one place.
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.experiments import REGISTRY, run_sweep, sweep_names
from repro.experiments.artifacts import default_out_dir
from repro.fl.engine import ENGINE_PRESETS

__all__ = ["main"]

# --engine accepts the replication engines (how replicate seeds are run)
# plus the EngineSpec preset names (which execution plane every cell uses);
# "auto" belongs to both vocabularies and keeps its replication meaning.
_REPLICATION_ENGINES = ("auto", "seed_vmap", "loop")
_ENGINE_CHOICES = list(_REPLICATION_ENGINES) + sorted(
    set(ENGINE_PRESETS) - {"auto"})


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.sweep",
        description="Run a registered paper-figure sweep and write "
                    "BENCH_feddif_<sweep>.json")
    ap.add_argument("--sweep", default=None,
                    help="registry name (see --list) or 'all'")
    ap.add_argument("--smoke", action="store_true",
                    help="smoke-sized grid (default unless --full)")
    ap.add_argument("--full", action="store_true",
                    help="paper-approaching grid sizes")
    ap.add_argument("--seeds", type=int, default=1,
                    help="number of replicate seeds (0..N-1)")
    ap.add_argument("--engine", choices=_ENGINE_CHOICES, default="auto",
                    help="replication engine (auto/seed_vmap/loop) or an "
                         "EngineSpec preset stamped on every cell (e.g. "
                         "'async' for the buffered-async round plane, "
                         "'async_barrier' for its sync comparison arm)")
    ap.add_argument("--executor", choices=["host", "fleet", "sharded"],
                    default="host",
                    help="data plane per cell: host reference loop, "
                         "client-stacked fleet, or client-sharded mesh "
                         "(FLConfig.executor)")
    ap.add_argument("--planner", choices=["host", "jax"], default="host",
                    help="control plane per cell: host numpy oracle or "
                         "batched jax device planner that pre-plans the "
                         "whole sweep in one device call (FLConfig.planner)")
    ap.add_argument("--out-dir", default=None,
                    help="artifact directory (default: "
                         "$REPRO_BENCH_DIR or benchmarks/results/ — the "
                         "same place benchmarks/run.py writes)")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    metavar="R",
                    help="durable mode: checkpoint full round state every "
                         "R communication rounds; a killed sweep restarts "
                         "bit-identically with --resume")
    ap.add_argument("--resume", action="store_true",
                    help="continue a previous durable run from its "
                         "manifest (done cells load stored records, "
                         "interrupted cells restart from their latest "
                         "round checkpoint, failed cells are retried)")
    ap.add_argument("--state-dir", default=None,
                    help="durable-state directory (default: "
                         "<artifact dir>/sweeps/<sweep>; with --sweep all, "
                         "a per-sweep subdirectory of this path)")
    ap.add_argument("--num-samples", type=int, default=None,
                    help="override ExperimentSpec.num_samples per cell "
                         "(small values make smoke/CI runs fast)")
    ap.add_argument("--list", action="store_true",
                    help="list registered sweeps and exit")
    args = ap.parse_args(argv)

    if args.list or not args.sweep:
        print(f"{'name':20s} {'paper':16s} axis        description")
        for name in sweep_names():
            d = REGISTRY[name]
            print(f"{name:20s} {d.figure:16s} {d.axis:11s} {d.description}")
        return 0

    smoke = not args.full
    if args.seeds < 1:
        print("error: --seeds must be >= 1", file=sys.stderr)
        return 2
    if args.sweep != "all" and args.sweep not in REGISTRY:
        print(f"error: unknown sweep {args.sweep!r}; registered: "
              f"{', '.join(sweep_names())} (or 'all')", file=sys.stderr)
        return 2
    names = sweep_names() if args.sweep == "all" else [args.sweep]
    seeds = tuple(range(args.seeds))
    out_dir = args.out_dir if args.out_dir is not None else default_out_dir()
    overrides = {}
    if args.num_samples is not None:
        overrides["num_samples"] = args.num_samples
    durable = (args.checkpoint_every > 0 or args.resume
               or args.state_dir is not None)
    for name in names:
        print(f"# === sweep {name} ({'smoke' if smoke else 'full'}, "
              f"seeds={list(seeds)}) ===", flush=True)
        state_dir = args.state_dir
        if state_dir is not None and args.sweep == "all":
            state_dir = os.path.join(state_dir, name)
        # Preset names select the execution plane for every cell; the
        # replication engine then defaults to "auto" (_pick_engine routes
        # fleet/sharded/async cells onto the loop engine anyway).
        preset = (args.engine if args.engine not in _REPLICATION_ENGINES
                  else None)
        repl_engine = args.engine if preset is None else "auto"
        artifact = run_sweep(name, smoke=smoke, seeds=seeds,
                             out_dir=out_dir, engine=repl_engine,
                             engine_preset=preset,
                             executor=args.executor, planner=args.planner,
                             checkpoint_every=args.checkpoint_every,
                             resume=args.resume,
                             state_dir=state_dir if durable else None,
                             log=lambda s: print(s, flush=True),
                             **overrides)
        pc = artifact["plan_cache"]
        failed = artifact.get("failed_cells", [])
        print(f"# wrote {artifact['path']} "
              f"(cells={len(artifact['cells'])}, "
              f"failed={len(failed)}, "
              f"plan_cache hits={pc.get('hits', 0)} "
              f"misses={pc.get('misses', 0)}, "
              f"{artifact['wall_clock_s']:.1f}s)", flush=True)
        if "manifest" in artifact:
            print(f"# manifest {artifact['manifest']}", flush=True)
        for fc in failed:
            print(f"# FAILED cell {fc['label']}: {fc['error']}",
                  file=sys.stderr, flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
