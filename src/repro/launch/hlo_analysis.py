"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE — with
scan-over-layers models that underreports FLOPs/bytes/collective volume by a
factor of ~num_layers.  This module parses the *partitioned, optimized* HLO
text, recovers every while loop's trip count from its condition computation,
propagates multipliers down the call graph, and accumulates:

  * ``dot_flops``      — 2·prod(out)·prod(contracting dims) per dot (MXU
                         work; elementwise VPU flops are ignored, which is
                         the right roofline simplification for LMs),
  * ``hbm_bytes``      — Σ (operand + output bytes) of top-level (fused)
                         ops: post-fusion buffer edges ≈ HBM traffic.  An
                         operand that a fusion's interior only SLICES (the
                         scan pattern — stacked per-layer params dynamic-
                         sliced every iteration) is charged at the slice
                         size, not the full buffer; otherwise loops would
                         overcount by their trip count.  Standalone
                         reshape/broadcast/transpose/convert are treated as
                         free (layout ops, usually elided or fused),
  * ``collective_bytes``/``collective_counts`` — per collective kind.

Validated against cost_analysis() on loop-free modules (tests/test_hlo_analysis.py).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict

__all__ = ["analyze_hlo", "xla_cost_analysis", "HloCost"]


def xla_cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict on every jaxlib.

    Pre-0.5 jaxlib returns a one-element list of per-device dicts; newer
    versions return the dict directly.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
               "s4": 1, "u4": 1}

_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_CALLED = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)="
                     r"\{?%?([\w.\-]+)")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_info(s: str):
    """Returns (total_bytes, dims_list) for the (possibly tuple) shape text
    before the op name."""
    total = 0.0
    dims_all = []
    for dt, dims in _SHAPE.findall(s):
        if dt not in DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",") if x] if dims else []
        n = math.prod(d) if d else 1
        total += n * DTYPE_BYTES[dt]
        dims_all.append((dt, d))
    return total, dims_all


class _Comp:
    def __init__(self, name):
        self.name = name
        self.lines: list[str] = []
        self.shapes: dict[str, tuple] = {}   # op name -> (bytes, dims)


def _parse_computations(txt: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    cur = None
    for raw in txt.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{", stripped)
        if m and not stripped.startswith("ROOT") and "=" not in \
                stripped.split("(")[0]:
            cur = _Comp(m.group(1))
            comps[cur.name] = cur
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None and stripped:
            cur.lines.append(stripped)
            dm = _DEF.match(stripped)
            if dm:
                rhs = dm.group(2)
                # shape text = up to the op name token
                cur.shapes[dm.group(1)] = _shape_info(rhs.split(" ", 1)[0]
                                                      if rhs.startswith("(")
                                                      else rhs)
    return comps


def _entry_name(txt: str, comps) -> str:
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", txt)
    if m and m.group(1) in comps:
        return m.group(1)
    # fallback: computation named like main
    for name in comps:
        if name.startswith("main"):
            return name
    return next(iter(comps))


def _trip_count(cond: _Comp) -> int:
    """Largest integer constant in the condition computation — the loop
    bound of a canonical jax scan/fori while."""
    best = 1
    for line in cond.lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


def _call_operands(line: str, opname: str) -> list[str]:
    """Operand names inside ``opname(...)`` — tolerant of both the bare
    (``dot(%a, %b)``) and the typed (``dot(f32[4]{0} %a, ...)``) operand
    syntax jaxlib switched to."""
    m = re.search(rf"\b{opname}\(([^)]*)\)", line)
    if not m:
        return []
    return re.findall(r"%([\w.\-]+)", m.group(1))


def _operand_dims(comp: _Comp, line: str, name: str) -> list[int] | None:
    """Dims of an operand: from the computation's def table, or — for
    operands jaxlib now annotates inline — parsed off the call site."""
    info = comp.shapes.get(name)
    if info and info[1]:
        return info[1][0][1]
    m = re.search(rf"([a-z0-9]+)\[([0-9,]*)\](?:\{{[^}}]*\}})?\s+"
                  rf"%{re.escape(name)}\b", line)
    if m and m.group(1) in DTYPE_BYTES:
        return [int(x) for x in m.group(2).split(",") if x]
    return None


def _dot_flops(line: str, comp: _Comp) -> float:
    dm = _DEF.match(line)
    if not dm:
        return 0.0
    out_bytes, out_dims = _shape_info(dm.group(2).split(" dot(")[0])
    out_n = math.prod(out_dims[0][1]) if out_dims and out_dims[0][1] else 1
    # contracting dims of the lhs operand
    ops = _call_operands(line, "dot")
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    if not ops or not cm:
        return 2.0 * out_n  # degenerate
    lhs_dims = _operand_dims(comp, line, ops[0])
    if not lhs_dims:
        return 2.0 * out_n
    contract = 1
    for idx in (int(x) for x in cm.group(1).split(",") if x):
        if idx < len(lhs_dims):
            contract *= lhs_dims[idx]
    return 2.0 * out_n * contract


def _conv_flops(line: str, comp: _Comp) -> float:
    dm = _DEF.match(line)
    if not dm:
        return 0.0
    _, out_dims = _shape_info(dm.group(2).split(" convolution")[0])
    out_n = math.prod(out_dims[0][1]) if out_dims and out_dims[0][1] else 1
    ops = _call_operands(line, "convolution")
    if len(ops) < 2:
        return 2.0 * out_n
    rhs_dims = _operand_dims(comp, line, ops[1])
    rhs_n = math.prod(rhs_dims) if rhs_dims else 1
    feat = re.search(r"feature_group_count=(\d+)", line)
    groups = int(feat.group(1)) if feat else 1
    # flops ≈ 2 · out · (kernel elems / out_features) — per-group kernel
    out_feat = out_dims[0][1][-1] if out_dims and out_dims[0][1] else 1
    per_out = rhs_n / max(out_feat, 1)
    return 2.0 * out_n * per_out * (1.0 / 1)  # groups already shrink rhs_n


class HloCost(dict):
    pass


_SLICE_ONLY = ("dynamic-slice", "slice", "gather")


def _param_charges(comp: _Comp) -> dict[int, float]:
    """Per-parameter HBM charge for a fusion computation: parameters whose
    every use is a slice-like op are charged at the sliced size."""
    params: dict[int, str] = {}
    for line in comp.lines:
        dm = _DEF.match(line)
        if dm and re.search(r"\bparameter\((\d+)\)", dm.group(2)):
            idx = int(re.search(r"parameter\((\d+)\)", dm.group(2)).group(1))
            params[idx] = dm.group(1)
    charges: dict[int, float] = {}
    for idx, pname in params.items():
        full = comp.shapes.get(pname, (0.0, []))[0]
        sliced = 0.0
        slice_only = True
        used = False
        for line in comp.lines:
            dm = _DEF.match(line)
            if dm is None or dm.group(1) == pname:
                continue
            if re.search(rf"%{re.escape(pname)}\b", dm.group(2)):
                used = True
                op_kind = dm.group(2).split("(")[0].split()[-1]
                if any(op_kind.startswith(s) for s in _SLICE_ONLY):
                    sliced += comp.shapes.get(dm.group(1), (0.0, []))[0]
                else:
                    slice_only = False
        if used and slice_only and sliced > 0:
            charges[idx] = min(sliced, full)
        else:
            charges[idx] = full
    return charges


def analyze_hlo(txt: str) -> HloCost:
    comps = _parse_computations(txt)
    entry = _entry_name(txt, comps)

    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    # Propagate multipliers breadth-first through the call graph.
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        m_cur = mult[cname]
        for line in comp.lines:
            if " while(" in line:
                bm = re.search(r"body=%?([\w.\-]+)", line)
                cm = re.search(r"condition=%?([\w.\-]+)", line)
                # Newer jaxlib stamps the recovered bound right on the while
                # op; the condition-constant scan is the fallback for HLO
                # that predates known_trip_count.
                km = re.search(r'known_trip_count[":{\s]+n[":\s]+(\d+)', line)
                if km:
                    trip = int(km.group(1))
                else:
                    trip = _trip_count(comps[cm.group(1)]) if cm and \
                        cm.group(1) in comps else 1
                if bm and bm.group(1) in comps:
                    mult[bm.group(1)] += m_cur * trip
                    if bm.group(1) not in seen:
                        seen.add(bm.group(1))
                        order.append(bm.group(1))
            else:
                for cal in _CALLED.finditer(line):
                    sub = cal.group(1)
                    if sub in comps and "condition=" not in \
                            line[:cal.start()]:
                        mult[sub] += m_cur
                        if sub not in seen:
                            seen.add(sub)
                            order.append(sub)

    dot_flops = 0.0
    hbm_bytes = 0.0
    coll_bytes: dict[str, float] = defaultdict(float)
    coll_counts: dict[str, float] = defaultdict(float)
    # computations that are called as fusions (their interior is not HBM
    # traffic, but their dots are real flops)
    fusion_called = set()
    for comp in comps.values():
        for line in comp.lines:
            fm = re.search(r"fusion\(.*calls=%?([\w.\-]+)", line)
            if fm:
                fusion_called.add(fm.group(1))
    charge_cache: dict[str, dict[int, float]] = {}

    def fusion_input_bytes(called: str, rhs: str) -> float:
        if called not in comps:
            return 0.0
        if called not in charge_cache:
            charge_cache[called] = _param_charges(comps[called])
        charges = charge_cache[called]
        args = re.search(r"fusion\(([^)]*)\)", rhs)
        n_args = len(re.findall(r"%[\w.\-]+", args.group(1))) if args else 0
        return sum(charges.get(i, 0.0) for i in range(n_args))

    for cname, comp in comps.items():
        m_cur = mult.get(cname, 0.0)
        if m_cur <= 0:
            continue
        interior = cname in fusion_called
        for line in comp.lines:
            if " dot(" in line:
                dot_flops += m_cur * _dot_flops(line, comp)
            elif " convolution(" in line:
                dot_flops += m_cur * _conv_flops(line, comp)
            dm = _DEF.match(line)
            if dm is None:
                continue
            opname = dm.group(1)
            rhs = dm.group(2)
            kind = None
            for ck in _COLLECTIVES:
                if re.search(rf"\b{ck}(-start)?\(", rhs):
                    kind = ck
                    break
            if kind and "-done(" not in rhs:
                b = comp.shapes[opname][0]
                coll_bytes[kind] += m_cur * b
                coll_counts[kind] += m_cur
            if not interior:
                # HBM traffic proxy: buffer edges of macro ops.
                out_b = comp.shapes.get(opname, (0.0,))[0]
                fm = re.search(r"fusion\(.*calls=%?([\w.\-]+)", rhs)
                if fm:
                    hbm_bytes += m_cur * (out_b
                                          + fusion_input_bytes(fm.group(1),
                                                               rhs))
                elif re.search(r"\bdynamic-update-slice\(", rhs):
                    # read-modify-write of the updated region only
                    ops_ = re.findall(r"%([\w.\-]+)", rhs)
                    upd = comp.shapes.get(ops_[1], (0.0,))[0] \
                        if len(ops_) > 1 else 0.0
                    hbm_bytes += m_cur * 2.0 * upd
                elif re.search(r"\b(dynamic-slice|slice|gather)\(", rhs):
                    hbm_bytes += m_cur * 2.0 * out_b
                elif re.search(r"\b(dot|convolution|copy|scatter|sort|"
                               r"all-gather|all-reduce|reduce-scatter|"
                               r"all-to-all|collective-permute|reduce|"
                               r"select-and-scatter|concatenate|pad)\(",
                               rhs):
                    in_b = 0.0
                    for om in re.finditer(r"%([\w.\-]+)", rhs):
                        if om.group(1) in comp.shapes and \
                                om.group(1) != opname:
                            in_b += comp.shapes[om.group(1)][0]
                    hbm_bytes += m_cur * (out_b + in_b)

    return HloCost(
        dot_flops=dot_flops,
        hbm_bytes=hbm_bytes,
        collective_bytes=dict(coll_bytes),
        collective_counts=dict(coll_counts),
        total_collective_bytes=sum(coll_bytes.values()),
    )
