import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, with ShapeDtypeStruct inputs (no allocation).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_0_6b \
        --shape train_4k [--multi-pod] [--feddif]
    PYTHONPATH=src python -m repro.launch.dryrun --all --out benchmarks/results

Writes one JSON per (arch, shape, mesh) with memory analysis, cost analysis,
and the per-collective byte breakdown parsed from the partitioned HLO —
the §Roofline inputs.

MUST be run as its own process: the XLA_FLAGS line above executes before any
jax import (jax locks the device count on first init).
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, SHAPES, ModelConfig, ShapeConfig, get_config
from repro.distributed import sharding as sh
from repro.distributed.fedshard import make_diffusion_step
from repro.launch.mesh import activate_mesh, make_production_mesh
from repro.models.zoo import build_model
from repro.train import optimizer as opt_lib
from repro.train.trainstep import (TrainState, make_serve_step,
                                   make_train_step)

COLLECTIVE_RE = re.compile(
    r"(\(|= )((?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?(?:, )?)+)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\(")
SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}


def _bytes_of_shape_str(s: str) -> float:
    total = 0.0
    for dt, dims in SHAPE_RE.findall(s):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-device output bytes of every collective op in partitioned HLO."""
    out: dict[str, float] = {}
    counts: dict[str, int] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.search(r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
                      r"collective-permute)(-start)?\(", line)
        if not m or "=" not in line:
            continue
        kind = m.group(1)
        # `%op.N = <shape(s)> all-gather(...)` — output shape(s) sit between
        # the `=` and the op name.  Skip the paired `-done` ops (same shape).
        if re.search(r"-done\(", line):
            continue
        rhs = line.split("=", 1)[1]
        rhs = rhs.split(m.group(1))[0]
        b = _bytes_of_shape_str(rhs)
        out[kind] = out.get(kind, 0.0) + b
        counts[kind] = counts.get(kind, 0) + 1
    out["_counts"] = counts
    return out


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStruct stand-ins for every model input (weak-type-correct,
    shardable, no device allocation)."""
    model = build_model(cfg)
    return model.input_specs(shape)


def lower_one(arch: str, shape_name: str, multi_pod: bool,
              feddif: bool = False, fsdp: bool | None = None,
              donate: bool = True, accum: int = 0) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return {"status": "skipped",
                "reason": "full-attention arch; long_500k requires "
                          "sub-quadratic attention (DESIGN.md §4)"}
    if accum == 0:
        # auto: big archs accumulate gradients over microbatches so live
        # activations fit the 16 GB/chip HBM budget (§Perf).
        n = cfg.param_count()
        accum = 8 if n > 5e10 else 4 if n > 5e9 else 1
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    opt = opt_lib.sgd(momentum=0.9)
    batch = input_specs(cfg, shape)
    t0 = time.time()

    with activate_mesh(mesh):
        if shape.mode == "train":
            key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
            state_shapes = jax.eval_shape(
                lambda k: TrainState(
                    params=model.init(k),
                    opt_state=opt.init(model.init(k)),
                    step=jnp.zeros((), jnp.int32)),
                key_spec)
            pspecs = sh.param_specs(state_shapes.params, cfg, mesh, fsdp)
            sspecs = sh.state_specs(pspecs, state_shapes.opt_state)
            bspecs = sh.batch_specs(batch, shape, mesh)
            from repro.models.layers import perf_opt_enabled
            accum_eff = accum if perf_opt_enabled("grad_accum") else 1
            if accum_eff > 1:
                # microbatch-stacked inputs: (K, B/K, ...) — the K axis is
                # replicated, B/K stays sharded over the data axes
                batch = jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(
                        (accum_eff, x.shape[0] // accum_eff) + x.shape[1:],
                        x.dtype), batch)
                bspecs = jax.tree.map(
                    lambda s: type(s)(None, *tuple(s)), bspecs,
                    is_leaf=lambda x: isinstance(
                        x, jax.sharding.PartitionSpec))
            step_fn = make_train_step(model, opt, opt_lib.constant_lr(0.01),
                                      accum_steps=accum_eff)
            jitted = jax.jit(
                step_fn,
                in_shardings=(sh.named(mesh, sspecs),
                              sh.named(mesh, bspecs)),
                donate_argnums=(0,) if donate else ())
            lowered = jitted.lower(state_shapes, batch)
        else:
            pspecs_shapes = jax.eval_shape(model.init,
                                           jax.ShapeDtypeStruct((2,),
                                                                jnp.uint32))
            pspecs = sh.param_specs(pspecs_shapes, cfg, mesh, fsdp)
            if shape.mode == "prefill":
                from repro.train.trainstep import make_prefill_step
                step_fn = make_prefill_step(model)
                bspecs = sh.batch_specs(batch, shape, mesh)
                jitted = jax.jit(step_fn,
                                 in_shardings=(sh.named(mesh, pspecs),
                                               sh.named(mesh, bspecs)))
                lowered = jitted.lower(pspecs_shapes, batch)
            else:  # decode
                cache = model.cache_specs(shape)
                cspecs = sh.cache_specs(cache, shape, mesh)
                bspecs = sh.batch_specs(batch, shape, mesh)
                step_fn = make_serve_step(model)
                jitted = jax.jit(
                    step_fn,
                    in_shardings=(sh.named(mesh, pspecs),
                                  sh.named(mesh, bspecs["tokens"]),
                                  sh.named(mesh, cspecs), None),
                    donate_argnums=(2,) if donate else ())
                pos = jax.ShapeDtypeStruct((), jnp.int32)
                lowered = jitted.lower(pspecs_shapes, batch["tokens"],
                                       cache, pos)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    from repro.launch.hlo_analysis import xla_cost_analysis
    cost = xla_cost_analysis(compiled)
    txt = compiled.as_text()
    dump = os.environ.get("DRYRUN_DUMP_HLO")
    if dump:
        os.makedirs(dump, exist_ok=True)
        with open(os.path.join(
                dump, f"{arch}_{shape_name}_"
                f"{'512' if multi_pod else '256'}.hlo"), "w") as f:
            f.write(txt)
    coll = collective_bytes(txt)
    # Trip-count-aware accounting (XLA's cost_analysis counts while bodies
    # once; scan-over-layers models need the corrected numbers).
    from repro.launch.hlo_analysis import analyze_hlo
    hlo = analyze_hlo(txt)
    result = {
        "status": "ok",
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_device": cost.get("flops", 0.0),
        "bytes_accessed_per_device": cost.get("bytes accessed", 0.0),
        "hlo_dot_flops_per_device": hlo["dot_flops"],
        "hlo_hbm_bytes_per_device": hlo["hbm_bytes"],
        "hlo_collective_bytes_per_device": hlo["collective_bytes"],
        "hlo_collective_counts": hlo["collective_counts"],
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        },
        "collectives": coll,
        "param_count": get_config(arch).param_count(),
        "active_param_count": get_config(arch).active_param_count(),
        "accum_steps": accum if shape.mode == "train" else None,
    }
    return result


def feddif_lower(arch: str, fsdp: bool | None = None) -> dict:
    """Lower the client-per-pod FedDif diffusion step on the 2×16×16 mesh.

    Proves the paper's data plane (D2D ppermute + weighted aggregation)
    shards over the ``pod`` axis.  Uses train_4k per-client shapes.
    """
    from jax.sharding import PartitionSpec as P
    cfg = get_config(arch)
    shape = SHAPES["train_4k"]
    mesh = make_production_mesh(multi_pod=True)
    model = build_model(cfg)
    opt = opt_lib.sgd(momentum=0.9)
    npod = mesh.shape["pod"]
    t0 = time.time()

    with activate_mesh(mesh):
        key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
        base_state = jax.eval_shape(
            lambda k: TrainState(params=model.init(k),
                                 opt_state=opt.init(model.init(k)),
                                 step=jnp.zeros((), jnp.int32)), key_spec)
        # stack a leading client axis (one client per pod)
        state_shapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct((npod,) + x.shape, x.dtype),
            base_state)
        base_pspecs = sh.param_specs(base_state.params, cfg, mesh, fsdp)
        stackP = lambda t: jax.tree.map(lambda s: P("pod", *s), t,
                                        is_leaf=lambda x: isinstance(x, P))
        pspecs = stackP(base_pspecs)
        sspecs = sh.state_specs(pspecs, state_shapes.opt_state)
        sspecs = TrainState(params=pspecs,
                            opt_state=sspecs.opt_state, step=P("pod"))

        batch = model.input_specs(shape)
        batch = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(
                (npod, x.shape[0] // npod) + x.shape[1:], x.dtype), batch)
        bspecs = jax.tree.map(
            lambda x: P("pod", "data", *([None] * (len(x.shape) - 2))), batch)

        step_fn = make_diffusion_step(model, opt)
        jitted = jax.jit(
            step_fn,
            in_shardings=(sh.named(mesh, sspecs), sh.named(mesh, bspecs),
                          None, None, None),
            donate_argnums=(0,))
        perm = jax.ShapeDtypeStruct((npod,), jnp.int32)
        mask = jax.ShapeDtypeStruct((npod,), jnp.bool_)
        w = jax.ShapeDtypeStruct((npod,), jnp.float32)
        lowered = jitted.lower(state_shapes, batch, perm, mask, w)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    from repro.launch.hlo_analysis import analyze_hlo, xla_cost_analysis
    cost = xla_cost_analysis(compiled)
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    hlo = analyze_hlo(txt)
    return {"status": "ok", "arch": arch, "shape": "train_4k",
            "mesh": "2x16x16-feddif", "chips": 512,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "flops_per_device": cost.get("flops", 0.0),
            "bytes_accessed_per_device": cost.get("bytes accessed", 0.0),
            "hlo_dot_flops_per_device": hlo["dot_flops"],
            "hlo_hbm_bytes_per_device": hlo["hbm_bytes"],
            "hlo_collective_bytes_per_device": hlo["collective_bytes"],
            "hlo_collective_counts": hlo["collective_counts"],
            "collectives": coll}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--feddif", action="store_true",
                    help="lower the client-per-pod FedDif diffusion step")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--fsdp", choices=["auto", "on", "off"], default="auto")
    ap.add_argument("--out", default=None, help="JSON output directory")
    args = ap.parse_args()
    fsdp = {"auto": None, "on": True, "off": False}[args.fsdp]

    jobs = []
    if args.all:
        for arch in ARCH_IDS:
            for shp in SHAPES:
                jobs.append((arch, shp, args.multi_pod))
    else:
        jobs.append((args.arch, args.shape, args.multi_pod))

    results = []
    for arch, shp, mp in jobs:
        label = f"{arch}/{shp}/{'512' if mp else '256'}"
        try:
            if args.feddif:
                r = feddif_lower(arch, fsdp)
            else:
                r = lower_one(arch, shp, mp, fsdp=fsdp)
        except Exception as e:
            r = {"status": "error", "arch": arch, "shape": shp,
                 "error": f"{type(e).__name__}: {e}",
                 "trace": traceback.format_exc()[-2000:]}
        results.append(r)
        print(f"[{label}] {r['status']}", flush=True)
        if r["status"] == "ok":
            print(f"  flops/dev={r['flops_per_device']:.3e} "
                  f"bytes/dev={r.get('bytes_accessed_per_device', 0):.3e} "
                  f"compile={r.get('compile_s')}s", flush=True)
        elif r["status"] == "error":
            print("  " + r["error"], flush=True)
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            suffix = "feddif" if args.feddif else (
                "512" if mp else "256")
            path = os.path.join(args.out, f"dryrun_{arch}_{shp}_{suffix}.json")
            with open(path, "w") as f:
                json.dump(r, f, indent=1)
    bad = [r for r in results if r["status"] == "error"]
    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()
