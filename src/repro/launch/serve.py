"""Batched decode serving driver.

Loads (or random-inits) a model, prefers the decode path with a KV/SSM
cache, and serves batched token-generation requests, reporting tokens/s.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_0_6b --smoke \
        --batch 4 --context 64 --new-tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import build_model
from repro.train import latest_step, restore_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3_0_6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--context", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.frontend is not None and cfg.family != "audio":
        raise SystemExit("serve.py drives text decoders")
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    if args.ckpt_dir:
        step = latest_step(args.ckpt_dir)
        if step is not None:
            params = restore_checkpoint(args.ckpt_dir, step, params)
            print(f"restored checkpoint step {step}")

    max_seq = args.context + args.new_tokens
    b = args.batch
    if cfg.family == "audio":
        frames = jax.random.normal(key, (b, cfg.num_frontend_tokens,
                                         cfg.d_model), jnp.bfloat16)
        cache = model.init_cache(params, frames, b, max_seq)
    else:
        cache = model.init_cache(params, b, max_seq)

    decode = jax.jit(model.decode_step)
    prompt = jax.random.randint(key, (b, args.context), 0, cfg.vocab_size)

    # prefill via sequential decode (teacher-forced context ingestion)
    t0 = time.time()
    logits = None
    for t in range(args.context):
        logits, cache = decode(params, prompt[:, t:t + 1], cache,
                               jnp.int32(t))
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    # autoregressive generation
    tok = jnp.argmax(logits[:, -1], axis=-1, keepdims=True).astype(jnp.int32)
    outs = [tok]
    t0 = time.time()
    for t in range(args.context, max_seq - 1):
        logits, cache = decode(params, tok, cache, jnp.int32(t))
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, -1] / args.temperature, axis=-1)[:, None]
            tok = tok.astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], -1, keepdims=True).astype(
                jnp.int32)
        outs.append(tok)
    jax.block_until_ready(tok)
    t_gen = time.time() - t0
    gen = np.asarray(jnp.concatenate(outs, axis=1))
    n_new = gen.shape[1]
    print(f"arch={cfg.name} batch={b} context={args.context}")
    print(f"prefill: {args.context / max(t_prefill,1e-9):.1f} tok/s/seq")
    print(f"decode:  {b * n_new / max(t_gen,1e-9):.1f} tok/s aggregate "
          f"({n_new} new tokens/seq)")
    print("sample token ids:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
