"""SPMD FedDif runtime — a thin CLI over the RoundSchedule/Executor layer.

The FedDif *scheduler* (``repro.fl.schedulers.schedule_feddif``) plans each
communication round on host — auctions, DoL bookkeeping, wire accounting
[PUCCH] — and this driver replays the resulting
:class:`~repro.core.schedule.RoundSchedule` on an LM fleet with
``repro.distributed.fedshard``'s jitted data plane: vmapped local update per
``TrainOp``, collective-permute + masked train per ``PermuteOp``, Eq.-11
weighted aggregation from the schedule's chain weights [PUSCH].  The ledger
is charged by :func:`~repro.core.schedule.charge_schedule` — the same
function the host simulator uses, so fleet runs report the same Table-II
metrics.

On a pod, the client axis is a real mesh axis (``data`` on-pod for
paper-scale fleets, ``pod`` across pods — see fedshard); on this CPU host
it runs on the 1-device mesh, which is the same program.  With
``--shard-clients`` the stacked TrainState and batches are placed with
``NamedSharding`` over a ``("clients",)`` mesh
(:func:`repro.launch.mesh.make_clients_mesh` /
:func:`repro.distributed.sharding.client_shardings`) so GSPMD partitions
every jitted step across devices — the same client-sharded plane the
``sharded`` FL executor uses, here on LM fleets.

    PYTHONPATH=src python -m repro.launch.fl_spmd --clients 4 --rounds 3
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python -m repro.launch.fl_spmd --clients 4 --shard-clients
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.channels.fading import ChannelModel
from repro.channels.resources import GAMMA_FLOOR, ResourceLedger
from repro.channels.topology import CellTopology
from repro.configs import ARCH_IDS, get_smoke_config
from repro.core import aggregation as agg
from repro.core.auction import AuctionConfig
from repro.core.diffusion import DiffusionPlanner
from repro.core.schedule import PermuteOp, TrainOp, charge_schedule
from repro.data.partitioner import dirichlet_partition
from repro.data.synthetic import class_labels_for_lm, lm_corpus
from repro.distributed.fedshard import (fleet_aggregate,
                                        make_diffusion_step,
                                        make_fleet_train_step)
from repro.fl.schedulers import RoundContext, schedule_feddif
from repro.fl.server import FLConfig, _uplink_gamma
from repro.models import build_model
from repro.train import optimizer as opt_lib
from repro.train.trainstep import TrainState

__all__ = ["run_spmd_feddif"]


def _stack_states(model, opt, key, n):
    """One model replica per client slot (BS clones the global model)."""
    params = model.init(key)
    one = TrainState(params=params, opt_state=opt.init(params),
                     step=jnp.zeros((), jnp.int32))
    return jax.tree.map(lambda x: jnp.broadcast_to(
        x, (n,) + x.shape).copy(), one)


def run_spmd_feddif(arch: str = "smollm_360m", clients: int = 4,
                    rounds: int = 3, alpha: float = 0.5, seq_len: int = 64,
                    batch: int = 4, lr: float = 0.01, epsilon: float = 0.04,
                    seed: int = 0, shard_clients: bool = False, log=print):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    opt = opt_lib.sgd()
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)

    client_sharding = None
    if shard_clients:
        from repro.distributed.sharding import client_shardings
        from repro.launch.mesh import make_clients_mesh
        mesh = make_clients_mesh(clients)
        client_sharding = lambda tree: client_shardings(mesh, tree)  # noqa: E731
        log(f"client mesh: {mesh} "
            f"({clients // mesh.shape['clients']} clients/device)")

    # --- non-IID client corpora -------------------------------------
    corpus = lm_corpus(200_000, vocab=cfg.vocab_size, seed=seed)
    n_docs = len(corpus) // seq_len
    docs = corpus[:n_docs * seq_len].reshape(n_docs, seq_len)
    labels = class_labels_for_lm(corpus, 10, seq_len)
    part = dirichlet_partition(labels, clients, alpha, rng)

    def client_batch(c):
        ix = rng.choice(part.indices[c], size=batch,
                        replace=len(part.indices[c]) < batch)
        chunk = docs[ix]
        return {"tokens": jnp.asarray(chunk[:, :-1]),
                "labels": jnp.asarray(chunk[:, 1:])}

    def fleet_batch():
        per = [client_batch(c) for c in range(clients)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
        if client_sharding is not None:
            stacked = jax.device_put(stacked, client_sharding(stacked))
        return stacked

    # --- jitted data plane ------------------------------------------
    fleet_step = jax.jit(make_fleet_train_step(model, opt, lr, remat=False))
    diff_step = jax.jit(make_diffusion_step(model, opt, lr, remat=False))
    aggregate = jax.jit(fleet_aggregate)

    # --- host control plane (shared with the FL simulator) ----------
    fl_cfg = FLConfig(strategy="feddif", num_clients=clients,
                      num_models=clients, rounds=rounds, lr=lr,
                      epsilon=epsilon, seed=seed)
    topology = CellTopology(num_pues=clients)
    channel = ChannelModel()
    auction = AuctionConfig(gamma_min=fl_cfg.gamma_min)
    planner = DiffusionPlanner(topology, channel, auction, epsilon=epsilon)
    state = _stack_states(model, opt, key, clients)
    if client_sharding is not None:
        state = jax.device_put(state, client_sharding(state))
    model_bits = agg.model_bits(state.params)
    auction.model_bits = model_bits
    ledger = ResourceLedger()
    history = []

    for t in range(rounds):
        t0 = time.time()
        pos = topology.sample_positions(rng, clients)
        up_gamma = np.maximum(_uplink_gamma(channel, pos, rng),
                              GAMMA_FLOOR)
        ctx = RoundContext(cfg=fl_cfg, t=t, dsi=part.dsi,
                           data_sizes=part.data_sizes, pos=pos, rng=rng,
                           up_gamma=up_gamma, topology=topology,
                           channel=channel, planner=planner,
                           model_bits=model_bits, param_template=None)
        schedule = schedule_feddif(ctx)
        charge_schedule(ledger, schedule)

        metrics = {"loss": jnp.zeros((clients,))}
        for op in schedule.ops:
            if isinstance(op, TrainOp):          # initial fleet update
                state, metrics = fleet_step(state, fleet_batch())
            elif isinstance(op, PermuteOp):      # one diffusion round
                state, metrics = diff_step(state, fleet_batch(),
                                           jnp.asarray(op.src_of_dst),
                                           jnp.asarray(op.train_mask), None)
        # Eq.-11 aggregation + broadcast, chain-data-size weighted.
        weights = jnp.asarray(schedule.slot_weights(), jnp.float32)
        state = TrainState(params=aggregate(state.params, weights),
                           opt_state=state.opt_state, step=state.step)
        loss = float(jnp.mean(metrics["loss"]))
        history.append(loss)
        log(f"round {t + 1}: diffusion_rounds={schedule.diffusion_rounds} "
            f"mean_client_loss={loss:.4f} "
            f"final_iid={schedule.mean_iid:.4f} "
            f"subframes={ledger.subframes} "
            f"({time.time() - t0:.1f}s)")
    return state, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm_360m")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--shard-clients", action="store_true",
                    help="shard the client axis over a ('clients',) mesh "
                         "(use XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=K for a multi-device CPU mesh)")
    args = ap.parse_args()
    _, hist = run_spmd_feddif(args.arch, args.clients, args.rounds,
                              args.alpha, args.seq_len, args.batch,
                              shard_clients=args.shard_clients)
    print("loss history:", [round(h, 3) for h in hist])


if __name__ == "__main__":
    main()
