"""SPMD FedDif runtime: the paper's Algorithm 2 with the data plane jitted.

Bridges the host control plane (``repro.core.diffusion.DiffusionPlanner`` —
auctions, DoL bookkeeping, wireless ledger) and the SPMD data plane
(``repro.distributed.fedshard`` — client-stacked fleet training, diffusion
permutation, weighted aggregation) into one driver:

  per communication round t:
    1. host: plan all diffusion rounds (auction; Algorithm 1)      [PUCCH]
    2. device: initial fleet local update (vmapped train step)
    3. device: per diffusion round k — permute params across the
       client axis with the plan's bijection, train at winners      [PUSCH]
    4. device: data-size-weighted aggregation (Eq. 11) + broadcast

On a pod, the client axis is a real mesh axis (``data`` on-pod for
paper-scale fleets, ``pod`` across pods — see fedshard); on this CPU host
it runs on the 1-device mesh, which is the same program.

    PYTHONPATH=src python -m repro.launch.fl_spmd --clients 4 --rounds 3
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.core.diffusion import DiffusionPlanner
from repro.core.dol import DiffusionState
from repro.data.partitioner import dirichlet_partition
from repro.data.synthetic import class_labels_for_lm, lm_corpus
from repro.distributed.fedshard import (fleet_aggregate,
                                        make_diffusion_step,
                                        make_fleet_train_step)
from repro.models import build_model
from repro.train import optimizer as opt_lib
from repro.train.trainstep import TrainState

__all__ = ["run_spmd_feddif"]


def _stack_states(model, opt, key, n):
    """One model replica per client slot (BS clones the global model)."""
    params = model.init(key)
    one = TrainState(params=params, opt_state=opt.init(params),
                     step=jnp.zeros((), jnp.int32))
    return jax.tree.map(lambda x: jnp.broadcast_to(
        x, (n,) + x.shape).copy(), one)


def run_spmd_feddif(arch: str = "smollm_360m", clients: int = 4,
                    rounds: int = 3, alpha: float = 0.5, seq_len: int = 64,
                    batch: int = 4, lr: float = 0.01, epsilon: float = 0.04,
                    seed: int = 0, log=print):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    opt = opt_lib.sgd()
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)

    # --- non-IID client corpora -------------------------------------
    corpus = lm_corpus(200_000, vocab=cfg.vocab_size, seed=seed)
    n_docs = len(corpus) // seq_len
    docs = corpus[:n_docs * seq_len].reshape(n_docs, seq_len)
    labels = class_labels_for_lm(corpus, 10, seq_len)
    part = dirichlet_partition(labels, clients, alpha, rng)

    def client_batch(c):
        ix = rng.choice(part.indices[c], size=batch,
                        replace=len(part.indices[c]) < batch)
        chunk = docs[ix]
        return {"tokens": jnp.asarray(chunk[:, :-1]),
                "labels": jnp.asarray(chunk[:, 1:])}

    def fleet_batch():
        per = [client_batch(c) for c in range(clients)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per)

    # --- jitted data plane ------------------------------------------
    fleet_step = jax.jit(make_fleet_train_step(model, opt, lr, remat=False))
    diff_step = jax.jit(make_diffusion_step(model, opt, lr, remat=False))
    aggregate = jax.jit(fleet_aggregate)

    planner = DiffusionPlanner(epsilon=epsilon)
    state = _stack_states(model, opt, key, clients)
    weights = jnp.asarray(part.data_sizes, jnp.float32)
    history = []

    for t in range(rounds):
        t0 = time.time()
        # host control plane: plan the whole communication round
        dstate = DiffusionState.init(clients, clients, part.dsi.shape[1])
        for m in range(clients):
            dstate.record_training(m, m, part.dsi[m],
                                   float(part.data_sizes[m]))
        plan = planner.plan_communication_round(
            dstate, part.dsi, part.data_sizes, rng)
        perms = plan.as_permutations(clients)

        # device data plane: initial local update ...
        state, metrics = fleet_step(state, fleet_batch())
        # ... diffusion rounds ...
        for perm, mask in perms:
            # planner emits dst-of-src; the gather needs src-of-dst
            src_of_dst = np.argsort(perm)
            state, metrics = diff_step(state, fleet_batch(),
                                       jnp.asarray(src_of_dst),
                                       jnp.asarray(mask), None)
        # ... and Eq.-11 aggregation + broadcast.
        state = TrainState(params=aggregate(state.params, weights),
                           opt_state=state.opt_state, step=state.step)
        loss = float(jnp.mean(metrics["loss"]))
        history.append(loss)
        log(f"round {t + 1}: diffusion_rounds={plan.num_rounds} "
            f"mean_client_loss={loss:.4f} "
            f"final_iid={float(np.mean(plan.final_iid_distance)):.4f} "
            f"({time.time() - t0:.1f}s)")
    return state, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm_360m")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()
    _, hist = run_spmd_feddif(args.arch, args.clients, args.rounds,
                              args.alpha, args.seq_len, args.batch)
    print("loss history:", [round(h, 3) for h in hist])


if __name__ == "__main__":
    main()
