"""Public kernel ops: jit'd wrappers that dispatch Pallas on TPU and the
pure-jnp oracle (ref.py) elsewhere — the dry-run path lowers the oracle
because Pallas-TPU cannot compile on a CPU backend (DESIGN.md §2).

``implementation`` ∈ {"auto", "pallas", "pallas_interpret", "xla"}
("ref" is accepted as an alias for "xla" — the pure-jnp reference twins in
``ref.py`` ARE the XLA path).

The ``REPRO_KERNELS_IMPL`` environment variable overrides what ``"auto"``
resolves to (explicit ``implementation=`` arguments always win).  CI's
``pallas-interpret`` job sets it to ``pallas_interpret`` so the Pallas
kernel bodies — not just the XLA fallbacks — are exercised on CPU runners.

LM-side kernels (flash_attention / stc_compress / ssm_scan / ssd_scan) are
joined by the FL diffusion data plane (mix_aggregate / stc_topk /
dol_bid_scores — ``kernels/diffusion.py``), which the executors, fedshard
and the planner call through the same dispatch so one env var flips the
whole system between Pallas and reference bodies.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.diffusion import (bid_value_fuse_pallas,
                                     dol_bid_scores_pallas,
                                     mix_aggregate_pallas, stack_ravel,
                                     stack_unravel, stc_rows_pallas)
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.quant import quant_pack_pallas, quant_unpack_pallas
from repro.kernels.ssm_scan import ssm_scan_pallas
from repro.kernels.stc_compress import stc_apply_pallas, stc_reduce_pallas

__all__ = ["flash_attention", "stc_compress", "ssm_scan", "ssd_scan",
           "mix_aggregate", "mix_aggregate_tree", "stc_topk",
           "dol_bid_scores", "bid_value_fuse", "quant_pack", "quant_unpack"]

_IMPLS = ("pallas", "pallas_interpret", "xla", "ref")


def _resolve(implementation: str) -> str:
    if implementation != "auto":
        return "xla" if implementation == "ref" else implementation
    forced = os.environ.get("REPRO_KERNELS_IMPL", "")
    if forced:
        if forced not in _IMPLS:
            raise ValueError(
                f"REPRO_KERNELS_IMPL={forced!r}: expected one of {_IMPLS}")
        return "xla" if forced == "ref" else forced
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    scale: float | None = None,
                    implementation: str = "auto") -> jax.Array:
    impl = _resolve(implementation)
    if impl == "xla":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       scale=scale)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  scale=scale,
                                  interpret=(impl == "pallas_interpret"))


def stc_compress(x, sparsity: float = 0.01, *,
                 implementation: str = "auto") -> jax.Array:
    impl = _resolve(implementation)
    if impl == "xla":
        return ref.stc_compress_ref(x, sparsity)
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    k = max(1, int(n * sparsity))
    # τ = k-th largest |x| (global sort: stays in XLA; see stc_compress.py)
    thr = jnp.sort(jnp.abs(flat))[n - k]
    interpret = impl == "pallas_interpret" or jax.default_backend() != "tpu"
    ssum, cnt = stc_reduce_pallas(flat, thr, interpret=interpret)
    mu = ssum / jnp.maximum(cnt, 1.0)
    out = stc_apply_pallas(flat, thr, mu, interpret=interpret)
    return out.reshape(x.shape).astype(x.dtype)


def mix_aggregate(x, w, *, implementation: str = "auto") -> jax.Array:
    """Eq. (10)/(11) fused mix/aggregate: x (C, F) client-stacked flat
    params, w (G, C) weights (a MixOp matrix, an aggregation row, or a
    sharded Wᵀ block) → (G, F) fp32 in one pass."""
    impl = _resolve(implementation)
    if impl == "xla":
        return ref.mix_aggregate_ref(x, w)
    interpret = impl == "pallas_interpret" or jax.default_backend() != "tpu"
    return mix_aggregate_pallas(x, w, interpret=interpret)


def mix_aggregate_tree(params, w, *, collapse: bool = False,
                       keep_float32: bool = False,
                       implementation: str = "auto"):
    """Tree-level Eq. (10)/(11): mix/aggregate a client-stacked pytree.

    ``w`` is (G, C): a (C, C) MixOp matrix, a (1, C) Eq.-11 aggregation
    row, or a Wᵀ shard block.  ``collapse=True`` (aggregation) drops the
    leading slot axis — explicit rather than inferred from G=1, so a
    one-slot MixOp stays stacked.  ``keep_float32=True`` returns fp32
    leaves (for sharded partials that still cross a psum); otherwise leaf
    dtypes are preserved.

    Dispatch picks the *placement*, not just the body: the XLA path runs
    the per-leaf einsum chain (XLA-CPU fuses it well, and concatenating
    leaves costs a real copy there), while the Pallas path flattens the
    fleet once and streams it through :func:`mix_aggregate` in a single
    HBM pass — the per-leaf chain re-reads HBM per leaf on TPU.
    """
    impl = _resolve(implementation)
    w = jnp.asarray(w, jnp.float32)
    if collapse:
        assert w.shape[0] == 1, w.shape
    if impl == "xla":
        def leaf(x):
            out = jnp.einsum("gc,c...->g...", w, x.astype(jnp.float32))
            if not keep_float32:
                out = out.astype(x.dtype)
            return out[0] if collapse else out
        return jax.tree.map(leaf, params)
    flat, spec = stack_ravel(params)
    out = mix_aggregate(flat, w, implementation=impl)
    return stack_unravel(out, spec, collapse=collapse,
                         keep_float32=keep_float32)


def stc_topk(x, ref_row, mask, sparsity: float = 0.01, *,
             implementation: str = "auto") -> jax.Array:
    """Masked per-row (per-client) STC against a shared reference row —
    the D2D hop compression of ``fedshard.masked_stc_compress`` on one
    flattened leaf.  x (C, n); ref_row (n,); mask (C,) bool."""
    impl = _resolve(implementation)
    if impl == "xla":
        return ref.stc_rows_ref(x, ref_row, mask, sparsity)
    interpret = impl == "pallas_interpret" or jax.default_backend() != "tpu"
    return stc_rows_pallas(x, ref_row, mask, sparsity, interpret=interpret)


def quant_pack(x, *, implementation: str = "auto"):
    """Per-row int8 absmax pack — the adapter hop wire format.  x (R, B)
    fp32 → (q (R, B) int8, scale (R,) fp32), ``scale = max(absmax,
    1e-12)/127`` per row.  Rows here are the QUANT_BLOCK-element row-blocks
    of a flattened adapter (``fl/adapters.pack_rows``)."""
    impl = _resolve(implementation)
    if impl == "xla":
        return ref.quant_pack_ref(x)
    interpret = impl == "pallas_interpret" or jax.default_backend() != "tpu"
    return quant_pack_pallas(x, interpret=interpret)


def quant_unpack(q, scale, *, implementation: str = "auto") -> jax.Array:
    """Inverse of :func:`quant_pack`: (q (R, B) int8, scale (R,)) → (R, B)
    fp32 dequantized payload at the hop destination."""
    impl = _resolve(implementation)
    if impl == "xla":
        return ref.quant_unpack_ref(q, scale)
    interpret = impl == "pallas_interpret" or jax.default_backend() != "tpu"
    return quant_unpack_pallas(q, scale, interpret=interpret)


def dol_bid_scores(dol, chain_size, dsi, data_size, *,
                   metric: str = "w1_norm",
                   implementation: str = "auto") -> jax.Array:
    """The planner's (M, N) candidate IID-distance matrix (Eq. 32 bids).

    The Pallas path implements the paper's default ``w1_norm`` metric
    (Eq. B.1) as a tiled MXU contraction; the Appendix-C divergence
    metrics (kld/jsd/w1_true) have no closed matmul form and always use
    the reference composite.
    """
    impl = _resolve(implementation)
    if impl == "xla" or metric != "w1_norm":
        return ref.dol_bid_scores_ref(dol, chain_size, dsi, data_size,
                                      metric)
    interpret = impl == "pallas_interpret" or jax.default_backend() != "tpu"
    return dol_bid_scores_pallas(dol, chain_size, dsi, data_size,
                                 interpret=interpret)


def bid_value_fuse(bids, value, weight, *,
                   implementation: str = "auto") -> jax.Array:
    """Fuse the per-client learning value into the planner's bid matrix:
    ``bids · (1 + weight · value[None, :])`` — the uncertainty-weighted
    auction objective next to :func:`dol_bid_scores`."""
    impl = _resolve(implementation)
    if impl == "xla":
        return ref.bid_value_fuse_ref(bids, value, weight)
    interpret = impl == "pallas_interpret" or jax.default_backend() != "tpu"
    return bid_value_fuse_pallas(bids, value, weight, interpret=interpret)


def ssm_scan(da, dbx, *, implementation: str = "auto") -> jax.Array:
    impl = _resolve(implementation)
    if impl == "xla":
        return ref.ssm_scan_ref(da, dbx)
    interpret = impl == "pallas_interpret" or jax.default_backend() != "tpu"
    return ssm_scan_pallas(da, dbx, interpret=interpret)


def ssd_scan(xh, a, bmat, cmat, *, implementation: str = "auto") -> jax.Array:
    """Mamba-2 SSD chunk scan (zamba2)."""
    from repro.kernels.ssd_scan import ssd_scan_pallas, ssd_scan_ref
    impl = _resolve(implementation)
    if impl == "xla":
        return ssd_scan_ref(xh, a, bmat, cmat)
    interpret = impl == "pallas_interpret" or jax.default_backend() != "tpu"
    return ssd_scan_pallas(xh, a, bmat, cmat, interpret=interpret)
