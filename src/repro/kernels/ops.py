"""Public kernel ops: jit'd wrappers that dispatch Pallas on TPU and the
pure-jnp oracle (ref.py) elsewhere — the dry-run path lowers the oracle
because Pallas-TPU cannot compile on a CPU backend (DESIGN.md §2).

``implementation`` ∈ {"auto", "pallas", "pallas_interpret", "xla"}.

The ``REPRO_KERNELS_IMPL`` environment variable overrides what ``"auto"``
resolves to (explicit ``implementation=`` arguments always win).  CI's
``pallas-interpret`` job sets it to ``pallas_interpret`` so the Pallas
kernel bodies — not just the XLA fallbacks — are exercised on CPU runners.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ssm_scan import ssm_scan_pallas
from repro.kernels.stc_compress import stc_apply_pallas, stc_reduce_pallas

__all__ = ["flash_attention", "stc_compress", "ssm_scan", "ssd_scan"]


def _resolve(implementation: str) -> str:
    if implementation != "auto":
        return implementation
    forced = os.environ.get("REPRO_KERNELS_IMPL", "")
    if forced:
        if forced not in ("pallas", "pallas_interpret", "xla"):
            raise ValueError(
                f"REPRO_KERNELS_IMPL={forced!r}: expected pallas, "
                f"pallas_interpret or xla")
        return forced
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    scale: float | None = None,
                    implementation: str = "auto") -> jax.Array:
    impl = _resolve(implementation)
    if impl == "xla":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       scale=scale)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  scale=scale,
                                  interpret=(impl == "pallas_interpret"))


def stc_compress(x, sparsity: float = 0.01, *,
                 implementation: str = "auto") -> jax.Array:
    impl = _resolve(implementation)
    if impl == "xla":
        return ref.stc_compress_ref(x, sparsity)
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    k = max(1, int(n * sparsity))
    # τ = k-th largest |x| (global sort: stays in XLA; see stc_compress.py)
    thr = jnp.sort(jnp.abs(flat))[n - k]
    interpret = impl == "pallas_interpret" or jax.default_backend() != "tpu"
    ssum, cnt = stc_reduce_pallas(flat, thr, interpret=interpret)
    mu = ssum / jnp.maximum(cnt, 1.0)
    out = stc_apply_pallas(flat, thr, mu, interpret=interpret)
    return out.reshape(x.shape).astype(x.dtype)


def ssm_scan(da, dbx, *, implementation: str = "auto") -> jax.Array:
    impl = _resolve(implementation)
    if impl == "xla":
        return ref.ssm_scan_ref(da, dbx)
    interpret = impl == "pallas_interpret" or jax.default_backend() != "tpu"
    return ssm_scan_pallas(da, dbx, interpret=interpret)


def ssd_scan(xh, a, bmat, cmat, *, implementation: str = "auto") -> jax.Array:
    """Mamba-2 SSD chunk scan (zamba2)."""
    from repro.kernels.ssd_scan import ssd_scan_pallas, ssd_scan_ref
    impl = _resolve(implementation)
    if impl == "xla":
        return ssd_scan_ref(xh, a, bmat, cmat)
    interpret = impl == "pallas_interpret" or jax.default_backend() != "tpu"
    return ssd_scan_pallas(xh, a, bmat, cmat, interpret=interpret)
