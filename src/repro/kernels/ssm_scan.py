"""Chunked selective-scan (diagonal linear recurrence) as a Pallas TPU kernel.

Computes ``h_t = da_t ⊙ h_{t-1} + dbx_t`` over the sequence axis — the inner
recurrence of Mamba-1 (``repro.models.ssm``).  Blocking mirrors the model's
chunked scan, adapted to the TPU memory hierarchy:

* grid = (batch, d_inner blocks, seq chunks) — seq innermost/sequential, so
  the carried state h (block_d, N) persists in VMEM scratch across chunks;
* per grid step the kernel loads a (chunk, block_d, N) tile of da/dbx into
  VMEM (default 128×256×16 fp32 = 2 MB/operand), runs the recurrence with a
  ``fori_loop`` over the chunk, and writes the states tile;
* channel blocks are independent → the d grid axis parallelizes across
  cores, and the `model`-axis sharding of d_inner composes on top.

Validated in interpret mode against ``ref.ssm_scan_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssm_scan_pallas"]


def _scan_kernel(da_ref, dbx_ref, h_ref, h_scr, *, chunk: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    def body(t, h):
        a = da_ref[0, t].astype(jnp.float32)        # (block_d, N)
        bx = dbx_ref[0, t].astype(jnp.float32)
        h = a * h + bx
        h_ref[0, t] = h.astype(h_ref.dtype)
        return h

    h_scr[...] = jax.lax.fori_loop(0, chunk, body, h_scr[...])


@functools.partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def ssm_scan_pallas(da: jax.Array, dbx: jax.Array, *, chunk: int = 128,
                    block_d: int = 256, interpret: bool = True) -> jax.Array:
    """da/dbx: (B, S, D, N) -> all states (B, S, D, N)."""
    b, s, d, n = da.shape
    chunk = min(chunk, s)
    block_d = min(block_d, d)
    pad_s = (-s) % chunk
    pad_d = (-d) % block_d
    if pad_s or pad_d:
        cfg = ((0, 0), (0, pad_s), (0, pad_d), (0, 0))
        da = jnp.pad(da, cfg, constant_values=1.0)
        dbx = jnp.pad(dbx, cfg)
    ns = da.shape[1] // chunk
    nd = da.shape[2] // block_d

    kernel = functools.partial(_scan_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(b, nd, ns),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d, n),
                         lambda bi, di, si: (bi, si, di, 0)),
            pl.BlockSpec((1, chunk, block_d, n),
                         lambda bi, di, si: (bi, si, di, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d, n),
                               lambda bi, di, si: (bi, si, di, 0)),
        out_shape=jax.ShapeDtypeStruct(da.shape, jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
    )(da, dbx)
    return out[:, :s, :d]
