"""Int8 absmax quantization as a Pallas kernel pair — the adapter hop wire.

The FedDif hop payload (the trainable-adapter view of a client model,
``repro.fl.adapters``) is packed per row-block before every PermuteOp move:

  pack   (``_pack_kernel``):   per (1, block) row tile, ``scale =
         max(absmax, ε)/127`` and ``q = clip(round(x/scale), ±127)`` int8;
  unpack (``_unpack_kernel``): ``q·scale`` back to fp32 at the destination.

One fp32 scale per block-row rides along with the int8 payload, so a packed
hop costs ``8·block + 32`` bits per row against ``32·block`` for fp32 — the
4x the Eq.-15 ledger charges via ``spec_adapter_bits``.  All-zero rows hit
the ε floor and quantize to exact zeros, which keeps padded mesh slots inert.
Grid is one program per row; block sizes here are the adapter row-blocks
(512 elements = 2 KB fp32 in VMEM), far under the stc_compress 64k tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["quant_pack_pallas", "quant_unpack_pallas", "QUANT_BLOCK"]

QUANT_BLOCK = 512   # elements per quantization row-block (fp32: 2 KB)

_EPS = 1e-12        # absmax floor: all-zero rows stay exactly zero


def _pack_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                       # (1, block)
    # multiply by the fp32 reciprocal, NOT /127.0: XLA lowers constant
    # division to a reciprocal multiply only on some paths, and the 1-ulp
    # scale drift would break ref/pallas bitwise wire parity
    scale = jnp.maximum(jnp.max(jnp.abs(x)), _EPS) * jnp.float32(1 / 127)
    s_ref[...] = scale.reshape(1, 1)
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127.0, 127.0).astype(
        jnp.int8)


def _unpack_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def quant_pack_pallas(x: jax.Array, *, interpret: bool = True):
    """x (R, B) fp32 → (q (R, B) int8, scale (R,) fp32), absmax per row."""
    r, b = x.shape
    q, s = pl.pallas_call(
        _pack_kernel,
        grid=(r,),
        in_specs=[pl.BlockSpec((1, b), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, b), lambda i: (i, 0)),
                   pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((r, b), jnp.int8),
                   jax.ShapeDtypeStruct((r, 1), jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.float32))
    return q, s[:, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def quant_unpack_pallas(q: jax.Array, scale: jax.Array, *,
                        interpret: bool = True) -> jax.Array:
    """(q (R, B) int8, scale (R,)) → (R, B) fp32 dequantized payload."""
    r, b = q.shape
    return pl.pallas_call(
        _unpack_kernel,
        grid=(r,),
        in_specs=[pl.BlockSpec((1, b), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, b), jnp.float32),
        interpret=interpret,
    )(q, scale.reshape(r, 1).astype(jnp.float32))
