"""Pallas kernels for the FL diffusion data plane (Eq. 10/11 + STC hops).

Three hot loops of the communication round run as tiled single-pass kernels
instead of long per-leaf ``jnp`` chains:

* :func:`mix_aggregate_pallas` — Eq. (10)/(11): one weighted reduction
  ``out[g, f] = Σ_c w[g, c] · x[c, f]`` over a *flattened client-stacked
  parameter block* ``x`` (every pytree leaf raveled and concatenated on one
  feature axis).  A ``MixOp`` is ``w = W`` (the (C, C) mixing matrix), the
  Eq.-11 aggregation is ``w = weights[None, :]`` (one output row), and a
  sharded partial is ``w = Wᵀ_local`` — all the per-leaf
  ``einsum → mask → psum`` chains in ``repro.fl.executors`` become ONE
  MXU pass per feature tile, one HBM read of the fleet.

* :func:`stc_rows_pallas` — per-row (per-client) sparse ternary compression
  fused with the masked blend of ``fedshard.masked_stc_compress``:
  ``out[c] = mask[c] ? ref + μ_c·sign(x_c − ref)·1[|x_c − ref| ≥ τ_c]
  : x[c]``.  Two tiled passes (row-wise survivor reduction, then
  ternarize+blend) replace the host composite (a ``vmap`` of ``top_k`` +
  scatter per client per leaf).  τ itself stays an XLA sort, exactly like
  ``kernels.stc_compress`` (DESIGN.md §2).

* :func:`dol_bid_scores_pallas` — the planner's candidate IID-distance
  matrix (Sec. III-B / Eq. 32 bids) without materializing the (M, N, C)
  candidate-DoL tensor.  Centering DoLs/DSIs on the uniform point
  ``u = 1/C`` collapses Eq. (2) + Eq. (B.1) to a rank-C matmul plus
  rank-1 corrections::

      cand − u·1 = (a·ψc + b·dc)/s′ + u·δ·1,
          ψc = ψ − u,  dc = d − u,  s′ = max(a + b, 1),  δ = (a+b)/s′ − 1
      ‖cand − u‖² = (a²‖ψc‖² + 2ab·(ψc·dc) + b²‖dc‖²)/s′²
                    + 2uδ·(a·Σψc + b·Σdc)/s′ + C·u²·δ²

  ``ψc·dcᵀ`` is an (M, C)×(C, N) MXU contraction; everything else is a
  row or column statistic.  The centered form is exact *and* cancellation
  free as the DoLs converge to uniform (dist → 0), where the naive
  ``‖cand‖² − 1/C`` expansion loses all precision.
  :func:`dol_bid_scores_xla_fused` is the same math as a pure-jnp twin —
  the fast XLA path for large-N pre-planning and the oracle the kernel is
  tested against (which is itself validated against
  ``repro.core.dol.iid_distance_candidates``).

All kernels carry ``interpret=`` so CI's pallas-interpret job runs the
bodies on CPU; dispatch (auto/pallas/pallas_interpret/ref) lives in
``kernels.ops``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["mix_aggregate_pallas", "stc_rows_pallas", "dol_bid_scores_pallas",
           "dol_bid_scores_xla_fused", "stack_ravel", "stack_unravel"]

BLOCK_F = 8192      # feature-axis tile (fp32 (C, BF) block in VMEM)
BLOCK_C = 1024      # client-axis tile (streaming accumulate over C)
VMEM_BUDGET = 4 << 20   # per-operand VMEM budget used to shrink BLOCK_F


def stack_ravel(params) -> tuple[jax.Array, tuple]:
    """Flatten a client-stacked pytree to one (C, F) fp32 block.

    Every leaf (C, *shape) is raveled to (C, n) and concatenated on the
    feature axis — the layout :func:`mix_aggregate_pallas` streams through
    VMEM in a single HBM pass.  Returns ``(flat, spec)``;
    :func:`stack_unravel` inverts (restoring leaf shapes and dtypes).
    The concatenate is only worth its copy where the kernel runs (one pass
    over HBM beats L separate per-leaf passes); the XLA reference path in
    ``ops.mix_aggregate_tree`` therefore keeps the per-leaf chain instead.
    """
    leaves, treedef = jax.tree.flatten(params)
    c = leaves[0].shape[0]
    flat = jnp.concatenate(
        [x.reshape(c, -1).astype(jnp.float32) for x in leaves], axis=1)
    meta = tuple((x.shape[1:], x.dtype) for x in leaves)
    return flat, (treedef, meta)


def stack_unravel(flat: jax.Array, spec: tuple, *, collapse: bool = False,
                  keep_float32: bool = False):
    """Inverse of :func:`stack_ravel`.

    ``flat`` may carry any leading slot count G (a (C, F) mixed fleet, an
    (nl, F) shard block, or a (1, F) Eq.-11 aggregate).  ``collapse=True``
    drops the leading axis (requires G=1) — explicit, because a legitimate
    one-slot MixOp also has G=1 and must stay stacked.  ``keep_float32``
    skips the restore to each leaf's stored dtype (for partials that still
    cross a reduction).
    """
    treedef, meta = spec
    g = flat.shape[0]
    if collapse:
        assert g == 1, g
    leaves, off = [], 0
    for shape, dtype in meta:
        n = 1
        for d in shape:
            n *= d
        blk = flat[:, off:off + n]
        off += n
        blk = (blk.reshape(shape) if collapse
               else blk.reshape((g,) + shape))
        leaves.append(blk if keep_float32 else blk.astype(dtype))
    return jax.tree.unflatten(treedef, leaves)


def _feature_block(rows: int, block: int, n: int) -> int:
    """Largest lane-aligned feature tile with (rows, tile) under budget."""
    cap = max(128, VMEM_BUDGET // (4 * max(rows, 1)))
    b = min(block, cap, max(128, n))
    return max(128, (b // 128) * 128)


# ------------------------------------------------------------ mix/aggregate

def _mix_kernel(w_ref, x_ref, o_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = w_ref[...].astype(jnp.float32)             # (G, BC)
    x = x_ref[...].astype(jnp.float32)             # (BC, BF)
    o_ref[...] += jax.lax.dot(w, x,
                              preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_f", "block_c",
                                             "interpret"))
def mix_aggregate_pallas(x: jax.Array, w: jax.Array, *,
                         block_f: int = BLOCK_F, block_c: int = BLOCK_C,
                         interpret: bool = True) -> jax.Array:
    """``w @ x`` over (feature, client) tiles: x (C, F), w (G, C) → (G, F).

    Grid cell (i, k) streams the (BC, BF) client tile through VMEM and
    accumulates its Wᵀ-partial into the *revolving* (G, BF) output block:
    the output index map ignores k, so the block stays resident in VMEM
    across the inner client loop while Pallas double-buffers the next x
    tile's HBM fetch behind the current MXU pass — Eq. (10)/(11) streams
    over fleets far larger than VMEM instead of barriering on one (C, BF)
    slab.  Fleets with C ≤ block_c keep the original single-tile schedule
    (and its exact summation order).
    """
    c, f = x.shape
    g = w.shape[0]
    assert w.shape == (g, c), (w.shape, x.shape)
    bc = min(block_c, max(8, -(-c // 8) * 8))
    pad_c = (-c) % bc
    if pad_c:
        # Zero client rows / weight columns contribute nothing to any sum.
        x = jnp.pad(x, ((0, pad_c), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, pad_c)))
    nc = x.shape[0] // bc
    bf = _feature_block(max(bc, g), block_f, f)
    pad = (-f) % bf
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    grid = (x.shape[1] // bf, nc)
    out = pl.pallas_call(
        _mix_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((g, bc), lambda i, k: (0, k)),
                  pl.BlockSpec((bc, bf), lambda i, k: (k, i))],
        out_specs=pl.BlockSpec((g, bf), lambda i, k: (0, i)),
        out_shape=jax.ShapeDtypeStruct((g, x.shape[1]), jnp.float32),
        interpret=interpret,
    )(w.astype(jnp.float32), x.astype(jnp.float32))
    return out[:, :f]


# ------------------------------------------------------------------ stc rows

def _stc_reduce_kernel(x_ref, r_ref, thr_ref, sum_ref, cnt_ref, *,
                       n_valid: int, block: int):
    # Two-bank revolving accumulator: even feature tiles land in bank 0,
    # odd tiles in bank 1, so consecutive grid steps extend *independent*
    # serial FP-add chains (the banks are summed on the host side).  That
    # halves the loop-carried latency the pipeline must hide while the
    # next x tile streams in.
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    d = x_ref[...].astype(jnp.float32) - r_ref[...].astype(jnp.float32)
    idx = j * block + jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)
    keep = jnp.logical_and(jnp.abs(d) >= thr_ref[0, 0], idx < n_valid)
    bank = (jax.lax.broadcasted_iota(jnp.int32, (1, 2), 1)
            == j % 2).astype(jnp.float32)
    sum_ref[...] += jnp.sum(jnp.where(keep, jnp.abs(d), 0.0)) * bank
    cnt_ref[...] += jnp.sum(keep.astype(jnp.float32)) * bank


def _stc_apply_kernel(x_ref, r_ref, thr_ref, mu_ref, mask_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    r = r_ref[...].astype(jnp.float32)
    d = x - r
    tern = jnp.where(jnp.abs(d) >= thr_ref[0, 0],
                     jnp.sign(d) * mu_ref[0, 0], 0.0)
    o_ref[...] = jnp.where(mask_ref[0, 0] != 0, r + tern, x).astype(
        o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("sparsity", "block",
                                             "interpret"))
def stc_rows_pallas(x: jax.Array, ref_row: jax.Array, mask: jax.Array,
                    sparsity: float, *, block: int = BLOCK_F,
                    interpret: bool = True) -> jax.Array:
    """Masked per-row STC against a shared reference row.

    x (C, n); ref_row (n,) — the broadcast global every PUE holds; mask
    (C,) bool.  Row c with ``mask[c]`` becomes ``ref + STC(x_c − ref)``
    (the compressed D2D payload), other rows pass through bit-untouched.
    The top-k threshold is an XLA per-row sort (a quantile serializes a
    Pallas grid — see kernels/stc_compress.py); the survivor reduction and
    the fused ternarize+blend are tiled row-wise passes.
    """
    c, n = x.shape
    k = max(1, int(n * sparsity))
    delta = x.astype(jnp.float32) - ref_row.astype(jnp.float32)[None, :]
    thr = jnp.sort(jnp.abs(delta), axis=1)[:, n - k]            # (C,)

    blk = _feature_block(1, block, n)
    pad = (-n) % blk
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, pad)))
    rp = jnp.pad(ref_row.astype(jnp.float32), (0, pad)).reshape(1, -1)
    nb = xp.shape[1] // blk
    thr2 = thr.reshape(c, 1)
    reduce_kernel = functools.partial(_stc_reduce_kernel, n_valid=n,
                                     block=blk)
    ssum, cnt = pl.pallas_call(
        reduce_kernel,
        grid=(c, nb),
        in_specs=[pl.BlockSpec((1, blk), lambda i, j: (i, j)),
                  pl.BlockSpec((1, blk), lambda i, j: (0, j)),
                  pl.BlockSpec((1, 1), lambda i, j: (i, 0))],
        out_specs=[pl.BlockSpec((1, 2), lambda i, j: (i, 0)),
                   pl.BlockSpec((1, 2), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((c, 2), jnp.float32),
                   jax.ShapeDtypeStruct((c, 2), jnp.float32)],
        interpret=interpret,
    )(xp, rp, thr2)
    ssum = ssum.sum(axis=1, keepdims=True)                      # (C, 1)
    cnt = cnt.sum(axis=1, keepdims=True)
    mu = ssum / jnp.maximum(cnt, 1.0)                           # (C, 1)
    mask2 = mask.astype(jnp.int32).reshape(c, 1)
    out = pl.pallas_call(
        _stc_apply_kernel,
        grid=(c, nb),
        in_specs=[pl.BlockSpec((1, blk), lambda i, j: (i, j)),
                  pl.BlockSpec((1, blk), lambda i, j: (0, j)),
                  pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((1, blk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=interpret,
    )(xp, rp, thr2, mu, mask2)
    return out[:, :n]


# ------------------------------------------------------------ dol bid scores

def _center_stats(dol, chain_size, dsi, data_size):
    """Centered operands + row/col statistics of the fused expansion."""
    m, c = dol.shape
    u = 1.0 / c
    psi_c = dol.astype(jnp.float32) - u                       # (M, C)
    d_c = dsi.astype(jnp.float32) - u                         # (N, C)
    a = chain_size.astype(jnp.float32).reshape(m, 1)          # (M, 1)
    b = data_size.astype(jnp.float32).reshape(-1, 1)          # (N, 1)
    p_psi = jnp.sum(psi_c * psi_c, axis=1, keepdims=True)     # (M, 1)
    s_psi = jnp.sum(psi_c, axis=1, keepdims=True)             # (M, 1)
    p_d = jnp.sum(d_c * d_c, axis=1, keepdims=True)           # (N, 1)
    s_d = jnp.sum(d_c, axis=1, keepdims=True)                 # (N, 1)
    return psi_c, d_c, a, b, p_psi, s_psi, p_d, s_d


def _bid_scores_from_stats(cross, a, b, p_psi, s_psi, p_d, s_d, u):
    """dist²(cand, U) from the centered statistics; see module docstring."""
    bt = b.reshape(1, -1)                                     # (1, N)
    p_dt = p_d.reshape(1, -1)
    s_dt = s_d.reshape(1, -1)
    s = a + bt                                                # (M, N)
    sp = jnp.maximum(s, 1.0)
    delta = s / sp - 1.0                                      # 0 when s ≥ 1
    core = (a * a * p_psi + 2.0 * a * bt * cross
            + bt * bt * p_dt) / (sp * sp)
    lin = 2.0 * u * delta * (a * s_psi + bt * s_dt) / sp
    quad = (1.0 / u) * (u * delta) ** 2                       # C·u²·δ²
    return jnp.sqrt(jnp.maximum(core + lin + quad, 0.0))


def dol_bid_scores_xla_fused(dol: jax.Array, chain_size: jax.Array,
                             dsi: jax.Array, data_size: jax.Array
                             ) -> jax.Array:
    """Pure-jnp twin of the kernel math (w1_norm metric).

    Identical algebra — one (M, C)×(C, N) contraction, no (M, N, C)
    broadcast — so it is both the kernel's parity oracle and the fast XLA
    path for large-N planning on backends without Pallas.
    """
    psi_c, d_c, a, b, p_psi, s_psi, p_d, s_d = _center_stats(
        dol, chain_size, dsi, data_size)
    cross = psi_c @ d_c.T                                     # (M, N)
    return _bid_scores_from_stats(cross, a, b, p_psi, s_psi, p_d, s_d,
                                  1.0 / dol.shape[1])


def _bid_kernel(psi_ref, a_ref, ppsi_ref, spsi_ref,
                d_ref, b_ref, pd_ref, sd_ref, o_ref, *, u: float):
    psi = psi_ref[...].astype(jnp.float32)                    # (BM, C)
    d = d_ref[...].astype(jnp.float32)                        # (BN, C)
    cross = jax.lax.dot_general(
        psi, d, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                   # (BM, BN)
    o_ref[...] = _bid_scores_from_stats(
        cross, a_ref[...], b_ref[...].reshape(1, -1),
        ppsi_ref[...], spsi_ref[...],
        pd_ref[...].reshape(1, -1), sd_ref[...].reshape(1, -1), u)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n",
                                             "interpret"))
def dol_bid_scores_pallas(dol: jax.Array, chain_size: jax.Array,
                          dsi: jax.Array, data_size: jax.Array, *,
                          block_m: int = 128, block_n: int = 256,
                          interpret: bool = True) -> jax.Array:
    """Candidate IID-distance matrix (M, N) on the MXU, tiled over (M, N).

    Grid cell (i, j) loads the centered (BM, C) DoL block and (BN, C) DSI
    block, contracts them once, and finishes with rank-1 statistics — the
    (M, N, C) candidate tensor never exists in HBM.  w1_norm metric (the
    paper's Eq. B.1 default); other metrics fall back to the reference
    composite in ``kernels.ops``.
    """
    m, c = dol.shape
    n = dsi.shape[0]
    psi_c, d_c, a, b, p_psi, s_psi, p_d, s_d = _center_stats(
        dol, chain_size, dsi, data_size)
    bm = min(block_m, max(8, -(-m // 8) * 8))
    bn = min(block_n, max(128, -(-n // 128) * 128))
    pm, pn = (-m) % bm, (-n) % bn
    pad_m = lambda t: jnp.pad(t, ((0, pm), (0, 0)))     # noqa: E731
    pad_n = lambda t: jnp.pad(t, ((0, pn), (0, 0)))     # noqa: E731
    psi_c, a, p_psi, s_psi = map(pad_m, (psi_c, a, p_psi, s_psi))
    d_c, b, p_d, s_d = map(pad_n, (d_c, b, p_d, s_d))
    grid = (psi_c.shape[0] // bm, d_c.shape[0] // bn)
    kernel = functools.partial(_bid_kernel, u=1.0 / c)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, c), lambda i, j: (i, 0)),
                  pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
                  pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
                  pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
                  pl.BlockSpec((bn, c), lambda i, j: (j, 0)),
                  pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
                  pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
                  pl.BlockSpec((bn, 1), lambda i, j: (j, 0))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((psi_c.shape[0], d_c.shape[0]),
                                       jnp.float32),
        interpret=interpret,
    )(psi_c, a, p_psi, s_psi, d_c, b, p_d, s_d)
    return out[:m, :n]


# ------------------------------------------------------------ bid value fuse

def _bid_value_kernel(bids_ref, val_ref, w_ref, o_ref):
    o_ref[...] = bids_ref[...] * (1.0 + w_ref[0, 0] * val_ref[...])


@functools.partial(jax.jit, static_argnames=("block_m", "block_n",
                                             "interpret"))
def bid_value_fuse_pallas(bids: jax.Array, value: jax.Array,
                          weight: jax.Array | float, *,
                          block_m: int = 128, block_n: int = 256,
                          interpret: bool = True) -> jax.Array:
    """Fuse the per-client learning value into the (M, N) bid matrix.

    Elementwise VPU tile: grid cell (i, j) scales its bid block by
    ``1 + w · value`` with the value row broadcast down the model axis —
    the companion of ``dol_bid_scores_pallas`` in the planner's auction
    surface.  Semantics of record: ``kernels.ref.bid_value_fuse_ref``.
    """
    m, n = bids.shape
    bids32 = bids.astype(jnp.float32)
    val = value.astype(jnp.float32).reshape(1, n)
    w = jnp.asarray(weight, jnp.float32).reshape(1, 1)
    bm = min(block_m, max(8, -(-m // 8) * 8))
    bn = min(block_n, max(128, -(-n // 128) * 128))
    pm, pn = (-m) % bm, (-n) % bn
    bp = jnp.pad(bids32, ((0, pm), (0, pn)))
    vp = jnp.pad(val, ((0, 0), (0, pn)))
    grid = (bp.shape[0] // bm, bp.shape[1] // bn)
    out = pl.pallas_call(
        _bid_value_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
                  pl.BlockSpec((1, bn), lambda i, j: (0, j)),
                  pl.BlockSpec((1, 1), lambda i, j: (0, 0))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(bp.shape, jnp.float32),
        interpret=interpret,
    )(bp, vp, w)
    return out[:m, :n]
