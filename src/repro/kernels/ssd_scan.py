"""Mamba-2 SSD chunk scan as a Pallas TPU kernel (zamba2's hot spot).

Semantics (scalar-decay-per-head state space, Dao & Gu 2024):

    h_t = exp(a_t)[h] · h_{t-1} + x_t[h,p] ⊗ b_t[n]
    y_t[h,p] = Σ_n c_t[n] · h_t[h,p,n]

Blocking — one grid step processes a (chunk × head-block) tile entirely in
VMEM: the intra-chunk contribution is the quadratic-within-chunk form
(C Bᵀ ∘ decay-tril) · X, the inter-chunk contribution flows through the
(head_block, P, N) state scratch that persists across the sequential
seq-chunk grid axis.  Default tile (chunk 128 × 8 heads × P64 × N64) keeps
the fp32 working set ≈ 4.5 MB — half of VMEM with double-buffering room.

Validated in interpret mode against ``ref_ssd.ssd_scan_ref`` (sequential
recurrence oracle) and against the model-layer chunked implementation
(``repro.models.ssm._ssd_chunk_scan``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan_pallas", "ssd_scan_ref"]


def ssd_scan_ref(xh: jax.Array, a: jax.Array, bmat: jax.Array,
                 cmat: jax.Array) -> jax.Array:
    """Sequential oracle.  xh (B,S,H,P), a (B,S,H), b/c (B,S,N) -> (B,S,H,P)."""
    b_, s, h, p = xh.shape
    n = bmat.shape[-1]

    def step(hst, xs):
        x_t, a_t, b_t, c_t = xs
        hst = jnp.exp(a_t)[:, :, None, None] * hst \
            + x_t[..., None] * b_t[:, None, None, :]
        y_t = jnp.einsum("bn,bhpn->bhp", c_t, hst)
        return hst, y_t

    h0 = jnp.zeros((b_, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(step, h0,
                         (jnp.moveaxis(xh, 1, 0).astype(jnp.float32),
                          jnp.moveaxis(a, 1, 0).astype(jnp.float32),
                          jnp.moveaxis(bmat, 1, 0).astype(jnp.float32),
                          jnp.moveaxis(cmat, 1, 0).astype(jnp.float32)))
    return jnp.moveaxis(ys, 0, 1)


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, h_scr, *, chunk: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)            # (L, hb, P)
    a = a_ref[0].astype(jnp.float32)            # (L, hb)
    b = b_ref[0].astype(jnp.float32)            # (L, N)
    c = c_ref[0].astype(jnp.float32)            # (L, N)
    acum = jnp.cumsum(a, axis=0)                # (L, hb)

    # intra-chunk: y[q] += Σ_k 1[k<=q]·exp(acum_q−acum_k)·(c_q·b_k)·x_k
    rel = acum[:, None, :] - acum[None, :, :]   # (Lq, Lk, hb)
    ltri = jnp.tril(jnp.ones((x.shape[0], x.shape[0]), jnp.bool_))
    dec = jnp.exp(jnp.where(ltri[:, :, None], rel, -1e30))
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Lq, Lk)
    w = cb[:, :, None] * dec                    # (Lq, Lk, hb)
    y_intra = jnp.einsum("qkh,khp->qhp", w, x)

    # inter-chunk: carried state h (hb, P, N)
    h = h_scr[...]
    y_state = jnp.einsum("qn,hpn,qh->qhp", c, h, jnp.exp(acum))
    # state update
    tot = jnp.exp(acum[-1])                     # (hb,)
    decay_k = jnp.exp(acum[-1:, :] - acum)      # (L, hb)
    h_scr[...] = tot[:, None, None] * h + jnp.einsum(
        "kn,khp,kh->hpn", b, x, decay_k)

    y_ref[0] = (y_intra + y_state).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "block_h", "interpret"))
def ssd_scan_pallas(xh: jax.Array, a: jax.Array, bmat: jax.Array,
                    cmat: jax.Array, *, chunk: int = 128, block_h: int = 8,
                    interpret: bool = True) -> jax.Array:
    """xh (B,S,H,P), a (B,S,H), b/c (B,S,N) -> y (B,S,H,P)."""
    b_, s, h, p = xh.shape
    n = bmat.shape[-1]
    chunk = min(chunk, s)
    block_h = min(block_h, h)
    pad_s = (-s) % chunk
    pad_h = (-h) % block_h
    if pad_s or pad_h:
        xh = jnp.pad(xh, ((0, 0), (0, pad_s), (0, pad_h), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad_s), (0, pad_h)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad_s), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad_s), (0, 0)))
    ns = xh.shape[1] // chunk
    nh = xh.shape[2] // block_h

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=(b_, nh, ns),
        in_specs=[
            pl.BlockSpec((1, chunk, block_h, p),
                         lambda bi, hi, si: (bi, si, hi, 0)),
            pl.BlockSpec((1, chunk, block_h),
                         lambda bi, hi, si: (bi, si, hi)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, si: (bi, si, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, si: (bi, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_h, p),
                               lambda bi, hi, si: (bi, si, hi, 0)),
        out_shape=jax.ShapeDtypeStruct(xh.shape, jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_h, p, n), jnp.float32)],
        interpret=interpret,
    )(xh, a, bmat, cmat)
    return y[:, :s, :h]
