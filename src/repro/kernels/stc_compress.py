"""Sparse Ternary Compression as a two-pass Pallas TPU kernel pipeline.

STC (the paper's Table-II compression baseline, Sattler et al. [41]) maps a
tensor to ``μ·sign(x)·1[|x| ≥ τ]`` with τ the top-k magnitude threshold and
μ the mean magnitude of the survivors.  On TPU this runs as:

  pass 1 (``_reduce_kernel``): tiled reduction computing, per VMEM block,
          ``(Σ |x|·1[|x|≥τ], Σ 1[|x|≥τ])`` — accumulated across the
          sequential grid in SMEM-like (1,1) accumulator tiles;
  pass 2 (``_apply_kernel``):  tiled elementwise ternarize with the final μ.

τ itself is a quantile — a global sort that XLA already does well (and that
would serialize a Pallas grid), so ``ops.stc_compress`` computes it with
``jnp.quantile`` and hands it to the kernels as a scalar operand.  Block
size 64k elements = 256 KB fp32 per buffer in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["stc_reduce_pallas", "stc_apply_pallas"]

BLOCK = 65536   # elements per tile (fp32: 256 KB in VMEM)


def _reduce_kernel(x_ref, thr_ref, sum_ref, cnt_ref, *, n_valid: int,
                   block: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    x = x_ref[...].astype(jnp.float32)                      # (1, block)
    idx = i * block + jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)
    keep = jnp.logical_and(jnp.abs(x) >= thr_ref[0, 0], idx < n_valid)
    mag = jnp.where(keep, jnp.abs(x), 0.0)
    sum_ref[...] += jnp.sum(mag).reshape(1, 1)
    cnt_ref[...] += jnp.sum(keep.astype(jnp.float32)).reshape(1, 1)


def _apply_kernel(x_ref, thr_ref, mu_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    keep = jnp.abs(x) >= thr_ref[0, 0]
    o_ref[...] = jnp.where(keep, jnp.sign(x) * mu_ref[0, 0], 0.0).astype(
        o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def stc_reduce_pallas(flat: jax.Array, thr: jax.Array, *,
                      block: int = BLOCK, interpret: bool = True):
    """Returns (Σ|x| over survivors, #survivors) for a flat fp32 array."""
    n = flat.shape[0]
    block = min(block, max(128, n))
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    grid = flat.shape[0] // block
    x2 = flat.reshape(grid, block)
    thr2 = thr.reshape(1, 1).astype(jnp.float32)
    kernel = functools.partial(_reduce_kernel, n_valid=n, block=block)
    s, c = pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)),
                   pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, 1), jnp.float32),
                   jax.ShapeDtypeStruct((1, 1), jnp.float32)],
        interpret=interpret,
    )(x2, thr2)
    return s[0, 0], c[0, 0]


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def stc_apply_pallas(flat: jax.Array, thr: jax.Array, mu: jax.Array, *,
                     block: int = BLOCK, interpret: bool = True):
    n = flat.shape[0]
    block = min(block, max(128, n))
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    grid = flat.shape[0] // block
    x2 = flat.reshape(grid, block)
    out = pl.pallas_call(
        _apply_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid, block), flat.dtype),
        interpret=interpret,
    )(x2, thr.reshape(1, 1).astype(jnp.float32),
      mu.reshape(1, 1).astype(jnp.float32))
    return out.reshape(-1)[:n]
