"""Flash attention as a Pallas TPU kernel.

Blockwise online-softmax attention with explicit VMEM tiling:

* grid = (batch·heads, q_blocks, kv_blocks) — the kv axis is innermost, so
  the fp32 (m, l, acc) running-softmax state lives in VMEM scratch across kv
  iterations (TPU grids execute sequentially over the last axis).
* BlockSpec tiles: q/o (1, block_q, head_dim); k/v (1, block_k, head_dim) —
  block sizes default to (256, 512), MXU-aligned multiples of 128 chosen so
  the working set (q + k + v + acc ≈ 0.6 MB at d=128) sits comfortably in
  the ~16 MB/core VMEM with room for double-buffering.
* causal/sliding-window masking is applied in-kernel; fully-masked kv blocks
  are skipped with ``pl.when`` (on real TPUs this prunes ~half the FLOPs of
  a causal prefill — the XLA fallback cannot skip; see §Roofline).

Validated in interpret mode against ``ref.flash_attention_ref`` over shape /
dtype / window sweeps (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

__all__ = ["flash_attention_pallas"]


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, block_q: int, block_k: int, seq_q: int,
                 seq_k: int, causal: bool, window: int | None,
                 num_kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Absolute token positions of this tile (q right-aligned to kv end).
    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + (seq_k - seq_q)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # Block-level visibility: skip tiles that are fully masked.
    q_blk_max = qi * block_q + block_q - 1 + (seq_k - seq_q)
    q_blk_min = qi * block_q + (seq_k - seq_q)
    k_blk_min = ki * block_k
    k_blk_max = ki * block_k + block_k - 1
    visible = jnp.asarray(True)
    if causal:
        visible = jnp.logical_and(visible, k_blk_min <= q_blk_max)
    if window is not None:
        visible = jnp.logical_and(visible, k_blk_max > q_blk_min - window)

    @pl.when(visible)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = k_pos < seq_k
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        safe_m = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.exp(s - safe_m[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0,
                          jnp.exp(m_prev - safe_m))
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1)
        acc_scr[...] = alpha[:, None] * acc_scr[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret", "scale"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int | None = None,
                           scale: float | None = None, block_q: int = 256,
                           block_k: int = 512,
                           interpret: bool = True) -> jax.Array:
    """q: (B, Sq, H, D); k/v: (B, Sk, H, D) — same head count (pre-repeated
    for GQA by the caller).  Returns (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = float(1.0 / (d ** 0.5)) if scale is None else float(scale)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)

    # fold heads into batch; pad seq to block multiples
    def fold(x, s):
        x = jnp.moveaxis(x, 2, 1).reshape(b * h, s, d)
        return x

    qf, kf, vf = fold(q, sq), fold(k, sk), fold(v, sk)
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))
    nq = qf.shape[1] // block_q
    nk = kf.shape[1] // block_k

    kernel = functools.partial(
        _attn_kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_q=sq, seq_k=sk, causal=causal, window=window, num_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, nq * block_q, d), q.dtype),
        scratch_shapes=[
            # fp32 online-softmax state in VMEM, persistent across kv blocks
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)

    out = out[:, :sq].reshape(b, h, sq, d)
    return jnp.moveaxis(out, 1, 2)
