"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics of record: each kernel's test sweeps shapes/dtypes
and asserts allclose against these functions.  They are also the XLA
fallbacks used on non-TPU backends (the dry-run lowers these — Pallas-TPU
cannot compile on a CPU host; see DESIGN.md §2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["flash_attention_ref", "stc_compress_ref", "ssm_scan_ref"]


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: int | None = None,
                        scale: float | None = None) -> jax.Array:
    """Naive softmax attention.  q: (B, Sq, H, D); k/v: (B, Sk, H, D)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = (1.0 / np.sqrt(d)) if scale is None else scale
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(sq)[:, None] + (sk - sq)   # right-aligned positions
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)           # fully-masked rows
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def stc_compress_ref(x: jax.Array, sparsity: float) -> jax.Array:
    """Sparse ternary compression (Sattler et al.): keep the top-k entries
    by |magnitude|, replace them with sign(x)·mean(|top-k|)."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    k = max(1, int(n * sparsity))
    topv, topi = jax.lax.top_k(jnp.abs(flat), k)
    mu = jnp.mean(topv)
    out = jnp.zeros_like(flat).at[topi].set(jnp.sign(flat[topi]) * mu)
    return out.reshape(x.shape).astype(x.dtype)


def ssm_scan_ref(da: jax.Array, dbx: jax.Array,
                 h0: jax.Array | None = None) -> jax.Array:
    """Diagonal linear recurrence h_t = da_t * h_{t-1} + dbx_t.

    da/dbx: (B, S, D, N) fp32.  Returns all states (B, S, D, N).
    """
    b, s, d, n = da.shape
    if h0 is None:
        h0 = jnp.zeros((b, d, n), jnp.float32)

    def step(h, x):
        a, bx = x
        h = a * h + bx
        return h, h

    _, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                         (jnp.moveaxis(da, 1, 0).astype(jnp.float32),
                          jnp.moveaxis(dbx, 1, 0).astype(jnp.float32)))
    return jnp.moveaxis(hs, 0, 1)
