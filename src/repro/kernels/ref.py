"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics of record: each kernel's test sweeps shapes/dtypes
and asserts allclose against these functions.  They are also the XLA
fallbacks used on non-TPU backends (the dry-run lowers these — Pallas-TPU
cannot compile on a CPU host; see DESIGN.md §2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["flash_attention_ref", "stc_compress_ref", "ssm_scan_ref",
           "mix_aggregate_ref", "stc_rows_ref", "dol_bid_scores_ref",
           "bid_value_fuse_ref", "quant_pack_ref", "quant_unpack_ref"]


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: int | None = None,
                        scale: float | None = None) -> jax.Array:
    """Naive softmax attention.  q: (B, Sq, H, D); k/v: (B, Sk, H, D)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = (1.0 / np.sqrt(d)) if scale is None else scale
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(sq)[:, None] + (sk - sq)   # right-aligned positions
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)           # fully-masked rows
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def stc_compress_ref(x: jax.Array, sparsity: float) -> jax.Array:
    """Sparse ternary compression (Sattler et al.): keep the top-k entries
    by |magnitude|, replace them with sign(x)·mean(|top-k|)."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    k = max(1, int(n * sparsity))
    topv, topi = jax.lax.top_k(jnp.abs(flat), k)
    mu = jnp.mean(topv)
    out = jnp.zeros_like(flat).at[topi].set(jnp.sign(flat[topi]) * mu)
    return out.reshape(x.shape).astype(x.dtype)


def mix_aggregate_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Eq. (10)/(11) weighted reduction on a flattened client-stacked block:
    ``out[g, f] = Σ_c w[g, c]·x[c, f]``.  x (C, F); w (G, C) → (G, F) fp32."""
    return jnp.einsum("gc,cf->gf", w.astype(jnp.float32),
                      x.astype(jnp.float32))


def stc_rows_ref(x: jax.Array, ref_row: jax.Array, mask: jax.Array,
                 sparsity: float) -> jax.Array:
    """Masked per-row STC against a shared reference row — the exact host
    composite of ``fedshard.masked_stc_compress`` on one flattened leaf:
    row c becomes ``ref + STC(x_c − ref)`` where masked, else passes
    through."""
    ref_row = ref_row.astype(jnp.float32)
    comp = jax.vmap(
        lambda row: ref_row + stc_compress_ref(
            row.astype(jnp.float32) - ref_row, sparsity))(x)
    return jnp.where(mask.reshape(-1, 1), comp.astype(x.dtype), x)


def quant_pack_ref(x: jax.Array):
    """Per-row int8 absmax pack — the adapter hop wire format.  x (R, B)
    fp32 → (q (R, B) int8, scale (R,) fp32) with ``scale = max(absmax,
    1e-12)/127``; all-zero rows hit the floor and quantize to exact zeros."""
    x = x.astype(jnp.float32)
    # reciprocal multiply, matching kernels/quant.py bit for bit (XLA does
    # not lower /127.0 identically on every path)
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1),
                        1e-12) * jnp.float32(1 / 127)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127.0, 127.0).astype(
        jnp.int8)
    return q, scale


def quant_unpack_ref(q: jax.Array, scale: jax.Array) -> jax.Array:
    """(q (R, B) int8, scale (R,)) → (R, B) fp32 dequantized payload."""
    return q.astype(jnp.float32) * scale[:, None].astype(jnp.float32)


def dol_bid_scores_ref(dol: jax.Array, chain_size: jax.Array,
                       dsi: jax.Array, data_size: jax.Array,
                       metric: str = "w1_norm") -> jax.Array:
    """Candidate IID-distance matrix via the (M, N, C) broadcast composite
    — ``repro.core.dol.iid_distance_candidates``, the semantics of record
    for the planner's Eq.-32 bid tensor."""
    from repro.core.dol import iid_distance_candidates
    return iid_distance_candidates(dol, chain_size, dsi, data_size, metric)


def ssm_scan_ref(da: jax.Array, dbx: jax.Array,
                 h0: jax.Array | None = None) -> jax.Array:
    """Diagonal linear recurrence h_t = da_t * h_{t-1} + dbx_t.

    da/dbx: (B, S, D, N) fp32.  Returns all states (B, S, D, N).
    """
    b, s, d, n = da.shape
    if h0 is None:
        h0 = jnp.zeros((b, d, n), jnp.float32)

    def step(h, x):
        a, bx = x
        h = a * h + bx
        return h, h

    _, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                         (jnp.moveaxis(da, 1, 0).astype(jnp.float32),
                          jnp.moveaxis(dbx, 1, 0).astype(jnp.float32)))
    return jnp.moveaxis(hs, 0, 1)


def bid_value_fuse_ref(bids: jax.Array, value: jax.Array,
                       weight: jax.Array | float) -> jax.Array:
    """Learning-value bid fusion: ``bids · (1 + w · value[None, :])``.

    ``value`` is the per-client predictive-uncertainty score in [0, 1];
    the multiplicative form preserves the sign of the Eq.-32 valuations so
    constraint (18b) feasibility is decided on the fused bids without
    changing its structure.  Oracle for ``bid_value_fuse_pallas``.
    """
    w = jnp.asarray(weight, jnp.float32)
    return (bids.astype(jnp.float32)
            * (1.0 + w * value.astype(jnp.float32)[None, :]))
