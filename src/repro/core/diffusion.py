"""Diffusion-round planner — the control plane of Algorithm 2 (lines 14–26).

``plan_communication_round`` runs the DoL-broadcast → bid → auction →
schedule loop until the halting condition ``W1(ψ, U) ≤ ε`` holds for every
model (or no feasible pair remains), producing a :class:`DiffusionPlan`:
the per-diffusion-round list of (model, src PUE, dst PUE, γ, bandwidth).

The plan is *pure scheduling* — no training happens here.  The FL runtime
(``repro.fl.server``) executes a plan by running local updates and parameter
transfers (host mode) or ppermute collectives (SPMD mode), and the launcher
replays plans on the production mesh.  This mirrors the paper's split between
PUCCH control signalling and PUSCH model transmission.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict

import numpy as np

from repro.channels.fading import ChannelModel
from repro.channels.resources import spectral_efficiency
from repro.channels.topology import CellTopology
from repro.core import dol as dol_lib
from repro.core.auction import AuctionConfig, run_auction

__all__ = ["DiffusionHop", "DiffusionPlan", "DiffusionPlanner", "PlanCache",
           "plan_cache_key", "feddif_cache_key", "PLANNER_MODES"]

PLANNER_MODES = ("host", "jax")


@dataclasses.dataclass
class DiffusionHop:
    model: int
    src: int
    dst: int
    gamma: float            # spectral efficiency of the scheduled link
    bandwidth: float        # Eq. 15 cost (Hz·s)
    decrement: float        # δ (Eq. 17)
    round_index: int


@dataclasses.dataclass
class DiffusionPlan:
    hops: list[DiffusionHop]
    num_rounds: int
    final_iid_distance: np.ndarray      # (M,)
    efficiency_per_round: list[float]
    num_models: int | None = None       # M — set by the planner

    def hops_in_round(self, k: int) -> list[DiffusionHop]:
        return [h for h in self.hops if h.round_index == k]

    def as_permutations(self, num_clients: int,
                        num_models: int | None = None
                        ) -> list[tuple[np.ndarray, np.ndarray]]:
        """Per-round (permutation, train_mask) for the SPMD ppermute path.

        The auction's matching is *partial* (some models stay put), but
        ``jax.lax.ppermute`` needs a bijection over client slots.
        :func:`repro.core.schedule.complete_round_permutation` completes the
        partial mapping src→dst to a permutation (unscheduled sources stay
        put where possible, displaced idle models are "parked" on free
        slots); ``train_mask`` marks the slots whose freshly received model
        performs a local update, i.e. the scheduled dsts.

        ``num_models`` is the fleet size M; models that never hop still own
        a slot, so inferring M from the hop list would silently drop them
        from the parking bookkeeping.  Defaults to the plan's recorded M
        (falling back to hop-list inference only for plans from external
        sources that predate the field).

        perm[k][c] = slot that receives slot c's buffer in round k.
        """
        from repro.core.schedule import complete_round_permutation
        if num_models is None:
            num_models = self.num_models
        if num_models is None:
            num_models = (max(h.model for h in self.hops) + 1
                          if self.hops else 0)
        slot_of_model = np.arange(num_models) % max(num_clients, 1)
        out = []
        for k in range(self.num_rounds):
            hops = [(h.model, h.dst) for h in self.hops_in_round(k)]
            src_of_dst, mask, slot_of_model = complete_round_permutation(
                hops, slot_of_model, num_clients)
            out.append((np.argsort(src_of_dst), mask))
        return out


def plan_cache_key(topology_seed: int, round_index: int, dsi: np.ndarray,
                   data_sizes: np.ndarray, epsilon: float, gamma_min: float,
                   metric: str, extra: tuple = ()) -> tuple:
    """Cache key for one communication round's :class:`DiffusionPlan`.

    A plan is a pure function of the control-plane inputs: the topology /
    channel draw (derived from ``(topology_seed, round_index)``), the client
    DSIs and data sizes (fixed by the data seed), and the planner knobs
    (ε, γ_min, metric, …).  It is *independent of the model-init seed*, which
    is what makes multi-seed replication cacheable: the orchestrator replans
    once per sweep cell and replays the plan for every replicate seed.
    """
    h = hashlib.sha1()
    h.update(np.ascontiguousarray(dsi, np.float32).tobytes())
    h.update(np.ascontiguousarray(data_sizes, np.float64).tobytes())
    return (int(topology_seed), int(round_index), float(epsilon),
            float(gamma_min), str(metric), h.hexdigest(), tuple(extra))


def feddif_cache_key(cfg, t: int, dsi: np.ndarray, data_sizes: np.ndarray,
                     model_bits: float, auction: AuctionConfig,
                     values: np.ndarray | None = None) -> tuple:
    """The one :func:`plan_cache_key` builder for FedDif call sites.

    ``cfg`` is the experiment's ``FLConfig`` (duck-typed to avoid the import
    cycle).  Folds in every plan input: the sizing knobs, the full
    :class:`AuctionConfig` surface (incl. ``outage_max`` and
    ``bandwidth_budget``, which alter feasibility/FCFS), the world scenario
    and learning-value weight, and the planner mode (host and jax plans are
    parity-checked but not bit-guaranteed, so they never share a cache
    line).  Schedulers, the replicate engines and the sweep pre-planner all
    call this helper — hand-built ``extra=`` tuples cannot drift apart.

    ``values`` is the round's learning-value vector.  It depends on the
    model parameters — i.e. on the *model-init seed* — so when the value
    signal is active its digest joins the key and plans stop being
    shareable across replicate seeds (the pre-planner skips those cells).
    """
    vdigest = ""
    if values is not None and getattr(cfg, "uncertainty_weight", 0.0):
        vdigest = hashlib.sha1(
            np.ascontiguousarray(values, np.float32).tobytes()).hexdigest()
    return plan_cache_key(
        cfg.topology_seed, t, dsi, data_sizes, cfg.epsilon, cfg.gamma_min,
        cfg.metric,
        extra=(cfg.num_clients, cfg.num_models, float(model_bits),
               cfg.max_diffusion_rounds, cfg.allow_retraining, cfg.underlay,
               float(auction.outage_max), float(auction.bandwidth_budget),
               getattr(cfg, "planner", "host"),
               getattr(cfg, "scenario", "static"),
               float(getattr(cfg, "uncertainty_weight", 0.0)), vdigest))


class PlanCache:
    """LRU memo of ``(DiffusionPlan, post-plan DiffusionState)`` snapshots.

    ``DiffusionPlanner.plan_communication_round`` consults it when given a
    ``cache_key``: on a hit the stored plan is returned and the caller's
    mutable :class:`~repro.core.dol.DiffusionState` is fast-forwarded to the
    stored post-plan snapshot — the auction / bidding loop (the expensive
    host-side control plane) is skipped entirely.  Keys come from
    :func:`plan_cache_key`; see there for what makes two rounds equivalent.
    """

    def __init__(self, max_entries: int = 256):
        self.max_entries = max_entries
        self._store: OrderedDict[tuple, tuple] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, key: tuple) -> bool:
        """Presence probe that does not touch hit/miss counters or LRU
        order (used by the sweep pre-planner to skip planned rounds)."""
        return key in self._store

    def lookup(self, key: tuple):
        """Return ``(plan, post_state)`` or ``None``; counts hits/misses."""
        entry = self._store.get(key)
        if entry is None:
            self.misses += 1
            return None
        self._store.move_to_end(key)
        self.hits += 1
        return entry

    def store(self, key: tuple, plan: "DiffusionPlan",
              post_state: dol_lib.DiffusionState) -> None:
        self._store[key] = (plan, post_state.snapshot())
        self._store.move_to_end(key)
        while len(self._store) > self.max_entries:
            self._store.popitem(last=False)

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._store)}

    # ------------------------------------------------------- serialization
    #
    # Durable sweeps persist the cache across preemptions so a resumed run
    # *replays* plans instead of replanning them.  The state dict is pure
    # JSON-able data (no pickle): keys are nested tuples of scalars (lists
    # on disk, retupled on load), plans/hops/states are plain number lists.

    def state_dict(self) -> dict:
        entries = []
        for key, (plan, state) in self._store.items():
            entries.append({
                "key": _key_jsonable(key),
                "plan": {
                    "hops": [[h.model, h.src, h.dst, h.gamma, h.bandwidth,
                              h.decrement, h.round_index]
                             for h in plan.hops],
                    "num_rounds": int(plan.num_rounds),
                    "final_iid_distance":
                        np.asarray(plan.final_iid_distance,
                                   np.float32).tolist(),
                    "efficiency_per_round":
                        [float(e) for e in plan.efficiency_per_round],
                    "num_models": plan.num_models,
                },
                "state": {
                    "dol": np.asarray(state.dol, np.float32).tolist(),
                    "chain_size":
                        np.asarray(state.chain_size, np.float32).tolist(),
                    "visited": np.asarray(state.visited, bool).tolist(),
                    "holder": np.asarray(state.holder, np.int64).tolist(),
                    "round_index": int(state.round_index),
                },
            })
        return {"version": 1, "max_entries": self.max_entries,
                "hits": self.hits, "misses": self.misses,
                "entries": entries}

    def load_state_dict(self, state: dict) -> None:
        """Merge serialized entries into this cache (counters adopted too,
        so a resumed sweep's cache statistics continue, not restart)."""
        self.max_entries = int(state.get("max_entries", self.max_entries))
        self.hits = int(state.get("hits", 0))
        self.misses = int(state.get("misses", 0))
        for e in state["entries"]:
            key = _key_from_jsonable(e["key"])
            p, s = e["plan"], e["state"]
            plan = DiffusionPlan(
                hops=[DiffusionHop(model=int(h[0]), src=int(h[1]),
                                   dst=int(h[2]), gamma=float(h[3]),
                                   bandwidth=float(h[4]),
                                   decrement=float(h[5]),
                                   round_index=int(h[6]))
                      for h in p["hops"]],
                num_rounds=int(p["num_rounds"]),
                final_iid_distance=np.asarray(p["final_iid_distance"],
                                              np.float32),
                efficiency_per_round=[float(x)
                                      for x in p["efficiency_per_round"]],
                num_models=(None if p["num_models"] is None
                            else int(p["num_models"])))
            post = dol_lib.DiffusionState(
                dol=np.asarray(s["dol"], np.float32),
                chain_size=np.asarray(s["chain_size"], np.float32),
                visited=np.asarray(s["visited"], bool),
                holder=np.asarray(s["holder"], np.int64),
                round_index=int(s["round_index"]))
            self._store[key] = (plan, post)
            self._store.move_to_end(key)
        while len(self._store) > self.max_entries:
            self._store.popitem(last=False)

    @classmethod
    def from_state_dict(cls, state: dict) -> "PlanCache":
        cache = cls(max_entries=int(state.get("max_entries", 256)))
        cache.load_state_dict(state)
        return cache


def _key_jsonable(key):
    """Cache keys are nested tuples of (int, float, bool, str, None) — JSON
    keeps every scalar type distinct, only the tuple/list shape changes."""
    if isinstance(key, tuple):
        return [_key_jsonable(k) for k in key]
    return key


def _key_from_jsonable(key):
    if isinstance(key, list):
        return tuple(_key_from_jsonable(k) for k in key)
    return key


class DiffusionPlanner:
    """Plans all diffusion rounds of one communication round."""

    def __init__(self, topology: CellTopology | None = None,
                 channel: ChannelModel | None = None,
                 auction: AuctionConfig | None = None,
                 epsilon: float = 0.04,
                 max_rounds: int | None = None,
                 underlay: bool = False,
                 mode: str = "host"):
        assert mode in PLANNER_MODES, mode
        if mode == "jax" and underlay:
            raise ValueError("planner mode 'jax' does not model underlay "
                             "CUE interference (Appendix C-F); use 'host'")
        self.topology = topology or CellTopology()
        self.channel = channel or ChannelModel()
        self.auction = auction or AuctionConfig()
        self.epsilon = epsilon          # minimum tolerable IID distance
        self.max_rounds = max_rounds
        self.underlay = underlay        # Appendix C-F: D2D reuses CUE PRBs
        self.mode = mode                # "host" oracle | "jax" device plane

    def plan_communication_round(
            self, state: dol_lib.DiffusionState, dsi: np.ndarray,
            data_sizes: np.ndarray, rng: np.random.Generator,
            positions: np.ndarray | None = None,
            cache: PlanCache | None = None,
            cache_key: tuple | None = None,
            interference: np.ndarray | float = 0.0,
            values: np.ndarray | None = None,
            value_weight: float = 0.0,
            world=None, step_m: float = 0.0) -> DiffusionPlan:
        """Runs auctions until halting; mutates ``state`` with visited sets.

        When ``cache``/``cache_key`` are given (see :func:`plan_cache_key`),
        a hit skips the whole auction loop: the cached plan is returned and
        ``state`` is fast-forwarded to the cached post-plan snapshot.  The
        caller is responsible for a key that captures every plan input.

        ``interference`` is the world's per-receiver co-channel power
        (multicell SINR — frozen within the round); ``values`` /
        ``value_weight`` fuse the learning-value signal into the bids;
        ``world`` + ``step_m`` (mobile scenario) step a random-waypoint
        WorldState one deterministic substep per diffusion round, moving
        every link's pathloss under the auction as the paper's Eqs. 12–14
        would see it.  All default off — the static path is bit-identical
        to the pre-world planner.

        With ``mode='jax'`` the same contract is served by the jitted
        device planner (:mod:`repro.core.planner`): identical hop lists on
        the same channel draws, but the draws are pre-sampled ``max_rounds``
        deep, so the *post-plan position* of ``rng`` differs from the lazy
        host loop's.
        """
        if self.mode == "jax":
            from repro.core.planner import plan_communication_round_jax
            return plan_communication_round_jax(
                self, state, dsi, data_sizes, rng, positions=positions,
                cache=cache, cache_key=cache_key,
                interference=interference, values=values,
                value_weight=value_weight, world=world, step_m=step_m)
        if cache is not None and cache_key is not None:
            entry = cache.lookup(cache_key)
            if entry is not None:
                plan, post_state = entry
                state.restore(post_state)
                return plan
        n = dsi.shape[0]
        pos = way = None
        if world is not None:
            pos = np.asarray(world.positions, np.float64)
            way = np.asarray(world.waypoints, np.float64)
            positions = pos
        elif positions is None:
            positions = self.topology.sample_positions(rng, n)
        dist = self.topology.pairwise_distances(positions)
        beta = 10 ** (self.channel.large_scale_db(dist) / 10.0)
        mean_snr = self.channel.snr(beta, interference)  # Rayleigh power
        #                                                  marginalized

        hops: list[DiffusionHop] = []
        eff_hist: list[float] = []
        # Worst case O(N_P(N_P-1)) rounds (Sec. V-D); each PUE trains each
        # model at most once, so N_P rounds suffice when all M hop per round.
        max_rounds = self.max_rounds or n * (n - 1)
        k = 0
        while k < max_rounds:
            iid = state.iid_distances(self.auction.metric)
            active = iid > self.epsilon
            if not self.auction.allow_retraining:
                # Models at chain length N visited everyone (full diffusion).
                active &= ~state.visited.all(axis=1)
            if not active.any():
                break
            if world is not None:
                # Host mirror of the planner-loop world step (mobile).
                delta = way - pos
                d = np.linalg.norm(delta, axis=-1, keepdims=True)
                frac = np.minimum(step_m, d) / np.maximum(d, 1e-9)
                pos = pos + delta * frac
                dist = self.topology.pairwise_distances(pos)
                beta = 10 ** (self.channel.large_scale_db(dist) / 10.0)
                mean_snr = self.channel.snr(beta, interference)
            gains = self.channel.sample_gains(dist, rng)
            cue_interference = 0.0
            if self.underlay:
                n_cues = rng.poisson(self.topology.cue_rate)
                cue_interference = self.channel.sample_cue_interference(
                    rng, n_cues, self.topology.radius_m)
            snr = self.channel.snr(gains, interference + cue_interference)
            result = run_auction(state, dsi, data_sizes, gains, mean_snr,
                                 snr, self.auction, values=values,
                                 value_weight=value_weight)
            # Only schedule hops for still-active models.
            scheduled = [(m, i) for m, i in result.pairs if active[m]]
            if not scheduled:
                break
            k += 1
            gamma = spectral_efficiency(snr)
            for m, i in scheduled:
                src = int(state.holder[m])
                hops.append(DiffusionHop(
                    model=m, src=src, dst=i,
                    gamma=float(gamma[src, i]),
                    bandwidth=result.bandwidth[m],
                    decrement=result.decrements[m],
                    round_index=k - 1))
                state.record_training(m, i, dsi[i], float(data_sizes[i]))
            eff_hist.append(result.efficiency)
        state.round_index += k
        plan = DiffusionPlan(hops=hops, num_rounds=k,
                             final_iid_distance=state.iid_distances(
                                 self.auction.metric),
                             efficiency_per_round=eff_hist,
                             num_models=int(state.dol.shape[0]))
        if cache is not None and cache_key is not None:
            cache.store(cache_key, plan, state)
        return plan
