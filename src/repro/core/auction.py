"""Auction-based winner selection — Sec. V, Algorithm 1.

Each diffusion round:

1. every PUE computes its *valuation* of every model (Eq. 32): the decrement
   of IID distance the model would gain by training on that PUE's data;
2. bids (valuations) + CSI bundles (Eq. 34) go to the BS;
3. the BS builds edge weights ``c(m, i) = v / B̃`` (Eq. 36) — zeroed when any
   of constraints (18b) positive decrement, (18c) no retraining,
   (18e) min-QoS/outage hold is violated;
4. Kuhn–Munkres finds the max-weight matching (Eq. 38);
5. the bandwidth budget (18f) is enforced by a greedy FCFS pass over the
   matched edges in decreasing efficiency (Sec. V-C uses FCFS scheduling).

Second-price bookkeeping: the winner of each model "pays" the second-highest
feasible bid for that model; payments are recorded for incentive analysis but
do not alter the schedule (standard Vickrey bookkeeping).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import dol as dol_lib
from repro.core.matching import max_weight_matching
from repro.channels.resources import (outage_probability, required_bandwidth,
                                      spectral_efficiency)

__all__ = ["AuctionConfig", "AuctionResult", "compute_bids",
           "fuse_learning_value", "run_auction"]


@dataclasses.dataclass
class AuctionConfig:
    gamma_min: float = 1.0          # minimum tolerable QoS (bit/s/Hz)
    outage_max: float = 0.05        # P_out ≤ 5 % (Sec. V-C)
    metric: str = "w1_norm"         # IID-distance metric
    bandwidth_budget: float = np.inf  # Eq. (18f) cap on Σ B (Hz·s units)
    model_bits: float = 1e6         # S — size of one serialized model
    allow_retraining: bool = False  # Appendix C-D: drop constraint (18c)


@dataclasses.dataclass
class AuctionResult:
    pairs: list[tuple[int, int]]            # (model, next-trainer PUE)
    bandwidth: dict[int, float]             # model -> B̃ (Eq. 37)
    efficiency: float                       # E(i*, B*) (Eq. 16)
    decrements: dict[int, float]            # model -> δ (Eq. 17)
    payments: dict[int, float]              # model -> second price
    bids: np.ndarray                        # (M, N) valuation matrix
    feasible: np.ndarray                    # (M, N) bool


def compute_bids(state: dol_lib.DiffusionState, dsi: np.ndarray,
                 data_sizes: np.ndarray, metric: str = "w1_norm"
                 ) -> np.ndarray:
    """Valuation matrix v[m, i] (Eq. 32): current minus candidate IID distance.

    Positive where PUE i's data would pull model m's DoL toward uniform.
    """
    cur = dol_lib.iid_distance(np.asarray(state.dol), metric)       # (M,)
    cand = dol_lib.iid_distance_candidates(
        np.asarray(state.dol), np.asarray(state.chain_size),
        np.asarray(dsi), np.asarray(data_sizes), metric)            # (M,N)
    return np.asarray(cur)[:, None] - np.asarray(cand)


def fuse_learning_value(bids: np.ndarray, values: np.ndarray | None,
                        value_weight: float) -> np.ndarray:
    """Learning-value bid fusion: ``bids · (1 + w · value[i])``.

    ``values`` is a per-client predictive-uncertainty score in [0, 1]
    (``fl/experiment.py``'s held-out probe); scaling the IID-distance
    valuation multiplicatively keeps the (18b) positivity constraint's
    sign structure intact while routing models toward *informative* data.
    Host oracle of ``repro.kernels.ops.bid_value_fuse``.
    """
    if values is None or value_weight == 0.0:
        return bids
    return bids * (1.0 + value_weight * np.asarray(values)[None, :])


def run_auction(state: dol_lib.DiffusionState, dsi: np.ndarray,
                data_sizes: np.ndarray, gains_sq: np.ndarray,
                mean_snr: np.ndarray, snr: np.ndarray,
                config: AuctionConfig, values: np.ndarray | None = None,
                value_weight: float = 0.0) -> AuctionResult:
    """One diffusion-configuration step (Algorithm 1).

    Args:
      state:      diffusion bookkeeping (DoLs, chains, visited, holders).
      dsi:        (N, C) client DSIs.
      data_sizes: (N,) client dataset sizes.
      gains_sq:   (N, N) sampled |g|^2 between PUEs (Eq. 12).
      mean_snr:   (N, N) large-scale-only mean SNR (for Eq. 39 outage).
      snr:        (N, N) instantaneous SNR (for Eq. 14 rate).
      config:     auction parameters.
      values / value_weight: optional per-client learning-value signal
        fused into the valuations (:func:`fuse_learning_value`); the
        default (off) path is bit-identical to the pre-value auction.
    """
    m_models, n_pues = state.visited.shape
    bids = compute_bids(state, dsi, data_sizes, config.metric)       # (M,N)
    bids = fuse_learning_value(bids, values, value_weight)

    gamma = spectral_efficiency(snr)                                 # (N,N)
    # Per (model, PUE) edge: the link is holder(m) -> i.
    hold = state.holder                                              # (M,)
    gamma_edge = gamma[hold][:, np.arange(n_pues)]                   # (M,N)
    pout_edge = outage_probability(config.gamma_min, mean_snr[hold]) # (M,N)

    feasible = np.ones((m_models, n_pues), dtype=bool)
    feasible &= bids > 0.0                                   # (18b)
    if not config.allow_retraining:
        feasible &= ~state.visited                           # (18c)
    feasible &= gamma_edge >= config.gamma_min               # (18e) QoS
    feasible &= pout_edge <= config.outage_max               # (39) outage
    # A PUE does not transmit to itself.
    feasible[np.arange(m_models), hold] = False

    bw = required_bandwidth(config.model_bits, gamma_edge)           # (M,N)
    with np.errstate(divide="ignore", invalid="ignore"):
        weight = np.where(feasible & np.isfinite(bw) & (bw > 0),
                          bids / bw, 0.0)                            # Eq. 36

    pairs = max_weight_matching(weight)  # enforces (18d): matching is 1-1

    # (18f) bandwidth budget: FCFS over matched edges by decreasing efficiency.
    pairs.sort(key=lambda mi: -weight[mi[0], mi[1]])
    chosen: list[tuple[int, int]] = []
    budget = config.bandwidth_budget
    for m, i in pairs:
        cost = bw[m, i]
        if cost <= budget:
            chosen.append((m, i))
            budget -= cost

    decrements = {m: float(bids[m, i]) for m, i in chosen}
    bandwidth = {m: float(bw[m, i]) for m, i in chosen}

    # Second-price payments: second-best feasible valuation for each model,
    # capped at the winner's own bid (the matching optimizes *global*
    # efficiency, so the winner need not be the model's top bidder).
    payments = {}
    for m, i in chosen:
        others = bids[m][feasible[m]]
        others = np.sort(others)[::-1]
        second = float(others[1]) if others.size > 1 else 0.0
        payments[m] = min(second, float(bids[m, i]))

    eff = 0.0
    if chosen:
        eff = float(np.mean([decrements[m] / bandwidth[m] for m, _ in chosen
                             if bandwidth[m] > 0]))
    return AuctionResult(pairs=chosen, bandwidth=bandwidth, efficiency=eff,
                         decrements=decrements, payments=payments,
                         bids=bids, feasible=feasible)
