"""Kuhn–Munkres (Hungarian) maximum-weight bipartite matching.

Used by the winner-selection algorithm (Algorithm 1) to pair models with
next-trainer PUEs maximizing total diffusion efficiency (Eq. 38).

Pure-numpy O(n^3) shortest-augmenting-path implementation (Jonker–Volgenant
style potentials) so the control plane has no scipy dependency and the same
code runs under CI on any host.  ``scipy.optimize.linear_sum_assignment`` is
used as the test oracle.
"""
from __future__ import annotations

import numpy as np

__all__ = ["max_weight_matching", "hungarian_min_cost"]

_INF = float("inf")


def hungarian_min_cost(cost: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Solve the rectangular assignment problem, minimizing total cost.

    Args:
      cost: (n_rows, n_cols) float matrix, n_rows <= n_cols (callers pad).

    Returns:
      (row_ind, col_ind) arrays of length n_rows with the optimal assignment.
    """
    cost = np.asarray(cost, dtype=np.float64)
    n, m = cost.shape
    transposed = False
    if n > m:
        cost = cost.T
        n, m = m, n
        transposed = True

    # Jonker-Volgenant with row/col potentials; 1-based col sentinel at 0.
    u = np.zeros(n + 1)
    v = np.zeros(m + 1)
    p = np.zeros(m + 1, dtype=np.int64)   # p[j] = row matched to col j (1-based)
    way = np.zeros(m + 1, dtype=np.int64)
    for i in range(1, n + 1):
        p[0] = i
        j0 = 0
        minv = np.full(m + 1, _INF)
        used = np.zeros(m + 1, dtype=bool)
        while True:
            used[j0] = True
            i0 = p[j0]
            delta = _INF
            j1 = -1
            cur = cost[i0 - 1, :] - u[i0] - v[1:]
            for j in range(1, m + 1):
                if used[j]:
                    continue
                c = cur[j - 1]
                if c < minv[j]:
                    minv[j] = c
                    way[j] = j0
                if minv[j] < delta:
                    delta = minv[j]
                    j1 = j
            for j in range(m + 1):
                if used[j]:
                    u[p[j]] += delta
                    v[j] -= delta
                else:
                    minv[j] -= delta
            j0 = j1
            if p[j0] == 0:
                break
        while j0 != 0:
            j1 = way[j0]
            p[j0] = p[j1]
            j0 = j1

    row_of_col = p[1:]  # 1-based rows
    rows, cols = [], []
    for j, r in enumerate(row_of_col):
        if r > 0:
            rows.append(r - 1)
            cols.append(j)
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    order = np.argsort(rows)
    rows, cols = rows[order], cols[order]
    if transposed:
        rows, cols = cols, rows
        order = np.argsort(rows)
        rows, cols = rows[order], cols[order]
    return rows, cols


def max_weight_matching(weight: np.ndarray, forbid: np.ndarray | None = None,
                        ) -> list[tuple[int, int]]:
    """Maximum-total-weight matching of models (rows) to PUEs (cols).

    Edges with non-positive weight or ``forbid[m, i]`` are excluded from the
    result (the paper's Eq. 36 sets infeasible edges to weight 0, and a
    0-weight pairing is never beneficial: constraint 18b requires a strictly
    positive IID-distance decrement).

    Returns a list of (model, pue) pairs.
    """
    w = np.array(weight, dtype=np.float64, copy=True)
    if forbid is not None:
        w[forbid] = -_INF

    n, m = w.shape
    # Pad to allow "leave model unmatched" via dummy columns of weight 0.
    big = np.full((n, m + n), 0.0)
    big[:, :m] = np.where(np.isfinite(w), w, -1e18)
    rows, cols = hungarian_min_cost(-big)
    pairs = []
    for r, c in zip(rows, cols):
        if c < m and w[r, c] > 0 and np.isfinite(w[r, c]):
            pairs.append((int(r), int(c)))
    return pairs
