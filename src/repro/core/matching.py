"""Maximum-weight bipartite matching for the winner-selection algorithm
(Algorithm 1): pair models with next-trainer PUEs maximizing total diffusion
efficiency (Eq. 38).

Two interchangeable solvers:

* :func:`hungarian_min_cost` / :func:`max_weight_matching` — pure-numpy
  O(n³) Kuhn–Munkres (Jonker–Volgenant potentials).  The host/parity oracle;
  no scipy dependency (``scipy.optimize.linear_sum_assignment`` is only the
  *test* oracle).
* :func:`auction_assign` / :func:`auction_matching` — Bertsekas **auction**
  with ε-scaling, written as a ``jax.lax.while_loop`` so it jits, runs on
  device inside the batched planner (:mod:`repro.core.planner`), and
  ``vmap``s over sweep cells.  With the final ε below the optimum's
  resolution the assignment matches the Hungarian oracle; it is also
  literally the paper's auction-theoretic mechanism (Sec. V), so the
  device hot path *is* Algorithm 1.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["max_weight_matching", "hungarian_min_cost",
           "auction_assign", "auction_matching"]

_INF = float("inf")
_BIG = 1e30          # finite stand-in for ∞ inside jitted arithmetic


def hungarian_min_cost(cost: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Solve the rectangular assignment problem, minimizing total cost.

    Args:
      cost: (n_rows, n_cols) float matrix, n_rows <= n_cols (callers pad).

    Returns:
      (row_ind, col_ind) arrays of length n_rows with the optimal assignment.
    """
    cost = np.asarray(cost, dtype=np.float64)
    n, m = cost.shape
    transposed = False
    if n > m:
        cost = cost.T
        n, m = m, n
        transposed = True

    # Jonker-Volgenant with row/col potentials; 1-based col sentinel at 0.
    u = np.zeros(n + 1)
    v = np.zeros(m + 1)
    p = np.zeros(m + 1, dtype=np.int64)   # p[j] = row matched to col j (1-based)
    way = np.zeros(m + 1, dtype=np.int64)
    for i in range(1, n + 1):
        p[0] = i
        j0 = 0
        minv = np.full(m + 1, _INF)
        used = np.zeros(m + 1, dtype=bool)
        while True:
            used[j0] = True
            i0 = p[j0]
            delta = _INF
            j1 = -1
            cur = cost[i0 - 1, :] - u[i0] - v[1:]
            for j in range(1, m + 1):
                if used[j]:
                    continue
                c = cur[j - 1]
                if c < minv[j]:
                    minv[j] = c
                    way[j] = j0
                if minv[j] < delta:
                    delta = minv[j]
                    j1 = j
            for j in range(m + 1):
                if used[j]:
                    u[p[j]] += delta
                    v[j] -= delta
                else:
                    minv[j] -= delta
            j0 = j1
            if p[j0] == 0:
                break
        while j0 != 0:
            j1 = way[j0]
            p[j0] = p[j1]
            j0 = j1

    row_of_col = p[1:]  # 1-based rows
    rows, cols = [], []
    for j, r in enumerate(row_of_col):
        if r > 0:
            rows.append(r - 1)
            cols.append(j)
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    order = np.argsort(rows)
    rows, cols = rows[order], cols[order]
    if transposed:
        rows, cols = cols, rows
        order = np.argsort(rows)
        rows, cols = rows[order], cols[order]
    return rows, cols


def max_weight_matching(weight: np.ndarray, forbid: np.ndarray | None = None,
                        ) -> list[tuple[int, int]]:
    """Maximum-total-weight matching of models (rows) to PUEs (cols).

    Edges with non-positive weight or ``forbid[m, i]`` are excluded from the
    result (the paper's Eq. 36 sets infeasible edges to weight 0, and a
    0-weight pairing is never beneficial: constraint 18b requires a strictly
    positive IID-distance decrement).

    Returns a list of (model, pue) pairs.
    """
    w = np.array(weight, dtype=np.float64, copy=True)
    if forbid is not None:
        w[forbid] = -_INF

    n, m = w.shape
    # Pad to allow "leave model unmatched" via dummy columns of weight 0.
    big = np.full((n, m + n), 0.0)
    big[:, :m] = np.where(np.isfinite(w), w, -1e18)
    rows, cols = hungarian_min_cost(-big)
    pairs = []
    for r, c in zip(rows, cols):
        if c < m and w[r, c] > 0 and np.isfinite(w[r, c]):
            pairs.append((int(r), int(c)))
    return pairs


# ------------------------------------------------------- Bertsekas auction


@partial(jax.jit, static_argnames=("phases", "max_iters"))
def auction_assign(weight: jax.Array, phases: int = 10, theta: float = 5.0,
                   max_iters: int = 5000) -> jax.Array:
    """Forward Jacobi auction with ε-scaling — jit/vmap-safe assignment.

    Args:
      weight: (R, C) edge weights.  Entries that are non-positive or
        non-finite are infeasible (the paper's Eq. 36 zeroes them; a
        0-weight pairing is never scheduled — constraint 18b needs a
        strictly positive decrement).
      phases: ε-scaling phases; prices persist across phases, assignments
        reset.  ε starts at ``max(weight)/4`` and divides by ``theta`` per
        phase, floored at 1e-6·max(weight) (≥16 float32 ulps at price
        magnitude, so price rises never round away; the optimality gap is
        R·ε_final = R·1e-6·max(weight)).
      max_iters: safety cap on bidding iterations per phase.

    Returns:
      ``(dst, converged)`` — ``dst`` is (R,) int32, the matched column per
      row or -1 for "stay put"; ``converged`` is a scalar bool that is
      False when any ε-phase hit ``max_iters`` before clearing its queue
      (the assignment is then truncated: unconverged rows read as "stay
      put").  Callers on the planner hot path surface this as a warning —
      a silent partial matching is indistinguishable from an optimal one.

    This is the Bertsekas–Castañón *forward-reverse* auction for the
    asymmetric problem (persons = rows; each row also owns a private
    zero-weight dummy column = "stay put").  Forward Jacobi rounds let
    unassigned rows bid prices up; whenever all rows are assigned but some
    object is *stranded* (unowned at a stale positive price — the classic
    forward-only failure mode: rows shun it forever), one reverse step
    lets the highest-priced stranded object cut its price to the
    second-best competitive margin and steal its best row.  Both
    directions preserve the ε-CS invariant ``π_i + p_j ≥ w_ij − ε``, and
    a phase ends with every row assigned and every unowned object at its
    reservation price λ = 0 — the asymmetric optimality conditions — so
    the result is within R·ε_final of the optimum; ties aside, the
    Hungarian assignment.  (A square filler embedding is also correct but
    spends >90 % of its iterations on filler collision wars grinding
    stranded prices back ε-step by ε-step; the reverse step level-jumps
    instead, ~10-15x fewer iterations on planner weight matrices.)
    """
    r, c = weight.shape
    ct = c + r
    w = jnp.where(jnp.isfinite(weight) & (weight > 0.0),
                  weight.astype(jnp.float32), -_BIG)
    wmax = jnp.maximum(jnp.max(jnp.where(w > 0.0, w, 0.0)), 1e-12)
    # ≥16 float32 ulps at price magnitude: a smaller ε would partially
    # round away against grown prices and stretch bidding wars ~25x.
    eps_floor = 1e-6 * wmax
    # Columns: C real objects then R private dummies.
    dummies = jnp.where(jnp.eye(r, dtype=bool), 0.0, -_BIG)
    big_w = jnp.concatenate([w, dummies], axis=1)           # (R, C + R)

    iota_r = jnp.arange(r, dtype=jnp.int32)
    iota_c = jnp.arange(ct, dtype=jnp.int32)

    def forward_round(eps, prices, owner, col_of_row):
        # Jacobi bid round; lean body (no scatters — XLA CPU serializes
        # them; no top_k — sort-based and ~7x slower than two maxes).
        unassigned = col_of_row < 0
        values = big_w - prices[None, :]
        best_j = jnp.argmax(values, axis=1).astype(jnp.int32)
        best_v = jnp.max(values, axis=1)
        second_v = jnp.max(jnp.where(iota_c[None, :] == best_j[:, None],
                                     -_BIG, values), axis=1)
        second_v = jnp.where(second_v > -_BIG / 2, second_v, best_v)
        bid = prices[best_j] + (best_v - second_v) + eps
        bid = jnp.where(unassigned, bid, -_BIG)
        # Each object goes to its highest bidder.
        bid_mat = jnp.where(iota_c[None, :] == best_j[:, None],
                            bid[:, None], -_BIG)            # (R, C + R)
        col_bid = jnp.max(bid_mat, axis=0)
        col_winner = jnp.argmax(bid_mat, axis=0).astype(jnp.int32)
        has_bid = col_bid > -_BIG / 2
        prices = jnp.where(has_bid, col_bid, prices)
        owner = jnp.where(has_bid, col_winner, owner)       # evicts old owner
        return prices, owner

    def reverse_step(eps, prices, owner, col_of_row):
        # Highest-priced stranded object undercuts to win back its best row.
        stranded = (owner < 0) & (prices > 0.0)
        j = jnp.argmax(jnp.where(stranded, prices, -jnp.inf)).astype(
            jnp.int32)
        pi = (big_w[iota_r, jnp.clip(col_of_row, 0, ct - 1)]
              - prices[jnp.clip(col_of_row, 0, ct - 1)])    # row profits
        margin = big_w[:, j] - pi                           # (R,)
        i_star = jnp.argmax(margin).astype(jnp.int32)
        b1 = margin[i_star]
        b2 = jnp.maximum(jnp.max(jnp.where(iota_r == i_star, -_BIG, margin)),
                         0.0)                               # λ floors rivals
        act = b1 >= eps
        new_price = jnp.where(act, jnp.maximum(0.0, b2 - eps), 0.0)
        prices = jnp.where(iota_c == j, new_price, prices)
        old = col_of_row[i_star]
        owner = jnp.where(act & (iota_c == old), -1, owner)
        owner = jnp.where(act & (iota_c == j), i_star, owner)
        return prices, owner

    def body(eps, state):
        prices, owner, col_of_row, it = state
        prices, owner = jax.lax.cond(
            jnp.any(col_of_row < 0), forward_round, reverse_step,
            eps, prices, owner, col_of_row)
        owned = owner[None, :] == iota_r[:, None]           # (R, C + R)
        col_of_row = jnp.where(jnp.any(owned, axis=1),
                               jnp.argmax(owned, axis=1).astype(jnp.int32),
                               -1)
        return prices, owner, col_of_row, it + 1

    def phase_cond(state):
        prices, owner, col_of_row, it = state
        pending = jnp.any(col_of_row < 0) | \
            jnp.any((owner < 0) & (prices > 0.0))
        return pending & (it < max_iters)

    def phase_body(p, carry):
        prices, _, converged = carry
        eps = jnp.maximum(wmax * 0.25 / (theta ** p), eps_floor)
        state = (prices, jnp.full((ct,), -1, jnp.int32),
                 jnp.full((r,), -1, jnp.int32), jnp.int32(0))
        state = jax.lax.while_loop(phase_cond,
                                   lambda st: body(eps, st), state)
        return state[0], state[2], converged & ~phase_cond_pending(state)

    def phase_cond_pending(state):
        prices, owner, col_of_row, _ = state
        return jnp.any(col_of_row < 0) | \
            jnp.any((owner < 0) & (prices > 0.0))

    prices0 = jnp.zeros((ct,), jnp.float32)
    _, col_of_row, converged = jax.lax.fori_loop(
        0, phases, phase_body,
        (prices0, jnp.full((r,), -1, jnp.int32), jnp.bool_(True)))
    matched_real = (col_of_row >= 0) & (col_of_row < c)
    has_weight = w[iota_r, jnp.clip(col_of_row, 0, c - 1)] > 0.0
    return jnp.where(matched_real & has_weight, col_of_row, -1), converged


def auction_matching(weight: np.ndarray, forbid: np.ndarray | None = None,
                     ) -> list[tuple[int, int]]:
    """Drop-in :func:`max_weight_matching` replacement backed by the
    device auction solver; same (model, pue) pair-list contract."""
    import warnings
    w = np.array(weight, dtype=np.float32, copy=True)
    if forbid is not None:
        w[forbid] = -np.inf
    dst, converged = auction_assign(jnp.asarray(w))
    if not bool(converged):
        warnings.warn("auction_assign hit its iteration cap before "
                      "converging; the matching may be partial",
                      RuntimeWarning, stacklevel=2)
    return [(int(m), int(j)) for m, j in enumerate(np.asarray(dst))
            if j >= 0]
