"""Device-resident diffusion planner — the jitted/batched twin of
:meth:`repro.core.diffusion.DiffusionPlanner.plan_communication_round`.

The host planner runs Algorithm 1/2's bid → auction → schedule loop as a
Python ``while`` with an O(n³) Hungarian per diffusion round.  This module
ports the whole loop to JAX:

* the round loop is a ``lax.while_loop`` over an immutable
  :class:`~repro.core.dol.PlannerState` with **fixed-shape padded hop
  buffers** (``max_rounds`` static), so one compilation serves every round;
* the matching is the Bertsekas ε-scaling **auction**
  (:func:`repro.core.matching.auction_assign`) — parallelizable,
  ``while_loop``-shaped, and literally the paper's auction mechanism
  (Sec. V / Eq. 38);
* the whole round planner ``vmap``s over a leading batch axis, so a sweep
  orchestrator can plan *every cell × communication round of a sweep in one
  device call* and pre-populate the :class:`~repro.core.diffusion.PlanCache`
  (see :func:`repro.experiments.orchestrator.prepopulate_plan_cache`).

Parity contract: both planner modes consume the *same host-drawn channel
realizations* (``draw_gamma_sequence`` pre-draws ``max_rounds`` Rayleigh
rounds from the caller's ``numpy`` Generator in exactly the order the lazy
host loop would), and the arithmetic mirrors the host oracle op-for-op, so
the decoded hop lists (model, src, dst, round) coincide with the host
planner's — asserted by ``tests/test_planner_jax.py`` and the
``planner_speedup`` benchmark.  A fully device-resident draw
(:func:`device_gamma_sequence`, explicit PRNG key) is available when host
parity is not required.
"""
from __future__ import annotations

import warnings
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.channels.resources import (outage_probability_jax,
                                      required_bandwidth_jax,
                                      spectral_efficiency,
                                      spectral_efficiency_jax)
from repro.core import dol as dol_lib
from repro.core.dol import PlannerState
from repro.core.matching import auction_assign
from repro.kernels import ops as kernel_ops

__all__ = ["PlanInputs", "PlanOutputs", "draw_gamma_sequence",
           "draw_fading_sequence", "device_gamma_sequence",
           "plan_round_inputs", "plan_rounds", "plan_rounds_batched",
           "decode_plan", "plan_communication_round_jax"]


class PlanInputs(NamedTuple):
    """Per-cell planner inputs — a flat array pytree, stackable over cells.

    ``epsilon`` … ``model_bits`` are traced scalars (not statics), so one
    compiled planner serves a whole sweep grid over ε / γ_min / α / tasks;
    only shapes and the distance metric specialize the compilation.
    """
    dol0: jax.Array          # (M, C) post-initial-training DoLs
    chain_size0: jax.Array   # (M,)
    visited0: jax.Array      # (M, N) bool
    holder0: jax.Array       # (M,) int32
    dsi: jax.Array           # (N, C)
    data_sizes: jax.Array    # (N,)
    gamma_seq: jax.Array     # (R, N, N) per-round spectral efficiency —
                             # reinterpreted as the raw Exp(1) Rayleigh
                             # powers |h|² when the mobile world recomputes
                             # γ from stepped positions inside the loop
    mean_snr: jax.Array      # (N, N) large-scale-only SNR (Eq. 39 outage)
    epsilon: jax.Array       # () halting tolerance
    gamma_min: jax.Array     # () constraint (18e)
    outage_max: jax.Array    # () Eq. (39) cap
    bandwidth_budget: jax.Array  # () constraint (18f)
    model_bits: jax.Array    # () S in Eq. (15)
    # Optional trailing fields (None keeps the pre-world pytree structure
    # and therefore the pre-world compiled traces).
    value: jax.Array | None = None         # (N,) learning value in [0, 1]
    value_weight: jax.Array | None = None  # () fusion weight w
    world: object | None = None            # WorldState (mobile scenario)
    chan: jax.Array | None = None          # (4,) [p/σ², β₀dB, κ, d₀] for
                                           # in-loop Eq. 12–14 (mobile)


class PlanOutputs(NamedTuple):
    """Padded plan tensors for one cell: row k of each (R, M) buffer holds
    diffusion round k, valid where ``scheduled[k]`` (and k < num_rounds)."""
    num_rounds: jax.Array    # () int32
    dst: jax.Array           # (R, M) int32
    scheduled: jax.Array     # (R, M) bool
    src: jax.Array           # (R, M) int32
    gamma: jax.Array         # (R, M) link spectral efficiency of the hop
    bandwidth: jax.Array     # (R, M) Eq. 15 cost
    decrement: jax.Array     # (R, M) δ (Eq. 17)
    weight: jax.Array        # (R, M) Eq. 36 edge weight (hop ordering)
    efficiency: jax.Array    # (R,) E(i*, B*) per round (Eq. 16)
    state: PlannerState      # post-plan diffusion state
    final_iid: jax.Array     # (M,)
    converged: jax.Array     # () bool — False if any used auction hit its
                             # iteration cap (plan may be truncated)


def _plan_rounds(inp: PlanInputs, *, metric: str, allow_retraining: bool,
                 mobility: bool = False, step_m: float = 0.0,
                 use_value: bool = False) -> PlanOutputs:
    """One cell's whole communication round, as a masked ``while_loop``.

    ``mobility`` (static) threads the WorldState carry through the loop:
    each diffusion round deterministically steps the random-waypoint world
    by ``step_m`` meters and recomputes Eqs. 12–14/39 from the stepped
    positions — ``inp.gamma_seq`` then carries the raw Exp(1) Rayleigh
    powers instead of precomputed γ.  ``use_value`` (static) fuses the
    per-client learning value into the Eq.-32 bids via the kernel data
    plane.  Both flags default off, leaving the pre-world trace untouched.
    """
    max_rounds, n, _ = inp.gamma_seq.shape
    m = inp.dol0.shape[0]
    mi = jnp.arange(m)
    pout = outage_probability_jax(inp.gamma_min, inp.mean_snr)   # (N, N)
    state0 = PlannerState(
        dol=jnp.asarray(inp.dol0, jnp.float32),
        chain_size=jnp.asarray(inp.chain_size0, jnp.float32),
        visited=jnp.asarray(inp.visited0, bool),
        holder=jnp.asarray(inp.holder0, jnp.int32),
        world=inp.world if mobility else None)
    bufs0 = PlanOutputs(
        num_rounds=jnp.int32(0),
        dst=jnp.zeros((max_rounds, m), jnp.int32),
        scheduled=jnp.zeros((max_rounds, m), bool),
        src=jnp.zeros((max_rounds, m), jnp.int32),
        gamma=jnp.zeros((max_rounds, m), jnp.float32),
        bandwidth=jnp.zeros((max_rounds, m), jnp.float32),
        decrement=jnp.zeros((max_rounds, m), jnp.float32),
        weight=jnp.zeros((max_rounds, m), jnp.float32),
        efficiency=jnp.zeros((max_rounds,), jnp.float32),
        state=state0,
        final_iid=dol_lib.iid_distance(state0.dol, metric),
        converged=jnp.bool_(True))

    def body(carry):
        st, k, done, out = carry
        if mobility:
            # One deterministic random-waypoint substep per diffusion
            # round, then Eqs. 12–14/39 from the stepped positions — all
            # inside the trace, zero host round-trips.
            from repro.channels.topology import CellTopology
            from repro.channels.world import step as world_step
            w = world_step(st.world, step_m=step_m)
            st = st._replace(world=w)
            dist = CellTopology.pairwise_distances_jax(w.positions)
            p_over_noise, beta0_db, kappa, d0 = (inp.chan[0], inp.chan[1],
                                                 inp.chan[2], inp.chan[3])
            ls_db = beta0_db - 10.0 * kappa * jnp.log10(
                jnp.maximum(dist, d0) / d0)
            mean_snr_k = 10.0 ** (ls_db / 10.0) * p_over_noise   # (N, N)
            pout_k = outage_probability_jax(inp.gamma_min, mean_snr_k)
            h2 = jax.lax.dynamic_index_in_dim(inp.gamma_seq, k, 0,
                                              keepdims=False)
            gamma = spectral_efficiency_jax(mean_snr_k * h2)
        else:
            pout_k = pout
            gamma = jax.lax.dynamic_index_in_dim(inp.gamma_seq, k, 0,
                                                 keepdims=False)
        iid = dol_lib.iid_distance(st.dol, metric)
        active = iid > inp.epsilon
        if not allow_retraining:
            # Models at chain length N visited everyone (full diffusion).
            active &= ~jnp.all(st.visited, axis=1)
        any_active = jnp.any(active)

        # Bids (Eq. 32) and feasibility (18b/c/e + Eq. 39 outage).  The
        # (M, N) candidate scores run through the kernel data plane: the
        # tiled Pallas contraction on TPU / under REPRO_KERNELS_IMPL, the
        # broadcast composite (bit-identical to the host oracle) on the
        # reference path.
        cand = kernel_ops.dol_bid_scores(
            st.dol, st.chain_size, inp.dsi, inp.data_sizes, metric=metric)
        bids = iid[:, None] - cand                           # (M, N)
        if use_value:
            bids = kernel_ops.bid_value_fuse(bids, inp.value,
                                             inp.value_weight)
        gamma_edge = gamma[st.holder]                        # (M, N)
        feas = bids > 0.0
        if not allow_retraining:
            feas &= ~st.visited
        feas &= gamma_edge >= inp.gamma_min
        feas &= pout_k[st.holder] <= inp.outage_max
        feas = feas.at[mi, st.holder].set(False)  # no self-transmission
        bw = required_bandwidth_jax(inp.model_bits, gamma_edge)
        wmat = jnp.where(feas & jnp.isfinite(bw) & (bw > 0.0),
                         bids / bw, 0.0)                     # Eq. 36

        dst0, auc_ok = auction_assign(wmat)                  # Eq. 38 (18d)
        matched = dst0 >= 0
        dstc = jnp.clip(dst0, 0, n - 1)
        w_sel = jnp.where(matched, wmat[mi, dstc], -jnp.inf)
        bw_sel = jnp.where(matched, bw[mi, dstc], 0.0)
        dec_sel = jnp.where(matched, bids[mi, dstc], 0.0)

        # (18f) FCFS over matched edges by decreasing efficiency: an edge
        # that does not fit is skipped, later (cheaper) ones may still fit.
        order = jnp.argsort(-w_sel)

        def fcfs(budget_rem, model):
            cost = bw_sel[model]
            take = matched[model] & (cost <= budget_rem)
            return budget_rem - jnp.where(take, cost, 0.0), take

        _, takes = jax.lax.scan(
            fcfs, jnp.asarray(inp.bandwidth_budget, jnp.float32), order)
        chosen = jnp.zeros((m,), bool).at[order].set(takes) & matched

        n_eff = jnp.sum(chosen & (bw_sel > 0.0))
        eff = jnp.where(
            n_eff > 0,
            jnp.sum(jnp.where(chosen & (bw_sel > 0.0),
                              dec_sel / jnp.maximum(bw_sel, 1e-30), 0.0))
            / jnp.maximum(n_eff, 1), 0.0)

        # Only still-active models actually hop (the matching may pair an
        # inactive model — it competed for PUEs and budget, like the host).
        scheduled = chosen & active
        do = jnp.logical_and(~done, any_active & jnp.any(scheduled))
        sched = scheduled & do
        src = st.holder
        st_new = st.record_round(dstc, sched, inp.dsi, inp.data_sizes)

        def put(buf, row):
            return jax.lax.dynamic_update_index_in_dim(buf, row, k, 0)

        out = out._replace(
            dst=put(out.dst, dstc),
            scheduled=put(out.scheduled, sched),
            src=put(out.src, src),
            gamma=put(out.gamma, gamma[src, dstc]),
            bandwidth=put(out.bandwidth, bw_sel),
            decrement=put(out.decrement, dec_sel),
            weight=put(out.weight, w_sel),
            efficiency=jax.lax.dynamic_update_index_in_dim(
                out.efficiency, eff, k, 0),
            # flag any capped auction on a still-active lane — even one
            # that scheduled nothing may have halted the loop wrongly
            converged=out.converged & (auc_ok | done))
        return st_new, k + do.astype(jnp.int32), done | ~do, out

    def cond(carry):
        _, k, done, _ = carry
        return jnp.logical_and(~done, k < max_rounds)

    state, k, _, out = jax.lax.while_loop(
        cond, body, (state0, jnp.int32(0), jnp.bool_(False), bufs0))
    return out._replace(num_rounds=k, state=state,
                        final_iid=dol_lib.iid_distance(state.dol, metric))


plan_rounds = jax.jit(_plan_rounds,
                      static_argnames=("metric", "allow_retraining",
                                       "mobility", "step_m", "use_value"))


@partial(jax.jit, static_argnames=("metric", "allow_retraining"))
def _plan_rounds_vmapped(stacked: PlanInputs, metric: str,
                         allow_retraining: bool) -> PlanOutputs:
    fn = partial(_plan_rounds, metric=metric,
                 allow_retraining=allow_retraining)
    return jax.vmap(fn)(stacked)


def plan_rounds_batched(inputs: list[PlanInputs], metric: str,
                        allow_retraining: bool) -> list[PlanOutputs]:
    """Plan a batch of cells/rounds in one device call.

    Every item must share shapes (N, M, C, max_rounds) and the static knobs;
    ε / γ_min / outage / budget / model_bits may differ per item (they are
    traced), which is what lets one call cover a whole sweep grid.
    """
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *inputs)
    out = _plan_rounds_vmapped(stacked, metric=metric,
                               allow_retraining=allow_retraining)
    return [jax.tree.map(lambda x, i=i: x[i], out)
            for i in range(len(inputs))]


# ---------------------------------------------------------------- host glue


def draw_gamma_sequence(channel, dist: np.ndarray, rng: np.random.Generator,
                        max_rounds: int,
                        interference: np.ndarray | float = 0.0
                        ) -> np.ndarray:
    """Pre-draw ``max_rounds`` Rayleigh rounds from the host Generator.

    Draw k equals the lazy host loop's draw for diffusion round k (numpy
    Generators are sequential), so host and jax planners see identical
    channels; the jax mode just consumes the stream ``max_rounds`` draws
    deep regardless of where the loop halts.  ``interference`` is the
    per-receiver (or scalar) co-channel power of the multicell world —
    frozen within a communication round, so folding it here keeps the
    planner body interference-free.
    """
    gains = np.stack([channel.sample_gains(dist, rng)
                      for _ in range(max_rounds)])
    return spectral_efficiency(channel.snr(gains, interference))


def draw_fading_sequence(rng: np.random.Generator, n: int,
                         max_rounds: int) -> np.ndarray:
    """(R, N, N) raw Exp(1) Rayleigh powers |h|², stream-identical to the
    draws inside ``channel.sample_gains`` (which consumes exactly one
    ``rng.exponential`` of the distance shape per call).  The mobile world
    consumes these and recomputes β — hence γ — from stepped positions
    inside the planner loop."""
    return np.stack([rng.exponential(scale=1.0, size=(n, n))
                     for _ in range(max_rounds)])


def device_gamma_sequence(channel, key: jax.Array, dist: jax.Array,
                          max_rounds: int) -> jax.Array:
    """Fully device-resident channel draw (no host RNG): ``max_rounds``
    Rayleigh rounds from an explicit PRNG key.  Not parity-preserving with
    the numpy stream — for device-only planning at scale."""
    keys = jax.random.split(key, max_rounds)
    gains = jax.vmap(lambda k: channel.sample_gains_jax(k, dist))(keys)
    return spectral_efficiency_jax(channel.snr_jax(gains))


def plan_round_inputs(planner, state, dsi: np.ndarray,
                      data_sizes: np.ndarray, rng: np.random.Generator,
                      positions: np.ndarray | None = None,
                      interference: np.ndarray | float = 0.0,
                      values: np.ndarray | None = None,
                      value_weight: float = 0.0,
                      world=None) -> tuple[PlanInputs, np.ndarray | None]:
    """Build :class:`PlanInputs` the way the host planner would see them.

    Returns ``(inputs, gamma_seq64)`` — the float64 host-precision channel
    realizations are kept alongside the float32 device copy so
    :func:`decode_plan` can stamp hops with the exact γ the host ledger
    would charge (bit-identical ``bandwidth_hz_s``).

    ``interference`` folds the (frozen-within-round) multicell SINR into
    the pre-drawn γ sequence; ``values``/``value_weight`` populate the
    learning-value fields; ``world`` (a float32 WorldState) switches to
    mobile form — ``gamma_seq`` then carries raw Exp(1) powers, the
    channel constants ride in ``chan``, and ``gamma_seq64`` is ``None``
    (γ is computed in-loop at float32).
    """
    n = dsi.shape[0]
    chan = planner.channel
    if world is not None:
        positions = np.asarray(world.positions)
    elif positions is None:
        positions = planner.topology.sample_positions(rng, n)
    dist = planner.topology.pairwise_distances(positions)
    beta = 10 ** (chan.large_scale_db(dist) / 10.0)
    mean_snr = chan.snr(beta, interference)
    max_rounds = planner.max_rounds or n * (n - 1)
    if world is not None:
        seq = draw_fading_sequence(rng, n, max_rounds)
        gamma_seq64 = None
        p = chan.params
        chan_vec = jnp.asarray([p.tx_power_w / p.noise_w, p.beta0_db,
                                p.kappa, p.d0_m], jnp.float32)
    else:
        seq = draw_gamma_sequence(chan, dist, rng, max_rounds, interference)
        gamma_seq64 = seq
        chan_vec = None
    a = planner.auction
    use_value = values is not None and value_weight != 0.0
    return PlanInputs(
        dol0=jnp.asarray(state.dol, jnp.float32),
        chain_size0=jnp.asarray(state.chain_size, jnp.float32),
        visited0=jnp.asarray(state.visited, bool),
        holder0=jnp.asarray(state.holder, jnp.int32),
        dsi=jnp.asarray(dsi, jnp.float32),
        data_sizes=jnp.asarray(data_sizes, jnp.float32),
        gamma_seq=jnp.asarray(seq, jnp.float32),
        mean_snr=jnp.asarray(mean_snr, jnp.float32),
        epsilon=jnp.float32(planner.epsilon),
        gamma_min=jnp.float32(a.gamma_min),
        outage_max=jnp.float32(a.outage_max),
        bandwidth_budget=jnp.float32(a.bandwidth_budget),
        model_bits=jnp.float32(a.model_bits),
        value=(jnp.asarray(values, jnp.float32) if use_value else None),
        value_weight=(jnp.float32(value_weight) if use_value else None),
        world=(jax.tree.map(jnp.asarray, world) if world is not None
               else None),
        chan=chan_vec), gamma_seq64


def decode_plan(out: PlanOutputs, num_models: int,
                gamma_seq64: np.ndarray | None = None,
                model_bits: float | None = None):
    """Padded plan tensors → host :class:`~repro.core.diffusion.DiffusionPlan`.

    Hops within a round are emitted in decreasing Eq.-36 weight — the host
    planner's FCFS order — so the two modes produce identical hop lists.
    When the float64 channel realizations (and S) are provided, hop γ and
    Eq.-15 bandwidth are re-read at host precision, making ledger charges
    bit-identical to the host planner's.
    """
    from repro.core.diffusion import DiffusionHop, DiffusionPlan
    k = int(out.num_rounds)
    sched = np.asarray(out.scheduled)
    dst = np.asarray(out.dst)
    src = np.asarray(out.src)
    gamma = np.asarray(out.gamma)
    bw = np.asarray(out.bandwidth)
    dec = np.asarray(out.decrement)
    weight = np.asarray(out.weight)
    eff = np.asarray(out.efficiency)
    hops = []
    for r in range(k):
        models = [int(m) for m in np.flatnonzero(sched[r])]
        models.sort(key=lambda m: -weight[r, m])
        for m in models:
            s, d = int(src[r, m]), int(dst[r, m])
            if gamma_seq64 is not None:
                g = float(gamma_seq64[r, s, d])
                b = (float(model_bits) / g if model_bits is not None
                     else float(bw[r, m]))
            else:
                g, b = float(gamma[r, m]), float(bw[r, m])
            hops.append(DiffusionHop(
                model=m, src=s, dst=d, gamma=g, bandwidth=b,
                decrement=float(dec[r, m]), round_index=r))
    return DiffusionPlan(
        hops=hops, num_rounds=k,
        final_iid_distance=np.asarray(out.final_iid),
        efficiency_per_round=[float(e) for e in eff[:k]],
        num_models=num_models)


def plan_communication_round_jax(planner, state, dsi: np.ndarray,
                                 data_sizes: np.ndarray,
                                 rng: np.random.Generator,
                                 positions: np.ndarray | None = None,
                                 cache=None, cache_key: tuple | None = None,
                                 interference: np.ndarray | float = 0.0,
                                 values: np.ndarray | None = None,
                                 value_weight: float = 0.0,
                                 world=None, step_m: float = 0.0):
    """Jax-mode twin of ``DiffusionPlanner.plan_communication_round``:
    same signature/contract (mutates ``state``, consults the cache), but the
    whole bid → auction → schedule loop runs in one jitted device call."""
    if planner.underlay:
        raise ValueError("the jax planner does not model underlay CUE "
                         "interference; use planner='host' for underlay "
                         "scenarios (Appendix C-F)")
    if cache is not None and cache_key is not None:
        entry = cache.lookup(cache_key)
        if entry is not None:
            plan, post_state = entry
            state.restore(post_state)
            return plan
    inp, gamma64 = plan_round_inputs(planner, state, dsi, data_sizes, rng,
                                     positions, interference=interference,
                                     values=values,
                                     value_weight=value_weight, world=world)
    out = plan_rounds(inp, metric=planner.auction.metric,
                      allow_retraining=planner.auction.allow_retraining,
                      mobility=world is not None, step_m=float(step_m),
                      use_value=inp.value is not None)
    if not bool(out.converged):
        warnings.warn("jax planner: an auction hit its iteration cap; the "
                      "plan may schedule fewer hops than the host oracle",
                      RuntimeWarning, stacklevel=2)
    plan = decode_plan(out, num_models=state.dol.shape[0],
                       gamma_seq64=gamma64,
                       model_bits=planner.auction.model_bits)
    state.update_from(out.state, rounds_advanced=int(out.num_rounds))
    if cache is not None and cache_key is not None:
        cache.store(cache_key, plan, state)
    return plan
