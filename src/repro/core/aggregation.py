"""Global aggregation (Eq. 11) and weight-divergence tracking (Prop. 1).

``fedavg`` is the host/pytree path used by the FL simulator; the SPMD psum
path lives in ``repro.distributed.collectives``.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["fedavg", "weight_distance", "divergence_bound", "model_bits"]


def fedavg(params_list: Sequence, weights: Sequence[float]):
    """Eq. (11): data-size-weighted average of parameter pytrees."""
    w = np.asarray(weights, np.float64)
    total = w.sum()
    if total <= 0:
        raise ValueError("aggregation weights must sum to a positive value")
    w = (w / total).astype(np.float32)

    def combine(*leaves):
        acc = leaves[0].astype(jnp.float32) * w[0]
        for wi, leaf in zip(w[1:], leaves[1:]):
            acc = acc + leaf.astype(jnp.float32) * wi
        return acc.astype(leaves[0].dtype)

    return jax.tree.map(combine, *params_list)


def weight_distance(a, b) -> float:
    """Global L2 distance between two parameter pytrees: ‖w_a − w_b‖."""
    sq = sum(float(jnp.sum((x.astype(jnp.float32) - y.astype(jnp.float32)) ** 2))
             for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))
    return float(np.sqrt(sq))


def divergence_bound(init_gap: float, lipschitz: np.ndarray, eta: float,
                     mu: float, prob_distance: np.ndarray, k: int) -> float:
    """Prop. 1 / Eq. (20): upper bound on ‖w^(m)_{t,K} − w^(c)_{t,K}‖.

    ``a = 1 + η·mean(λ_i)``; bound = a^K·‖w0 gap‖ + (a^K−1)/(a−1)·η·μ·mean(Σ_c
    |P(X_i=c) − P(X_g=c)|).
    """
    lam = float(np.mean(lipschitz))
    a = 1.0 + eta * lam
    pd = float(np.mean(prob_distance))
    geom = k if abs(a - 1.0) < 1e-12 else (a ** k - 1.0) / (a - 1.0)
    return (a ** k) * init_gap + geom * eta * mu * pd


def model_bits(params, bits_per_param: int = 32) -> float:
    """S — serialized model size in bits (Eq. 15 numerator)."""
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    return float(n * bits_per_param)
