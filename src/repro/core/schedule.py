"""RoundSchedule — the strategy-agnostic IR between schedulers and executors.

Every FL strategy (FedDif's auction plan, FedAvg's broadcast, FedSwap's random
swaps, gossip's pairings, …) expresses one communication round as a
:class:`RoundSchedule`: a list of slot-level *ops* (train / permute+train /
group-mix), the *wire events* to charge against the
:class:`~repro.channels.resources.ResourceLedger`, and the final aggregation
weights.  Scheduling is pure — no training, no parameters — which is what
makes a schedule

* **executable anywhere**: ``repro.fl.executors.HostExecutor`` replays it on a
  per-slot pytree list (the reference semantics), ``FleetExecutor`` replays
  the *same object* on a client-stacked pytree with vmapped/jitted steps, and
  ``repro.launch.fl_spmd`` replays it on a mesh-sharded LM fleet;
* **chargeable once**: :func:`charge_schedule` replays the wire events into a
  ledger, so host and fleet runs report bit-identical Table-II metrics; and
* **cacheable**: FedDif's plans already memoize in
  :class:`~repro.core.diffusion.PlanCache`; the schedule derived from a plan
  is deterministic given the plan.

Slots vs clients vs models
--------------------------
A schedule is written over ``num_slots`` *client slots* (slot ``c`` always
draws client ``c``'s batches).  Models are placed on slots; the paper lets a
PUE hold several models, which an SPMD buffer cannot, so partial hop sets are
completed to slot bijections by :func:`complete_round_permutation` (displaced
idle models are "parked" on free slots — an artifact excluded from the
ledger, since the real system would not move them).  This generalizes what
``DiffusionPlan.as_permutations`` did for FedDif to every strategy.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["WireEvent", "TrainOp", "PermuteOp", "MixOp", "RoundSchedule",
           "complete_round_permutation", "charge_schedule", "apply_churn",
           "ArrivalModel", "annotate_arrivals"]


@dataclasses.dataclass(frozen=True)
class WireEvent:
    """One charged transmission: ``kind`` in {"d2d", "uplink", "downlink"}.

    ``gamma`` is stored already clamped to the scheduler's feasibility floor,
    so replaying events is a pure ledger operation.

    ``src`` attributes the transmission to the sending client slot so the
    energy-capped world can charge per-client joules; ``-1`` means "no UE
    transmitter" (BS downlink, or a legacy scheduler that predates
    attribution) and charges no client budget.
    """
    kind: str
    bits: float
    gamma: float
    n_users: int = 1
    src: int = -1


@dataclasses.dataclass(frozen=True)
class TrainOp:
    """Local update at every slot where ``train_mask`` is True."""
    train_mask: np.ndarray          # (C,) bool


@dataclasses.dataclass(frozen=True)
class PermuteOp:
    """One diffusion round: slot ``c`` receives the model held by slot
    ``src_of_dst[c]``, then the slots in ``train_mask`` run a local update
    (the auction winners / hop receivers).

    ``compress`` marks STC-compressed hops (``feddif_stc``): payloads feeding
    a *trained* destination are replaced by ``ref + STC(params − ref)``
    before the move, where ``ref`` is the round-start global model every PUE
    holds from the broadcast.  Parked (untrained) moves ship uncompressed —
    they are an SPMD artifact and never touch the wire or the ledger.
    """
    src_of_dst: np.ndarray          # (C,) int — bijection over slots
    train_mask: np.ndarray          # (C,) bool
    compress: bool = False

    def compress_src_mask(self) -> np.ndarray:
        """(C,) bool — slots whose *outgoing* payload is STC-compressed
        (sources feeding a trained destination)."""
        mask = np.zeros_like(self.train_mask)
        mask[self.src_of_dst[self.train_mask]] = True
        return mask


@dataclasses.dataclass(frozen=True)
class MixOp:
    """In-place group averaging: every slot in a group is overwritten by the
    group's data-size-weighted mean (gossip pairs, TT-HF clusters, the BS
    broadcast when one group spans all slots)."""
    groups: tuple                   # of (members: tuple[int], weights: tuple[float])

    def matrix(self, num_slots: int) -> np.ndarray:
        """(C, C) row-stochastic mixing matrix for the stacked executor."""
        w = np.eye(num_slots, dtype=np.float32)
        for members, weights in self.groups:
            ws = np.asarray(weights, np.float64)
            ws = (ws / ws.sum()).astype(np.float32)
            for i in members:
                w[i, :] = 0.0
                w[i, list(members)] = ws
        return w


@dataclasses.dataclass
class RoundSchedule:
    """One communication round, strategy-agnostic.

    Attributes:
      num_slots: C — client slots (slot c trains on client c's data).
      ops: ordered TrainOp / PermuteOp / MixOp steps.
      wire: every transmission to charge (see :func:`charge_schedule`).
      agg: ordered ``(slot, weight)`` pairs — Eq. (11) aggregation over the
        models' final slots.  The order reproduces the host reference's
        model-major summation; :meth:`slot_weights` is the dense per-slot
        form for stacked executors.  With ``persistent=True`` the aggregate
        is *reported* (evaluated) but slots keep their state.
      agg_mode: "params" (weighted mean of slot params) or "stc_delta"
        (weighted mean of STC-compressed deltas vs the round-start global —
        the STC [41] uplink).
      persistent: slots carry state across communication rounds (gossip,
        TT-HF); otherwise each round starts from a broadcast of the global.
      stc_sparsity: sparsity for compressed hops / stc_delta aggregation.
      diffusion_rounds / mean_iid: strategy bookkeeping surfaced into
        FLResult histories.
    """
    num_slots: int
    ops: list
    wire: list
    agg: list
    agg_mode: str = "params"
    persistent: bool = False
    stc_sparsity: float = 0.01
    diffusion_rounds: int = 0
    mean_iid: float = 0.0

    def slot_weights(self) -> np.ndarray:
        """Dense (C,) aggregation weight vector (zero for empty slots)."""
        w = np.zeros(self.num_slots, np.float64)
        for slot, weight in self.agg:
            w[slot] += weight
        return w


def complete_round_permutation(hops: list, slot_of_model: np.ndarray,
                               num_slots: int
                               ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Complete a partial set of model hops into a slot bijection.

    Args:
      hops: ``(model, dst_client)`` pairs, 1-1 over destinations.
      slot_of_model: (M,) current slot of every model (mutated copy returned,
        input untouched).
      num_slots: C.

    Returns ``(src_of_dst, train_mask, new_slot_of_model)`` where
    ``src_of_dst[c]`` is the slot whose buffer lands in slot ``c`` and
    ``train_mask`` marks the scheduled destinations.  Unscheduled sources
    stay put when possible, otherwise they are parked on any free
    destination (communication upper bound, excluded from the ledger).
    """
    mask = np.zeros(num_slots, dtype=bool)
    dst_of_src = np.full(num_slots, -1, dtype=np.int64)
    used_dst: set[int] = set()
    for model, dst in hops:
        src = int(slot_of_model[model])
        assert dst not in used_dst, "matching must be 1-1 over dsts"
        assert dst_of_src[src] == -1, "slot invariant violated"
        dst_of_src[src] = dst
        used_dst.add(int(dst))
        mask[dst] = True
    free = [d for d in range(num_slots) if d not in used_dst]
    for src in range(num_slots):
        if dst_of_src[src] >= 0:
            continue
        if src not in used_dst:
            dst_of_src[src] = src
            used_dst.add(src)
            free.remove(src)
        else:
            dst_of_src[src] = free.pop(0)
            used_dst.add(int(dst_of_src[src]))
    assert sorted(dst_of_src.tolist()) == list(range(num_slots)), dst_of_src
    new_slot_of_model = dst_of_src[slot_of_model]
    src_of_dst = np.argsort(dst_of_src)
    return src_of_dst, mask, new_slot_of_model


def apply_churn(schedule: RoundSchedule, drop: np.ndarray) -> RoundSchedule:
    """Straggler/churn dropout: dropped clients neither train nor aggregate.

    ``drop`` is a (C,) bool mask of clients that fail to complete the round
    (churned out of the cell, or stragglers the round deadline moves on
    without).  The returned schedule

    * clears the dropped slots from every Train/Permute ``train_mask`` (the
      device never finishes its local session),
    * removes their ``agg`` entries, so :meth:`RoundSchedule.slot_weights`
      carries **zero aggregation weight** at dropped slots (the masked-psum
      plane then reduces nothing from them), and
    * leaves ``wire`` untouched: stragglers consumed their scheduled airtime
      before missing the deadline, so the ledger still charges the full
      schedule — identical for every executor.

    If dropout would empty the aggregation entirely, the round is left
    unchanged (the BS falls back to whatever arrives — no 0/0 global).
    """
    drop = np.asarray(drop, dtype=bool)
    assert drop.shape == (schedule.num_slots,), drop.shape
    agg2 = [(s, w) for s, w in schedule.agg if not drop[s]]
    if not agg2 or not drop.any():
        return schedule
    ops2: list = []
    for op in schedule.ops:
        if isinstance(op, TrainOp):
            ops2.append(TrainOp(op.train_mask & ~drop))
        elif isinstance(op, PermuteOp):
            ops2.append(dataclasses.replace(op,
                                            train_mask=op.train_mask & ~drop))
        else:
            ops2.append(op)
    return dataclasses.replace(schedule, ops=ops2, agg=agg2)


@dataclasses.dataclass(frozen=True)
class ArrivalModel:
    """Per-slot timing world of one round — the async plane's delay inputs.

    All entries are seconds.  ``train_s[c]`` is the duration of one local
    training session at slot ``c`` (data rows x per-row compute / client
    speed, with the round's lognormal jitter already applied);
    ``hop_s[s, d]`` is the D2D link time to move one hop payload from slot
    ``s`` to slot ``d`` (payload bits / (gamma_{sd} * PRB_HZ), from the jnp
    channel twins); ``uplink_s[c]`` is slot ``c``'s uplink time for its
    aggregation contribution.  A zero model (``ArrivalModel.zeros``) makes
    every arrival instantaneous — the sync-degenerate configuration.
    """
    train_s: np.ndarray     # (C,)
    hop_s: np.ndarray       # (C, C)
    uplink_s: np.ndarray    # (C,)

    @classmethod
    def zeros(cls, num_slots: int) -> "ArrivalModel":
        return cls(train_s=np.zeros(num_slots),
                   hop_s=np.zeros((num_slots, num_slots)),
                   uplink_s=np.zeros(num_slots))


def annotate_arrivals(schedule: RoundSchedule, model: ArrivalModel,
                      hop_deadline_s: float | None = None
                      ) -> tuple[RoundSchedule, np.ndarray, int]:
    """Propagate per-slot ready times through a schedule's ops.

    Replays the op list against :class:`ArrivalModel`, tracking when each
    slot's payload is *ready*:

    * ``TrainOp`` adds ``train_s`` at every masked slot;
    * ``PermuteOp`` moves readiness along the hop (``ready[src] +
      hop_s[src, dst]`` for genuine moves; parked identity moves are free,
      matching the ledger, which never charges them), then adds the
      destination's training time;
    * ``MixOp`` is a group barrier: members synchronize at the group max
      plus the slowest pairwise exchange.

    When ``hop_deadline_s`` is set, hops whose payload would arrive at the
    carrier later than the deadline are **parked**: the destination keeps
    the (late) model but skips its training session — its ``train_mask``
    bit clears, exactly the :func:`apply_churn` semantics — while the wire
    events stay untouched, so the Eq.-15 ledger still charges the airtime
    the transmission consumed.

    Returns ``(schedule', arrival_s, parked)`` where ``arrival_s[c]`` is
    slot ``c``'s aggregation-contribution arrival time at the server
    (ready + uplink) relative to the round's dispatch, and ``parked``
    counts the cleared hop-training bits.  With a zero model and no
    deadline the schedule passes through with identical op content.
    """
    c = schedule.num_slots
    ready = np.zeros(c, np.float64)
    idx = np.arange(c)
    parked = 0
    ops2: list = []
    for op in schedule.ops:
        if isinstance(op, TrainOp):
            ready = ready + np.where(op.train_mask, model.train_s, 0.0)
            ops2.append(op)
        elif isinstance(op, PermuteOp):
            src = np.asarray(op.src_of_dst, np.int64)
            moved = src != idx
            incoming = ready[src] + np.where(moved, model.hop_s[src, idx],
                                             0.0)
            mask = np.asarray(op.train_mask, bool)
            if hop_deadline_s is not None:
                late = incoming > float(hop_deadline_s)
                parked += int(np.count_nonzero(late & mask))
                mask = mask & ~late
                ops2.append(dataclasses.replace(op, train_mask=mask))
            else:
                ops2.append(op)
            ready = incoming + np.where(mask, model.train_s, 0.0)
        elif isinstance(op, MixOp):
            for members, _ in op.groups:
                mem = list(members)
                exchange = max((float(model.hop_s[i, j])
                                for i in mem for j in mem if i != j),
                               default=0.0)
                ready[mem] = float(ready[mem].max()) + exchange
            ops2.append(op)
        else:
            raise TypeError(f"unknown op {type(op).__name__}")
    arrival = ready + model.uplink_s
    if parked == 0:
        return schedule, arrival, 0
    return dataclasses.replace(schedule, ops=ops2), arrival, parked


def charge_schedule(ledger, schedule: RoundSchedule) -> None:
    """Replay a schedule's wire events into a ResourceLedger.

    The single charging path shared by every executor: communication cost is
    a property of the *schedule*, not of who executes it, so host and fleet
    runs of the same schedule report identical Table-II metrics.
    """
    for ev in schedule.wire:
        if ev.kind == "d2d":
            ledger.charge_d2d(ev.bits, ev.gamma)
        elif ev.kind == "uplink":
            ledger.charge_uplink(ev.bits, ev.gamma)
        elif ev.kind == "downlink":
            ledger.charge_downlink(ev.bits, ev.gamma, ev.n_users)
        else:
            raise ValueError(f"unknown wire event kind {ev.kind!r}")
