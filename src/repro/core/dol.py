"""Degree-of-Learning (DoL) and IID-distance primitives of FedDif.

Implements Section III-B of the paper:

* **DSI** (data state information), Eq. before (2): a client's per-class data
  fraction ``d_i`` — a point on the probability simplex ``Δ^C``.
* **DoL** update, Eq. (2): the data-size-weighted running mixture of the DSIs
  of every client in a model's diffusion sub-chain.
* **IID distance**, Eq. (4)/(B.1): the distance of the DoL from the uniform
  distribution ``U = 1/C``.  The paper instantiates the Wasserstein-1 bound
  with the Euclidean norm (Eq. B.1); Appendix-C Scenario 2 also evaluates
  KL divergence and Jensen–Shannon divergence — all three are provided here.
* **Optimal DSI** of Lemma 1 (Eq. 29) and the feasibility bound of
  Corollary 1 (Eq. A.16).
* **Closed-form real-world IID distance** of Lemma 2 (Eq. 30).

Everything is pure ``jax.numpy`` on small ``(C,)``/``(N, C)`` arrays so it can
run inside jitted schedulers and on host alike.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

__all__ = [
    "DiffusionState",
    "PlannerState",
    "uniform_dol",
    "dsi_from_counts",
    "update_dol",
    "iid_distance",
    "iid_distance_candidates",
    "optimal_dsi",
    "min_feasible_data_size",
    "closed_form_iid_distance",
    "entropy",
]


def uniform_dol(num_classes: int, dtype=jnp.float32) -> Array:
    """``U = (1/C)·1`` — DoL of a model trained on perfectly IID data."""
    return jnp.full((num_classes,), 1.0 / num_classes, dtype=dtype)


def dsi_from_counts(counts: Array) -> Array:
    """DSI vector from per-class sample counts: ``d[c] = n_c / Σ n``.

    Accepts a trailing class axis; broadcasts over leading (client) axes.
    Degenerate all-zero counts map to the uniform simplex point (an empty
    client is "IID by vacuity" and contributes nothing anyway, because the
    DoL update weights by data size).
    """
    counts = jnp.asarray(counts, jnp.float32)
    total = jnp.sum(counts, axis=-1, keepdims=True)
    c = counts.shape[-1]
    return jnp.where(total > 0, counts / jnp.maximum(total, 1.0), 1.0 / c)


def update_dol(dol: Array, chain_size: Array, dsi: Array, data_size: Array
               ) -> tuple[Array, Array]:
    """Eq. (2): fold one client's data into a model's DoL.

    ``ψ_k = (D_{k-1}·ψ_{k-1} + D_i·d_i) / (D_{k-1} + D_i)``

    Returns ``(new_dol, new_chain_size)``.  Broadcasts over leading axes so a
    whole fleet of models can be updated in one call.
    """
    chain_size = jnp.asarray(chain_size, jnp.float32)
    data_size = jnp.asarray(data_size, jnp.float32)
    new_size = chain_size + data_size
    num = chain_size[..., None] * dol + data_size[..., None] * dsi
    new_dol = num / jnp.maximum(new_size[..., None], 1.0)
    # A model that has never trained (chain 0) adopts the client's DSI.
    return new_dol, new_size


def _w1_norm(p: Array, num_classes: int) -> Array:
    """Paper's Eq. (B.1) instantiation: ``‖ψ − U‖₂``."""
    return jnp.linalg.norm(p - 1.0 / num_classes, axis=-1)


def _w1_true(p: Array, num_classes: int) -> Array:
    """True Wasserstein-1 on the ordered class line (CDF L1 distance).

    The paper *defines* IID distance via W1 (Eq. 3) but evaluates the
    Euclidean form (Eq. B.1).  We expose the genuine transport distance as
    well — used in tests to show both orderings agree on simplex mixtures.
    """
    u = jnp.full_like(p, 1.0 / num_classes)
    return jnp.sum(jnp.abs(jnp.cumsum(p - u, axis=-1)), axis=-1)


def _kld(p: Array, num_classes: int) -> Array:
    """KL(ψ ‖ U) — Appendix C, Scenario 2."""
    eps = 1e-12
    pc = jnp.clip(p, eps, 1.0)
    return jnp.sum(pc * (jnp.log(pc) - jnp.log(1.0 / num_classes)), axis=-1)


def _jsd(p: Array, num_classes: int) -> Array:
    """Jensen–Shannon divergence to uniform — Appendix C, Scenario 2."""
    eps = 1e-12
    u = 1.0 / num_classes
    m = 0.5 * (p + u)
    pc = jnp.clip(p, eps, 1.0)
    mc = jnp.clip(m, eps, 1.0)
    t1 = jnp.sum(pc * (jnp.log(pc) - jnp.log(mc)), axis=-1)
    t2 = jnp.sum(u * (jnp.log(u) - jnp.log(mc)), axis=-1)
    return 0.5 * (t1 + t2)


_DISTANCES = {
    "w1_norm": _w1_norm,   # the paper's default (Eq. B.1)
    "w1_true": _w1_true,
    "kld": _kld,
    "jsd": _jsd,
}


def iid_distance(dol: Array, metric: str = "w1_norm") -> Array:
    """IID distance ``δ(ψ) = dist(ψ, U)`` with a trailing class axis."""
    fn = _DISTANCES[metric]
    return fn(jnp.asarray(dol, jnp.float32), dol.shape[-1])


def iid_distance_candidates(dol: Array, chain_size: Array, dsi: Array,
                            data_size: Array, metric: str = "w1_norm"
                            ) -> Array:
    """Candidate IID distances (Sec. III-B "candidates of IID distance
    reporting"): for every (model m, client i) pair, the IID distance the
    model *would* have after client i trains it.

    Args:
      dol:        (M, C) current DoLs.
      chain_size: (M,)   current chain data sizes ``D_{P_{k-1}}``.
      dsi:        (N, C) client DSIs.
      data_size:  (N,)   client data sizes.

    Returns: (M, N) candidate IID distance matrix.
    """
    dol = jnp.asarray(dol, jnp.float32)[:, None, :]          # (M,1,C)
    chain = jnp.asarray(chain_size, jnp.float32)[:, None]    # (M,1)
    dsi = jnp.asarray(dsi, jnp.float32)[None, :, :]          # (1,N,C)
    size = jnp.asarray(data_size, jnp.float32)[None, :]      # (1,N)
    cand, _ = update_dol(dol, chain, dsi, size)
    return iid_distance(cand, metric)


def optimal_dsi(dol: Array, chain_size: Array, data_size: Array) -> Array:
    """Lemma 1 / Eq. (29): the DSI a model *wants* from its next trainer.

    ``d*[c] = (D_{P_k}/C − D_{P_{k-1}}·ψ_{k-1}[c]) / D_i`` with
    ``D_{P_k} = D_{P_{k-1}} + D_i``.  May leave the simplex when ``D_i`` is
    below the Corollary-1 bound; callers clip when sampling.
    """
    dol = jnp.asarray(dol, jnp.float32)
    chain = jnp.asarray(chain_size, jnp.float32)[..., None]
    di = jnp.asarray(data_size, jnp.float32)[..., None]
    c = dol.shape[-1]
    return ((chain + di) / c - chain * dol) / jnp.maximum(di, 1e-9)


def min_feasible_data_size(dol: Array, chain_size: Array) -> Array:
    """Corollary 1 / Eq. (A.16): smallest ``D_i`` for which the optimal DSI
    stays on the simplex: ``max_c { C·D_{k-1}·ψ[c] − D_{k-1} }``."""
    dol = jnp.asarray(dol, jnp.float32)
    chain = jnp.asarray(chain_size, jnp.float32)
    c = dol.shape[-1]
    return jnp.maximum(jnp.max(c * chain[..., None] * dol - chain[..., None],
                               axis=-1), 0.0)


def closed_form_iid_distance(variation: Array, chain_size: Array) -> Array:
    """Lemma 2 / Eq. (30): ``W1(ψ_k, U) = ‖φ_k − φ̄_k‖ / D_{P_k}``.

    ``variation`` is the per-class data-size gap φ between the real and the
    optimal next trainer.  Used by the Fig.-2 analytical-results benchmark.
    """
    phi = jnp.asarray(variation, jnp.float32)
    centred = phi - jnp.mean(phi, axis=-1, keepdims=True)
    return jnp.linalg.norm(centred, axis=-1) / jnp.maximum(
        jnp.asarray(chain_size, jnp.float32), 1e-9)


def entropy(dol: Array) -> Array:
    """Shannon entropy of a DoL (Eq. 27) — the quantity Lemma 1 maximizes."""
    eps = 1e-12
    p = jnp.clip(jnp.asarray(dol, jnp.float32), eps, 1.0)
    return -jnp.sum(p * jnp.log(p), axis=-1)


class PlannerState(NamedTuple):
    """Functional (immutable) twin of :class:`DiffusionState`.

    A plain array pytree — every field is a fixed-shape ``jax.Array`` — so a
    whole diffusion round loop over it can live inside ``jax.lax.scan`` /
    ``lax.while_loop`` and be ``vmap``-ed over a leading batch axis (sweep
    cells, topology seeds).  All updates return a *new* state; masked-update
    helpers keep shapes static for the jitted planner
    (:mod:`repro.core.planner`).
    """
    dol: Array            # (..., M, C)
    chain_size: Array     # (..., M)
    visited: Array        # (..., M, N) bool
    holder: Array         # (..., M) int32
    #: Optional wireless-world carry (``repro.channels.world.WorldState``):
    #: the mobile scenario steps it once per diffusion round inside the
    #: jitted planner loop.  ``None`` (an empty pytree subtree) everywhere
    #: else, keeping the pre-world tree structure and traces untouched.
    world: object | None = None

    @classmethod
    def init(cls, num_models: int, num_clients: int, num_classes: int
             ) -> "PlannerState":
        return cls(
            dol=jnp.zeros((num_models, num_classes), jnp.float32),
            chain_size=jnp.zeros((num_models,), jnp.float32),
            visited=jnp.zeros((num_models, num_clients), bool),
            holder=(jnp.arange(num_models, dtype=jnp.int32)
                    % max(num_clients, 1)),
        )

    def record_training(self, model: Array | int, client: Array | int,
                        dsi: Array, data_size: Array | float
                        ) -> "PlannerState":
        """Eq. (2) fold of one (model, client) pair — functional analogue of
        ``DiffusionState.record_training``; jit/scan safe."""
        new_dol, new_size = update_dol(self.dol[model], self.chain_size[model],
                                       jnp.asarray(dsi), data_size)
        return PlannerState(
            dol=self.dol.at[model].set(new_dol),
            chain_size=self.chain_size.at[model].set(new_size),
            visited=self.visited.at[model, client].set(True),
            holder=self.holder.at[model].set(
                jnp.asarray(client, self.holder.dtype)),
            world=self.world,
        )

    def record_round(self, dst: Array, mask: Array, dsi: Array,
                     data_sizes: Array) -> "PlannerState":
        """Fold one diffusion round of hops in a single masked update.

        Args:
          dst:  (M,) int — destination client per model (ignored where
            ``mask`` is False; must still be a valid index).
          mask: (M,) bool — which models actually hop this round.
          dsi / data_sizes: (N, C) / (N,) client control-plane inputs.

        Each scheduled model trains on its destination (constraint 18d makes
        destinations unique, so rows never collide).  Shapes are static —
        this is the update the jitted round loop applies every ``lax.scan`` /
        ``while_loop`` step.
        """
        dst = jnp.asarray(dst, self.holder.dtype)
        new_dol, new_size = update_dol(self.dol, self.chain_size,
                                       dsi[dst], data_sizes[dst])
        m = jnp.arange(self.dol.shape[0])
        return PlannerState(
            dol=jnp.where(mask[:, None], new_dol, self.dol),
            chain_size=jnp.where(mask, new_size, self.chain_size),
            visited=self.visited.at[m, dst].set(self.visited[m, dst] | mask),
            holder=jnp.where(mask, dst, self.holder),
            world=self.world,
        )

    def iid_distances(self, metric: str = "w1_norm") -> Array:
        return iid_distance(self.dol, metric)


@dataclasses.dataclass
class DiffusionState:
    """Host-side bookkeeping for one communication round of FedDif.

    Tracks, per model m: the DoL, the chain data size, and the set of clients
    already visited (constraint 18c — no retraining).
    """
    dol: np.ndarray            # (M, C)
    chain_size: np.ndarray     # (M,)
    visited: np.ndarray        # (M, N) bool — True if client i already trained m
    holder: np.ndarray         # (M,) int — client currently holding model m
    round_index: int = 0

    @classmethod
    def init(cls, num_models: int, num_clients: int, num_classes: int,
             initial_holder: Sequence[int] | None = None) -> "DiffusionState":
        holder = (np.arange(num_models) % num_clients
                  if initial_holder is None else np.asarray(initial_holder))
        return cls(
            dol=np.zeros((num_models, num_classes), np.float32),
            chain_size=np.zeros((num_models,), np.float32),
            visited=np.zeros((num_models, num_clients), bool),
            holder=holder.astype(np.int64),
        )

    def record_training(self, model: int, client: int, dsi: np.ndarray,
                        data_size: float) -> None:
        new_dol, new_size = update_dol(self.dol[model], self.chain_size[model],
                                       jnp.asarray(dsi), data_size)
        self.dol[model] = np.asarray(new_dol)
        self.chain_size[model] = float(new_size)
        self.visited[model, client] = True
        self.holder[model] = client

    def iid_distances(self, metric: str = "w1_norm") -> np.ndarray:
        return np.asarray(iid_distance(jnp.asarray(self.dol), metric))

    def snapshot(self) -> "DiffusionState":
        """Deep copy — used by the plan cache to store post-plan state."""
        return DiffusionState(dol=self.dol.copy(),
                              chain_size=self.chain_size.copy(),
                              visited=self.visited.copy(),
                              holder=self.holder.copy(),
                              round_index=self.round_index)

    def restore(self, other: "DiffusionState") -> None:
        """Overwrite this state in place from a snapshot (cache replay)."""
        self.dol = other.dol.copy()
        self.chain_size = other.chain_size.copy()
        self.visited = other.visited.copy()
        self.holder = other.holder.copy()
        self.round_index = other.round_index

    def functional(self) -> PlannerState:
        """Device-ready immutable view for the jitted planner plane."""
        return PlannerState(
            dol=jnp.asarray(self.dol, jnp.float32),
            chain_size=jnp.asarray(self.chain_size, jnp.float32),
            visited=jnp.asarray(self.visited, bool),
            holder=jnp.asarray(self.holder, jnp.int32),
        )

    def update_from(self, fstate: PlannerState, rounds_advanced: int = 0
                    ) -> None:
        """Adopt a post-plan :class:`PlannerState` (in-place, host arrays)."""
        self.dol = np.asarray(fstate.dol, np.float32)
        self.chain_size = np.asarray(fstate.chain_size, np.float32)
        self.visited = np.asarray(fstate.visited, bool)
        self.holder = np.asarray(fstate.holder, np.int64)
        self.round_index += int(rounds_advanced)
