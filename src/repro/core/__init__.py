"""FedDif core: the paper's primary contribution as composable modules.

- ``dol``: DSI/DoL state and IID-distance metrics (Sec. III-B, Lemmas 1–2);
  the mutable host ``DiffusionState`` and its immutable array-pytree twin
  ``PlannerState``.
- ``matching``: the two Algorithm-1 solvers — Kuhn–Munkres (host oracle)
  and the Bertsekas ε-scaling auction (jitted device hot path).
- ``auction``: bids, feasibility constraints (18b–18f), winner selection.
- ``diffusion``: diffusion-round planner (Algorithm 2 control plane) with
  ``mode="host" | "jax"``.
- ``planner``: the jitted/batched device planner behind ``mode="jax"``.
- ``schedule``: the strategy-agnostic RoundSchedule IR + ledger replay
  (the seam between schedulers and executors).
- ``aggregation``: FedAvg (Eq. 11) + Prop.-1 divergence bound.
"""
from repro.core.dol import (DiffusionState, PlannerState, dsi_from_counts,
                            iid_distance, iid_distance_candidates,
                            optimal_dsi, min_feasible_data_size,
                            closed_form_iid_distance, uniform_dol,
                            update_dol, entropy)
from repro.core.matching import (max_weight_matching, hungarian_min_cost,
                                 auction_assign, auction_matching)
from repro.core.auction import AuctionConfig, AuctionResult, compute_bids, run_auction
from repro.core.diffusion import (DiffusionHop, DiffusionPlan,
                                  DiffusionPlanner, PlanCache,
                                  feddif_cache_key, plan_cache_key)
from repro.core.schedule import (MixOp, PermuteOp, RoundSchedule, TrainOp,
                                 WireEvent, charge_schedule,
                                 complete_round_permutation)
from repro.core.aggregation import (fedavg, weight_distance, divergence_bound,
                                    model_bits)

__all__ = [
    "DiffusionState", "PlannerState", "dsi_from_counts", "iid_distance",
    "iid_distance_candidates", "optimal_dsi", "min_feasible_data_size",
    "closed_form_iid_distance", "uniform_dol", "update_dol", "entropy",
    "max_weight_matching", "hungarian_min_cost",
    "auction_assign", "auction_matching",
    "AuctionConfig", "AuctionResult", "compute_bids", "run_auction",
    "DiffusionHop", "DiffusionPlan", "DiffusionPlanner", "PlanCache",
    "feddif_cache_key", "plan_cache_key",
    "MixOp", "PermuteOp", "RoundSchedule", "TrainOp", "WireEvent",
    "charge_schedule", "complete_round_permutation",
    "fedavg", "weight_distance", "divergence_bound", "model_bits",
]
