"""FedDif core: the paper's primary contribution as composable modules.

- ``dol``: DSI/DoL state and IID-distance metrics (Sec. III-B, Lemmas 1–2).
- ``matching``: Kuhn–Munkres assignment (Algorithm 1's solver).
- ``auction``: bids, feasibility constraints (18b–18f), winner selection.
- ``diffusion``: diffusion-round planner (Algorithm 2 control plane).
- ``schedule``: the strategy-agnostic RoundSchedule IR + ledger replay
  (the seam between schedulers and executors).
- ``aggregation``: FedAvg (Eq. 11) + Prop.-1 divergence bound.
"""
from repro.core.dol import (DiffusionState, dsi_from_counts, iid_distance,
                            iid_distance_candidates, optimal_dsi,
                            min_feasible_data_size, closed_form_iid_distance,
                            uniform_dol, update_dol, entropy)
from repro.core.matching import max_weight_matching, hungarian_min_cost
from repro.core.auction import AuctionConfig, AuctionResult, compute_bids, run_auction
from repro.core.diffusion import DiffusionHop, DiffusionPlan, DiffusionPlanner
from repro.core.schedule import (MixOp, PermuteOp, RoundSchedule, TrainOp,
                                 WireEvent, charge_schedule,
                                 complete_round_permutation)
from repro.core.aggregation import (fedavg, weight_distance, divergence_bound,
                                    model_bits)

__all__ = [
    "DiffusionState", "dsi_from_counts", "iid_distance",
    "iid_distance_candidates", "optimal_dsi", "min_feasible_data_size",
    "closed_form_iid_distance", "uniform_dol", "update_dol", "entropy",
    "max_weight_matching", "hungarian_min_cost",
    "AuctionConfig", "AuctionResult", "compute_bids", "run_auction",
    "DiffusionHop", "DiffusionPlan", "DiffusionPlanner",
    "MixOp", "PermuteOp", "RoundSchedule", "TrainOp", "WireEvent",
    "charge_schedule", "complete_round_permutation",
    "fedavg", "weight_distance", "divergence_bound", "model_bits",
]
