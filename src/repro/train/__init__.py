from repro.train.optimizer import (Optimizer, sgd, adamw, apply_updates,
                                   clip_by_global_norm, global_norm,
                                   constant_lr, cosine_lr, warmup_cosine_lr)
from repro.train.trainstep import (TrainState, init_train_state,
                                   make_train_step, make_eval_step,
                                   make_prefill_step, make_serve_step)
from repro.train.checkpoint import (save_checkpoint, restore_checkpoint,
                                    restore_latest, latest_step,
                                    valid_steps, load_metadata,
                                    atomic_write_json)
