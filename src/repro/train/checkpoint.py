"""Checkpointing: pytree <-> .npz with path-flattened keys + metadata JSON.

No orbax dependency; restores onto an existing pytree structure (shapes and
dtypes validated leaf-by-leaf; :class:`jax.ShapeDtypeStruct` leaves work, so
callers can describe a template without materializing it).

Durability contract (the FL sweep orchestrator's resume path rides on it):

* every file — array payload *and* metadata JSON — is written to a temp
  file in the same directory and ``os.replace``-d into place, so a kill at
  any instant leaves either the old bytes or the new bytes, never a torn
  file;
* the metadata JSON is written *after* the ``.npz`` and acts as the commit
  marker: :func:`valid_steps` only reports steps whose pair is complete;
* :func:`restore_latest` walks steps newest-first and falls back (with a
  loud warning) past any checkpoint that is truncated, corrupt, or
  structurally incompatible — a bad latest step costs one cadence of
  progress, never a silent wrong restore.
"""
from __future__ import annotations

import json
import os
import tempfile
import warnings
from typing import Any

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "restore_latest",
           "latest_step", "valid_steps", "load_metadata",
           "atomic_write_json"]

_SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return str(p.name)
    return str(p)


def atomic_write_json(path: str, obj: Any, **dump_kwargs) -> str:
    """Serialize ``obj`` to JSON at ``path`` via temp-file + rename.

    The write is all-or-nothing: a reader (or a process killed mid-write)
    sees either the previous contents or the complete new document, never a
    truncated one.  Shared by checkpoints, sweep manifests and the BENCH
    artifact writers.
    """
    directory = os.path.dirname(path) or "."
    os.makedirs(directory, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".json.tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(obj, f, **dump_kwargs)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    return path


def _npz_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"ckpt_{step:08d}.npz")


def _meta_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"ckpt_{step:08d}.json")


def save_checkpoint(directory: str, step: int, tree: Any,
                    metadata: dict | None = None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    path = _npz_path(directory, step)
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".npz.tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    meta = dict(metadata or {})
    meta["step"] = step
    # Written last: the metadata JSON is the commit marker valid_steps keys
    # on, so a kill between the two writes leaves an ignorable orphan .npz.
    atomic_write_json(_meta_path(directory, step), meta)
    return path


def restore_checkpoint(directory: str, step: int, like: Any) -> Any:
    path = _npz_path(directory, step)
    with np.load(path) as data:
        flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path_k, leaf in flat_like:
            key = _SEP.join(_path_str(p) for p in path_k)
            if key not in data:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = data[key]
            if arr.shape != leaf.shape:
                raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
            leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)


def load_metadata(directory: str, step: int) -> dict:
    """The metadata JSON written alongside step ``step``'s arrays."""
    with open(_meta_path(directory, step)) as f:
        return json.load(f)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(f[5:13]) for f in os.listdir(directory)
             if f.startswith("ckpt_") and f.endswith(".npz")]
    return max(steps) if steps else None


def valid_steps(directory: str) -> list[int]:
    """Steps with a complete (npz, metadata) pair, ascending.

    A checkpoint whose metadata JSON is missing was interrupted before its
    commit marker landed; it is invisible here and to
    :func:`restore_latest`.
    """
    if not os.path.isdir(directory):
        return []
    steps = [int(f[5:13]) for f in os.listdir(directory)
             if f.startswith("ckpt_") and f.endswith(".npz")]
    return sorted(s for s in steps
                  if os.path.exists(_meta_path(directory, s)))


def restore_latest(directory: str, like: Any
                   ) -> tuple[int, Any, dict] | None:
    """Restore the newest readable checkpoint: ``(step, tree, metadata)``.

    Walks :func:`valid_steps` newest-first.  A step that fails to load —
    truncated/corrupt ``.npz``, unparseable metadata, missing leaves, shape
    mismatch — is skipped with a :class:`RuntimeWarning` naming the file and
    the error, and the previous step is tried instead.  Returns ``None``
    when no checkpoint (or no readable one) exists; it never silently
    restores wrong bytes.
    """
    for step in reversed(valid_steps(directory)):
        try:
            meta = load_metadata(directory, step)
            tree = restore_checkpoint(directory, step, like)
            return step, tree, meta
        except Exception as e:                      # noqa: BLE001 — any
            # unreadable checkpoint (zip truncation, JSON decode, missing
            # leaf) must fall through to the previous step, loudly.
            warnings.warn(
                f"checkpoint step {step} in {directory!r} is unreadable "
                f"({type(e).__name__}: {e}); falling back to the previous "
                f"step", RuntimeWarning, stacklevel=2)
    return None
