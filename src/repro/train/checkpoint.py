"""Checkpointing: pytree <-> .npz with path-flattened keys + metadata JSON.

No orbax dependency; restores onto an existing pytree structure (shapes and
dtypes validated leaf-by-leaf).  Atomic via write-to-temp + rename.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]

_SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return str(p.name)
    return str(p)


def save_checkpoint(directory: str, step: int, tree: Any,
                    metadata: dict | None = None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".npz")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    meta = dict(metadata or {})
    meta["step"] = step
    with open(os.path.join(directory, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump(meta, f)
    return path


def restore_checkpoint(directory: str, step: int, like: Any) -> Any:
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_k, leaf in flat_like:
        key = _SEP.join(_path_str(p) for p in path_k)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(f[5:13]) for f in os.listdir(directory)
             if f.startswith("ckpt_") and f.endswith(".npz")]
    return max(steps) if steps else None
