"""Optimizers and LR schedules (self-contained, no optax).

The paper trains every local model with SGD + momentum 0.9, lr 0.01,
batch 16 (Sec. VI-A) — ``sgd`` is therefore the FL default.  ``adamw`` is
provided for LM-scale pretraining runs of the assigned architectures.

An optimizer is an ``Optimizer(init, update)`` pair over pytrees:
  state  = init(params)
  updates, state = update(grads, state, params, lr)
  params = apply_updates(params, updates)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any

__all__ = ["Optimizer", "sgd", "adamw", "apply_updates", "global_norm",
           "clip_by_global_norm", "constant_lr", "cosine_lr",
           "warmup_cosine_lr"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], Any]
    update: Callable[..., tuple[Params, Any]]


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(
        p.dtype), params, updates)


def sgd(momentum: float = 0.9, nesterov: bool = False,
        weight_decay: float = 0.0) -> Optimizer:
    """SGD with (heavy-ball) momentum — the paper's local optimizer."""

    def init(params):
        return {"mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                   params)}

    def update(grads, state, params, lr):
        def one(g, mu, p):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            mu_new = momentum * mu + g
            step = g + momentum * mu_new if nesterov else mu_new
            return -lr * step, mu_new

        flat_g = jax.tree.leaves(grads)
        flat_mu = jax.tree.leaves(state["mu"])
        flat_p = jax.tree.leaves(params)
        outs = [one(g, m, p) for g, m, p in zip(flat_g, flat_mu, flat_p)]
        treedef = jax.tree.structure(grads)
        updates = jax.tree.unflatten(treedef, [o[0] for o in outs])
        new_mu = jax.tree.unflatten(treedef, [o[1] for o in outs])
        return updates, {"mu": new_mu}

    return Optimizer(init, update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        z = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                 params)
        return {"m": z(), "v": z(), "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        c = state["count"] + 1
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def one(g, m, v, p):
            g = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g
            v_new = b2 * v + (1 - b2) * g * g
            mhat = m_new / bc1
            vhat = v_new / bc2
            upd = -lr * (mhat / (jnp.sqrt(vhat) + eps)
                         + weight_decay * p.astype(jnp.float32))
            return upd, m_new, v_new

        treedef = jax.tree.structure(grads)
        outs = [one(g, m, v, p) for g, m, v, p in zip(
            jax.tree.leaves(grads), jax.tree.leaves(state["m"]),
            jax.tree.leaves(state["v"]), jax.tree.leaves(params))]
        return (jax.tree.unflatten(treedef, [o[0] for o in outs]),
                {"m": jax.tree.unflatten(treedef, [o[1] for o in outs]),
                 "v": jax.tree.unflatten(treedef, [o[2] for o in outs]),
                 "count": c})

    return Optimizer(init, update)


# ------------------------------------------------------------- schedules

def constant_lr(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_lr(peak: float, total_steps: int, floor: float = 0.0):
    def fn(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        return floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * t))
    return fn


def warmup_cosine_lr(peak: float, warmup: int, total_steps: int,
                     floor: float = 0.0):
    cos = cosine_lr(peak, max(total_steps - warmup, 1), floor)
    def fn(step):
        w = peak * step / max(warmup, 1)
        return jnp.where(step < warmup, w, cos(step - warmup))
    return fn
