"""Train / serve step builders shared by the FL runtime and the launcher.

``make_train_step(model, opt, lr_fn)`` returns a pure function
``(state, batch) -> (state, metrics)`` suitable for ``jax.jit`` with
shardings, where ``state = TrainState(params, opt_state, step)``.

``make_serve_step(model)`` returns the one-token decode function used by the
decode_32k / long_500k shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.zoo import Model
from repro.train import optimizer as opt_lib

Params = Any

__all__ = ["TrainState", "make_train_step", "make_eval_step",
           "make_serve_step", "make_prefill_step", "init_train_state"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Params
    opt_state: Any
    step: jax.Array


def init_train_state(model: Model, key, opt: opt_lib.Optimizer) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt_state=opt.init(params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(model: Model, opt: opt_lib.Optimizer,
                    lr_fn: Callable | None = None,
                    clip_norm: float | None = 1.0,
                    remat: bool = True,
                    accum_steps: int = 1):
    """``accum_steps > 1`` scans a grad-accumulation loop over microbatches
    — live activations shrink ~proportionally, which is what lets the ≥12B
    archs fit the 16 GB/chip HBM budget at global batch 256 (§Perf).

    The caller passes batch leaves already stacked as ``(K, B/K, ...)`` with
    the microbatch axis replicated and ``B/K`` sharded over the data axes
    (an in-graph reshape of a sharded batch axis triggers XLA's involuntary
    full rematerialization — measured +3.4 TB of collectives)."""
    lr_fn = lr_fn or opt_lib.constant_lr(0.01)

    def train_step(state: TrainState, batch: dict):
        def loss_fn(p, b):
            return model.loss(p, b, remat=remat)

        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        else:
            micro = batch
            for leaf in jax.tree.leaves(micro):
                assert leaf.shape[0] == accum_steps, (
                    "with accum_steps=K pass batch leaves stacked (K, B/K, …)")

            def acc_body(carry, mb):
                loss_sum, g_acc = carry
                l, g = jax.value_and_grad(loss_fn)(state.params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (loss_sum + l, g_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, grads), _ = jax.lax.scan(acc_body, (0.0, zeros), micro)
            inv = 1.0 / accum_steps
            loss = loss * inv
            grads = jax.tree.map(lambda g: g * inv, grads)
        if clip_norm is not None:
            grads, gnorm = opt_lib.clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = opt_lib.global_norm(grads)
        lr = lr_fn(state.step)
        updates, opt_state = opt.update(grads, state.opt_state, state.params,
                                        lr)
        params = opt_lib.apply_updates(state.params, updates)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return TrainState(params=params, opt_state=opt_state,
                          step=state.step + 1), metrics

    return train_step


def make_eval_step(model: Model):
    def eval_step(params: Params, batch: dict):
        return model.loss(params, batch, remat=False)
    return eval_step


def make_prefill_step(model: Model):
    """Forward pass producing per-position logits-free hidden loss (the
    prefill benchmark target: full-context forward, no grad)."""
    def prefill_step(params: Params, batch: dict):
        b = dict(batch)
        if "labels" not in b:
            b["labels"] = jnp.zeros_like(b["tokens"])
        return model.loss(params, b, remat=False)
    return prefill_step


def make_serve_step(model: Model):
    """One-token decode: (params, tokens (B,1), cache, pos) -> (logits, cache)."""
    def serve_step(params: Params, tokens, cache, pos):
        return model.decode_step(params, tokens, cache, pos)
    return serve_step
