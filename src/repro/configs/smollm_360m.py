"""SmolLM-360M — 32L, d_model 960, 15H (GQA kv=5), d_ff 2560, vocab 49152,
llama-architecture small model, tied embeddings.
[hf:HuggingFaceTB/SmolLM-135M family]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    num_layers=32, d_model=960, num_heads=15, num_kv_heads=5,
    d_ff=2560, vocab_size=49152, tie_embeddings=True,
    citation="hf:HuggingFaceTB/SmolLM-135M",
)

def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="smollm-smoke", num_layers=2, d_model=96,
        num_heads=3, num_kv_heads=1, d_ff=256, vocab_size=256)
