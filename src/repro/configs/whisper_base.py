"""Whisper-base — 6 encoder + 6 decoder layers, d_model 512, 8H (MHA),
d_ff 2048, vocab 51865, encoder-decoder with stubbed conv/mel frontend
(1500 precomputed frame embeddings). [arXiv:2212.04356]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    num_layers=6, encoder_layers=6, d_model=512, num_heads=8,
    num_kv_heads=8, d_ff=2048, vocab_size=51865,
    cross_attention=True, frontend="audio", num_frontend_tokens=1500,
    tie_embeddings=True, norm_eps=1e-5,
    citation="arXiv:2212.04356",
)

def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="whisper-smoke", num_layers=2, encoder_layers=2,
        d_model=128, num_heads=4, num_kv_heads=4, d_ff=256, vocab_size=256,
        num_frontend_tokens=32)
