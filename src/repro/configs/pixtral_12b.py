"""Pixtral-12B — 40L, d_model 5120, 32H (GQA kv=8), d_ff 14336, vocab 131072.
LM backbone only: the Pixtral-ViT vision encoder + projector are stubbed —
``input_specs()`` provides 1024 precomputed patch embeddings per image.
[hf:mistralai/Pixtral-12B-2409]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=131072,
    frontend="vision", num_frontend_tokens=1024,
    rope_theta=1_000_000_000.0,
    citation="hf:mistralai/Pixtral-12B-2409",
)

def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="pixtral-smoke", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=256,
        num_frontend_tokens=16)
