from repro.configs.base import (ARCH_IDS, SHAPES, ModelConfig, MoEConfig,
                                SSMConfig, ShapeConfig, get_config,
                                get_smoke_config)

__all__ = ["ARCH_IDS", "SHAPES", "ModelConfig", "MoEConfig", "SSMConfig",
           "ShapeConfig", "get_config", "get_smoke_config"]
