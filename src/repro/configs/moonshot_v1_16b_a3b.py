"""Moonlight-16B-A3B — 48L, d_model 2048, 16H (MHA kv=16), per-expert
d_ff 1408, vocab 163840, MoE 64 experts top-6.  The assignment pool tags it
[dense] but specifies a MoE geometry; built as MoE per the explicit spec
(noted in DESIGN.md). [hf:moonshotai/Moonlight-16B-A3B]"""
import dataclasses
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=0, vocab_size=163840,
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408,
                  num_shared_experts=2),
    rope_theta=50_000.0,
    citation="hf:moonshotai/Moonlight-16B-A3B",
)

def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="moonshot-smoke", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=4, vocab_size=256,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64,
                      num_shared_experts=1))
