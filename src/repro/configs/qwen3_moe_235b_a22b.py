"""Qwen3-MoE 235B-A22B — 94L, d_model 4096, 64H (GQA kv=4), per-expert
d_ff 1536, vocab 151936, MoE 128 experts top-8, qk-norm, head_dim 128.
[hf:Qwen/Qwen3-30B-A3B family scaling per assignment]"""
import dataclasses
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4,
    head_dim=128, d_ff=0, vocab_size=151936,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=1536),
    qk_norm=True, rope_theta=1_000_000.0,
    citation="hf:Qwen/Qwen3-30B-A3B",
)

def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="qwen3-moe-smoke", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, head_dim=32, vocab_size=256,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64))
