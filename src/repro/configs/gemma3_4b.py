"""Gemma3-4B — 34L, d_model 2560, 8H (GQA kv=4), d_ff 10240, vocab 262144,
5:1 local:global attention (sliding window 1024), 128k context, tied + scaled
embeddings, qk-norm. [hf:google/gemma-3-1b-pt family]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", family="dense",
    num_layers=34, d_model=2560, num_heads=8, num_kv_heads=4,
    head_dim=256, d_ff=10240, vocab_size=262144,
    sliding_window=1024, local_global_ratio=5,
    qk_norm=True, tie_embeddings=True, scale_embeddings=True,
    rope_theta=1_000_000.0,
    citation="hf:google/gemma-3-1b-pt",
)

def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="gemma3-smoke", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=256,
        sliding_window=32, local_global_ratio=1)
