"""Zamba2-2.7B — 54 Mamba-2 layers, d_model 2560, ssm_state 64, plus a
*shared* attention block (32H MHA, d_ff 10240) applied every 6 SSM blocks,
vocab 32000. [arXiv:2411.15242]"""
import dataclasses
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, version=2, head_dim=64),
    attn_period=6,
    citation="arXiv:2411.15242",
)

def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="zamba2-smoke", num_layers=4, d_model=128,
        num_heads=4, num_kv_heads=4, d_ff=256, vocab_size=256,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, version=2,
                      head_dim=32, chunk=16),
        attn_period=2)
