"""Model / shape / run configuration dataclasses and the arch registry.

Every assigned architecture provides a ``CONFIG`` (exact published geometry,
cited in its module docstring) and a ``smoke_config()`` (reduced same-family
variant: ≤2 layers, d_model ≤ 512, ≤4 experts) used by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

__all__ = ["MoEConfig", "SSMConfig", "ModelConfig", "ShapeConfig", "SHAPES",
           "ARCH_IDS", "get_config", "get_smoke_config", "FamilyLiteral"]

FamilyLiteral = str  # 'dense' | 'moe' | 'ssm' | 'hybrid' | 'vlm' | 'audio'


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01   # load-balance loss (Switch-style)
    num_shared_experts: int = 0
    # Every MoE in the zoo (Mixtral, Qwen3-MoE, Kimi/Moonshot) routes
    # droplessly in its reference implementation; capacity_factor then only
    # sizes the dispatch buffers for the roofline, it never drops tokens.
    # Capacity-bounded (Switch/GShard) dispatch remains available for
    # experiments by setting dropless=False.
    dropless: bool = True


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int
    d_conv: int = 4
    expand: int = 2
    version: int = 1            # 1 = Mamba-1 selective scan, 2 = Mamba-2 SSD
    head_dim: int = 64          # Mamba-2 only
    dt_rank: int = 0            # 0 -> ceil(d_model/16) (Mamba-1 default)
    chunk: int = 128            # scan chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: FamilyLiteral
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                   # 0 -> d_model // num_heads
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    sliding_window: Optional[int] = None    # SWA width (tokens)
    local_global_ratio: int = 0         # N local layers per 1 global (gemma3)
    attn_period: int = 0                # hybrid: shared attn every N ssm blocks
    qk_norm: bool = False
    encoder_layers: int = 0             # enc-dec (whisper)
    cross_attention: bool = False
    frontend: Optional[str] = None      # 'audio' | 'vision' (stubbed)
    num_frontend_tokens: int = 0        # audio frames / image patches
    tie_embeddings: bool = False
    scale_embeddings: bool = False      # gemma-style sqrt(d_model) scaling
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    citation: str = ""

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # attention chunking for the XLA online-softmax path (0 = auto by size;
    # §Perf A/B: bigger tiles cut scan-boundary HBM+collective traffic, but
    # the fp32 score tile must fit alongside the rest of the step)
    q_chunk: int = 0
    kv_chunk: int = 0

    @property
    def attn_chunks(self) -> tuple[int, int]:
        if self.q_chunk and self.kv_chunk:
            return self.q_chunk, self.kv_chunk
        if self.d_model <= 1536:
            return 2048, 4096
        if self.d_model <= 4096:
            return 1024, 2048
        return 512, 1024

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if long_500k decode is admissible (see DESIGN.md table)."""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window is not None
                or self.local_global_ratio > 0)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for 6ND roofline."""
        d, v = self.d_model, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        hd = self.resolved_head_dim
        attn = d * hd * self.num_heads + 2 * d * hd * self.num_kv_heads \
            + hd * self.num_heads * d
        if self.moe is not None:
            ffn = self.moe.num_experts * 3 * d * self.moe.d_ff_expert \
                + d * self.moe.num_experts
        elif self.d_ff:
            ffn = 3 * d * self.d_ff
        else:
            ffn = 0
        if self.family == "ssm":
            s = self.ssm
            d_in = s.expand * d
            dt_rank = s.dt_rank or -(-d // 16)
            per_layer = (2 * d * d_in + s.d_conv * d_in
                         + d_in * (dt_rank + 2 * s.d_state)
                         + dt_rank * d_in + d_in * s.d_state + d_in
                         + d_in * d)
        elif self.family == "hybrid":
            s = self.ssm
            d_in = s.expand * d
            nh = d_in // s.head_dim
            per_layer = (d * (2 * d_in + 2 * nh * s.d_state + nh) + s.d_conv
                         * (d_in + 2 * nh * s.d_state) + d_in * d + nh)
            shared = attn + 3 * d * self.d_ff
            return emb + per_layer * self.num_layers + shared
        else:
            per_layer = attn + ffn
        total = emb + per_layer * self.num_layers
        if self.encoder_layers:
            total += self.encoder_layers * (attn + 3 * d * self.d_ff)
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE top-k only), for MoE 6·N_active·D."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.num_layers * (
            self.moe.num_experts * 3 * d * self.moe.d_ff_expert)
        active_ffn = self.num_layers * (self.moe.top_k
                                        * 3 * d * self.moe.d_ff_expert)
        return int(dense + active_ffn)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                  # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "qwen3_moe_235b_a22b",
    "moonshot_v1_16b_a3b",
    "gemma3_4b",
    "mixtral_8x22b",
    "smollm_360m",
    "pixtral_12b",
    "qwen3_0_6b",
    "whisper_base",
    "zamba2_2_7b",
    "falcon_mamba_7b",
]

# CLI-facing ids use dashes; module names use underscores.
def _norm(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_norm(arch_id)}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_norm(arch_id)}")
    return mod.smoke_config()
