"""Mixtral-8x22B — 56L, d_model 6144, 48H (GQA kv=8), expert d_ff 16384,
vocab 32768, MoE 8 experts top-2, sliding-window attention (4096).
[arXiv:2401.04088]"""
import dataclasses
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=0, vocab_size=32768,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=16384),
    sliding_window=4096, rope_theta=1_000_000.0,
    citation="arXiv:2401.04088",
)

def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="mixtral-smoke", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, vocab_size=256,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64),
        sliding_window=32)
