"""Falcon-Mamba-7B — 64 Mamba-1 layers (attention-free), d_model 4096,
ssm_state 16, vocab 65024. [arXiv:2410.05355]"""
import dataclasses
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    num_layers=64, d_model=4096, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=65024,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, version=1),
    citation="arXiv:2410.05355",
)

def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="falcon-mamba-smoke", num_layers=2, d_model=128,
        vocab_size=256,
        ssm=SSMConfig(d_state=8, d_conv=4, expand=2, version=1, chunk=16))
