"""Machine-readable benchmark artifacts: every ``BENCH_*.json`` in the repo.

One artifact per sweep run (``BENCH_feddif_<sweep>.json``) containing
per-cell accuracy curves (per seed), the communication ledger (consumed
sub-frames, transmitted models/bits, and the cumulative PUSCH bandwidth of
Eq. 15 in Hz·s), wall-clock, and plan-cache statistics; plus one per perf
bench (``BENCH_planner_speedup.json``, ``BENCH_executor_speedup.json``,
``BENCH_fleet_scaling.json``).  The schema is versioned so downstream trend
tooling can evolve without guessing.

This module is also the **single artifact-location authority**: every
producer (the ``repro.launch.sweep`` CLI, ``benchmarks/run.py``, the
orchestrator) resolves its output directory through :func:`default_out_dir`
— ``$REPRO_BENCH_DIR`` or ``benchmarks/results/`` — so CI's ``test -f`` /
upload globs and the budget gate read from exactly one place.
"""
from __future__ import annotations

import os
import time
from typing import Any

import numpy as np

from repro.train.checkpoint import atomic_write_json

__all__ = ["SCHEMA_VERSION", "DEFAULT_OUT_DIR", "default_out_dir",
           "bench_file", "bench_path", "build_artifact", "write_artifact",
           "write_bench_json", "summarize_curves", "strip_volatile"]

SCHEMA_VERSION = 1

# Resolved relative to the process CWD (the repo root for every entry point).
DEFAULT_OUT_DIR = os.path.join("benchmarks", "results")


def default_out_dir() -> str:
    """The one BENCH artifact directory: ``$REPRO_BENCH_DIR`` override or
    ``benchmarks/results/``."""
    return os.environ.get("REPRO_BENCH_DIR", DEFAULT_OUT_DIR)


def bench_file(name: str, out_dir: str | None = None) -> str:
    """Path of ``BENCH_<name>.json`` under the (default) artifact dir."""
    return os.path.join(default_out_dir() if out_dir is None else out_dir,
                        f"BENCH_{name}.json")


def bench_path(sweep: str, out_dir: str | None = None) -> str:
    """Path of a sweep artifact, ``BENCH_feddif_<sweep>.json``."""
    return bench_file(f"feddif_{sweep}", out_dir)


def write_bench_json(name: str, record: dict,
                     out_dir: str | None = None) -> str:
    """Write a non-sweep bench record to ``BENCH_<name>.json``; returns the
    path (perf benches: planner_speedup / executor_speedup / fleet_scaling).
    """
    path = bench_file(name, out_dir)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    # Atomic (temp + rename): a reader — or a resumed sweep diffing against
    # a clean run — never observes a torn BENCH file from a killed writer.
    atomic_write_json(path, record, indent=2, default=_json_default)
    return path


def summarize_curves(curves: list[list[float]]) -> dict:
    """Per-seed curves -> mean/std of the peak and of the final value."""
    peaks = [max(c) for c in curves if c]
    finals = [c[-1] for c in curves if c]
    return {
        "peak_mean": float(np.mean(peaks)) if peaks else None,
        "peak_std": float(np.std(peaks)) if peaks else None,
        "final_mean": float(np.mean(finals)) if finals else None,
        "final_std": float(np.std(finals)) if finals else None,
        "per_seed_peak": [float(p) for p in peaks],
    }


def build_artifact(sweep_name: str, figure: str, axis: str, smoke: bool,
                   seeds: list[int], cells: list[dict],
                   executor: str = "host", planner: str = "host",
                   plan_cache_stats: dict | None = None,
                   wall_clock_s: float | None = None,
                   failed_cells: list[dict] | None = None) -> dict:
    """Assemble one ``BENCH_feddif_<sweep>.json`` payload.

    ``plan_cache_stats`` carries the sweep-level
    :meth:`~repro.core.diffusion.PlanCache.stats` (hits / misses / entries);
    each cell record additionally carries its own per-cell hit/miss delta
    under ``cells[i]["plan_cache"]`` so cache efficacy is visible in the
    perf trajectory, not just as one sweep-wide total.

    ``failed_cells`` (durable sweeps) records cells whose run raised and was
    isolated by the work queue: ``[{"label": ..., "error": ...}, ...]``.
    Always present in the payload so downstream tooling can gate on
    "no failed cells" without probing for the key.
    """
    return {
        "schema_version": SCHEMA_VERSION,
        "sweep": sweep_name,
        "figure": figure,
        "axis": axis,
        "mode": "smoke" if smoke else "full",
        "executor": executor,
        "planner": planner,
        "seeds": [int(s) for s in seeds],
        "created_unix": time.time(),
        "wall_clock_s": wall_clock_s,
        "plan_cache": plan_cache_stats or {},
        "failed_cells": list(failed_cells or []),
        "cells": cells,
    }


def write_artifact(artifact: dict, out_dir: str | None = None) -> str:
    """Write ``BENCH_feddif_<sweep>.json`` atomically; returns the path."""
    out_dir = default_out_dir() if out_dir is None else out_dir
    os.makedirs(out_dir, exist_ok=True)
    path = bench_path(artifact["sweep"], out_dir)
    atomic_write_json(path, artifact, indent=2, sort_keys=False,
                      default=_json_default)
    return path


# Keys that legitimately differ between two runs of the same sweep (timing,
# cache-warmth counters, filesystem locations).  ``strip_volatile`` removes
# them so a resumed sweep's artifact can be diffed bit-for-bit against an
# uninterrupted run's — the resume-parity contract checked by
# ``benchmarks/resume_smoke.py`` and ``tests/test_resume_orchestration.py``.
_VOLATILE_TOP = ("created_unix", "wall_clock_s", "plan_cache", "path",
                 "manifest")
_VOLATILE_CELL = ("wall_clock_s", "plan_cache")


def strip_volatile(artifact: dict) -> dict:
    """Copy of a sweep artifact with run-dependent fields removed."""
    out = {k: v for k, v in artifact.items() if k not in _VOLATILE_TOP}
    out["cells"] = [{k: v for k, v in cell.items()
                     if k not in _VOLATILE_CELL}
                    for cell in artifact.get("cells", [])]
    return out


def _json_default(obj: Any):
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    raise TypeError(f"not JSON serializable: {type(obj)}")
