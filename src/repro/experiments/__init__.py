"""Paper-figure sweep orchestration.

Public API:

* :mod:`repro.experiments.registry` — the declarative sweep registry
  (``REGISTRY``, :class:`SweepDef`, :class:`SweepCell`,
  :func:`expand_sweep`): one entry per paper figure/table.
* :mod:`repro.experiments.orchestrator` — :func:`run_sweep` /
  :func:`run_cell`: expand a registry entry, run it with multi-seed
  replication and a shared diffusion-plan cache, emit a
  ``BENCH_feddif_<sweep>.json`` artifact.
* :mod:`repro.experiments.replicate` — the replication engines
  (seed-vmapped data plane vs process-level loop).
* :mod:`repro.experiments.artifacts` — artifact schema and writer.
* :mod:`repro.experiments.durability` — durable sweeps: the work-queue
  manifest, per-cell records and plan-cache snapshot behind
  ``run_sweep(..., checkpoint_every=R)`` / ``resume=True``.

CLI: ``PYTHONPATH=src python -m repro.launch.sweep --sweep fig3_alpha --smoke``.
"""
from repro.experiments.artifacts import (bench_file, bench_path,
                                         build_artifact, default_out_dir,
                                         strip_volatile, write_artifact,
                                         write_bench_json)
from repro.experiments.durability import (SweepManifest, cell_slug,
                                          default_state_dir)
from repro.experiments.orchestrator import run_cell, run_sweep
from repro.experiments.registry import (REGISTRY, SweepCell, SweepDef,
                                        expand_sweep, get_sweep, register,
                                        sweep_names)
from repro.experiments.replicate import (SEED_VMAP_STRATEGIES,
                                         run_replicates_loop,
                                         run_replicates_vmapped)

__all__ = [
    "REGISTRY", "SweepCell", "SweepDef", "expand_sweep", "get_sweep",
    "register", "sweep_names",
    "run_cell", "run_sweep",
    "SEED_VMAP_STRATEGIES", "run_replicates_loop", "run_replicates_vmapped",
    "bench_file", "bench_path", "build_artifact", "default_out_dir",
    "strip_volatile", "write_artifact", "write_bench_json",
    "SweepManifest", "cell_slug", "default_state_dir",
]
