"""Declarative sweep registry — one entry per paper figure/table.

Each :class:`SweepDef` declares *what the paper varied* (the axis and its
values), *what it compared* (the strategies), and the experiment sizing in
both ``smoke`` (CPU-minutes) and full (paper-approaching) modes.
``SweepDef.expand`` turns an entry into concrete
:class:`~repro.fl.experiment.ExperimentSpec` cells; the orchestrator
(:mod:`repro.experiments.orchestrator`) runs them with multi-seed
replication and writes ``BENCH_feddif_<sweep>.json`` artifacts.

Registered sweeps (paper Sec. VI):

==================  =======================  ==================================
name                paper artifact           axis
==================  =======================  ==================================
``fig3_alpha``      Fig. 3                   Dirichlet concentration α
``fig4_epsilon``    Fig. 4                   halting tolerance ε (min IID dist)
``fig5_gamma_min``  Fig. 5                   min spectral efficiency γ_min
``fig6_tasks``      Fig. 6 / Table I         ML task (logistic…cnn)
``table2_strategies``  Table II              strategy (FedAvg…FedDif)
``fig7_scaling``    scaling (beyond paper)   client population N (with churn)
``fig_async``       async (beyond paper)     engine preset (sync vs buffered)
``fig_scenarios``   world (beyond paper)     wireless scenario (static…energy)
==================  =======================  ==================================

Consumers must not hand-roll their own grids: ``benchmarks/run.py`` and the
``repro.launch.sweep`` CLI both expand the same registry, so a figure's
definition lives in exactly one place.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.fl.engine import ENGINE_PRESETS
from repro.fl.experiment import ExperimentSpec
from repro.fl.models import TASK_MODELS
from repro.fl.server import FLConfig, STRATEGIES

__all__ = ["SweepCell", "SweepDef", "REGISTRY", "register", "get_sweep",
           "sweep_names", "expand_sweep"]

# Axis name -> (which dataclass it lands on, field name).
AXIS_TARGETS = {
    "alpha": ("spec", "alpha"),
    "epsilon": ("fl", "epsilon"),
    "gamma_min": ("fl", "gamma_min"),
    "task": ("spec", "task"),
    "strategy": ("fl", "strategy"),
    "num_clients": ("fl", "num_clients"),   # num_models tracks it (M = N)
    "engine": ("fl", "engine"),             # EngineSpec preset name
    "scenario": ("fl", "scenario"),         # channels/world.SCENARIOS name
}


@dataclasses.dataclass(frozen=True)
class SweepCell:
    """One grid point of a sweep: an axis value × strategy, ready to run."""
    sweep: str
    figure: str
    axis: str
    value: Any
    strategy: str
    spec: ExperimentSpec

    @property
    def label(self) -> str:
        if self.axis == "strategy":
            return f"strategy={self.value}"
        return f"{self.axis}={self.value}/{self.strategy}"

    def with_fl(self, **overrides) -> "SweepCell":
        """Copy of this cell with ``FLConfig`` fields replaced (e.g. the
        durable orchestrator stamping ``checkpoint_every``)."""
        return dataclasses.replace(
            self, spec=dataclasses.replace(
                self.spec, fl=dataclasses.replace(self.spec.fl, **overrides)))


@dataclasses.dataclass(frozen=True)
class SweepDef:
    """Declarative description of one paper figure/table sweep."""
    name: str
    figure: str
    axis: str                       # key of AXIS_TARGETS
    values: tuple                   # full-mode axis values
    smoke_values: tuple             # CPU-smoke axis values (subset)
    description: str = ""
    strategies: tuple = ("feddif",)   # compared per point (ignored when the
                                      # axis itself is "strategy")
    rounds: int = 20
    smoke_rounds: int = 2
    num_clients: int = 10
    smoke_num_clients: int = 4
    num_samples: int = 8000
    smoke_num_samples: int = 1000
    spec_overrides: dict = dataclasses.field(default_factory=dict)
    fl_overrides: dict = dataclasses.field(default_factory=dict)
    # Per-axis-value strategy overrides, e.g. dropping the O(N³) Hungarian
    # auction (feddif) at N ≥ 1024 in fig7_scaling.  Ignored when the axis
    # itself is "strategy".
    value_strategies: dict = dataclasses.field(default_factory=dict)

    def expand(self, smoke: bool = True, topology_seed: int = 0,
               executor: str = "host", planner: str = "host",
               **overrides) -> list[SweepCell]:
        """Expand to concrete cells.

        Args:
          smoke: pick the smoke-sized grid (CPU-minutes) vs the full grid.
          topology_seed: control-plane seed stamped on every cell so
            diffusion plans are shareable across replicate seeds (see
            ``FLConfig.topology_seed``).
          executor: data plane stamped on every cell — ``"host"`` (per-slot
            reference loop), ``"fleet"`` (client-stacked vmap) or
            ``"sharded"`` (client axis sharded over a ``("clients",)``
            mesh); see ``FLConfig.executor``.
          planner: control plane stamped on every cell — ``"host"`` numpy
            oracle or ``"jax"`` batched device planner; see
            ``FLConfig.planner``.
          overrides: extra ``ExperimentSpec`` field overrides (e.g.
            ``num_samples=500`` for tests).
        """
        values = self.smoke_values if smoke else self.values
        clients = self.smoke_num_clients if smoke else self.num_clients
        rounds = self.smoke_rounds if smoke else self.rounds
        samples = self.smoke_num_samples if smoke else self.num_samples

        cells: list[SweepCell] = []
        for value in values:
            strategies = ((value,) if self.axis == "strategy"
                          else self.value_strategies.get(value,
                                                         self.strategies))
            for strategy in strategies:
                fl_kwargs: dict = dict(
                    strategy=strategy, rounds=rounds, num_clients=clients,
                    num_models=clients, seed=0, topology_seed=topology_seed,
                    executor=executor, planner=planner)
                spec_kwargs: dict = dict(
                    task="fcn", alpha=1.0, num_samples=samples, data_seed=0)
                fl_kwargs.update(self.fl_overrides)
                spec_kwargs.update(self.spec_overrides)
                where, field = AXIS_TARGETS[self.axis]
                if where == "fl":
                    fl_kwargs[field] = value
                    if field == "num_clients":
                        # The paper trains M ≤ N; scaling sweeps keep M = N.
                        fl_kwargs["num_models"] = value
                elif field != "strategy":
                    spec_kwargs[field] = value
                spec_kwargs.update(overrides)
                spec = ExperimentSpec(fl=FLConfig(**fl_kwargs), **spec_kwargs)
                cells.append(SweepCell(sweep=self.name, figure=self.figure,
                                       axis=self.axis, value=value,
                                       strategy=strategy, spec=spec))
        return cells

    def validate(self) -> None:
        assert self.axis in AXIS_TARGETS, self.axis
        assert set(self.smoke_values) <= set(self.values), self.name
        for s in self.strategies:
            assert s in STRATEGIES, s
        for strategies in self.value_strategies.values():
            for s in strategies:
                assert s in STRATEGIES, s
        if self.axis == "strategy":
            for v in self.values:
                assert v in STRATEGIES, v
        if self.axis == "task":
            for v in self.values:
                assert v in TASK_MODELS, v
        if self.axis == "engine":
            for v in self.values:
                assert v in ENGINE_PRESETS, v
        if self.axis == "scenario":
            from repro.channels.world import SCENARIOS
            for v in self.values:
                assert v in SCENARIOS, v


REGISTRY: dict[str, SweepDef] = {}


def register(defn: SweepDef) -> SweepDef:
    defn.validate()
    if defn.name in REGISTRY:
        raise ValueError(f"duplicate sweep {defn.name!r}")
    REGISTRY[defn.name] = defn
    return defn


def get_sweep(name: str) -> SweepDef:
    if name not in REGISTRY:
        raise KeyError(f"unknown sweep {name!r}; "
                       f"registered: {', '.join(sorted(REGISTRY))}")
    return REGISTRY[name]


def sweep_names() -> list[str]:
    return sorted(REGISTRY)


def expand_sweep(name: str, smoke: bool = True, **overrides
                 ) -> list[SweepCell]:
    """Convenience: ``get_sweep(name).expand(...)``."""
    return get_sweep(name).expand(smoke=smoke, **overrides)


# --------------------------------------------------------------- the entries

register(SweepDef(
    name="fig3_alpha",
    figure="Fig. 3",
    axis="alpha",
    description="Accuracy / diffusion rounds / comm cost vs Dirichlet "
                "concentration α (degree of non-IIDness).",
    values=(0.1, 0.2, 0.5, 1.0, 100.0),
    smoke_values=(0.2, 1.0),
    strategies=("fedavg", "feddif"),
))

register(SweepDef(
    name="fig4_epsilon",
    figure="Fig. 4",
    axis="epsilon",
    description="Minimum tolerable IID distance ε — the halting knob of "
                "Algorithm 2's diffusion loop (accuracy vs comm trade-off).",
    values=(0.0, 0.02, 0.04, 0.1, 0.2),
    smoke_values=(0.0, 0.2),
    strategies=("feddif",),
))

register(SweepDef(
    name="fig5_gamma_min",
    figure="Fig. 5",
    axis="gamma_min",
    description="Minimum tolerable QoS γ_min (bit/s/Hz) — constraint (18e) "
                "on which D2D links the auction may schedule.",
    values=(0.5, 1.0, 2.0, 4.0),
    smoke_values=(1.0, 4.0),
    strategies=("feddif",),
))

register(SweepDef(
    name="fig6_tasks",
    figure="Fig. 6 / Table I",
    axis="task",
    description="FedDif vs FedAvg across the paper's five evaluation models.",
    values=TASK_MODELS,
    smoke_values=("logistic", "fcn"),
    strategies=("fedavg", "feddif"),
))

register(SweepDef(
    name="fig7_scaling",
    figure="Scaling (beyond paper)",
    axis="num_clients",
    description="Large-N fleet scaling: client population N (M = N models) "
                "× strategy under per-round churn/straggler dropout — the "
                "regime the 2-D (clients × model) sharded executor targets "
                "(run with --executor sharded).  At N ≥ 1024 the Hungarian "
                "auction control plane is O(N³), so only the auction-free "
                "strategies run there.",
    values=(20, 64, 256, 1024, 4096),
    smoke_values=(20, 64),
    strategies=("fedavg", "d2d_random_walk", "feddif"),
    value_strategies={1024: ("fedavg", "d2d_random_walk"),
                      4096: ("fedavg", "d2d_random_walk")},
    rounds=6,
    smoke_rounds=2,
    num_samples=25600,
    smoke_num_samples=6400,
    fl_overrides={"churn_rate": 0.05, "max_diffusion_rounds": 8},
))

register(SweepDef(
    name="fig_lm",
    figure="LM diffusion (beyond paper)",
    axis="strategy",
    description="FedDif-over-LMs: strategies on the small LoRA transformer "
                "with Dirichlet-partitioned token data, hopping the "
                "int8-packed trainable-adapter view (repro.fl.adapters) — "
                "the Eq.-15 ledger charges packed adapter bits per D2D hop "
                "plus a one-time round-0 base broadcast.",
    values=("fedavg", "d2d_random_walk", "feddif"),
    smoke_values=("fedavg", "feddif"),
    rounds=10,
    smoke_rounds=2,
    num_clients=8,
    smoke_num_clients=4,
    num_samples=4096,
    smoke_num_samples=768,
    spec_overrides={"task": "lm", "dim": 32},
    fl_overrides={"hop_quant": "int8", "max_diffusion_rounds": 4},
))

register(SweepDef(
    name="fig_async",
    figure="Async rounds (beyond paper)",
    axis="engine",
    description="Buffered-async (FedBuff-style) round plane vs the same "
                "event queue with a full barrier (async_barrier), under "
                "lognormal compute stragglers, channel-drawn link delays "
                "and 5% per-round churn: accuracy vs the virtual clock and "
                "arrival throughput.  Both arms share the delay model, so "
                "the gap isolates what buffering K=frac·M arrivals per "
                "server tick buys.",
    values=("async_barrier", "async"),
    smoke_values=("async_barrier", "async"),
    strategies=("fedavg", "d2d_random_walk"),
    rounds=10,
    smoke_rounds=2,
    num_clients=16,
    smoke_num_clients=4,
    fl_overrides={"churn_rate": 0.05, "max_diffusion_rounds": 4},
))

register(SweepDef(
    name="fig_scenarios",
    figure="World scenarios (beyond paper)",
    axis="scenario",
    description="The time-evolving wireless world (channels/world): static "
                "placement (the paper's per-round redraw), random-waypoint "
                "mobility stepping under the diffusion loop, multi-cell "
                "placement with SINR handoff + inter-cell interference, and "
                "finite per-client TX-energy budgets (depleted clients drop "
                "out).  Strategy × scenario matrix of accuracy and the "
                "ledger (incl. joules) — how much of FedDif's gain survives "
                "a world that moves under it.",
    values=("static", "mobile", "multicell", "energy_capped"),
    smoke_values=("static", "mobile", "energy_capped"),
    strategies=("fedavg", "d2d_random_walk", "feddif"),
    rounds=12,
    smoke_rounds=2,
    num_clients=20,
    smoke_num_clients=4,
    num_samples=8000,
    smoke_num_samples=1000,
    fl_overrides={"max_diffusion_rounds": 6},
))

register(SweepDef(
    name="table2_strategies",
    figure="Table II",
    axis="strategy",
    description="Communication efficiency (sub-frames / transmitted models / "
                "Eq. 15 bandwidth) across strategies, incl. the auction-free "
                "d2d_random_walk ablation.",
    values=("fedavg", "stc", "fedswap", "d2d_random_walk", "feddif"),
    smoke_values=("fedavg", "d2d_random_walk", "feddif"),
    rounds=25,
))
