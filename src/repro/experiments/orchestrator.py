"""Sweep orchestrator: registry entry -> grid -> replicated runs -> artifact.

``run_sweep`` is the one-command reproduction path for a paper figure::

    from repro.experiments import run_sweep
    artifact = run_sweep("fig3_alpha", smoke=True, seeds=(0, 1, 2))

For every cell of the sweep's grid it

1. stamps a shared ``topology_seed`` so the wireless control plane is
   independent of the replicate seed,
2. runs the cell at every seed — vmapped over the seed axis on the data
   plane where the strategy allows (:data:`SEED_VMAP_STRATEGIES`),
   process-level loop otherwise,
3. shares one :class:`~repro.core.diffusion.PlanCache` across the whole
   sweep, so FedDif's host-side auction loop runs once per distinct
   (topology seed, round, partition, ε, γ_min) and is *replayed* for every
   other replicate, and
4. folds the per-seed accuracy/loss curves, Eq.-15 cumulative PUSCH
   bandwidth, sub-frame ledger and wall-clock into one JSON cell record.

The CLI wrapper lives in ``repro.launch.sweep``; ``benchmarks/run.py``
drives the same function, so sweep definitions exist in exactly one place
(:mod:`repro.experiments.registry`).
"""
from __future__ import annotations

import os
import time
from typing import Sequence

import numpy as np

from repro.channels.fading import ChannelModel
from repro.channels.topology import CellTopology
from repro.core.auction import AuctionConfig
from repro.core.diffusion import PlanCache, feddif_cache_key
from repro.core.dol import DiffusionState
from repro.experiments import artifacts
from repro.experiments.registry import SweepCell, expand_sweep, get_sweep
from repro.experiments.replicate import (SEED_VMAP_STRATEGIES,
                                         run_replicates_loop,
                                         run_replicates_vmapped)
from repro.fl.engine import SHARDED_CROSSOVER_N, resolve_engine
from repro.fl.server import _uplink_gamma

__all__ = ["run_cell", "run_sweep", "prepopulate_plan_cache",
           "SHARDED_CROSSOVER_N"]

_FEDDIF_STRATEGIES = ("feddif", "feddif_stc", "feddif_prox")


def prepopulate_plan_cache(cells: Sequence[SweepCell], cache: PlanCache
                           ) -> dict:
    """Plan every FedDif cell × communication round in batched device calls.

    For each eligible cell (FedDif family, ``planner='jax'``, topology seed
    set, no underlay) this replays the control-plane RNG exactly as
    ``run_federated`` would (positions → uplink draw → Rayleigh rounds),
    builds one :class:`~repro.core.planner.PlanInputs` per communication
    round, groups them by static signature (N, M, C, max_rounds, metric,
    retraining) and plans each group in **one** vmapped device call.  The
    decoded plans + post-plan states land in ``cache`` under the same
    :func:`~repro.core.diffusion.feddif_cache_key` the schedulers build, so
    every subsequent ``run_cell`` — any engine, any replicate seed — replays
    instead of replanning.

    Returns ``{"planned": k, "skipped": j, "batches": b}``.
    """
    from repro.core.diffusion import DiffusionPlanner
    from repro.core.planner import (decode_plan, plan_round_inputs,
                                    plan_rounds_batched)
    from repro.fl.experiment import load_experiment_data, spec_model_bits

    groups: dict[tuple, list] = {}
    skipped = 0
    for cell in cells:
        cfg = cell.spec.fl
        if (cell.strategy not in _FEDDIF_STRATEGIES
                or getattr(cfg, "planner", "host") != "jax"
                or cfg.topology_seed is None or cfg.underlay
                or getattr(cfg, "scenario", "static") != "static"
                or getattr(cfg, "uncertainty_weight", 0.0) > 0.0):
            # Non-static worlds replay their own RNG/mobility inside
            # run_federated; value-fused plans depend on each seed's params.
            skipped += 1
            continue
        _, _, part, _ = load_experiment_data(cell.spec, with_loaders=False)
        dsi, data_sizes = part.dsi, part.data_sizes
        n, m, c = cfg.num_clients, cfg.num_models, dsi.shape[1]
        model_bits = spec_model_bits(cell.spec)
        topology = CellTopology(num_pues=n)
        channel = ChannelModel()
        auction = AuctionConfig(gamma_min=cfg.gamma_min, metric=cfg.metric,
                                allow_retraining=cfg.allow_retraining,
                                model_bits=model_bits)
        planner = DiffusionPlanner(topology, channel, auction,
                                   epsilon=cfg.epsilon,
                                   max_rounds=cfg.max_diffusion_rounds,
                                   mode="jax")
        max_rounds = cfg.max_diffusion_rounds or n * (n - 1)
        for t in range(cfg.rounds):
            key = feddif_cache_key(cfg, t, dsi, data_sizes, model_bits,
                                   auction)
            if key in cache:
                skipped += 1
                continue
            # Mirror run_federated's control-plane stream for round t.
            ctrl_rng = np.random.default_rng([cfg.topology_seed, t])
            pos = topology.sample_positions(ctrl_rng, n)
            _uplink_gamma(channel, pos, ctrl_rng)     # keep stream aligned
            state = DiffusionState.init(m, n, c)
            for mi in range(m):
                holder = int(state.holder[mi])
                state.record_training(mi, holder, dsi[holder],
                                      float(data_sizes[holder]))
            inp, gamma64 = plan_round_inputs(planner, state, dsi, data_sizes,
                                             ctrl_rng, positions=pos)
            sig = (n, m, c, max_rounds, cfg.metric, cfg.allow_retraining)
            groups.setdefault(sig, []).append(
                (key, inp, state, m, gamma64, model_bits))

    planned = 0
    for sig, items in groups.items():
        _, _, _, max_rounds, metric, allow_retraining = sig
        outs = plan_rounds_batched([inp for _, inp, _, _, _, _ in items],
                                   metric=metric,
                                   allow_retraining=allow_retraining)
        for (key, _, state, m, gamma64, model_bits), out in zip(items, outs):
            if not bool(out.converged):
                import warnings
                warnings.warn("sweep pre-planner: an auction hit its "
                              "iteration cap; the cached plan may be "
                              "truncated", RuntimeWarning, stacklevel=2)
            plan = decode_plan(out, num_models=m, gamma_seq64=gamma64,
                               model_bits=model_bits)
            state.update_from(out.state, rounds_advanced=int(out.num_rounds))
            cache.store(key, plan, state)
            planned += 1
    return {"planned": planned, "skipped": skipped, "batches": len(groups)}


# The measured fleet/sharded N-crossover now lives in repro.fl.engine
# (SHARDED_CROSSOVER_N, re-exported above for back-compat); the downgrade
# heuristic formerly in _pick_executor is EngineSpec.auto().


def _pick_executor(cell: SweepCell, engine: str) -> SweepCell:
    """Crossover downgrade, delegated to :meth:`EngineSpec.auto`.

    ``resolve_engine`` maps the cell's config (typed ``fl.engine`` or the
    legacy string kwargs) onto a spec and applies the sharded->fleet
    downgrade below :data:`SHARDED_CROSSOVER_N`; the resolved mode is
    stamped back onto the cell so replication engines see it.
    """
    cfg = cell.spec.fl
    if engine == "auto" and cfg.engine is None and cfg.executor == "sharded":
        mode = resolve_engine(cfg).auto(cfg.num_clients).mode
        if mode != cfg.executor:
            print(f"orchestrator,{cell.label},executor={mode},"
                  f"reason=N={cfg.num_clients}<crossover="
                  f"{SHARDED_CROSSOVER_N}", flush=True)
            return cell.with_fl(executor=mode)
    return cell


def _pick_engine(cell: SweepCell, engine: str) -> str:
    mode = resolve_engine(cell.spec.fl).mode
    if mode in ("fleet", "sharded", "async"):
        # fleet/sharded already vmap/shard the *client* axis, and the async
        # plane's event queue is inherently sequential over ticks; replicate
        # seeds run on the loop engine (the seed_vmap engine is its own
        # host-side seed-stacked data plane and would bypass the executor
        # seam).
        return "loop"
    if cell.spec.fl.churn_rate > 0.0:
        # Churn masks are applied schedule-side in run_federated; the
        # seed_vmap engine hand-rolls fedavg/feddif rounds and would skip
        # them.
        return "loop"
    if (getattr(cell.spec.fl, "scenario", "static") != "static"
            or getattr(cell.spec.fl, "uncertainty_weight", 0.0) > 0.0):
        # Evolving-world scenarios advance HostWorld state on the host
        # control plane, and the value signal makes plans seed-dependent —
        # both outside the seed-stacked engine's contract.
        return "loop"
    if engine == "auto":
        return ("seed_vmap" if cell.strategy in SEED_VMAP_STRATEGIES
                else "loop")
    return engine


def run_cell(cell: SweepCell, seeds: Sequence[int],
             plan_cache: PlanCache | None = None,
             engine: str = "auto",
             checkpoint_root: str | None = None) -> dict:
    """Run one sweep cell at every replicate seed; returns the JSON record.

    ``engine``: ``"auto"`` (vmap the seed axis when the strategy allows),
    ``"seed_vmap"``, or ``"loop"``; cells with ``fl.executor == "fleet"``
    always take the loop engine (the executor vmaps the client axis).

    ``checkpoint_root`` (durable sweeps) forces the loop engine — the
    seed-vmapped cohort bypasses ``run_federated`` and therefore the
    :class:`~repro.fl.resume.RoundCheckpointer` seam — and gives each
    replicate seed a round-checkpoint directory under it.
    """
    if not len(seeds):
        raise ValueError("run_cell needs at least one replicate seed")
    cell = _pick_executor(cell, engine)
    chosen = _pick_engine(cell, engine)
    if checkpoint_root is not None:
        chosen = "loop"
    cache_before = plan_cache.stats() if plan_cache is not None else None
    t0 = time.time()
    if chosen == "seed_vmap":
        results = run_replicates_vmapped(cell.spec, seeds, plan_cache)
    else:
        results = run_replicates_loop(cell.spec, seeds, plan_cache,
                                      checkpoint_root=checkpoint_root)
    wall = time.time() - t0

    # Per-cell plan-cache delta: how much of this cell's control plane was
    # replayed vs replanned (sweep cache efficacy in the perf trajectory).
    cache_stats = None
    if plan_cache is not None:
        after = plan_cache.stats()
        cache_stats = {"hits": after["hits"] - cache_before["hits"],
                       "misses": after["misses"] - cache_before["misses"],
                       "entries": after["entries"]}

    ledger = results[0].ledger            # seed-independent by construction
    curves = [r.accuracy for r in results]
    return {
        "label": cell.label,
        "axis": cell.axis,
        "value": cell.value,
        "strategy": cell.strategy,
        "engine": chosen,
        "executor": resolve_engine(cell.spec.fl).mode,
        "plan_cache": cache_stats,
        "seeds": [int(s) for s in seeds],
        "accuracy": curves,
        "loss": [r.loss for r in results],
        "summary": artifacts.summarize_curves(curves),
        "diffusion_rounds": list(results[0].diffusion_rounds),
        "iid_distance": [float(x) for x in results[0].iid_distance],
        "comm": {
            "subframes": int(ledger.subframes),
            "transmitted_models": int(ledger.transmitted_models),
            "transmitted_bits": float(ledger.transmitted_bits),
            "pusch_bandwidth_hz_s": float(ledger.bandwidth_hz_s),  # Eq. 15
            "uplink_models": int(ledger.uplink_models),
            "downlink_models": int(ledger.downlink_models),
            "energy_j": float(getattr(ledger, "energy_j", 0.0)),
        },
        "wall_clock_s": wall,
    }


def run_sweep(name: str, smoke: bool = True, seeds: Sequence[int] = (0,),
              out_dir: str | None = "auto", engine: str = "auto",
              executor: str = "host", planner: str = "host",
              engine_preset: str | None = None,
              plan_cache: PlanCache | None = None,
              checkpoint_every: int = 0, resume: bool = False,
              state_dir: str | None = None,
              log=None, **spec_overrides) -> dict:
    """Expand a registered sweep, run every cell, write the BENCH artifact.

    Args:
      name: registry key (``fig3_alpha`` … ``table2_strategies``).
      smoke: smoke-sized grid (CPU-minutes) vs full grid.
      seeds: replicate seeds; curves are reported per seed.
      out_dir: where ``BENCH_feddif_<name>.json`` is written; the default
        ``"auto"`` resolves through
        :func:`repro.experiments.artifacts.default_out_dir` (the single
        artifact directory CI globs); ``None`` skips writing (used by tests
        and by callers composing artifacts).
      engine: replication engine, see :func:`run_cell`.
      executor: ``FLConfig.executor`` stamped on every cell — ``"host"``
        reference loop, ``"fleet"`` client-stacked data plane, or
        ``"sharded"`` client-sharded mesh plane.
      planner: ``FLConfig.planner`` stamped on every cell — ``"host"``
        numpy control plane or ``"jax"`` device planner.  With ``"jax"``
        the whole sweep's diffusion plans are computed up front in batched
        device calls (:func:`prepopulate_plan_cache`); the per-cell runs
        then replay them from the shared cache.
      engine_preset: an :data:`~repro.fl.engine.ENGINE_PRESETS` name (e.g.
        ``"async"``) stamped as ``FLConfig.engine`` on every cell.  The
        typed spec wins over the legacy ``executor`` string — this is how
        ``launch/sweep --engine async`` selects the buffered-async plane
        sweep-wide.
      plan_cache: share one across sweeps if desired; default is a fresh
        cache per sweep (still shared across all cells *and* seeds).
      checkpoint_every: round-checkpoint cadence R.  Any of
        ``checkpoint_every > 0``, ``resume`` or ``state_dir`` makes the
        sweep **durable**: a work-queue manifest, per-cell round
        checkpoints and finished-cell records live under ``state_dir``
        (default ``<artifact dir>/sweeps/<name>``), a crashing cell is
        marked failed and isolated while the rest of the grid completes,
        and a killed sweep is restartable with ``resume=True`` —
        reproducing the *identical* BENCH artifact (modulo wall-clock; see
        :func:`repro.experiments.artifacts.strip_volatile`).
      resume: continue a previous durable run from its manifest: done cells
        load their stored records, failed cells are retried, interrupted
        cells restart from their latest round checkpoint.
      state_dir: durable-state directory override.
      spec_overrides: forwarded to ``SweepDef.expand`` (e.g. tiny
        ``num_samples`` in tests).

    Returns the artifact dict (also written to disk unless out_dir=None).
    """
    defn = get_sweep(name)
    cells = expand_sweep(name, smoke=smoke, executor=executor,
                         planner=planner, **spec_overrides)
    if engine_preset is not None:
        cells = [c.with_fl(engine=engine_preset) for c in cells]
    cache = plan_cache if plan_cache is not None else PlanCache()
    durable = checkpoint_every > 0 or resume or state_dir is not None

    manifest = None
    if durable:
        from repro.experiments import durability
        state_dir = state_dir or durability.default_state_dir(name)
        os.makedirs(state_dir, exist_ok=True)
        config = {"sweep": name, "smoke": smoke,
                  "seeds": [int(s) for s in seeds], "executor": executor,
                  "planner": planner, "engine": engine,
                  "engine_preset": engine_preset,
                  "checkpoint_every": int(checkpoint_every),
                  "spec_overrides": spec_overrides}
        manifest = durability.SweepManifest.open(
            state_dir, name, config, [c.label for c in cells], resume)
        if checkpoint_every <= 0:
            # resume without an explicit cadence: adopt the stored one.
            checkpoint_every = int(
                manifest.data["config"].get("checkpoint_every") or 0) or 1
        if resume and durability.load_plan_cache_file(state_dir, cache):
            if log is not None:
                log(f"{name},plan_cache,restored="
                    f"{cache.stats()['entries']}")

    t0 = time.time()
    if planner == "jax":
        pre = prepopulate_plan_cache(cells, cache)
        if log is not None:
            log(f"{name},preplan,planned={pre['planned']},"
                f"batches={pre['batches']},sec={time.time() - t0:.1f}")
        if manifest is not None:
            from repro.experiments import durability
            durability.save_plan_cache_file(state_dir, cache)

    records = []
    for cell in cells:
        if manifest is not None:
            if manifest.status(cell.label) == "done":
                records.append(manifest.load_record(cell.label))
                if log is not None:
                    log(f"{name},{cell.label},resumed=done")
                continue
            manifest.mark(cell.label, "running")
            cell = cell.with_fl(checkpoint_every=int(checkpoint_every))
            ckpt_root = manifest.cell_checkpoint_root(cell.label)
            try:
                rec = run_cell(cell, seeds, plan_cache=cache, engine=engine,
                               checkpoint_root=ckpt_root)
            except Exception as e:          # noqa: BLE001 — cell isolation
                # One broken cell must not sink the grid: record the error,
                # keep going.  Preempted/KeyboardInterrupt (BaseException)
                # still abort the whole sweep.
                manifest.mark(cell.label, "failed",
                              error=f"{type(e).__name__}: {e}")
                if log is not None:
                    log(f"{name},{cell.label},FAILED={type(e).__name__}")
                continue
            manifest.store_record(cell.label, rec)
            manifest.mark(cell.label, "done")
            from repro.experiments import durability
            durability.save_plan_cache_file(state_dir, cache)
            rec = manifest.load_record(cell.label)  # canonical JSON types
        else:
            rec = run_cell(cell, seeds, plan_cache=cache, engine=engine)
        if log is not None:
            s = rec["summary"]
            log(f"{name},{rec['label']},engine={rec['engine']},"
                f"peak_acc={s['peak_mean']:.4f},"
                f"subframes={rec['comm']['subframes']},"
                f"bandwidth_hz_s={rec['comm']['pusch_bandwidth_hz_s']:.3e},"
                f"sec={rec['wall_clock_s']:.1f}")
        records.append(rec)

    artifact = artifacts.build_artifact(
        sweep_name=name, figure=defn.figure, axis=defn.axis, smoke=smoke,
        seeds=list(seeds), cells=records, executor=executor,
        planner=planner, plan_cache_stats=cache.stats(),
        wall_clock_s=time.time() - t0,
        failed_cells=manifest.failed_cells() if manifest is not None
        else None)
    if manifest is not None:
        artifact["manifest"] = manifest.path
    if out_dir is not None:
        if out_dir == "auto":
            out_dir = artifacts.default_out_dir()
        artifact["path"] = artifacts.write_artifact(artifact, out_dir)
    return artifact
