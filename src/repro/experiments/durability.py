"""Sweep-level durability: the manifest, cell records, and plan-cache file.

A **durable sweep** (``run_sweep(..., checkpoint_every=R)``) keeps all of
its restartable state under one *state directory*::

    <state_dir>/
      manifest.json            # work-queue ledger (atomic temp+rename)
      plan_cache.json          # PlanCache.state_dict() snapshot
      records/<cell>.json      # finished cells' JSON records
      cells/<cell>/seed<s>/    # RoundCheckpointer round checkpoints

``manifest.json`` is the single source of truth for the work queue: each
cell is ``pending → running → done | failed``.  Every transition is an
atomic :func:`~repro.train.checkpoint.atomic_write_json` rewrite, so a
SIGKILL at any instant leaves a readable manifest.  A cell found ``running``
on resume simply reruns — its round checkpoints make that cheap, and
rerunning from the last boundary is bit-identical to never having died.

Failure isolation: the orchestrator's work queue marks a crashing cell
``failed`` (storing the traceback summary) and moves on; ``failed`` cells
are retried on ``--resume``.  :class:`~repro.fl.resume.Preempted` and
``KeyboardInterrupt`` are ``BaseException``\\ s and deliberately escape this
net — a preemption kills the sweep, as it should.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import time

from repro.core.diffusion import PlanCache
from repro.train.checkpoint import atomic_write_json

__all__ = ["SweepManifest", "cell_slug", "default_state_dir",
           "save_plan_cache_file", "load_plan_cache_file"]

MANIFEST_VERSION = 1

# Config keys that may differ between the original launch and a --resume
# without invalidating stored progress: the checkpoint cadence (resume may
# tighten/loosen it) and the replication engine (durable sweeps force
# "loop" anyway).
_RESUME_SAFE_KEYS = ("checkpoint_every", "engine")


def cell_slug(label: str) -> str:
    """Filesystem-safe name for a cell label (``alpha=0.1/feddif`` →
    ``alpha-0.1__feddif``)."""
    return re.sub(r"[^A-Za-z0-9._-]+", "__",
                  label.replace("/", "__").replace("=", "-"))


def default_state_dir(name: str) -> str:
    """Durable-state home for sweep ``name`` under the artifact dir."""
    from repro.experiments import artifacts
    return os.path.join(artifacts.default_out_dir(), "sweeps", name)


class SweepManifest:
    """The durable work-queue ledger for one sweep run."""

    def __init__(self, state_dir: str, data: dict):
        self.state_dir = state_dir
        self.data = data

    # ------------------------------------------------------------ lifecycle

    @classmethod
    def open(cls, state_dir: str, sweep: str, config: dict,
             labels: list[str], resume: bool) -> "SweepManifest":
        """Create a fresh manifest, or adopt an existing one on resume.

        A fresh (non-resume) open refuses to reuse a state directory that
        already holds a manifest — silently clobbering durable progress is
        exactly the failure mode this module exists to prevent.
        """
        path = cls._path(state_dir)
        if os.path.exists(path):
            if not resume:
                raise FileExistsError(
                    f"{path} already exists — pass resume=True (CLI: "
                    f"--resume) to continue it, or use a fresh state_dir")
            m = cls.load(state_dir)
            m._check_config(config)
            # The grid may legitimately be re-expanded on resume; any label
            # the stored manifest has never seen starts pending.
            for lab in labels:
                m.data["cells"].setdefault(
                    lab, {"status": "pending", "error": None})
            m.data["order"] = list(labels)
            m.data["updated_unix"] = time.time()
            m.flush()
            return m
        if resume and not os.path.isdir(state_dir):
            raise FileNotFoundError(
                f"resume requested but no manifest at {path}")
        data = {
            "version": MANIFEST_VERSION,
            "sweep": sweep,
            "config": _jsonable(config),
            "created_unix": time.time(),
            "updated_unix": time.time(),
            "order": list(labels),
            "cells": {lab: {"status": "pending", "error": None}
                      for lab in labels},
        }
        m = cls(state_dir, data)
        m.flush()
        return m

    @classmethod
    def load(cls, state_dir: str) -> "SweepManifest":
        with open(cls._path(state_dir)) as f:
            return cls(state_dir, json.load(f))

    @staticmethod
    def _path(state_dir: str) -> str:
        return os.path.join(state_dir, "manifest.json")

    @property
    def path(self) -> str:
        return self._path(self.state_dir)

    def flush(self) -> None:
        self.data["updated_unix"] = time.time()
        atomic_write_json(self.path, self.data, indent=2)

    def _check_config(self, config: dict) -> None:
        saved = self.data.get("config", {})
        current = _jsonable(config)
        diffs = {k: (saved.get(k), current.get(k))
                 for k in set(saved) | set(current)
                 if k not in _RESUME_SAFE_KEYS
                 and saved.get(k) != current.get(k)}
        if diffs:
            raise ValueError(
                "refusing to resume: sweep was launched with a different "
                f"configuration — mismatched keys (saved, current): {diffs}")

    # ------------------------------------------------------------ work queue

    def status(self, label: str) -> str:
        return self.data["cells"][label]["status"]

    def mark(self, label: str, status: str, error: str | None = None) -> None:
        cell = self.data["cells"][label]
        cell["status"] = status
        cell["error"] = error
        self.flush()

    def failed_cells(self) -> list[dict]:
        return [{"label": lab, "error": c.get("error")}
                for lab, c in self.data["cells"].items()
                if c["status"] == "failed"]

    # ---------------------------------------------------------- cell records

    def record_path(self, label: str) -> str:
        return os.path.join(self.state_dir, "records",
                            f"{cell_slug(label)}.json")

    def store_record(self, label: str, record: dict) -> None:
        path = self.record_path(label)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        from repro.experiments.artifacts import _json_default
        atomic_write_json(path, record, indent=2, default=_json_default)

    def load_record(self, label: str) -> dict:
        with open(self.record_path(label)) as f:
            return json.load(f)

    # ------------------------------------------------------ cell checkpoints

    def cell_checkpoint_root(self, label: str) -> str:
        return os.path.join(self.state_dir, "cells", cell_slug(label))


# ------------------------------------------------------------- plan cache

def plan_cache_path(state_dir: str) -> str:
    return os.path.join(state_dir, "plan_cache.json")


def save_plan_cache_file(state_dir: str, cache: PlanCache) -> str:
    """Snapshot the sweep-shared plan cache (atomic); resumed runs *replay*
    already-planned control planes instead of replanning them."""
    path = plan_cache_path(state_dir)
    atomic_write_json(path, cache.state_dict())
    return path


def load_plan_cache_file(state_dir: str, cache: PlanCache) -> bool:
    """Merge a saved plan-cache snapshot into ``cache``; False if absent."""
    path = plan_cache_path(state_dir)
    if not os.path.exists(path):
        return False
    with open(path) as f:
        cache.load_state_dict(json.load(f))
    return True


def _jsonable(obj):
    """Round-trip through JSON so stored/loaded configs compare equal
    (tuples become lists, numpy scalars become Python scalars)."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        obj = dataclasses.asdict(obj)
    return json.loads(json.dumps(obj, default=str))
