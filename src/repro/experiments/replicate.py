"""Multi-seed replication engines.

Two ways to run one sweep cell at ``S`` replicate seeds:

* :func:`run_replicates_vmapped` — the fast path.  Model-init seeds only
  differ on the *data plane* (initial params and therefore every subsequent
  local update), so the whole cohort is trained as one pytree with a leading
  seed axis: ``init`` is ``jax.vmap``-ed over ``PRNGKey(seed)``s and every
  local SGD step is a jit-compiled ``vmap`` over that axis.  The *control
  plane* (topology draw, auction, diffusion plan, ledger charges) is
  seed-independent by construction (``FLConfig.topology_seed``), runs once,
  and is shared by every replicate — with a
  :class:`~repro.core.diffusion.PlanCache` it is not even replanned across
  cells that share a key.  Supports the strategies whose round structure is
  identical across seeds: ``fedavg`` and ``feddif``.

* :func:`run_replicates_loop` — the general path: one
  :func:`~repro.fl.experiment.run_experiment` call per seed (any strategy),
  still sharing the plan cache so FedDif's host control plane is replayed,
  not replanned, for seeds after the first.

Both return one :class:`~repro.fl.server.FLResult` per seed with identical
ledgers across seeds (communication is seed-independent given the topology
seed), so downstream aggregation code does not care which engine produced
them.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.channels.fading import ChannelModel
from repro.channels.resources import GAMMA_FLOOR, ResourceLedger
from repro.channels.topology import CellTopology
from repro.core import aggregation as agg
from repro.core.auction import AuctionConfig
from repro.core.diffusion import (DiffusionPlanner, PlanCache,
                                  feddif_cache_key)
from repro.core.dol import DiffusionState, iid_distance
from repro.fl.experiment import (ExperimentSpec, load_experiment_data,
                                 run_experiment)
from repro.fl.models import build_task_model
from repro.fl.server import FLResult, _uplink_gamma
from repro.train import optimizer as opt_lib

__all__ = ["SEED_VMAP_STRATEGIES", "run_replicates_vmapped",
           "run_replicates_loop"]

# Strategies whose per-round control flow is identical for every seed, so the
# seed axis can live on the data plane.  The others (fedswap's visit loop,
# gossip's pairings, …) stay on the process-level loop path.
SEED_VMAP_STRATEGIES = ("fedavg", "feddif")


def run_replicates_loop(spec: ExperimentSpec, seeds: Sequence[int],
                        plan_cache: PlanCache | None = None,
                        checkpoint_root: str | None = None
                        ) -> list[FLResult]:
    """One ``run_experiment`` per seed; plan cache shared across seeds.

    ``checkpoint_root`` (durable sweeps) gives each replicate seed its own
    round-checkpoint directory ``<root>/seed<seed>`` — a preempted cell
    resumes mid-cohort: finished seeds rerun from their final checkpoint in
    O(1 rounds), the interrupted seed from its last boundary.
    """
    import os
    results = []
    for s in seeds:
        spec_s = dataclasses.replace(
            spec, fl=dataclasses.replace(spec.fl, seed=int(s)))
        ckpt_dir = (os.path.join(checkpoint_root, f"seed{int(s)}")
                    if checkpoint_root is not None else None)
        results.append(run_experiment(spec_s, plan_cache=plan_cache,
                                      checkpoint_dir=ckpt_dir))
    return results


def _make_stacked_local_update(model, cfg, clip: float = 10.0):
    """Seed-stacked mirror of ``repro.fl.client.make_local_update``.

    The jitted step is ``vmap``-ed over a leading seed axis on (params,
    momentum); the batch is shared (the data partition is fixed by
    ``data_seed``, not the replicate seed).  Gradient clipping is *per seed*
    (inside the vmap), matching the loop engine's math exactly.
    """
    opt = opt_lib.sgd(momentum=cfg.momentum)

    def one(params, mu, batch, lr):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch))(params)
        grads, _ = opt_lib.clip_by_global_norm(grads, clip)
        updates, new_state = opt.update(grads, {"mu": mu}, params, lr)
        return opt_lib.apply_updates(params, updates), new_state["mu"], loss

    step = jax.jit(jax.vmap(one, in_axes=(0, 0, None, None)))

    def local_update(params, batches):
        mu = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        total, nb = None, 0
        for batch in batches:
            b = {k: jnp.asarray(v) for k, v in batch.items()}
            params, mu, loss = step(params, mu, b, cfg.lr)
            total = loss if total is None else total + loss
            nb += 1
        mean = total / max(nb, 1) if total is not None else None
        return params, mean

    return local_update


def run_replicates_vmapped(spec: ExperimentSpec, seeds: Sequence[int],
                           plan_cache: PlanCache | None = None
                           ) -> list[FLResult]:
    """Run one cell at ``len(seeds)`` replicate seeds, seed axis vmapped.

    Requires ``spec.fl.strategy in SEED_VMAP_STRATEGIES`` and
    ``spec.fl.topology_seed`` set (the control plane must be
    seed-independent for the cohort to share one plan/ledger).
    """
    cfg = spec.fl
    if cfg.strategy not in SEED_VMAP_STRATEGIES:
        raise ValueError(
            f"strategy {cfg.strategy!r} is not seed-vmappable; "
            f"use run_replicates_loop")
    if cfg.topology_seed is None:
        raise ValueError("seed-vmapped replication needs fl.topology_seed "
                         "(control plane must not depend on the model seed)")
    if cfg.churn_rate > 0.0:
        raise ValueError("seed-vmapped replication does not model churn "
                         "(fl.churn_rate > 0); use run_replicates_loop")
    if getattr(cfg, "scenario", "static") != "static":
        # Mobility / handoff / energy evolve HostWorld state per round on
        # the host control plane; the replicated device loop has no slot
        # for it (and value-fused plans are seed-dependent anyway).
        raise ValueError(
            f"seed-vmapped replication supports scenario='static' only "
            f"(got {cfg.scenario!r}); use run_replicates_loop")
    if getattr(cfg, "uncertainty_weight", 0.0) > 0.0:
        raise ValueError(
            "seed-vmapped replication cannot fuse learning values "
            "(fl.uncertainty_weight > 0): the value signal depends on each "
            "seed's params, so plans are not shareable; use "
            "run_replicates_loop")
    seeds = [int(s) for s in seeds]

    # ---- data / model setup (identical to run_experiment, done once) -----
    train, test, part, loaders = load_experiment_data(spec)
    model = build_task_model(spec.task, spec.dim, spec.num_classes)
    dsi, data_sizes = part.dsi, part.data_sizes
    n, m = cfg.num_clients, cfg.num_models

    keys = jnp.stack([jax.random.PRNGKey(s) for s in seeds])
    global_params = jax.vmap(model.init)(keys)      # leading seed axis S
    local_update = _make_stacked_local_update(model, cfg)

    @jax.jit
    def eval_stacked(params):
        def one(p):
            return (model.accuracy(p, test.x, test.y),
                    model.loss(p, {"x": test.x, "y": test.y}))
        return jax.vmap(one)(params)

    # ---- shared control plane -------------------------------------------
    topology = CellTopology(num_pues=n)
    channel = ChannelModel()
    auction = AuctionConfig(gamma_min=cfg.gamma_min, metric=cfg.metric,
                            allow_retraining=cfg.allow_retraining)
    planner = DiffusionPlanner(topology, channel, auction,
                               epsilon=cfg.epsilon,
                               max_rounds=cfg.max_diffusion_rounds,
                               underlay=cfg.underlay, mode=cfg.planner)
    ledger = ResourceLedger()
    one_seed = jax.tree.map(lambda x: x[0], global_params)
    model_bits = agg.model_bits(one_seed, cfg.bits_per_param)
    auction.model_bits = model_bits

    acc_hist, loss_hist, dif_hist, iid_hist = [], [], [], []

    for t in range(cfg.rounds):
        ctrl_rng = np.random.default_rng([cfg.topology_seed, t])
        pos = topology.sample_positions(ctrl_rng, n)
        up_gamma = np.maximum(_uplink_gamma(channel, pos, ctrl_rng),
                              GAMMA_FLOOR)

        if cfg.strategy == "fedavg":
            ledger.charge_downlink(model_bits, float(np.median(up_gamma)), n)
            locals_ = []
            for i in range(n):
                p, _ = local_update(global_params, list(loaders[i].epoch()))
                locals_.append(p)
                ledger.charge_uplink(model_bits, float(up_gamma[i]))
            global_params = agg.fedavg(locals_, list(data_sizes))
            dif_hist.append(0)
            iid_hist.append(float(np.mean(iid_distance(
                np.asarray(dsi), cfg.metric))))
        else:                                               # feddif
            ledger.charge_downlink(model_bits, float(np.median(up_gamma)), n)
            models = [global_params for _ in range(m)]
            state = DiffusionState.init(m, n, dsi.shape[1])
            for mi in range(m):
                holder = int(state.holder[mi])
                models[mi], _ = local_update(models[mi],
                                             list(loaders[holder].epoch()))
                state.record_training(mi, holder, dsi[holder],
                                      float(data_sizes[holder]))
            cache_key = None
            if plan_cache is not None:
                cache_key = feddif_cache_key(cfg, t, dsi, data_sizes,
                                             model_bits, auction)
            plan = planner.plan_communication_round(
                state, dsi, data_sizes, ctrl_rng, positions=pos,
                cache=plan_cache, cache_key=cache_key)
            for k in range(plan.num_rounds):
                for hop in plan.hops_in_round(k):
                    ledger.charge_d2d(model_bits, max(hop.gamma, GAMMA_FLOOR))
                    models[hop.model], _ = local_update(
                        models[hop.model], list(loaders[hop.dst].epoch()))
            for mi in range(m):
                ledger.charge_uplink(model_bits,
                                     float(up_gamma[int(state.holder[mi])]))
            weights = [float(state.chain_size[mi]) for mi in range(m)]
            global_params = agg.fedavg(models, weights)
            dif_hist.append(plan.num_rounds)
            iid_hist.append(float(np.mean(plan.final_iid_distance)))

        if (t + 1) % cfg.eval_every == 0 or t == cfg.rounds - 1:
            a, l = eval_stacked(global_params)
            acc_hist.append(np.asarray(a, np.float64))
            loss_hist.append(np.asarray(l, np.float64))

    # ---- unstack into one FLResult per seed -----------------------------
    results = []
    for si, s in enumerate(seeds):
        results.append(FLResult.from_histories(
            accuracy=[float(a[si]) for a in acc_hist],
            loss=[float(l[si]) for l in loss_hist],
            ledger=copy.deepcopy(ledger),
            diffusion_rounds=list(dif_hist),
            iid_distance=list(iid_hist),
            config=dataclasses.replace(cfg, seed=s),
            final_params=jax.tree.map(lambda x: x[si], global_params)))
    return results
