"""D2D channel model — Eqs. (12)–(14) of the paper.

``g = sqrt(beta) * h`` with Rayleigh small-scale fading ``h ~ CN(0,1)`` and
log-distance large-scale fading ``beta[dB] = beta0 − 10·kappa·log10(d/d0)``.

All quantities are kept in natural (linear) units internally; the dataclass
carries the dB-domain parameters as they appear in the paper.
"""
from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ChannelParams", "ChannelModel"]

_WARNED_INTERFERENCE_W = False


@dataclasses.dataclass
class ChannelParams:
    beta0_db: float = -30.0        # large-scale pathloss @ reference distance
    d0_m: float = 1.0              # reference distance
    kappa: float = 3.0             # pathloss exponent (urban)
    tx_power_dbm: float = 23.0     # UE max Tx power (3GPP)
    noise_psd_dbm_hz: float = -174.0   # AWGN PSD
    bandwidth_hz: float = 180e3    # per-PRB bandwidth (numerology 0)

    @property
    def tx_power_w(self) -> float:
        return 10 ** ((self.tx_power_dbm - 30.0) / 10.0)

    @property
    def noise_w(self) -> float:
        psd_w = 10 ** ((self.noise_psd_dbm_hz - 30.0) / 10.0)
        return psd_w * self.bandwidth_hz


class ChannelModel:
    """Samples channel gains and SNRs between user pairs."""

    def __init__(self, params: ChannelParams | None = None):
        self.params = params or ChannelParams()

    def large_scale_db(self, dist_m: np.ndarray) -> np.ndarray:
        """Eq. (13): beta in dB as a function of pairwise distance."""
        p = self.params
        return p.beta0_db - 10.0 * p.kappa * np.log10(
            np.maximum(dist_m, p.d0_m) / p.d0_m)

    def sample_gains(self, dist_m: np.ndarray, rng: np.random.Generator
                     ) -> np.ndarray:
        """Eq. (12): |g|^2 = beta * |h|^2, h ~ CN(0,1) (Rayleigh power ~Exp(1))."""
        beta = 10 ** (self.large_scale_db(dist_m) / 10.0)
        h2 = rng.exponential(scale=1.0, size=dist_m.shape)
        return beta * h2

    def snr(self, gains_sq: np.ndarray,
            interference: np.ndarray | float = 0.0, *,
            interference_w: float | None = None) -> np.ndarray:
        """|g|^2 p / (sigma^2 + I) — Eq. (14) generalized to SINR.

        ``interference`` is the per-link received co-channel power in watts
        and broadcasts against ``gains_sq``: a scalar models the underlay
        mode of D2D (Appendix C-F: D2D pairs reuse CUE uplink resources, so
        co-channel CUE power raises the noise floor uniformly), while an
        (n,) or (n, n) array carries per-receiver / per-link interference —
        the multi-cell world of ``repro.channels.world``.

        ``interference_w`` is the deprecated scalar spelling; it keeps
        working for one release through this shim (warns once per process).
        """
        if interference_w is not None:
            global _WARNED_INTERFERENCE_W
            if not _WARNED_INTERFERENCE_W:
                _WARNED_INTERFERENCE_W = True
                warnings.warn(
                    "ChannelModel.snr(interference_w=...) is deprecated; "
                    "pass the per-link `interference` array (a scalar still "
                    "broadcasts) — the legacy kwarg keeps working for one "
                    "release through this shim",
                    DeprecationWarning, stacklevel=2)
            interference = interference_w
        p = self.params
        return gains_sq * p.tx_power_w / (p.noise_w + interference)

    # ------------------------------------------------- device (jnp) plane
    #
    # Pure-JAX twins of the sampling/arithmetic above, keyed by explicit PRNG
    # keys so they are jit/vmap-safe inside the device-resident planner
    # (repro.core.planner).  The numpy methods stay the host/parity oracle.

    def large_scale_db_jax(self, dist_m: jax.Array) -> jax.Array:
        """Eq. (13) in jnp; traceable."""
        p = self.params
        return p.beta0_db - 10.0 * p.kappa * jnp.log10(
            jnp.maximum(dist_m, p.d0_m) / p.d0_m)

    def sample_gains_jax(self, key: jax.Array, dist_m: jax.Array
                         ) -> jax.Array:
        """Eq. (12) in jnp: |g|² = β·|h|², h ~ CN(0,1) ⇒ |h|² ~ Exp(1)."""
        beta = 10.0 ** (self.large_scale_db_jax(dist_m) / 10.0)
        h2 = jax.random.exponential(key, dist_m.shape)
        return beta * h2

    def snr_jax(self, gains_sq: jax.Array,
                interference: jax.Array | float = 0.0) -> jax.Array:
        """Eq. (14) SNR for traced arrays — :meth:`snr` is pure operator
        arithmetic and already trace-safe; this alias keeps the device
        plane's API uniform without duplicating the formula."""
        return self.snr(gains_sq, interference)

    def sample_cue_interference(self, rng: np.random.Generator,
                                n_cues: int, cell_radius_m: float = 250.0
                                ) -> float:
        """Aggregate received co-channel CUE power at a typical D2D receiver
        (underlay mode): CUEs uniform on the disc, large-scale pathloss +
        Rayleigh power per interferer."""
        if n_cues <= 0:
            return 0.0
        r = cell_radius_m * np.sqrt(rng.uniform(size=n_cues))
        beta = 10 ** (self.large_scale_db(np.maximum(r, 1.0)) / 10.0)
        h2 = rng.exponential(1.0, size=n_cues)
        return float(np.sum(beta * h2) * self.params.tx_power_w)
