"""WorldState: the time-evolving wireless world behind every scenario.

PR-9 and earlier sampled the channel piecemeal — a fresh uniform-disc
placement per round (``channels/topology.py``), a one-shot Rayleigh draw
(``channels/fading.py``) and a sub-frame ledger (``channels/resources.py``)
— which hard-wires the paper's single *static* evaluation world (Eqs.
12–14, 39).  This module packages placement, mobility, serving-cell
assignment, interference and per-client energy into one state object with
two synchronized planes:

* :class:`WorldState` — a NamedTuple **pytree** of arrays plus a pure,
  vmappable :func:`step` transition.  The device-resident planner carries
  it through its ``lax.while_loop`` (``core/planner.py``) so scenario
  evolution inside Algorithm 1/2 costs zero host round-trips.
* :class:`HostWorld` — the stateful host-side oracle the FL control plane
  (``fl/server.py`` / ``fl/async_plane.py`` / the replicate engines)
  advances once per communication round off the per-round control stream
  ``np.random.default_rng([topology_seed, t])``.

Scenarios (the ``FLConfig.scenario`` axis):

``static``
    The paper's world, verbatim: :meth:`HostWorld.advance_round` consumes
    exactly ``topology.sample_positions(rng, n)`` and nothing else, zero
    interference, infinite energy — so static runs stay bit-identical to
    pre-world code (the degeneracy contract).
``mobile``
    Random-waypoint traces: clients move toward a waypoint at
    ``speed_mps`` and redraw it on arrival.  Between communication rounds
    the host advances ``round_s`` of world time; within a round the
    planner steps ``substep_s`` per diffusion round — deterministically,
    so plans stay pure functions of their inputs.
``multicell``
    ``num_cells`` cells on a ring; each client redraws uniformly in its
    home cell每 round, is served by the nearest (max-mean-SINR) center —
    handoff — and every link sees deterministic per-receiver co-channel
    interference from the non-serving centers (Eq. 14 → SINR).
``energy_capped``
    Static placement (bit-identical draws) plus a finite per-client
    transmit-energy budget; depleted clients stop training/transmitting
    (churn semantics — the wire already committed is still charged).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.channels.fading import ChannelModel
from repro.channels.resources import TX_POWER_W, spectral_efficiency
from repro.channels.topology import CellTopology

__all__ = ["SCENARIOS", "WorldConfig", "WorldState", "HostWorld",
           "cell_centers", "init_world", "step", "receiver_interference_w"]

SCENARIOS = ("static", "mobile", "multicell", "energy_capped")

#: Default per-client transmit-energy budget (J) for ``energy_capped``;
#: ≈ a few hundred FCN-sized hops at cell-median spectral efficiency.
DEFAULT_ENERGY_BUDGET_J = 2.0


@dataclasses.dataclass(frozen=True)
class WorldConfig:
    """Static (hashable) scenario knobs — safe as a jit static argument."""
    scenario: str = "static"
    speed_mps: float = 15.0        # random-waypoint speed
    substep_s: float = 1.0         # world time per diffusion round (planner)
    round_s: float = 10.0          # world time per communication round
    num_cells: int = 3             # multicell ring size
    cell_spacing_factor: float = 2.0   # ring radius in units of cell radius
    energy_budget_j: float = float("inf")

    def __post_init__(self):
        if self.scenario not in SCENARIOS:
            raise ValueError(f"unknown scenario {self.scenario!r}; expected "
                             f"one of {SCENARIOS}")

    @property
    def step_m(self) -> float:
        """Distance moved per planner substep (mobile scenario)."""
        return self.speed_mps * self.substep_s

    @classmethod
    def for_scenario(cls, scenario: str,
                     energy_budget_j: float | None = None) -> "WorldConfig":
        if energy_budget_j is None:
            energy_budget_j = (DEFAULT_ENERGY_BUDGET_J
                               if scenario == "energy_capped"
                               else float("inf"))
        return cls(scenario=scenario, energy_budget_j=energy_budget_j)


class WorldState(NamedTuple):
    """The evolving world as a pytree of arrays (batchable under vmap)."""
    positions: jax.Array      # (..., n, 2) client positions [m]
    waypoints: jax.Array      # (..., n, 2) random-waypoint targets [m]
    serving: jax.Array        # (..., n) int32 serving-cell index
    energy_j: jax.Array       # (..., n) cumulative UE transmit energy [J]
    t: jax.Array              # (...) int32 substep counter


def cell_centers(cfg: WorldConfig, radius_m: float) -> np.ndarray:
    """(K, 2) cell centers: origin plus a ring of spacing-factor · radius."""
    k = max(int(cfg.num_cells), 1)
    if k == 1:
        return np.zeros((1, 2))
    ring = cfg.cell_spacing_factor * radius_m
    ang = 2.0 * np.pi * np.arange(k - 1) / (k - 1)
    ring_xy = ring * np.stack([np.cos(ang), np.sin(ang)], axis=-1)
    return np.concatenate([np.zeros((1, 2)), ring_xy], axis=0)


def init_world(cfg: WorldConfig, topology: CellTopology,
               rng: np.random.Generator, n: int) -> WorldState:
    """Host-side initial world (numpy arrays; ducks as the pytree)."""
    if cfg.scenario == "multicell":
        centers = cell_centers(cfg, topology.radius_m)
        home = np.arange(n) % len(centers)
        pos = topology.sample_positions(rng, n) + centers[home]
        serving = _nearest_center(pos, centers)
    else:
        pos = topology.sample_positions(rng, n)
        serving = np.zeros(n, dtype=np.int32)
    way = (topology.sample_positions(rng, n) if cfg.scenario == "mobile"
           else pos.copy())
    return WorldState(positions=pos, waypoints=way, serving=serving,
                      energy_j=np.zeros(n), t=np.int32(0))


def step(world: WorldState, key: jax.Array | None = None, *,
         step_m: float, radius_m: float = 250.0) -> WorldState:
    """Pure, vmappable world transition: one random-waypoint substep.

    Clients advance ``step_m`` meters toward their waypoint and clamp on
    arrival.  Without ``key`` the transition is fully deterministic — the
    form the jitted planner uses inside its while_loop, so plans remain
    pure functions of their inputs.  With ``key``, arrived clients redraw
    a fresh uniform-disc waypoint (the steady-state mobility form the
    ``world_step`` bench measures).
    """
    delta = world.waypoints - world.positions
    d = jnp.linalg.norm(delta, axis=-1, keepdims=True)
    frac = jnp.minimum(step_m, d) / jnp.maximum(d, 1e-9)
    pos = world.positions + delta * frac
    way = world.waypoints
    if key is not None:
        kr, kt = jax.random.split(key)
        shape = world.positions.shape[:-1]
        r = radius_m * jnp.sqrt(jax.random.uniform(kr, shape))
        th = jax.random.uniform(kt, shape, minval=0.0, maxval=2.0 * jnp.pi)
        cand = jnp.stack([r * jnp.cos(th), r * jnp.sin(th)], axis=-1)
        arrived = d[..., 0] <= step_m
        way = jnp.where(arrived[..., None], cand, way)
    return WorldState(positions=pos, waypoints=way, serving=world.serving,
                      energy_j=world.energy_j, t=world.t + 1)


def _nearest_center(pos: np.ndarray, centers: np.ndarray) -> np.ndarray:
    """SINR-based handoff: equal-power centers with a common pathloss
    exponent make argmax mean SINR ≡ argmin distance."""
    d = np.linalg.norm(pos[:, None, :] - centers[None, :, :], axis=-1)
    return np.argmin(d, axis=1).astype(np.int32)


def receiver_interference_w(pos: np.ndarray, serving: np.ndarray,
                            centers: np.ndarray, channel: ChannelModel
                            ) -> np.ndarray:
    """Per-receiver co-channel interference (W): Σ over non-serving cell
    centers of large-scale received power (Rayleigh marginalized, like the
    mean SNR of Eq. 39).  Deterministic given positions — both planner
    modes see identical values."""
    d = np.linalg.norm(pos[:, None, :] - centers[None, :, :], axis=-1)
    beta = 10.0 ** (channel.large_scale_db(np.maximum(d, 1.0)) / 10.0)
    rx = beta * channel.params.tx_power_w          # (n, K)
    total = rx.sum(axis=1)
    own = np.take_along_axis(rx, serving[:, None].astype(int), axis=1)[:, 0]
    return total - own


@dataclasses.dataclass
class HostWorld:
    """Stateful host-side world the FL control plane advances per round.

    The RNG discipline mirrors the pre-world control plane exactly: every
    consumption comes from the per-round stream the caller passes in, and
    the ``static`` scenario consumes *exactly* the draws the old code did
    (``topology.sample_positions`` then the uplink ``sample_gains``) — the
    bit-identical degeneracy contract.
    """
    cfg: WorldConfig
    topology: CellTopology
    channel: ChannelModel
    num_clients: int
    state: WorldState | None = None
    rounds_advanced: int = 0

    @classmethod
    def create(cls, scenario: str, topology: CellTopology,
               channel: ChannelModel, num_clients: int,
               energy_budget_j: float | None = None) -> "HostWorld":
        cfg = WorldConfig.for_scenario(scenario,
                                       energy_budget_j=energy_budget_j)
        return cls(cfg=cfg, topology=topology, channel=channel,
                   num_clients=num_clients)

    # ------------------------------------------------------- round advance

    def advance_round(self, rng: np.random.Generator) -> np.ndarray:
        """Advance one communication round; returns (n, 2) positions."""
        n, cfg = self.num_clients, self.cfg
        if cfg.scenario in ("static", "energy_capped"):
            pos = self.topology.sample_positions(rng, n)
            energy = (self.state.energy_j if self.state is not None
                      else np.zeros(n))
            self.state = WorldState(positions=pos, waypoints=pos.copy(),
                                    serving=np.zeros(n, dtype=np.int32),
                                    energy_j=energy,
                                    t=np.int32(self.rounds_advanced))
        elif cfg.scenario == "mobile":
            if self.state is None:
                self.state = init_world(cfg, self.topology, rng, n)
            else:
                st = self.state
                delta = st.waypoints - st.positions
                d = np.linalg.norm(delta, axis=-1, keepdims=True)
                move = cfg.speed_mps * cfg.round_s
                frac = np.minimum(move, d) / np.maximum(d, 1e-9)
                pos = st.positions + delta * frac
                # Fixed consumption: candidate waypoints are drawn every
                # round regardless of how many clients arrived, so the
                # control stream stays deterministic per (seed, t).
                cand = self.topology.sample_positions(rng, n)
                arrived = d[:, 0] <= move
                way = np.where(arrived[:, None], cand, st.waypoints)
                self.state = WorldState(positions=pos, waypoints=way,
                                        serving=st.serving,
                                        energy_j=st.energy_j,
                                        t=st.t + 1)
        elif cfg.scenario == "multicell":
            centers = self._centers()
            home = np.arange(n) % len(centers)
            pos = self.topology.sample_positions(rng, n) + centers[home]
            energy = (self.state.energy_j if self.state is not None
                      else np.zeros(n))
            self.state = WorldState(positions=pos, waypoints=pos.copy(),
                                    serving=_nearest_center(pos, centers),
                                    energy_j=energy,
                                    t=np.int32(self.rounds_advanced))
        self.rounds_advanced += 1
        return np.asarray(self.state.positions)

    def _centers(self) -> np.ndarray:
        return cell_centers(self.cfg, self.topology.radius_m)

    # -------------------------------------------------------- channel view

    def interference(self) -> np.ndarray | float:
        """Per-receiver co-channel interference this round (W).

        Scalar 0.0 outside multicell — the exact value the pre-world SNR
        path used, so static arithmetic is unchanged bit-for-bit."""
        if self.cfg.scenario != "multicell" or self.state is None:
            return 0.0
        return receiver_interference_w(np.asarray(self.state.positions),
                                       np.asarray(self.state.serving),
                                       self._centers(), self.channel)

    def link_interference(self) -> np.ndarray | float:
        """(n, n) per-link interference: receiver-side broadcast of
        :meth:`interference` (columns index the receiving client)."""
        i_rx = self.interference()
        if np.isscalar(i_rx):
            return i_rx
        return np.broadcast_to(np.asarray(i_rx)[None, :],
                               (self.num_clients, self.num_clients))

    def uplink_gamma(self, rng: np.random.Generator) -> np.ndarray:
        """Per-client uplink spectral efficiency to the serving BS.

        Static path is arithmetic- and draw-identical to the pre-world
        ``_uplink_gamma``: distance to the origin, one Rayleigh draw, zero
        interference.  Multicell uses the serving-center distance and the
        deterministic inter-cell interference seen at that BS."""
        pos = np.asarray(self.state.positions)
        if self.cfg.scenario == "multicell":
            centers = self._centers()
            serving = np.asarray(self.state.serving)
            d = np.maximum(np.linalg.norm(pos - centers[serving], axis=-1),
                           1.0)
            rx = (10.0 ** (self.channel.large_scale_db(
                np.maximum(np.linalg.norm(
                    centers[serving][:, None, :] - centers[None, :, :],
                    axis=-1), 1.0)) / 10.0) * self.channel.params.tx_power_w)
            own = np.take_along_axis(rx, serving[:, None].astype(int),
                                     axis=1)[:, 0]
            interference = rx.sum(axis=1) - own
        else:
            d = np.maximum(np.linalg.norm(pos, axis=-1), 1.0)
            interference = 0.0
        gains = self.channel.sample_gains(d, rng)
        return spectral_efficiency(self.channel.snr(gains, interference))

    # ------------------------------------------------------------- energy

    @property
    def has_energy_cap(self) -> bool:
        return np.isfinite(self.cfg.energy_budget_j)

    def depleted(self) -> np.ndarray:
        """(n,) mask of clients whose cumulative TX energy spent the budget
        in *prior* rounds — the set the scheduler drops this round."""
        if self.state is None:
            return np.zeros(self.num_clients, dtype=bool)
        return np.asarray(self.state.energy_j) >= self.cfg.energy_budget_j

    def charge_energy(self, per_client_j: np.ndarray) -> None:
        """Accumulate this round's per-client transmit energy."""
        st = self.state
        self.state = WorldState(positions=st.positions,
                                waypoints=st.waypoints, serving=st.serving,
                                energy_j=np.asarray(st.energy_j)
                                + np.asarray(per_client_j), t=st.t)

    # ----------------------------------------------------------- planning

    def planner_world(self) -> WorldState | None:
        """The within-round WorldState handed to the diffusion planner —
        float32 to match the device plane.  Only mobile needs in-loop
        stepping; the other scenarios are frozen within a round and are
        fully described by (positions, interference)."""
        if self.cfg.scenario != "mobile" or self.state is None:
            return None
        st = self.state
        return WorldState(
            positions=np.asarray(st.positions, np.float32),
            waypoints=np.asarray(st.waypoints, np.float32),
            serving=np.asarray(st.serving, np.int32),
            energy_j=np.asarray(st.energy_j, np.float32),
            t=np.int32(st.t))


def per_client_energy_j(schedule, num_clients: int,
                        bandwidth_hz: float) -> np.ndarray:
    """Decompose a round schedule's wire into per-client TX energy (J).

    Events with an unknown transmitter (``src < 0``, e.g. BS downlink)
    charge no client.  Mirrors the ledger's joule arithmetic exactly:
    ``P_tx · bits / (γ·B)`` per event."""
    e = np.zeros(num_clients)
    for ev in schedule.wire:
        if ev.kind in ("d2d", "uplink") and ev.src >= 0:
            g = max(float(ev.gamma), 1e-9)
            e[ev.src] += TX_POWER_W * float(ev.bits) / (g * bandwidth_hz)
    return e
