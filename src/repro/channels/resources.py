"""Spectral-efficiency, bandwidth and sub-frame accounting — Eqs. (14), (15),
(39) and the evaluation metrics of Sec. VI (consumed sub-frames, transmitted
models).

The sub-frame ledger follows 5G numerology 0 (3GPP TR 37.885): 1 ms sub-frames,
180 kHz PRBs.  A model of S bits sent at spectral efficiency γ (bit/s/Hz) over
bandwidth B occupies ``ceil(S / (γ·B·T_sf))`` sub-frames.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["spectral_efficiency", "required_bandwidth", "outage_probability",
           "spectral_efficiency_jax", "required_bandwidth_jax",
           "outage_probability_jax", "ResourceLedger", "GAMMA_FLOOR",
           "TX_POWER_W"]

SUBFRAME_S = 1e-3          # 1 ms
PRB_HZ = 180e3             # physical resource block bandwidth
GAMMA_FLOOR = 0.05         # feasibility floor applied before ledger charging
TX_POWER_W = 10 ** ((23.0 - 30.0) / 10.0)  # 23 dBm UE Tx power (3GPP)


def spectral_efficiency(snr: np.ndarray) -> np.ndarray:
    """Eq. (14): γ = log2(1 + SNR)  [bit/s/Hz]."""
    return np.log2(1.0 + snr)


def required_bandwidth(model_bits: float, gamma: np.ndarray) -> np.ndarray:
    """Eq. (15)/(37): B = S / γ  — "total bits per unit spectral efficiency".

    The paper treats this as the frequency-domain cost of one diffusion hop;
    units are Hz·s (bits / (bit/s/Hz)).  Infeasible links (γ→0) cost ∞.
    """
    g = np.asarray(gamma, dtype=np.float64)
    with np.errstate(divide="ignore"):
        return np.where(g > 1e-9, model_bits / g, np.inf)


def outage_probability(gamma_min: np.ndarray | float, snr: np.ndarray
                       ) -> np.ndarray:
    """Eq. (39): Rayleigh outage ``P(γ ≤ γ_min) = 1 − exp(−(2^γ_min − 1)/SNR̄)``.

    ``snr`` is the *mean* SNR of the link (large-scale only); the small-scale
    Rayleigh power is the Exp(1) random variable marginalized analytically.
    """
    thr = 2.0 ** np.asarray(gamma_min, np.float64) - 1.0
    snr = np.maximum(np.asarray(snr, np.float64), 1e-12)
    return 1.0 - np.exp(-thr / snr)


# ----------------------------------------------------- device (jnp) plane
#
# Pure-JAX twins of the three closed forms above, traceable inside the jitted
# planner plane (repro.core.planner); the numpy versions remain the
# host/parity oracle used by the ledger path.

def spectral_efficiency_jax(snr: jax.Array) -> jax.Array:
    """Eq. (14) in jnp: γ = log2(1 + SNR)."""
    return jnp.log2(1.0 + snr)


def required_bandwidth_jax(model_bits: jax.Array | float, gamma: jax.Array
                           ) -> jax.Array:
    """Eq. (15)/(37) in jnp: B = S / γ, ∞ on dead links."""
    return jnp.where(gamma > 1e-9, model_bits / jnp.maximum(gamma, 1e-9),
                     jnp.inf)


def outage_probability_jax(gamma_min: jax.Array | float, snr: jax.Array
                           ) -> jax.Array:
    """Eq. (39) Rayleigh outage in jnp.

    ``-expm1`` rather than ``1 - exp``: float32 cancellation at small
    outage would otherwise quantize P_out to ~1e-7 steps.
    """
    thr = 2.0 ** jnp.asarray(gamma_min) - 1.0
    return -jnp.expm1(-thr / jnp.maximum(snr, 1e-12))


@dataclasses.dataclass
class ResourceLedger:
    """Accumulates the paper's Table-II communication-efficiency metrics.

    ``energy_j`` extends the ledger to UE-side transmit energy: each D2D
    hop / uplink charge adds ``P_tx · S / (γ·B)`` joules (transmit power
    times airtime at the link's achievable rate).  Downlink broadcasts are
    BS-side and charge no UE energy.
    """
    subframes: int = 0
    transmitted_models: int = 0
    transmitted_bits: float = 0.0
    bandwidth_hz_s: float = 0.0     # Σ required bandwidth (Eq. 15 units)
    uplink_models: int = 0          # model uploads to the BS (aggregation)
    downlink_models: int = 0        # model broadcasts from the BS
    energy_j: float = 0.0           # Σ UE transmit energy (D2D + uplink)

    def charge_d2d(self, model_bits: float, gamma: float,
                   bandwidth_hz: float = PRB_HZ) -> int:
        """Charge one D2D model transmission; returns sub-frames consumed."""
        if not np.isfinite(gamma) or gamma <= 0:
            raise ValueError("cannot transmit over a zero-rate link")
        rate = gamma * bandwidth_hz                  # bit/s
        sf = int(np.ceil(model_bits / (rate * SUBFRAME_S)))
        self.subframes += sf
        self.transmitted_models += 1
        self.transmitted_bits += model_bits
        self.bandwidth_hz_s += model_bits / gamma
        self.energy_j += TX_POWER_W * model_bits / (gamma * bandwidth_hz)
        return sf

    def charge_uplink(self, model_bits: float, gamma: float,
                      bandwidth_hz: float = PRB_HZ) -> int:
        rate = max(gamma, 1e-9) * bandwidth_hz
        sf = int(np.ceil(model_bits / (rate * SUBFRAME_S)))
        self.subframes += sf
        self.uplink_models += 1
        self.transmitted_models += 1
        self.transmitted_bits += model_bits
        self.energy_j += TX_POWER_W * model_bits / rate
        return sf

    def charge_downlink(self, model_bits: float, gamma: float, n_users: int,
                        bandwidth_hz: float = PRB_HZ) -> int:
        """Broadcast costs one transmission regardless of n_users (PDSCH)."""
        rate = max(gamma, 1e-9) * bandwidth_hz
        sf = int(np.ceil(model_bits / (rate * SUBFRAME_S)))
        self.subframes += sf
        self.downlink_models += 1
        return sf

    def merge(self, other: "ResourceLedger") -> "ResourceLedger":
        return ResourceLedger(
            subframes=self.subframes + other.subframes,
            transmitted_models=self.transmitted_models + other.transmitted_models,
            transmitted_bits=self.transmitted_bits + other.transmitted_bits,
            bandwidth_hz_s=self.bandwidth_hz_s + other.bandwidth_hz_s,
            uplink_models=self.uplink_models + other.uplink_models,
            downlink_models=self.downlink_models + other.downlink_models,
            energy_j=self.energy_j + other.energy_j,
        )

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)
