"""Cell topology: PUE placement and CUE arrivals (Sec. VI-A).

The paper deploys every user uniformly at random in a circular cell of radius
250 m each communication round; cellular (non-participating) UEs arrive by a
Poisson point process and consume part of the uplink band (constraint 18f).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CellTopology"]


@dataclasses.dataclass
class CellTopology:
    """Uniform-disc user placement + PPP background traffic."""
    radius_m: float = 250.0
    num_pues: int = 10
    cue_rate: float = 5.0          # mean CUEs per round (PPP intensity)
    cue_bandwidth_hz: float = 180e3  # one PRB per CUE, 3GPP numerology 0

    def sample_positions(self, rng: np.random.Generator, n: int | None = None
                         ) -> np.ndarray:
        """(n, 2) uniform positions on the disc (inverse-CDF radius)."""
        n = self.num_pues if n is None else n
        r = self.radius_m * np.sqrt(rng.uniform(size=n))
        theta = rng.uniform(0.0, 2 * np.pi, size=n)
        return self.positions_from_polar(r, theta, np)

    @staticmethod
    def positions_from_polar(r, theta, xp=np):
        """Shared (r, θ) → (n, 2) transform behind both sampling twins.

        Factored out so the host/jax parity property tests can feed the SAME
        polar draws through both array namespaces — any drift between the
        numpy and jnp position math shows up as a direct mismatch here."""
        return xp.stack([r * xp.cos(theta), r * xp.sin(theta)], axis=-1)

    def pairwise_distances(self, pos: np.ndarray) -> np.ndarray:
        """(n, n) Euclidean distance matrix with a safe diagonal."""
        diff = pos[:, None, :] - pos[None, :, :]
        d = np.linalg.norm(diff, axis=-1)
        np.fill_diagonal(d, 1.0)  # self-links never used; avoid log(0)
        return d

    def sample_cue_load(self, rng: np.random.Generator) -> float:
        """Bandwidth (Hz) consumed by background CUEs this round (Σ B̃ in 18f)."""
        n_cues = rng.poisson(self.cue_rate)
        return float(n_cues) * self.cue_bandwidth_hz

    # ------------------------------------------------- device (jnp) plane

    def sample_positions_jax(self, key: jax.Array, n: int | None = None
                             ) -> jax.Array:
        """Pure-JAX twin of :meth:`sample_positions`, keyed by an explicit
        PRNG key; broadcasts under ``vmap`` over a batch of keys."""
        n = self.num_pues if n is None else n
        kr, kt = jax.random.split(key)
        r = self.radius_m * jnp.sqrt(jax.random.uniform(kr, (n,)))
        theta = jax.random.uniform(kt, (n,), minval=0.0,
                                   maxval=2.0 * jnp.pi)
        return self.positions_from_polar(r, theta, jnp)

    @staticmethod
    def pairwise_distances_jax(pos: jax.Array) -> jax.Array:
        """jnp :meth:`pairwise_distances` (safe unit diagonal); traceable."""
        diff = pos[..., :, None, :] - pos[..., None, :, :]
        d = jnp.linalg.norm(diff, axis=-1)
        n = d.shape[-1]
        return jnp.where(jnp.eye(n, dtype=bool), 1.0, d)
