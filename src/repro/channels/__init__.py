from repro.channels.fading import ChannelModel, ChannelParams
from repro.channels.resources import (ResourceLedger, required_bandwidth,
                                      outage_probability, spectral_efficiency)
from repro.channels.topology import CellTopology
from repro.channels.world import (SCENARIOS, HostWorld, WorldConfig,
                                  WorldState, init_world, step)

__all__ = [
    "ChannelModel", "ChannelParams", "ResourceLedger", "required_bandwidth",
    "outage_probability", "spectral_efficiency", "CellTopology",
    "SCENARIOS", "HostWorld", "WorldConfig", "WorldState", "init_world",
    "step",
]
