from repro.channels.fading import ChannelModel, ChannelParams
from repro.channels.resources import (ResourceLedger, required_bandwidth,
                                      outage_probability, spectral_efficiency)
from repro.channels.topology import CellTopology

__all__ = [
    "ChannelModel", "ChannelParams", "ResourceLedger", "required_bandwidth",
    "outage_probability", "spectral_efficiency", "CellTopology",
]
