from repro.serving.sampler import SamplerConfig, sample
from repro.serving.engine import Request, ServingEngine
