"""Token samplers for the serving engine: greedy, temperature, top-k,
nucleus (top-p) — pure functions over (key, logits)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["SamplerConfig", "sample"]


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 1.0      # 0 => greedy
    top_k: int = 0                # 0 => disabled
    top_p: float = 1.0            # 1 => disabled


def sample(key: jax.Array, logits: jax.Array, cfg: SamplerConfig
           ) -> jax.Array:
    """logits: (B, V) -> token ids (B,) int32."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / cfg.temperature
    if cfg.top_k > 0:
        kth = jnp.sort(logits, axis=-1)[:, -cfg.top_k][:, None]
        logits = jnp.where(logits >= kth, logits, -jnp.inf)
    if cfg.top_p < 1.0:
        sorted_l = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_l, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # keep the smallest prefix with cumulative mass >= top_p
        cutoff_idx = jnp.sum(cum < cfg.top_p, axis=-1)
        cutoff = jnp.take_along_axis(sorted_l, cutoff_idx[:, None],
                                     axis=-1)
        logits = jnp.where(logits >= cutoff, logits, -jnp.inf)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
