"""Continuous-batching serving engine (vLLM-lite) over the decode step.

Maintains ``num_slots`` persistent KV-cache slots and a request queue:
finished or empty slots are refilled each step (admission), every step
decodes the whole batch once, and per-slot position counters drive ring/
mask logic inside the model's ``decode_step``.  Prompts are ingested
teacher-forced through the same decode path (one token/step), so one jitted
program serves both phases — the natural fit for slot-sharded pod serving
where recompilation per request shape is unacceptable.

The per-slot cache lives stacked on a leading slot axis; on a pod that axis
is sharded like the decode batch (see distributed/sharding.cache_specs).
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.zoo import Model
from repro.serving.sampler import SamplerConfig, sample

__all__ = ["Request", "ServingEngine"]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (len,) int32
    max_new_tokens: int = 16
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False

    @property
    def total_budget(self) -> int:
        return len(self.prompt) + self.max_new_tokens


class ServingEngine:
    def __init__(self, model: Model, params, num_slots: int = 4,
                 max_seq: int = 256, sampler: SamplerConfig | None = None,
                 eos_id: int | None = None, seed: int = 0):
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.sampler = sampler or SamplerConfig(temperature=0.0)
        self.eos_id = eos_id
        self.key = jax.random.PRNGKey(seed)
        self.queue: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * num_slots
        self.pos = np.zeros(num_slots, np.int64)       # per-slot lengths
        self.cache = model.init_cache(params, num_slots, max_seq)
        self._decode = jax.jit(model.decode_step)
        self.steps = 0

    # ------------------------------------------------------------- API
    def submit(self, req: Request) -> None:
        if req.total_budget > self.max_seq:
            raise ValueError(f"request {req.uid} exceeds max_seq")
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> list[Request]:
        finished: list[Request] = []
        while (self.queue or any(self.slots)) and self.steps < max_steps:
            finished.extend(self.step())
        return finished

    # ------------------------------------------------------------ core
    def _admit(self) -> None:
        for s in range(self.num_slots):
            if self.slots[s] is None and self.queue:
                self.slots[s] = self.queue.popleft()
                self.pos[s] = 0
                # NOTE: slot cache state is logically reset via position
                # masking — positions ≥ pos are never attended.

    def _next_inputs(self) -> np.ndarray:
        toks = np.zeros((self.num_slots, 1), np.int32)
        for s, req in enumerate(self.slots):
            if req is None:
                continue
            p = self.pos[s]
            if p < len(req.prompt):
                toks[s, 0] = req.prompt[p]          # prompt ingestion
            elif req.output:
                toks[s, 0] = req.output[-1]         # autoregressive
            else:
                toks[s, 0] = req.prompt[-1]
        return toks

    def step(self) -> list[Request]:
        """One engine step: admit → one ragged decode → harvest.

        Every slot decodes at ITS OWN position (decode_step accepts a (B,)
        position vector); idle slots run at pos 0 with a dummy token —
        harmless, as a newly admitted request rewrites its slot's cache
        sequentially from position 0.
        """
        self._admit()
        if not any(self.slots):
            return []
        toks = jnp.asarray(self._next_inputs())
        pos_vec = jnp.asarray(self.pos, jnp.int32)
        logits, self.cache = self._decode(self.params, toks, self.cache,
                                          pos_vec)
        self.key, sub = jax.random.split(self.key)
        out_tok = np.asarray(sample(sub, logits[:, -1], self.sampler))
        finished: list[Request] = []
        for s, req in enumerate(self.slots):
            if req is None:
                continue
            self.pos[s] += 1
            if self.pos[s] >= len(req.prompt):
                req.output.append(int(out_tok[s]))
                if (len(req.output) >= req.max_new_tokens
                        or (self.eos_id is not None
                            and req.output[-1] == self.eos_id)
                        or self.pos[s] >= self.max_seq - 1):
                    req.done = True
                    finished.append(req)
                    self.slots[s] = None
        self.steps += 1
        return finished
