from repro.models.zoo import Model, build_model

__all__ = ["Model", "build_model"]
