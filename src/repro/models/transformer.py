"""Decoder-only transformer stack covering dense / MoE / SSM / hybrid / VLM
families, built as *segments* of scanned layers.

A :class:`Segment` is ``(kinds, count)``: a tuple of layer kinds forming one
scan body, repeated ``count`` times with stacked parameters.  This keeps the
HLO size O(#segments) regardless of depth and expresses interleaved patterns
exactly (e.g. gemma3's 5 local + 1 global per scan body; zamba2's 6 mamba2
blocks + 1 *shared* attention block whose parameters are not scanned).

Layer kinds:
  ``attn``    full-causal GQA attention + MLP (SwiGLU or MoE)
  ``swa``     sliding-window GQA attention + MLP
  ``mamba1``  Mamba-1 selective-scan block (no MLP, as in the original arch)
  ``mamba2``  Mamba-2 SSD block
  ``shared``  hybrid shared attention+MLP block (one param set reused)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.attention import (AttnSpec, attn_decode, attn_forward,
                                    init_attention, init_kv_cache)

Array = jax.Array
Params = Any

__all__ = ["Segment", "build_plan", "init_lm", "forward_hidden", "lm_loss",
           "init_cache", "decode_step", "specs_for"]


Segment = tuple[tuple[str, ...], int]


def build_plan(cfg: ModelConfig) -> list[Segment]:
    n = cfg.num_layers
    if cfg.family == "ssm":
        return [(("mamba1",), n)]
    if cfg.family == "hybrid":
        period = cfg.attn_period or 6
        groups, rem = divmod(n, period)
        plan: list[Segment] = []
        if groups:
            plan.append((("mamba2",) * period + ("shared",), groups))
        if rem:
            plan.append((("mamba2",) * rem, 1))
        return plan
    if cfg.local_global_ratio > 0:
        r = cfg.local_global_ratio
        groups, rem = divmod(n, r + 1)
        plan = []
        if groups:
            plan.append((("swa",) * r + ("attn",), groups))
        if rem:
            plan.append((("swa",) * rem, 1))
        return plan
    kind = "swa" if cfg.sliding_window else "attn"
    return [((kind,), n)]


def specs_for(cfg: ModelConfig):
    """Attention / MoE / SSM specs derived from a ModelConfig."""
    cd = jnp.dtype(cfg.compute_dtype)
    qc, kvc = cfg.attn_chunks if L.perf_opt_enabled("attn_chunks") \
        else (256, 512)
    attn = AttnSpec(
        d_model=cfg.d_model, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
        qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta,
        use_rope=cfg.family != "audio", causal=True, window=None,
        q_chunk=qc, kv_chunk=kvc,
        norm_eps=cfg.norm_eps, compute_dtype=cd)
    swa = dataclasses.replace(attn, window=cfg.sliding_window or 4096)
    moe = None
    if cfg.moe is not None:
        moe = moe_lib.MoESpec(
            d_model=cfg.d_model, num_experts=cfg.moe.num_experts,
            top_k=cfg.moe.top_k, d_ff_expert=cfg.moe.d_ff_expert,
            capacity_factor=cfg.moe.capacity_factor,
            router_aux_coef=cfg.moe.router_aux_coef,
            num_shared_experts=cfg.moe.num_shared_experts,
            dropless=cfg.moe.dropless, compute_dtype=cd)
    m1 = m2 = None
    if cfg.ssm is not None:
        # §Perf P2b: larger scan chunks cut per-iteration boundary traffic
        # (measured: falcon train memory term 164→76 s from 128→1024).
        m1_chunk = (max(cfg.ssm.chunk, 1024)
                    if L.perf_opt_enabled("ssm_chunk") else cfg.ssm.chunk)
        if cfg.ssm.version == 1:
            m1 = ssm_lib.Mamba1Spec(
                d_model=cfg.d_model, d_state=cfg.ssm.d_state,
                d_conv=cfg.ssm.d_conv, expand=cfg.ssm.expand,
                dt_rank=cfg.ssm.dt_rank, chunk=m1_chunk,
                compute_dtype=cd)
        else:
            m2 = ssm_lib.Mamba2Spec(
                d_model=cfg.d_model, d_state=cfg.ssm.d_state,
                d_conv=cfg.ssm.d_conv, expand=cfg.ssm.expand,
                head_dim=cfg.ssm.head_dim, chunk=cfg.ssm.chunk,
                compute_dtype=cd)
    return attn, swa, moe, m1, m2


# ------------------------------------------------------------------ init

def _init_layer(key, kind: str, cfg: ModelConfig) -> Params:
    attn, swa, moe, m1, m2 = specs_for(cfg)
    k1, k2 = jax.random.split(key)
    if kind in ("attn", "swa", "shared"):
        spec = swa if kind == "swa" else attn
        p = {"ln1": L.init_rmsnorm(cfg.d_model),
             "attn": init_attention(k1, spec),
             "ln2": L.init_rmsnorm(cfg.d_model)}
        if cfg.moe is not None and kind != "shared":
            p["moe"] = moe_lib.init_moe(k2, moe)
        else:
            d_ff = cfg.d_ff or 4 * cfg.d_model
            p["mlp"] = L.init_swiglu(k2, cfg.d_model, d_ff)
        return p
    if kind == "mamba1":
        return {"ln": L.init_rmsnorm(cfg.d_model),
                "mamba": ssm_lib.init_mamba1(k1, m1)}
    if kind == "mamba2":
        return {"ln": L.init_rmsnorm(cfg.d_model),
                "mamba": ssm_lib.init_mamba2(k1, m2)}
    raise ValueError(kind)


def init_lm(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, 8)
    params: Params = {"embed": L.init_embedding(keys[0], cfg.vocab_size,
                                                cfg.d_model)}
    plan = build_plan(cfg)
    seg_params = []
    for si, (kinds, count) in enumerate(plan):
        seg: Params = {}
        for pi, kind in enumerate(kinds):
            name = f"{pi}_{kind}"
            if kind == "shared":
                continue    # shared params live at top level
            kseed = jax.random.fold_in(keys[1], si * 64 + pi)
            init_one = functools.partial(_init_layer, kind=kind, cfg=cfg)
            seg[name] = jax.vmap(lambda k: init_one(k))(
                jax.random.split(kseed, count))
        seg_params.append(seg)
    params["segments"] = seg_params
    if any("shared" in kinds for kinds, _ in plan):
        params["shared_block"] = _init_layer(keys[2], "shared", cfg)
    params["final_norm"] = L.init_rmsnorm(cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_dense(keys[3], cfg.d_model,
                                         cfg.vocab_size, scale=0.02)
    return params


# ------------------------------------------------------------------ forward

def _apply_layer(p: Params, kind: str, cfg: ModelConfig, x: Array,
                 positions: Array | None, aux: Array) -> tuple[Array, Array]:
    attn, swa, moe, m1, m2 = specs_for(cfg)
    if kind in ("attn", "swa", "shared"):
        spec = swa if kind == "swa" else attn
        x = x + attn_forward(p["attn"], spec, L.rmsnorm(p["ln1"], x,
                                                        cfg.norm_eps),
                             positions)
        h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if "moe" in p:
            y, a = moe_lib.moe_forward(p["moe"], moe, h)
            aux = aux + a
        else:
            y = L.swiglu(p["mlp"], h, spec.compute_dtype)
        return x + y, aux
    if kind == "mamba1":
        return x + ssm_lib.mamba1_forward(p["mamba"],
                                          m1, L.rmsnorm(p["ln"], x,
                                                        cfg.norm_eps)), aux
    if kind == "mamba2":
        return x + ssm_lib.mamba2_forward(p["mamba"],
                                          m2, L.rmsnorm(p["ln"], x,
                                                        cfg.norm_eps)), aux
    raise ValueError(kind)


def forward_hidden(params: Params, cfg: ModelConfig, x: Array,
                   positions: Array | None = None, *, remat: bool = True
                   ) -> tuple[Array, Array]:
    """Embedded inputs (B,S,D) -> final hidden (B,S,D), aux loss."""
    plan = build_plan(cfg)
    aux0 = jnp.zeros((), jnp.float32)

    def seg_scan(x, aux, seg_p, kinds):
        def body(carry, layer_p):
            h, a = carry
            for pi, kind in enumerate(kinds):
                name = f"{pi}_{kind}"
                if kind == "shared":
                    h, a = _apply_layer(params["shared_block"], "shared",
                                        cfg, h, positions, a)
                else:
                    h, a = _apply_layer(layer_p[name], kind, cfg, h,
                                        positions, a)
            return (h, a), None

        body_fn = jax.checkpoint(body) if remat else body
        (x, aux), _ = jax.lax.scan(body_fn, (x, aux), seg_p)
        return x, aux

    aux = aux0
    for seg_p, (kinds, _count) in zip(params["segments"], plan):
        x, aux = seg_scan(x, aux, seg_p, kinds)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux


def _embed_inputs(params: Params, cfg: ModelConfig, batch: dict) -> Array:
    cd = jnp.dtype(cfg.compute_dtype)
    x = L.embed(params["embed"], batch["tokens"], cd)
    if cfg.frontend == "vision" and "patch_embeddings" in batch:
        # VLM: prefix the (stub-encoded, pre-projected) patch embeddings.
        x = jnp.concatenate([batch["patch_embeddings"].astype(cd), x], axis=1)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model, cd) ** 0.5
    return x


def lm_loss(params: Params, cfg: ModelConfig, batch: dict, *,
            remat: bool = True) -> Array:
    """Next-token CE loss.  batch: tokens (B,S), labels (B,S) [, mask,
    patch_embeddings]."""
    x = _embed_inputs(params, cfg, batch)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    hidden, aux = forward_hidden(params, cfg, x, positions, remat=remat)
    n_text = batch["tokens"].shape[1]
    hidden = hidden[:, -n_text:]    # VLM: loss only over text positions
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    ce = L.chunked_cross_entropy(head, hidden, batch["labels"],
                                 tie=cfg.tie_embeddings,
                                 mask=batch.get("mask"))
    return ce + aux


# ------------------------------------------------------------------ decode

def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Params:
    attn, swa, moe, m1, m2 = specs_for(cfg)
    plan = build_plan(cfg)
    segs = []
    for kinds, count in plan:
        seg: Params = {}
        for pi, kind in enumerate(kinds):
            name = f"{pi}_{kind}"
            if kind in ("attn", "swa"):
                spec = swa if kind == "swa" else attn
                # A sliding-window layer only ever reads the last `window`
                # entries — allocate a ring of that size, rounded up to a
                # multiple of 256 so the ring is seq-shardable over up to
                # (data × model) = 256 devices.
                if kind == "swa":
                    length = min(-(-(spec.window + 1) // 256) * 256, max_seq)
                else:
                    length = max_seq
                one = init_kv_cache(spec, batch, length)
            elif kind == "shared":
                one = init_kv_cache(attn, batch, max_seq)
            elif kind == "mamba1":
                one = ssm_lib.init_mamba1_cache(m1, batch)
            elif kind == "mamba2":
                one = ssm_lib.init_mamba2_cache(m2, batch)
            else:
                raise ValueError(kind)
            seg[name] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (count,) + a.shape).copy(), one)
        segs.append(seg)
    return {"segments": segs}


def decode_step(params: Params, cfg: ModelConfig, tokens: Array,
                cache: Params, pos: Array) -> tuple[Array, Params]:
    """One decode step.  tokens: (B, 1) int32; pos: scalar current length.

    Returns (logits (B, 1, V), new cache).
    """
    attn, swa, moe, m1, m2 = specs_for(cfg)
    cd = jnp.dtype(cfg.compute_dtype)
    x = L.embed(params["embed"], tokens, cd)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model, cd) ** 0.5
    plan = build_plan(cfg)
    new_segs = []
    for seg_p, seg_c, (kinds, _count) in zip(params["segments"],
                                             cache["segments"], plan):
        def body(carry, xs):
            h = carry
            layer_p, layer_c = xs
            new_c = {}
            for pi, kind in enumerate(kinds):
                name = f"{pi}_{kind}"
                if kind in ("attn", "swa", "shared"):
                    spec = swa if kind == "swa" else attn
                    p = (params["shared_block"] if kind == "shared"
                         else layer_p[name])
                    c = layer_c[name]
                    # SWA caches are rings of length min(window+1, max_seq);
                    # the ring math degenerates to linear while pos < length.
                    y, c2 = attn_decode(p["attn"], spec,
                                        L.rmsnorm(p["ln1"], h, cfg.norm_eps),
                                        c, pos, ring=(kind == "swa"))
                    h = h + y
                    hh = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
                    if "moe" in p:
                        y2, _ = moe_lib.moe_forward(p["moe"], moe, hh)
                    else:
                        y2 = L.swiglu(p["mlp"], hh, cd)
                    h = h + y2
                    new_c[name] = c2
                elif kind == "mamba1":
                    y, c2 = ssm_lib.mamba1_decode(
                        layer_p[name]["mamba"], m1,
                        L.rmsnorm(layer_p[name]["ln"], h, cfg.norm_eps),
                        layer_c[name])
                    h = h + y
                    new_c[name] = c2
                elif kind == "mamba2":
                    y, c2 = ssm_lib.mamba2_decode(
                        layer_p[name]["mamba"], m2,
                        L.rmsnorm(layer_p[name]["ln"], h, cfg.norm_eps),
                        layer_c[name])
                    h = h + y
                    new_c[name] = c2
            return h, new_c

        x, new_c = jax.lax.scan(body, x, (seg_p, seg_c))
        new_segs.append(new_c)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = L.unembed_logits(params["embed"], x, cd)
    else:
        logits = L.dense(params["lm_head"], x, cd)
    return logits.astype(jnp.float32), {"segments": new_segs}
