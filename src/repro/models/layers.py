"""Shared neural building blocks (pure functional JAX).

Parameters are plain nested dicts of ``jax.Array``.  Every ``init_*`` takes a
PRNG key and returns the param subtree; every ``apply``-style function takes
``(params, inputs)``.  Compute runs in ``compute_dtype`` (bf16 by default)
with fp32 master params and fp32 norm/softmax accumulation.
"""
from __future__ import annotations

import functools
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


# Experimental opts that must be requested EXPLICITLY (never part of "all")
_OPT_IN = frozenset({"embed_dshard"})


def perf_opt_enabled(name: str) -> bool:
    """Beyond-paper performance optimizations (§Perf) are individually
    toggleable so the paper-faithful baseline stays reproducible:
    ``REPRO_PERF_OPTS=all`` (default) | ``none`` | comma-list of
    {ce_seqchunk, ce_mask, ssm_fuse, ssm_chunk, attn_chunks, grad_accum,
    wire_bf16, params_only_diffusion}.  Opt-in extras ({embed_dshard}) are
    enabled only when listed explicitly (``all,embed_dshard`` works)."""
    tokens = os.environ.get("REPRO_PERF_OPTS", "all").split(",")
    if name in _OPT_IN:
        return name in tokens
    if "all" in tokens:
        return True
    if tokens == ["none"]:
        return False
    return name in tokens

Array = jax.Array
Params = Any

__all__ = [
    "init_dense", "dense", "init_rmsnorm", "rmsnorm", "init_layernorm",
    "layernorm", "init_embedding", "embed", "unembed_logits", "rope_freqs",
    "apply_rope", "init_swiglu", "swiglu", "chunked_cross_entropy",
    "sinusoidal_positions", "silu", "count_params",
]


def silu(x: Array) -> Array:
    return x * jax.nn.sigmoid(x)


# ---------------------------------------------------------------- dense

def init_dense(key, d_in: int, d_out: int, dtype=jnp.float32,
               scale: float | None = None) -> Params:
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}


def dense(p: Params, x: Array, compute_dtype=jnp.bfloat16) -> Array:
    w = p["w"].astype(compute_dtype)
    return jnp.einsum("...i,io->...o", x.astype(compute_dtype), w)


# ---------------------------------------------------------------- norms

def init_rmsnorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: Params, x: Array, eps: float = 1e-6) -> Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(dtype)


def init_layernorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p: Params, x: Array, eps: float = 1e-6) -> Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(dtype)


# ---------------------------------------------------------------- embedding

def init_embedding(key, vocab: int, d: int, dtype=jnp.float32) -> Params:
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed(p: Params, tokens: Array, compute_dtype=jnp.bfloat16) -> Array:
    table = p["table"]
    if perf_opt_enabled("embed_dshard"):
        # §Perf bonus (opt-in): the lookup against a (vocab×d)-sharded table
        # lowers to masked-gather + a full-token-stream all-reduce (≈1 GB ×
        # remat on 152k-vocab archs).  Resharding the table to d-only first
        # (one cheap all-to-all of the 38 MB/device table) makes the gather
        # local; the d-sharded activations flow into the TP layers natively.
        try:
            from jax.sharding import PartitionSpec as P
            table = jax.lax.with_sharding_constraint(table, P(None, "model"))
        except Exception:
            pass   # no mesh context (CPU unit tests): keep as-is
    return table.astype(compute_dtype)[tokens]


def unembed_logits(p: Params, x: Array, compute_dtype=jnp.bfloat16) -> Array:
    """Tied-embedding readout: x @ tableᵀ."""
    t = p["table"].astype(compute_dtype)
    return jnp.einsum("...d,vd->...v", x.astype(compute_dtype), t)


# ---------------------------------------------------------------- RoPE

@functools.partial(jax.jit, static_argnums=(0, 1), inline=True)
def _rope_table(head_dim: int, theta: float, positions: Array):
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs   # (..., S, half)
    return jnp.cos(ang), jnp.sin(ang)


def rope_freqs(head_dim: int, theta: float, positions: Array):
    return _rope_table(head_dim, float(theta), positions)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x: (..., S, H, Dh); cos/sin: (..., S, Dh/2) broadcast over heads."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    x1, x2 = jnp.split(xf, 2, axis=-1)
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(dtype)


def sinusoidal_positions(seq: int, d: int) -> Array:
    pos = np.arange(seq)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * dim / d)
    out = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(out, jnp.float32)


# ---------------------------------------------------------------- SwiGLU

def init_swiglu(key, d: int, d_ff: int, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": init_dense(k1, d, d_ff, dtype),
        "w_up": init_dense(k2, d, d_ff, dtype),
        "w_down": init_dense(k3, d_ff, d, dtype),
    }


def swiglu(p: Params, x: Array, compute_dtype=jnp.bfloat16) -> Array:
    g = dense(p["w_gate"], x, compute_dtype)
    u = dense(p["w_up"], x, compute_dtype)
    return dense(p["w_down"], silu(g) * u, compute_dtype)


# ---------------------------------------------------------------- loss

def chunked_cross_entropy(emb_or_head: Params, hidden: Array, labels: Array,
                          *, tie: bool, chunk: int = 512,
                          compute_dtype=jnp.bfloat16,
                          mask: Array | None = None) -> Array:
    """Mean next-token cross-entropy without materializing (B, S, V) logits.

    ``hidden``: (B, S, D); ``labels``: (B, S) int32.

    §Perf P1: the scan runs over SEQUENCE chunks with the batch dimension
    intact.  Flattening (B·S) into the scan axis — the obvious layout —
    destroys the batch sharding: under SPMD every device must run every
    chunk of the *global* token stream, so XLA all-gathers the whole hidden
    tensor and each data-parallel rank redundantly computes all other
    ranks' logits (measured: +8.6 GB all-gather and ~16× duplicated CE
    FLOPs per device on the 16×16 mesh).  Chunking over S keeps the chunk
    slice local to each batch shard.
    """
    b, s, d = hidden.shape
    m = (jnp.ones((b, s), jnp.float32) if mask is None
         else mask.astype(jnp.float32))
    if perf_opt_enabled("ce_seqchunk"):
        chunk = min(chunk, s)
        pad = (-s) % chunk
        if pad:
            hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)))
            m = jnp.pad(m, ((0, 0), (0, pad)))
        nchunks = hidden.shape[1] // chunk
        # scan xs carry leading chunk axis; batch stays axis 1 (sharded)
        hs = jnp.moveaxis(hidden.reshape(b, nchunks, chunk, d), 1, 0)
        ys = jnp.moveaxis(labels.reshape(b, nchunks, chunk), 1, 0)
        ms = jnp.moveaxis(m.reshape(b, nchunks, chunk), 1, 0)
    else:
        # baseline layout: flatten (B·S) into the scan axis.  Kept for the
        # §Perf A/B — under SPMD this replicates CE compute across the
        # data axis (see the P1 log).
        n = b * s
        flat_h = hidden.reshape(n, d)
        flat_y = labels.reshape(n)
        flat_m = m.reshape(n)
        pad = (-n) % chunk
        if pad:
            flat_h = jnp.pad(flat_h, ((0, pad), (0, 0)))
            flat_y = jnp.pad(flat_y, (0, pad))
            flat_m = jnp.pad(flat_m, (0, pad))
        nchunks = flat_h.shape[0] // chunk
        hs = flat_h.reshape(nchunks, 1, chunk, d)
        ys = flat_y.reshape(nchunks, 1, chunk)
        ms = flat_m.reshape(nchunks, 1, chunk)

    if tie:
        w = emb_or_head["table"].astype(compute_dtype)      # (V, D)
        proj = lambda h: jnp.einsum("btd,vd->btv", h, w)
    else:
        w = emb_or_head["w"].astype(compute_dtype)          # (D, V)
        proj = lambda h: jnp.einsum("btd,dv->btv", h, w)

    # Rematerialized per chunk: the backward pass recomputes each logits
    # block instead of saving all of them (tens of GB at LM scale).
    @functools.partial(jax.checkpoint, prevent_cse=False)
    def body(carry, xs):
        h, y, msk = xs
        logits = proj(h.astype(compute_dtype)).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        if perf_opt_enabled("ce_mask"):
            # Gold-logit extraction via mask+reduce, NOT take_along_axis:
            # with vocab-sharded logits a gather forces collectives; the
            # masked reduce lowers to a local select + tiny all-reduce.
            vocab_pos = jnp.arange(logits.shape[-1])
            gold = jnp.sum(jnp.where(y[..., None] == vocab_pos, logits,
                                     0.0), axis=-1)
        else:
            gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        loss_sum, cnt = carry
        return (loss_sum + jnp.sum((logz - gold) * msk),
                cnt + jnp.sum(msk)), None

    (loss_sum, cnt), _ = jax.lax.scan(body, (0.0, 0.0), (hs, ys, ms))
    return loss_sum / jnp.maximum(cnt, 1.0)


def count_params(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
