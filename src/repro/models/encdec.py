"""Whisper-style encoder–decoder transformer backbone.

Per the assignment carve-out, the mel-spectrogram + conv feature extractor is
a STUB: ``input_specs()`` supplies precomputed frame embeddings
``(B, num_frames, d_model)`` (1500 frames for whisper-base's 30 s window).
This module implements everything downstream: sinusoidal-position encoder
with bidirectional attention, causal decoder with self- + cross-attention,
GELU MLPs and pre-LayerNorm as in the original architecture
[arXiv:2212.04356].
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.attention import (AttnSpec, attn_decode, attn_forward,
                                    cross_attn_decode, init_attention,
                                    init_kv_cache, precompute_cross_kv)

Array = jax.Array
Params = Any

__all__ = ["enc_spec", "dec_spec", "init_encdec", "encode", "encdec_loss",
           "init_encdec_cache", "encdec_decode_step"]


def enc_spec(cfg: ModelConfig) -> AttnSpec:
    return AttnSpec(d_model=cfg.d_model, num_heads=cfg.num_heads,
                    num_kv_heads=cfg.num_kv_heads,
                    head_dim=cfg.resolved_head_dim, use_rope=False,
                    causal=False, norm_eps=cfg.norm_eps,
                    compute_dtype=jnp.dtype(cfg.compute_dtype))


def dec_spec(cfg: ModelConfig) -> AttnSpec:
    return AttnSpec(d_model=cfg.d_model, num_heads=cfg.num_heads,
                    num_kv_heads=cfg.num_kv_heads,
                    head_dim=cfg.resolved_head_dim, use_rope=False,
                    causal=True, norm_eps=cfg.norm_eps,
                    compute_dtype=jnp.dtype(cfg.compute_dtype))


def _init_mlp(key, d: int, d_ff: int) -> Params:
    k1, k2 = jax.random.split(key)
    return {"w1": L.init_dense(k1, d, d_ff), "w2": L.init_dense(k2, d_ff, d)}


def _mlp(p: Params, x: Array, cd) -> Array:
    return L.dense(p["w2"], jax.nn.gelu(L.dense(p["w1"], x, cd)), cd)


def _init_enc_layer(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {"ln1": L.init_layernorm(cfg.d_model),
            "attn": init_attention(k1, enc_spec(cfg)),
            "ln2": L.init_layernorm(cfg.d_model),
            "mlp": _init_mlp(k2, cfg.d_model, cfg.d_ff)}


def _init_dec_layer(key, cfg: ModelConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": L.init_layernorm(cfg.d_model),
            "self_attn": init_attention(k1, dec_spec(cfg)),
            "ln_x": L.init_layernorm(cfg.d_model),
            "cross_attn": init_attention(k2, enc_spec(cfg)),
            "ln2": L.init_layernorm(cfg.d_model),
            "mlp": _init_mlp(k3, cfg.d_model, cfg.d_ff)}


def init_encdec(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 6)
    ne = cfg.encoder_layers or cfg.num_layers
    nd = cfg.num_layers
    return {
        "embed": L.init_embedding(ks[0], cfg.vocab_size, cfg.d_model),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(
            jax.random.split(ks[1], ne)),
        "enc_norm": L.init_layernorm(cfg.d_model),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(
            jax.random.split(ks[2], nd)),
        "dec_norm": L.init_layernorm(cfg.d_model),
    }


def encode(params: Params, cfg: ModelConfig, frames: Array, *,
           remat: bool = True) -> Array:
    """frames: (B, T_audio, D) stub conv-frontend output -> encoder states."""
    cd = jnp.dtype(cfg.compute_dtype)
    spec = enc_spec(cfg)
    x = frames.astype(cd) + L.sinusoidal_positions(
        frames.shape[1], cfg.d_model).astype(cd)

    def body(h, p):
        h = h + attn_forward(p["attn"], spec,
                             L.layernorm(p["ln1"], h, cfg.norm_eps))
        h = h + _mlp(p["mlp"], L.layernorm(p["ln2"], h, cfg.norm_eps), cd)
        return h, None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
    return L.layernorm(params["enc_norm"], x, cfg.norm_eps)


def _decode_hidden(params: Params, cfg: ModelConfig, tokens: Array,
                   enc_out: Array, *, remat: bool = True) -> Array:
    cd = jnp.dtype(cfg.compute_dtype)
    sspec, xspec = dec_spec(cfg), enc_spec(cfg)
    x = L.embed(params["embed"], tokens, cd)
    x = x + L.sinusoidal_positions(tokens.shape[1],
                                   cfg.d_model).astype(cd)

    def body(h, p):
        h = h + attn_forward(p["self_attn"], sspec,
                             L.layernorm(p["ln1"], h, cfg.norm_eps))
        h = h + attn_forward(p["cross_attn"], xspec,
                             L.layernorm(p["ln_x"], h, cfg.norm_eps),
                             context=enc_out)
        h = h + _mlp(p["mlp"], L.layernorm(p["ln2"], h, cfg.norm_eps), cd)
        return h, None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_layers"])
    return L.layernorm(params["dec_norm"], x, cfg.norm_eps)


def encdec_loss(params: Params, cfg: ModelConfig, batch: dict, *,
                remat: bool = True) -> Array:
    """batch: frames (B,T,D), tokens (B,S), labels (B,S)."""
    enc_out = encode(params, cfg, batch["frames"], remat=remat)
    hidden = _decode_hidden(params, cfg, batch["tokens"], enc_out,
                            remat=remat)
    return L.chunked_cross_entropy(params["embed"], hidden, batch["labels"],
                                   tie=True, mask=batch.get("mask"))


# ------------------------------------------------------------------ decode

def init_encdec_cache(params: Params, cfg: ModelConfig, frames: Array,
                      batch: int, max_seq: int) -> Params:
    """Runs the encoder once; returns self-attn KV rings + static cross KV."""
    enc_out = encode(params, cfg, frames, remat=False)
    sspec, xspec = dec_spec(cfg), enc_spec(cfg)
    nd = cfg.num_layers

    self_cache = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (nd,) + a.shape).copy(),
        init_kv_cache(sspec, batch, max_seq))
    cross_cache = jax.vmap(
        lambda p: precompute_cross_kv(p["cross_attn"], xspec, enc_out))(
            params["dec_layers"])
    return {"self": self_cache, "cross": cross_cache}


def encdec_decode_step(params: Params, cfg: ModelConfig, tokens: Array,
                       cache: Params, pos: Array) -> tuple[Array, Params]:
    cd = jnp.dtype(cfg.compute_dtype)
    sspec, xspec = dec_spec(cfg), enc_spec(cfg)
    x = L.embed(params["embed"], tokens, cd)
    pe = L.sinusoidal_positions(cache["self"]["k"].shape[2],
                                cfg.d_model).astype(cd)
    pos_vec = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1),
                               (tokens.shape[0],))
    x = x + jnp.take(pe, pos_vec, axis=0)[:, None, :]

    def body(h, xs):
        p, sc, xc = xs
        y, sc2 = attn_decode(p["self_attn"], sspec,
                             L.layernorm(p["ln1"], h, cfg.norm_eps), sc, pos)
        h = h + y
        h = h + cross_attn_decode(p["cross_attn"], xspec,
                                  L.layernorm(p["ln_x"], h, cfg.norm_eps), xc)
        h = h + _mlp(p["mlp"], L.layernorm(p["ln2"], h, cfg.norm_eps), cd)
        return h, sc2

    x, new_self = jax.lax.scan(body, x,
                               (params["dec_layers"], cache["self"],
                                cache["cross"]))
    x = L.layernorm(params["dec_norm"], x, cfg.norm_eps)
    logits = L.unembed_logits(params["embed"], x, cd)
    return logits.astype(jnp.float32), {"self": new_self,
                                        "cross": cache["cross"]}
