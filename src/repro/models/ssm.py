"""State-space blocks: Mamba-1 selective scan and Mamba-2 SSD (chunked).

Both are sub-quadratic in sequence length.  Training/prefill runs a chunked
scan: ``lax.scan`` over sequence chunks carrying the recurrent state, with a
parallel ``associative_scan`` (Mamba-1) or the SSD quadratic-within-chunk
form (Mamba-2) inside each chunk — this bounds the live state tensor to one
chunk and is the natural TPU blocking (the Pallas ``ssm_scan`` kernel tiles
the same way into VMEM).

Decode is a single recurrence step on the carried state; the "cache" of an
SSM layer is ``(conv_buffer, ssm_state)`` — O(1) in context length, which is
why the long_500k shape is admissible for these families (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L

Array = jax.Array
Params = Any

__all__ = ["Mamba1Spec", "init_mamba1", "mamba1_forward", "init_mamba1_cache",
           "mamba1_decode", "Mamba2Spec", "init_mamba2", "mamba2_forward",
           "init_mamba2_cache", "mamba2_decode"]


# ===================================================================
# Mamba-1 (falcon-mamba-7b): per-channel selective scan, diagonal A.
# ===================================================================

@dataclasses.dataclass(frozen=True)
class Mamba1Spec:
    d_model: int
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0
    chunk: int = 128
    compute_dtype: Any = jnp.bfloat16

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def resolved_dt_rank(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)


def init_mamba1(key, spec: Mamba1Spec) -> Params:
    ks = jax.random.split(key, 7)
    d, di, n = spec.d_model, spec.d_inner, spec.d_state
    r = spec.resolved_dt_rank
    return {
        "in_proj": L.init_dense(ks[0], d, 2 * di),
        "conv_w": jax.random.normal(ks[1], (spec.d_conv, di), jnp.float32)
                  * (1.0 / spec.d_conv),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": L.init_dense(ks[2], di, r + 2 * n),
        "dt_proj": {"w": jax.random.normal(ks[3], (r, di), jnp.float32)
                         * (r ** -0.5),
                    "b": jnp.log(jnp.expm1(
                        jnp.exp(jax.random.uniform(
                            ks[4], (di,), minval=jnp.log(1e-3),
                            maxval=jnp.log(1e-1))))),},
        # S4D-real init: A_log[c, n] = log(n+1)
        "a_log": jnp.broadcast_to(jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)),
                                  (di, n)).copy(),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": L.init_dense(ks[5], di, d),
    }


def _causal_conv(x: Array, w: Array, b: Array, state: Array | None = None):
    """Depthwise causal conv1d.  x: (B,S,C), w: (K,C).  Returns (y, new_state)
    where state is the trailing (K-1) inputs for streaming decode."""
    k = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    # window sum: y[t] = sum_j w[j] * xp[t+j]
    y = sum(xp[:, j:j + x.shape[1], :] * w[j] for j in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else None
    return y + b, new_state


def _ssm_params(p: Params, spec: Mamba1Spec, x_conv: Array):
    """Input-dependent (Δ, B, C) and continuous A for tokens x_conv (B,S,di)."""
    r, n = spec.resolved_dt_rank, spec.d_state
    proj = L.dense(p["x_proj"], x_conv, jnp.float32)
    dt_low, bmat, cmat = jnp.split(proj, [r, r + n], axis=-1)
    dt = jnp.einsum("bsr,rd->bsd", dt_low, p["dt_proj"]["w"]) \
        + p["dt_proj"]["b"]
    dt = jax.nn.softplus(dt)                                 # (B,S,di)
    a = -jnp.exp(p["a_log"])                                 # (di,N)
    da = jnp.exp(dt[..., None] * a)                          # (B,S,di,N)
    dbx = dt[..., None] * bmat[:, :, None, :] \
        * x_conv.astype(jnp.float32)[..., None]              # (B,S,di,N)
    return da, dbx, cmat


def _chunked_linear_scan(da: Array, dbx: Array, h0: Array, chunk: int):
    """h_t = da_t * h_{t-1} + dbx_t, returning all h_t.  Shapes (B,S,di,N)."""
    b, s, di, n = da.shape
    pad = (-s) % chunk
    if pad:
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0), (0, 0)),
                     constant_values=1.0)
        dbx = jnp.pad(dbx, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = da.shape[1] // chunk
    da_c = da.reshape(b, nc, chunk, di, n).transpose(1, 0, 2, 3, 4)
    dbx_c = dbx.reshape(b, nc, chunk, di, n).transpose(1, 0, 2, 3, 4)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    def body(h, xs):
        da_i, dbx_i = xs                                     # (B,chunk,di,N)
        # fold carry into the first element
        dbx_i = dbx_i.at[:, 0].add(da_i[:, 0] * h)
        aa, hh = jax.lax.associative_scan(combine, (da_i, dbx_i), axis=1)
        return hh[:, -1], hh

    h_last, hs = jax.lax.scan(body, h0, (da_c, dbx_c))
    hs = hs.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, di, n)
    return hs[:, :s], h_last


def _chunked_scan_project(da: Array, dbx: Array, cmat: Array, h0: Array,
                          chunk: int):
    """Scan + fused C-projection: emits y = Σ_n h[...,n]·C[...,n] per chunk
    so the (B,S,di,N) state tensor never round-trips HBM (§Perf P2) — only
    the N-times-smaller (B,S,di) output leaves the scan body."""
    b, s, di, n = da.shape
    pad = (-s) % chunk
    if pad:
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0), (0, 0)),
                     constant_values=1.0)
        dbx = jnp.pad(dbx, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    nc = da.shape[1] // chunk
    da_c = da.reshape(b, nc, chunk, di, n).transpose(1, 0, 2, 3, 4)
    dbx_c = dbx.reshape(b, nc, chunk, di, n).transpose(1, 0, 2, 3, 4)
    c_c = cmat.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    def body(h, xs):
        da_i, dbx_i, c_i = xs
        dbx_i = dbx_i.at[:, 0].add(da_i[:, 0] * h)
        _, hh = jax.lax.associative_scan(combine, (da_i, dbx_i), axis=1)
        y_i = jnp.einsum("bldn,bln->bld", hh, c_i)
        return hh[:, -1], y_i

    h_last, ys = jax.lax.scan(body, h0, (da_c, dbx_c, c_c))
    ys = ys.transpose(1, 0, 2, 3).reshape(b, nc * chunk, di)
    return ys[:, :s], h_last


def mamba1_forward(p: Params, spec: Mamba1Spec, x: Array) -> Array:
    """x: (B,S,D) -> (B,S,D)."""
    cd = spec.compute_dtype
    xz = L.dense(p["in_proj"], x, cd)
    xin, z = jnp.split(xz, 2, axis=-1)
    x_conv, _ = _causal_conv(xin, p["conv_w"].astype(cd),
                             p["conv_b"].astype(cd))
    x_conv = L.silu(x_conv)
    da, dbx, cmat = _ssm_params(p, spec, x_conv)
    h0 = jnp.zeros((x.shape[0], spec.d_inner, spec.d_state), jnp.float32)
    if L.perf_opt_enabled("ssm_fuse"):
        y, _ = _chunked_scan_project(da, dbx, cmat, h0, spec.chunk)
    else:
        hs, _ = _chunked_linear_scan(da, dbx, h0, spec.chunk)
        y = jnp.einsum("bsdn,bsn->bsd", hs, cmat)            # (B,S,di)
    y = y + p["d_skip"] * x_conv.astype(jnp.float32)
    y = y.astype(cd) * L.silu(z)
    return L.dense(p["out_proj"], y, cd)


def init_mamba1_cache(spec: Mamba1Spec, batch: int) -> Params:
    return {
        "conv": jnp.zeros((batch, spec.d_conv - 1, spec.d_inner),
                          jnp.float32),
        "h": jnp.zeros((batch, spec.d_inner, spec.d_state), jnp.float32),
    }


def mamba1_decode(p: Params, spec: Mamba1Spec, x: Array, cache: Params
                  ) -> tuple[Array, Params]:
    """One-token step. x: (B,1,D)."""
    cd = spec.compute_dtype
    xz = L.dense(p["in_proj"], x, cd)
    xin, z = jnp.split(xz, 2, axis=-1)
    x_conv, conv_state = _causal_conv(xin, p["conv_w"].astype(cd),
                                      p["conv_b"].astype(cd), cache["conv"])
    x_conv = L.silu(x_conv)
    da, dbx, cmat = _ssm_params(p, spec, x_conv)
    h = da[:, 0] * cache["h"] + dbx[:, 0]                    # (B,di,N)
    y = jnp.einsum("bdn,bn->bd", h, cmat[:, 0])
    y = y + p["d_skip"] * x_conv[:, 0].astype(jnp.float32)
    y = (y.astype(cd) * L.silu(z[:, 0]))[:, None, :]
    out = L.dense(p["out_proj"], y, cd)
    return out, {"conv": conv_state.astype(jnp.float32), "h": h}


# ===================================================================
# Mamba-2 / SSD (zamba2): scalar decay per head, chunked SSD algorithm.
# ===================================================================

@dataclasses.dataclass(frozen=True)
class Mamba2Spec:
    d_model: int
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128
    compute_dtype: Any = jnp.bfloat16

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def num_heads(self) -> int:
        return self.d_inner // self.head_dim


def init_mamba2(key, spec: Mamba2Spec) -> Params:
    ks = jax.random.split(key, 6)
    d, di, n, nh = spec.d_model, spec.d_inner, spec.d_state, spec.num_heads
    # Projections are kept separate (z/x sharded over d_inner on the `model`
    # mesh axis; B/C/dt small and replicated) — see distributed/sharding.py.
    return {
        "w_zx": L.init_dense(ks[0], d, 2 * di),
        "w_bc": L.init_dense(ks[1], d, 2 * n),
        "w_dt": L.init_dense(ks[2], d, nh),
        "conv_x": {"w": jax.random.normal(ks[3], (spec.d_conv, di),
                                          jnp.float32) * (1.0 / spec.d_conv),
                   "b": jnp.zeros((di,), jnp.float32)},
        "conv_bc": {"w": jax.random.normal(ks[4], (spec.d_conv, 2 * n),
                                           jnp.float32) * (1.0 / spec.d_conv),
                    "b": jnp.zeros((2 * n,), jnp.float32)},
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "out_norm": L.init_rmsnorm(di),
        "out_proj": L.init_dense(ks[5], di, d),
    }


def _ssd_chunk_scan(xh: Array, a: Array, bmat: Array, cmat: Array,
                    h0: Array, chunk: int):
    """Chunked SSD (Mamba-2) recurrence.

    xh:   (B,S,H,P)   value stream (dt-scaled)
    a:    (B,S,H)     per-step log decay (negative)
    bmat: (B,S,N)     input projection (shared across heads)
    cmat: (B,S,N)     output projection
    h0:   (B,H,P,N)   initial state
    Returns (y (B,S,H,P), h_last).
    """
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    pad = (-s) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    nc = xh.shape[1] // chunk
    xs = (xh.reshape(b, nc, chunk, h, p).transpose(1, 0, 2, 3, 4),
          a.reshape(b, nc, chunk, h).transpose(1, 0, 2, 3),
          bmat.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3),
          cmat.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3))

    def body(hprev, xs_i):
        x_i, a_i, b_i, c_i = xs_i          # (B,L,H,P) (B,L,H) (B,L,N) (B,L,N)
        acum = jnp.cumsum(a_i, axis=1)                       # (B,L,H)
        # intra-chunk (quadratic within chunk): decay matrix L.  Mask BEFORE
        # exp — masked rel is positive and can overflow, and inf·0 in the
        # VJP of a post-exp where() poisons gradients with NaNs.
        rel = acum[:, :, None, :] - acum[:, None, :, :]      # (B,Lq,Lk,H)
        ltri = jnp.tril(jnp.ones((x_i.shape[1], x_i.shape[1]), bool))
        dec = jnp.exp(jnp.where(ltri[None, :, :, None], rel, -1e30))
        cb = jnp.einsum("bqn,bkn->bqk", c_i, b_i)            # (B,Lq,Lk)
        w = cb[..., None] * dec                              # (B,Lq,Lk,H)
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", w, x_i)
        # contribution of the carried state
        y_state = jnp.einsum("bqn,bhpn,bqh->bqhp", c_i, hprev,
                             jnp.exp(acum))
        # state update: h_new = decay_total * h + sum_k decay_k b_k x_k
        tot = jnp.exp(acum[:, -1])                           # (B,H)
        decay_k = jnp.exp(acum[:, -1:, :] - acum)            # (B,L,H)
        h_new = tot[:, :, None, None] * hprev + jnp.einsum(
            "bkn,bkhp,bkh->bhpn", b_i, x_i, decay_k)
        return h_new, y_intra + y_state

    h_last, ys = jax.lax.scan(body, h0, xs)
    ys = ys.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, h, p)
    return ys[:, :s], h_last


def _mamba2_streams(p: Params, spec: Mamba2Spec, x: Array,
                    conv_state: Params | None):
    cd = spec.compute_dtype
    di, n, nh = spec.d_inner, spec.d_state, spec.num_heads
    zx = L.dense(p["w_zx"], x, cd)
    z, xin = jnp.split(zx, 2, axis=-1)
    bc = L.dense(p["w_bc"], x, cd)
    dt = L.dense(p["w_dt"], x, cd)
    cs_x = conv_state["x"] if conv_state is not None else None
    cs_bc = conv_state["bc"] if conv_state is not None else None
    xin, new_conv_x = _causal_conv(xin, p["conv_x"]["w"].astype(cd),
                                   p["conv_x"]["b"].astype(cd), cs_x)
    bc, new_conv_bc = _causal_conv(bc, p["conv_bc"]["w"].astype(cd),
                                   p["conv_bc"]["b"].astype(cd), cs_bc)
    xin = L.silu(xin)
    bc = L.silu(bc)
    new_conv = {"x": new_conv_x, "bc": new_conv_bc}
    bmat, cmat = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    a = -jnp.exp(p["a_log"])                                      # (H,)
    a_step = dt * a                                               # (B,S,H)
    xh = xin.astype(jnp.float32).reshape(*xin.shape[:-1], nh, spec.head_dim)
    xh = xh * dt[..., None]
    return z, xh, a_step, bmat.astype(jnp.float32), \
        cmat.astype(jnp.float32), new_conv


def mamba2_forward(p: Params, spec: Mamba2Spec, x: Array) -> Array:
    cd = spec.compute_dtype
    b = x.shape[0]
    z, xh, a_step, bmat, cmat, _ = _mamba2_streams(p, spec, x, None)
    h0 = jnp.zeros((b, spec.num_heads, spec.head_dim, spec.d_state),
                   jnp.float32)
    y, _ = _ssd_chunk_scan(xh, a_step, bmat, cmat, h0, spec.chunk)
    y = y + p["d_skip"][None, None, :, None] * xh
    y = y.reshape(b, x.shape[1], spec.d_inner).astype(cd)
    y = L.rmsnorm(p["out_norm"], y * L.silu(z))
    return L.dense(p["out_proj"], y, cd)


def init_mamba2_cache(spec: Mamba2Spec, batch: int) -> Params:
    return {
        "conv": {"x": jnp.zeros((batch, spec.d_conv - 1, spec.d_inner),
                                jnp.float32),
                 "bc": jnp.zeros((batch, spec.d_conv - 1, 2 * spec.d_state),
                                 jnp.float32)},
        "h": jnp.zeros((batch, spec.num_heads, spec.head_dim, spec.d_state),
                       jnp.float32),
    }


def mamba2_decode(p: Params, spec: Mamba2Spec, x: Array, cache: Params
                  ) -> tuple[Array, Params]:
    cd = spec.compute_dtype
    b = x.shape[0]
    z, xh, a_step, bmat, cmat, conv_state = _mamba2_streams(
        p, spec, x, cache["conv"])
    da = jnp.exp(a_step[:, 0])                                # (B,H)
    h = da[:, :, None, None] * cache["h"] + jnp.einsum(
        "bn,bhp->bhpn", bmat[:, 0], xh[:, 0])
    y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0], h)
    y = y + p["d_skip"][None, :, None] * xh[:, 0]
    y = y.reshape(b, 1, spec.d_inner).astype(cd)
    y = L.rmsnorm(p["out_norm"], y * L.silu(z[:, :1]))
    out = L.dense(p["out_proj"], y, cd)
    new_conv = jax.tree.map(lambda a: a.astype(jnp.float32), conv_state)
    return out, {"conv": new_conv, "h": h}
