"""Mixture-of-Experts layer: top-k router + capacity-bounded gather dispatch.

Dispatch strategy (TPU-native adaptation — see DESIGN.md §2):
tokens are *gathered* into per-expert buffers of static capacity
``C = ceil(T·k/E · capacity_factor)`` using indices derived from an argsort of
the routing assignment, experts run as one batched einsum over the expert
axis (shardable over the ``model`` mesh axis → the gather/scatter lower to
the MoE all-to-all under SPMD), and results scatter-add back weighted by the
router probabilities.  Overflowing tokens are dropped (standard capacity
semantics); a Switch-style load-balance auxiliary loss discourages overflow.

This costs the *active*-parameter FLOPs (E·C·d·f ≈ T·k·cf·d·f per matmul),
not the dense all-experts FLOPs — required for a faithful MoE roofline.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L

Array = jax.Array
Params = Any

__all__ = ["MoESpec", "init_moe", "moe_forward"]


@dataclasses.dataclass(frozen=True)
class MoESpec:
    d_model: int
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    num_shared_experts: int = 0
    compute_dtype: Any = jnp.bfloat16
    # Dropless dispatch: buffers sized to the worst case (every token on one
    # expert) so no token is ever dropped.  This is what Mixtral / Qwen3-MoE
    # reference implementations do, and it is REQUIRED for prefill ≡ decode:
    # capacity drops depend on which other tokens share the batch, so a
    # token kept at decode (T=1 step, no competition) can be dropped at
    # prefill — tests/test_models_consistency.py pins the parity.
    dropless: bool = False

    def capacity(self, num_tokens: int) -> int:
        if self.dropless:
            # Each token routes to ≤1 slot per expert (top-k indices are
            # distinct), so cap = T can never overflow.  Static worst-case
            # buffers are the price of dropless under fixed shapes: E·T
            # dispatch rows vs T·k·cf capacity-bounded — E/(k·cf)× more
            # expert-FFN work (12.8× for qwen3_moe's E=128/k=8), mostly
            # multiplying zeros.  Use dropless=False (Switch/GShard
            # semantics) for roofline/FLOP studies; a ragged grouped-GEMM
            # dispatch would make dropless cost exactly T·k and is the
            # known follow-up.
            c = num_tokens
        else:
            c = int(num_tokens * self.top_k * self.capacity_factor
                    / self.num_experts)
        return max(8, -(-c // 8) * 8)    # round up to 8 for TPU lanes


def init_moe(key, spec: MoESpec) -> Params:
    kr, k1, k2, k3, ks = jax.random.split(key, 5)
    e, d, f = spec.num_experts, spec.d_model, spec.d_ff_expert
    scale = 1.0 / (d ** 0.5)
    p = {
        "router": L.init_dense(kr, d, e, scale=0.02),
        "w_gate": jax.random.normal(k1, (e, d, f), jnp.float32) * scale,
        "w_up": jax.random.normal(k2, (e, d, f), jnp.float32) * scale,
        "w_down": jax.random.normal(k3, (e, f, d), jnp.float32)
                  * (1.0 / (f ** 0.5)),
    }
    if spec.num_shared_experts:
        p["shared"] = L.init_swiglu(ks, d,
                                    f * spec.num_shared_experts)
    return p


def moe_forward(p: Params, spec: MoESpec, x: Array) -> tuple[Array, Array]:
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    b, s, d = x.shape
    cd = spec.compute_dtype
    t = b * s
    xt = x.reshape(t, d)
    e, k = spec.num_experts, spec.top_k
    cap = spec.capacity(t)

    logits = L.dense(p["router"], xt, jnp.float32)            # (T, E) fp32
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                    # (T, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # ---- load-balance aux loss (Switch Transformer eq. 4) ----
    me = jnp.mean(probs, axis=0)                              # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=1), axis=0)
    aux = spec.router_aux_coef * e * jnp.sum(me * ce)

    # ---- capacity assignment via sort by expert id ----
    flat_e = top_e.reshape(t * k)                             # (T·k,)
    flat_p = top_p.reshape(t * k)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_tok = flat_tok[order]
    sorted_p = flat_p[order]
    # position of each routed pair within its expert's buffer:
    # arange minus the start offset of the pair's expert segment.
    counts = jnp.bincount(sorted_e, length=e)                 # (E,)
    starts = jnp.cumsum(counts) - counts                      # exclusive scan
    pos_in_expert = jnp.arange(t * k) - starts[sorted_e]
    keep = pos_in_expert < cap
    # buffer slot = expert*cap + pos; dropped pairs park in a trash slot.
    slot = jnp.where(keep, sorted_e * cap + pos_in_expert, e * cap)

    # ---- gather tokens into (E·cap, D) buffers ----
    buf_tok = jnp.zeros((e * cap + 1,), jnp.int32).at[slot].set(
        sorted_tok, mode="drop")
    buf_valid = jnp.zeros((e * cap + 1,), jnp.bool_).at[slot].set(
        keep, mode="drop")
    gathered = jnp.take(xt, buf_tok[:e * cap], axis=0)        # (E·cap, D)
    gathered = jnp.where(buf_valid[:e * cap, None], gathered, 0.0)
    ex_in = gathered.reshape(e, cap, d).astype(cd)

    # ---- batched expert FFN (SwiGLU) ----
    wg = p["w_gate"].astype(cd)
    wu = p["w_up"].astype(cd)
    wd = p["w_down"].astype(cd)
    h = L.silu(jnp.einsum("ecd,edf->ecf", ex_in, wg)) \
        * jnp.einsum("ecd,edf->ecf", ex_in, wu)
    ex_out = jnp.einsum("ecf,efd->ecd", h, wd)                # (E, cap, D)

    # ---- combine: scatter-add back weighted by router prob ----
    flat_out = ex_out.reshape(e * cap, d)
    pair_out = jnp.take(flat_out, jnp.minimum(slot, e * cap - 1), axis=0)
    pair_out = jnp.where(keep[:, None], pair_out, 0.0)
    contrib = pair_out.astype(jnp.float32) * sorted_p[:, None]
    out = jnp.zeros((t, d), jnp.float32).at[sorted_tok].add(contrib)

    if spec.num_shared_experts:
        out = out + L.swiglu(p["shared"], xt, cd).astype(jnp.float32)
    return out.reshape(b, s, d).astype(x.dtype), aux
