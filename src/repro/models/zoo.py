"""Uniform model API over every architecture family.

``build_model(cfg)`` returns a :class:`Model` whose members are pure
functions: ``init``, ``loss`` (train / prefill forward), ``init_cache`` /
``decode_step`` (serving), and ``input_specs`` / ``cache_specs`` returning
``jax.ShapeDtypeStruct`` stand-ins for the dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec as ed
from repro.models import transformer as tf

Array = jax.Array
Params = Any

__all__ = ["Model", "build_model"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[Array], Params]
    loss: Callable[..., Array]                  # (params, batch) -> scalar
    init_cache: Callable[..., Params]
    decode_step: Callable[..., tuple[Array, Params]]
    input_specs: Callable[[ShapeConfig], dict]
    cache_specs: Callable[[ShapeConfig], Params]

    def train_batch_specs(self, shape: ShapeConfig) -> dict:
        return self.input_specs(shape)


def _lm_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.mode == "decode":
        specs = {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    else:
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                 "labels": jax.ShapeDtypeStruct((b, s), i32)}
    if cfg.frontend == "vision" and shape.mode != "decode":
        specs["patch_embeddings"] = jax.ShapeDtypeStruct(
            (b, cfg.num_frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "audio":
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.num_frontend_tokens, cfg.d_model), jnp.bfloat16)
    return specs


def _lm_cache_specs(cfg: ModelConfig, shape: ShapeConfig) -> Params:
    return jax.eval_shape(
        lambda: tf.init_cache(cfg, shape.global_batch, shape.seq_len))


def _encdec_cache_specs(cfg: ModelConfig, shape: ShapeConfig) -> Params:
    b, s = shape.global_batch, shape.seq_len
    spec_s = ed.dec_spec(cfg)
    nd = cfg.encoder_layers or cfg.num_layers
    kh, hd = spec_s.num_kv_heads, spec_s.head_dim
    bf16 = jnp.bfloat16
    return {
        "self": {"k": jax.ShapeDtypeStruct((cfg.num_layers, b, s, kh, hd),
                                           bf16),
                 "v": jax.ShapeDtypeStruct((cfg.num_layers, b, s, kh, hd),
                                           bf16)},
        "cross": {"k": jax.ShapeDtypeStruct(
                      (cfg.num_layers, b, cfg.num_frontend_tokens, kh, hd),
                      bf16),
                  "v": jax.ShapeDtypeStruct(
                      (cfg.num_layers, b, cfg.num_frontend_tokens, kh, hd),
                      bf16)},
    }


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "audio":
        return Model(
            cfg=cfg,
            init=functools.partial(ed.init_encdec, cfg=cfg),
            loss=lambda params, batch, **kw: ed.encdec_loss(params, cfg,
                                                            batch, **kw),
            init_cache=lambda params, frames, batch, max_seq: (
                ed.init_encdec_cache(params, cfg, frames, batch, max_seq)),
            decode_step=lambda params, tokens, cache, pos: (
                ed.encdec_decode_step(params, cfg, tokens, cache, pos)),
            input_specs=functools.partial(_lm_input_specs, cfg),
            cache_specs=functools.partial(_encdec_cache_specs, cfg),
        )
    return Model(
        cfg=cfg,
        init=functools.partial(tf.init_lm, cfg=cfg),
        loss=lambda params, batch, **kw: tf.lm_loss(params, cfg, batch, **kw),
        init_cache=lambda params, batch, max_seq: tf.init_cache(cfg, batch,
                                                                max_seq),
        decode_step=lambda params, tokens, cache, pos: (
            tf.decode_step(params, cfg, tokens, cache, pos)),
        input_specs=functools.partial(_lm_input_specs, cfg),
        cache_specs=functools.partial(_lm_cache_specs, cfg),
    )
