"""Attention: GQA/MHA with RoPE, qk-norm, sliding-window, chunked prefill and
single-token decode with a KV cache.

Prefill uses a two-level chunked online-softmax (lax.map over query chunks,
lax.scan over KV chunks) so a 32k context never materializes an (S, S) score
matrix.  Sliding-window layers slice only ``window + q_chunk`` keys per query
chunk (true FLOP reduction); full-causal layers mask (XLA computes the full
rectangle — the Pallas kernel in ``repro.kernels.flash_attention`` skips
non-causal blocks on real TPUs; see EXPERIMENTS.md §Roofline for the
accounting).

Decode attends one query token against the whole cache in a single einsum;
with the cache sequence-sharded over the ``model`` mesh axis the softmax
reduction lowers to the flash-decoding-style cross-device combine
automatically (XLA SPMD inserts the all-reduce over partial stats).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L

Array = jax.Array
Params = Any

__all__ = ["AttnSpec", "init_attention", "attn_forward", "init_kv_cache",
           "attn_decode", "chunked_attention", "precompute_cross_kv",
           "cross_attn_decode"]

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    use_rope: bool = True
    rope_theta: float = 10000.0
    causal: bool = True
    window: Optional[int] = None        # sliding-window width in tokens
    q_chunk: int = 256
    kv_chunk: int = 512
    norm_eps: float = 1e-6
    compute_dtype: Any = jnp.bfloat16

    @property
    def q_groups(self) -> int:
        assert self.num_heads % self.num_kv_heads == 0
        return self.num_heads // self.num_kv_heads


def init_attention(key, spec: AttnSpec) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, hd = spec.d_model, spec.head_dim
    p = {
        "wq": L.init_dense(kq, d, spec.num_heads * hd),
        "wk": L.init_dense(kk, d, spec.num_kv_heads * hd),
        "wv": L.init_dense(kv, d, spec.num_kv_heads * hd),
        "wo": L.init_dense(ko, spec.num_heads * hd, d),
    }
    if spec.qk_norm:
        p["q_norm"] = L.init_rmsnorm(hd)
        p["k_norm"] = L.init_rmsnorm(hd)
    return p


def _project_qkv(p: Params, spec: AttnSpec, x: Array,
                 positions: Array | None):
    """Returns q (B,S,KH,G,Dh), k (B,S,KH,Dh), v (B,S,KH,Dh)."""
    b, s, _ = x.shape
    cd = spec.compute_dtype
    q = L.dense(p["wq"], x, cd).reshape(b, s, spec.num_heads, spec.head_dim)
    k = L.dense(p["wk"], x, cd).reshape(b, s, spec.num_kv_heads, spec.head_dim)
    v = L.dense(p["wv"], x, cd).reshape(b, s, spec.num_kv_heads, spec.head_dim)
    if spec.qk_norm:
        q = L.rmsnorm(p["q_norm"], q, spec.norm_eps)
        k = L.rmsnorm(p["k_norm"], k, spec.norm_eps)
    if spec.use_rope:
        if positions is None:
            positions = jnp.arange(s)[None, :]
        cos, sin = L.rope_freqs(spec.head_dim, spec.rope_theta, positions)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
    q = q.reshape(b, s, spec.num_kv_heads, spec.q_groups, spec.head_dim)
    return q, k, v


def _chunk_attend(q_blk: Array, k_blk: Array, v_blk: Array, mask: Array,
                  m_prev: Array, l_prev: Array, o_prev: Array, scale: float):
    """One online-softmax update.

    q_blk: (B,Tq,KH,G,Dh)  k_blk/v_blk: (B,Tk,KH,Dh)
    mask:  (Tq,Tk) True = attend
    state: m/l (B,KH,G,Tq), o (B,Tq,KH,G,Dh); fp32.
    """
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk).astype(jnp.float32)
    s = s * scale + jnp.where(mask, 0.0, NEG_INF)[None, None, None]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    # Guard fully-masked rows: exp(NEG_INF - NEG_INF) would be exp(0)=1.
    safe_m = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(s - safe_m[..., None])
    p = jnp.where(mask[None, None, None], p, 0.0)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0,
                      jnp.exp(m_prev - safe_m))
    l_new = alpha * l_prev + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bhgqk,bkhd->bqhgd",
                    p.astype(v_blk.dtype), v_blk).astype(jnp.float32)
    o_new = alpha.transpose(0, 3, 1, 2)[..., None] * o_prev + pv
    return m_new, l_new, o_new


def chunked_attention(q: Array, k: Array, v: Array, spec: AttnSpec,
                      q_offset: int = 0) -> Array:
    """Causal / sliding-window attention over (possibly long) sequences.

    q: (B,Sq,KH,G,Dh), k/v: (B,Sk,KH,Dh).  Returns (B,Sq,KH*G,Dh).
    ``q_offset``: absolute position of q[0] within the kv sequence (used by
    cross-shaped prefill; 0 for self-attention where Sq == Sk).
    """
    b, sq, kh, g, dh = q.shape
    sk = k.shape[1]
    scale = 1.0 / (dh ** 0.5)
    qc = min(spec.q_chunk, sq)
    kc = min(spec.kv_chunk, sk)
    # Pad to chunk multiples.
    pad_q = (-sq) % qc
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    nq = q.shape[1] // qc

    window = spec.window

    def per_q_chunk(qi):
        q_blk = jax.lax.dynamic_slice_in_dim(q, qi * qc, qc, axis=1)
        q_pos = q_offset + qi * qc + jnp.arange(qc)
        m0 = jnp.full((b, kh, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, g, qc), jnp.float32)
        o0 = jnp.zeros((b, qc, kh, g, dh), jnp.float32)

        if window is not None:
            # Only the last (window + qc) keys can be visible to this chunk.
            # Rematerialized: the VJP recomputes scores instead of saving the
            # (qc, span) probability block per chunk.
            span = min(window + qc, sk)

            @functools.partial(jax.checkpoint, prevent_cse=False)
            def windowed(q_blk, qi):
                start = jnp.clip(q_offset + qi * qc + qc - span, 0, sk - span)
                k_blk = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
                v_blk = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
                k_pos = start + jnp.arange(span)
                q_pos_in = q_offset + qi * qc + jnp.arange(qc)
                mask = (k_pos[None, :] <= q_pos_in[:, None]) & \
                       (k_pos[None, :] > q_pos_in[:, None] - window)
                return _chunk_attend(q_blk, k_blk, v_blk, mask, m0, l0, o0,
                                     scale)

            m, l, o = windowed(q_blk, qi)
        else:
            nk = -(-sk // kc)
            pad_k = nk * kc - sk
            k_pad = (jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
                     if pad_k else k)
            v_pad = (jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
                     if pad_k else v)

            # Rematerialized per KV block: k_pad/v_pad are closure constants
            # (saved once), so the scan VJP keeps only the small (m, l, o)
            # carries and recomputes each score block — flash-attention-style
            # memory in pure XLA.
            @functools.partial(jax.checkpoint, prevent_cse=False)
            def kv_body(carry, ki):
                m, l, o = carry
                k_blk = jax.lax.dynamic_slice_in_dim(k_pad, ki * kc, kc,
                                                     axis=1)
                v_blk = jax.lax.dynamic_slice_in_dim(v_pad, ki * kc, kc,
                                                     axis=1)
                k_pos = ki * kc + jnp.arange(kc)
                valid = k_pos[None, :] < sk
                if spec.causal:
                    mask = (k_pos[None, :] <= q_pos[:, None]) & valid
                else:
                    mask = jnp.broadcast_to(valid, (qc, kc))
                m, l, o = _chunk_attend(q_blk, k_blk, v_blk, mask, m, l, o,
                                        scale)
                return (m, l, o), None

            (m, l, o), _ = jax.lax.scan(kv_body, (m0, l0, o0),
                                        jnp.arange(nk))
        l_t = jnp.maximum(l.transpose(0, 3, 1, 2)[..., None], 1e-20)
        return (o / l_t).astype(spec.compute_dtype)   # (B,qc,KH,G,Dh)

    out = jax.lax.map(per_q_chunk, jnp.arange(nq))    # (nq,B,qc,KH,G,Dh)
    out = jnp.moveaxis(out, 0, 1).reshape(b, nq * qc, kh, g, dh)
    out = out[:, :sq]
    return out.reshape(b, sq, kh * g, dh)


def attn_forward(p: Params, spec: AttnSpec, x: Array,
                 positions: Array | None = None,
                 context: Array | None = None) -> Array:
    """Self-attention (context=None) or cross-attention (context=(B,Sc,D))."""
    b, s, _ = x.shape
    cd = spec.compute_dtype
    if context is None:
        q, k, v = _project_qkv(p, spec, x, positions)
    else:
        sc = context.shape[1]
        q = L.dense(p["wq"], x, cd).reshape(b, s, spec.num_heads,
                                            spec.head_dim)
        k = L.dense(p["wk"], context, cd).reshape(b, sc, spec.num_kv_heads,
                                                  spec.head_dim)
        v = L.dense(p["wv"], context, cd).reshape(b, sc, spec.num_kv_heads,
                                                  spec.head_dim)
        if spec.qk_norm:
            q = L.rmsnorm(p["q_norm"], q, spec.norm_eps)
            k = L.rmsnorm(p["k_norm"], k, spec.norm_eps)
        q = q.reshape(b, s, spec.num_kv_heads, spec.q_groups, spec.head_dim)
    out = chunked_attention(q, k, v, spec)
    out = out.reshape(b, s, spec.num_heads * spec.head_dim)
    return L.dense(p["wo"], out, cd)


# ---------------------------------------------------------------- decode

def init_kv_cache(spec: AttnSpec, batch: int, max_seq: int,
                  dtype=None) -> Params:
    dtype = spec.compute_dtype if dtype is None else dtype
    shape = (batch, max_seq, spec.num_kv_heads, spec.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attn_decode(p: Params, spec: AttnSpec, x: Array, cache: Params,
                pos: Array, ring: bool = False) -> tuple[Array, Params]:
    """One-token decode. x: (B, 1, D); pos: scalar int32 (current length)
    or a per-row (B,) vector — ragged continuous batching (serving engine)
    decodes slots at different sequence positions in one call.

    Linear mode writes the new K/V at ``pos`` and attends to ``cache[:pos+1]``
    via mask.  Ring mode (sliding-window layers) treats the cache as a ring
    buffer of length L: slot ``pos % L`` is overwritten and slot ``ri`` holds
    absolute position ``pos − ((pos − ri) mod L)``.
    """
    b = x.shape[0]
    cd = spec.compute_dtype
    pos_vec = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    positions = pos_vec[:, None]
    q, k_new, v_new = _project_qkv(p, spec, x, positions)
    s_max = cache["k"].shape[1]
    write_pos = jnp.remainder(pos_vec, s_max) if ring else pos_vec
    upd = jax.vmap(
        lambda c, u, s: jax.lax.dynamic_update_slice_in_dim(c, u, s, axis=0))
    cache_k = upd(cache["k"], k_new.astype(cache["k"].dtype), write_pos)
    cache_v = upd(cache["v"], v_new.astype(cache["v"].dtype), write_pos)
    kpos = jnp.arange(s_max)
    pv = pos_vec[:, None]
    if ring:
        abs_pos = pv - jnp.remainder(pv - kpos[None, :], s_max)   # (B, S)
        mask = abs_pos >= 0
        if spec.window is not None:
            mask &= abs_pos > pv - spec.window
    else:
        mask = kpos[None, :] <= pv
        if spec.window is not None:
            mask &= kpos[None, :] > pv - spec.window
    scale = 1.0 / (spec.head_dim ** 0.5)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q,
                        cache_k.astype(cd)).astype(jnp.float32) * scale
    scores = jnp.where(mask[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(cd)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, cache_v.astype(cd))
    out = out.reshape(b, 1, spec.num_heads * spec.head_dim)
    y = L.dense(p["wo"], out, cd)
    return y, {"k": cache_k, "v": cache_v}


def precompute_cross_kv(p: Params, spec: AttnSpec, context: Array) -> Params:
    """Project the encoder output once into a static cross-attention cache."""
    b, sc, _ = context.shape
    cd = spec.compute_dtype
    k = L.dense(p["wk"], context, cd).reshape(b, sc, spec.num_kv_heads,
                                              spec.head_dim)
    v = L.dense(p["wv"], context, cd).reshape(b, sc, spec.num_kv_heads,
                                              spec.head_dim)
    return {"k": k, "v": v}


def cross_attn_decode(p: Params, spec: AttnSpec, x: Array,
                      context_cache: Params) -> Array:
    """One-token cross-attention against a precomputed encoder KV cache."""
    b = x.shape[0]
    cd = spec.compute_dtype
    kc = context_cache["k"].astype(cd)
    vc = context_cache["v"].astype(cd)
    q = L.dense(p["wq"], x, cd).reshape(b, 1, spec.num_kv_heads,
                                        spec.q_groups, spec.head_dim)
    scale = 1.0 / (spec.head_dim ** 0.5)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, kc).astype(jnp.float32) * scale
    pr = jax.nn.softmax(s, axis=-1).astype(cd)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", pr, vc)
    return L.dense(p["wo"], o.reshape(b, 1, -1), cd)
