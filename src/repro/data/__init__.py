from repro.data.partitioner import ClientPartition, dirichlet_partition
from repro.data.synthetic import (ImageDataset, gaussian_image_dataset,
                                  lm_corpus, class_labels_for_lm)
from repro.data.pipeline import ClientLoader, make_client_loaders, lm_batches
