"""Per-client data pipeline: shuffled epoch iterators, batching, LM chunking.

Host-side numpy (the FL control plane), emitting device-ready dict batches.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.data.partitioner import ClientPartition
from repro.data.synthetic import ImageDataset

__all__ = ["ClientLoader", "make_client_loaders", "lm_batches"]


@dataclasses.dataclass
class ClientLoader:
    x: np.ndarray
    y: np.ndarray
    batch_size: int
    seed: int
    _epoch: int = 0

    @property
    def epochs_drawn(self) -> int:
        """Position of this client's shuffle-RNG stream: how many epochs
        have been drawn.  Epoch ``k`` shuffles with ``default_rng(seed + k)``
        — the stream is a counter, so a resumed run that :meth:`seek`-s back
        to a checkpointed position replays the exact same batch order."""
        return self._epoch

    def seek(self, epochs_drawn: int) -> None:
        """Reposition the shuffle stream (sweep resume restores cursors
        captured by :attr:`epochs_drawn` at the checkpointed round)."""
        self._epoch = int(epochs_drawn)

    def num_batches(self) -> int:
        if not len(self.y):      # empty shard: epoch() yields nothing
            return 0
        return max(1, len(self.y) // self.batch_size)

    def epoch(self) -> Iterator[dict]:
        if not len(self.y):      # empty shard: no local session this client
            return
        rng = np.random.default_rng(self.seed + self._epoch)
        self._epoch += 1
        perm = rng.permutation(len(self.y))
        nb = self.num_batches()
        for i in range(nb):
            idx = perm[i * self.batch_size:(i + 1) * self.batch_size]
            if len(idx) < self.batch_size:
                # Cyclic wrap-around pad: every emitted batch has exactly
                # batch_size rows even when the client's whole shard is
                # smaller (large-N Dirichlet tails) — the stacked executors
                # require rectangular per-step batches.
                pad = np.resize(perm, self.batch_size - len(idx))
                idx = np.concatenate([idx, pad])
            yield {"x": self.x[idx], "y": self.y[idx]}

    def one_batch(self) -> dict:
        if not len(self.y):
            raise ValueError("client shard is empty — no batch to draw")
        return next(self.epoch())


def make_client_loaders(ds: ImageDataset, part: ClientPartition,
                        batch_size: int, seed: int = 0) -> list[ClientLoader]:
    return [ClientLoader(ds.x[ix], ds.y[ix], batch_size, seed + 1000 * i)
            for i, ix in enumerate(part.indices)]


def lm_batches(tokens: np.ndarray, batch: int, seq_len: int, seed: int = 0
               ) -> Iterator[dict]:
    """Infinite iterator of (tokens, labels) LM batches."""
    rng = np.random.default_rng(seed)
    n = len(tokens) - seq_len - 1
    while True:
        starts = rng.integers(0, n, size=batch)
        xs = np.stack([tokens[s:s + seq_len] for s in starts])
        ys = np.stack([tokens[s + 1:s + seq_len + 1] for s in starts])
        yield {"tokens": xs.astype(np.int32), "labels": ys.astype(np.int32)}
