"""Synthetic datasets standing in for CIFAR-10 / FMNIST (offline container)
and a synthetic LM corpus for the assigned-architecture smoke/e2e runs.

``gaussian_image_dataset`` builds a C-class mixture of anisotropic Gaussians
in a flattened "image" space with controllable class separation.  A linear
probe cannot fully solve it (inputs pass through a random nonlinear warp), so
learning curves behave qualitatively like small-vision tasks: more/better
data → higher accuracy, biased shards → biased local models.  This is what
the paper's accuracy experiments need (relative orderings, not absolute
CIFAR numbers) — see DESIGN.md §1 scoping.

``lm_corpus`` generates a Zipf-distributed token stream with a planted
bigram structure (so next-token CE is learnable) used by train_4k e2e runs.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["ImageDataset", "gaussian_image_dataset", "lm_corpus",
           "class_labels_for_lm"]


@dataclasses.dataclass
class ImageDataset:
    x: np.ndarray           # (N, D) float32
    y: np.ndarray           # (N,) int64
    num_classes: int

    def split(self, frac: float, rng: np.random.Generator):
        n = len(self.y)
        perm = rng.permutation(n)
        k = int(n * frac)
        tr, te = perm[k:], perm[:k]
        return (ImageDataset(self.x[tr], self.y[tr], self.num_classes),
                ImageDataset(self.x[te], self.y[te], self.num_classes))


def gaussian_image_dataset(num_samples: int = 20_000, num_classes: int = 10,
                           dim: int = 64, separation: float = 0.7,
                           noise: float = 1.5,
                           seed: int = 0) -> ImageDataset:
    rng = np.random.default_rng(seed)
    means = rng.normal(size=(num_classes, dim)) * separation
    # shared random nonlinear warp makes the task non-linearly-separable
    w1 = rng.normal(size=(dim, dim)) / np.sqrt(dim)
    y = rng.integers(0, num_classes, size=num_samples)
    x = means[y] + rng.normal(size=(num_samples, dim)) * noise
    x = np.tanh(x @ w1) + 0.1 * x
    return ImageDataset(x.astype(np.float32), y.astype(np.int64),
                        num_classes)


def lm_corpus(num_tokens: int = 1_000_000, vocab: int = 256,
              seed: int = 0) -> np.ndarray:
    """Zipf unigrams + planted deterministic bigram transitions."""
    rng = np.random.default_rng(seed)
    probs = 1.0 / np.arange(1, vocab + 1) ** 1.1
    probs /= probs.sum()
    base = rng.choice(vocab, size=num_tokens, p=probs)
    succ = rng.permutation(vocab)          # planted bigram map
    out = base.copy()
    follow = rng.random(num_tokens) < 0.5  # half the stream is predictable
    out[1:][follow[1:]] = succ[out[:-1][follow[1:]]]
    return out.astype(np.int32)


def class_labels_for_lm(tokens: np.ndarray, num_classes: int,
                        seq_len: int) -> np.ndarray:
    """Assign a pseudo-class to each length-``seq_len`` document (dominant
    token bucket) so the Dirichlet partitioner applies to LM data too."""
    n_docs = len(tokens) // seq_len
    docs = tokens[:n_docs * seq_len].reshape(n_docs, seq_len)
    return (docs.mean(axis=1) * num_classes /
            max(tokens.max(), 1)).astype(np.int64) % num_classes
