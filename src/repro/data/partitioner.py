"""Dirichlet non-IID partitioner (Sec. VI-A; Hsu et al. 2019 [6]).

Splits a labelled dataset across N clients by drawing, for each client, a
class-mixture ``q_i ~ Dir(α·1_C)`` and sampling (without replacement) from
the class pools accordingly.  ``α → ∞`` recovers IID; ``α = 0.1`` is the
paper's "extreme non-IID" setting.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["ClientPartition", "dirichlet_partition"]


@dataclasses.dataclass
class ClientPartition:
    indices: list[np.ndarray]          # per-client sample indices
    dsi: np.ndarray                    # (N, C) data-state information
    data_sizes: np.ndarray             # (N,)
    alpha: float

    @property
    def num_clients(self) -> int:
        return len(self.indices)


def dirichlet_partition(labels: np.ndarray, num_clients: int, alpha: float,
                        rng: np.random.Generator,
                        min_per_client: int = 8) -> ClientPartition:
    labels = np.asarray(labels)
    classes = np.unique(labels)
    c = len(classes)
    pools = {cl: rng.permutation(np.where(labels == cl)[0]).tolist()
             for cl in classes}
    total = len(labels)
    base = total // num_clients

    # Target per-client class mixtures.
    mix = rng.dirichlet(np.full(c, alpha), size=num_clients)
    # Target sample counts per (client, class), capped by pool sizes.
    want = np.floor(mix * base).astype(int)
    want = np.maximum(want, 0)

    indices: list[list[int]] = [[] for _ in range(num_clients)]
    for j, cl in enumerate(classes):
        pool = pools[cl]
        # proportional allocation of this class's pool
        w = want[:, j].astype(float)
        if w.sum() == 0:
            continue
        alloc = np.floor(w / w.sum() * min(len(pool), int(w.sum()))).astype(int)
        pos = 0
        for i in range(num_clients):
            take = min(alloc[i], len(pool) - pos)
            indices[i].extend(pool[pos:pos + take])
            pos += take

    # Ensure a minimum shard size (paper's PUEs always hold data).
    leftovers = [idx for pool in pools.values() for idx in pool]
    used = set(i for sub in indices for i in sub)
    leftovers = [i for i in leftovers if i not in used]
    rng.shuffle(leftovers)
    for i in range(num_clients):
        while len(indices[i]) < min_per_client and leftovers:
            indices[i].append(leftovers.pop())

    idx_arrays = [np.asarray(sorted(ix), np.int64) for ix in indices]
    dsi = np.zeros((num_clients, c), np.float32)
    for i, ix in enumerate(idx_arrays):
        if len(ix):
            cnt = np.bincount(
                np.searchsorted(classes, labels[ix]), minlength=c)
            dsi[i] = cnt / max(cnt.sum(), 1)
    sizes = np.asarray([len(ix) for ix in idx_arrays], np.float64)
    return ClientPartition(indices=idx_arrays, dsi=dsi, data_sizes=sizes,
                           alpha=alpha)
