"""The paper's evaluation models (Sec. VI-A): FCN, CNN, LSTM, SVM, logistic.

Implemented for the synthetic image dataset (D-dim feature vectors standing
in for CIFAR-10/FMNIST — see DESIGN.md §1): CNN reshapes features to an
8×8 "image", LSTM consumes them as a length-8 sequence.  All expose
``init(key) -> params``, ``loss(params, batch) -> scalar``,
``predict(params, x) -> labels``.

The "lm" task extends the zoo past the paper's models: a small pre-norm
transformer (tied embeddings, causal attention) over token rows from
``data/synthetic.lm_corpus``, trained on next-token cross-entropy.  Its
attention/MLP projections carry LoRA factors, and ``split``/``merge``
expose the frozen-base / trainable-adapter view that the FL executors hop
instead of the full model (``repro.fl.adapters``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array
Params = Any

__all__ = ["TaskModel", "build_task_model", "TASK_MODELS",
           "LM_VOCAB", "LM_WIDTH", "LM_FF", "LM_LAYERS", "LM_HEADS",
           "LM_RANK"]

# The small-LM config: 2-layer/64-wide tied-embedding transformer with
# rank-2 LoRA adapters — sized so the adapter-int8 hop payload undercuts
# the full-f32 model by well over the 50x budget gate.
LM_VOCAB = 128
LM_WIDTH = 64
LM_FF = 128
LM_LAYERS = 2
LM_HEADS = 2
LM_RANK = 2


@dataclasses.dataclass(frozen=True)
class TaskModel:
    name: str
    init: Callable[[Array], Params]
    logits: Callable[[Params, Array], Array]
    loss: Callable[[Params, dict], Array]
    # Frozen-base / trainable-adapter view (repro.fl.adapters): ``split``
    # maps params -> (base, adapter), ``merge`` inverts it.  ``None`` means
    # full-params — the view degenerates to the identity.
    split: Callable[[Params], tuple[Params, Params]] | None = None
    merge: Callable[[Params, Params], Params] | None = None
    # Task-specific accuracy (next-token accuracy for "lm"); ``None`` means
    # argmax-class accuracy from ``logits``.
    accuracy_fn: Callable[[Params, Array, Array], Array] | None = None

    def predict(self, params: Params, x: Array) -> Array:
        return jnp.argmax(self.logits(params, x), axis=-1)

    def accuracy(self, params: Params, x: Array, y: Array) -> Array:
        if self.accuracy_fn is not None:
            return self.accuracy_fn(params, x, y)
        return jnp.mean((self.predict(params, x) == y).astype(jnp.float32))


def _xent(logits: Array, y: Array) -> Array:
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def _hinge(logits: Array, y: Array) -> Array:
    """Multiclass (Crammer–Singer) hinge — the SVM task."""
    c = logits.shape[-1]
    gold = jnp.take_along_axis(logits, y[:, None], axis=-1)
    margins = logits - gold + 1.0
    margins = margins * (1.0 - jax.nn.one_hot(y, c))
    return jnp.mean(jnp.max(margins, axis=-1))


def _dense_stack(key, dims):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": jax.random.normal(k, (a, b), jnp.float32) / jnp.sqrt(a),
             "b": jnp.zeros((b,), jnp.float32)}
            for k, a, b in zip(ks, dims[:-1], dims[1:])]


def _mlp_apply(layers, x, act=jax.nn.relu):
    for i, p in enumerate(layers):
        x = x @ p["w"] + p["b"]
        if i < len(layers) - 1:
            x = act(x)
    return x


def build_task_model(name: str, dim: int = 64, num_classes: int = 10,
                     hidden: int = 128) -> TaskModel:
    if name == "logistic":
        def init(key):
            return _dense_stack(key, [dim, num_classes])
        def logits(p, x):
            return _mlp_apply(p, x)
        return TaskModel(name, init, logits,
                         lambda p, b: _xent(logits(p, b["x"]), b["y"]))

    if name == "svm":
        def init(key):
            return _dense_stack(key, [dim, num_classes])
        def logits(p, x):
            return _mlp_apply(p, x)
        return TaskModel(name, init, logits,
                         lambda p, b: _hinge(logits(p, b["x"]), b["y"])
                         + 1e-4 * sum(jnp.sum(q["w"] ** 2) for q in p))

    if name == "fcn":
        def init(key):
            return _dense_stack(key, [dim, hidden, hidden, num_classes])
        def logits(p, x):
            return _mlp_apply(p, x)
        return TaskModel(name, init, logits,
                         lambda p, b: _xent(logits(p, b["x"]), b["y"]))

    if name == "cnn":
        side = int(dim ** 0.5)
        assert side * side == dim, "cnn task needs square feature dim"

        def init(key):
            k1, k2, k3, k4 = jax.random.split(key, 4)
            return {
                "c1": jax.random.normal(k1, (3, 3, 1, 16)) * 0.2,
                "c2": jax.random.normal(k2, (3, 3, 16, 32)) * 0.1,
                "head": _dense_stack(k3, [32 * (side // 4) ** 2, hidden,
                                          num_classes]),
            }

        def logits(p, x):
            b = x.shape[0]
            img = x.reshape(b, side, side, 1)
            h = jax.lax.conv_general_dilated(
                img, p["c1"], (1, 1), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            h = jax.nn.relu(h)
            h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                                      (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
            h = jax.lax.conv_general_dilated(
                h, p["c2"], (1, 1), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            h = jax.nn.relu(h)
            h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                                      (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
            return _mlp_apply(p["head"], h.reshape(b, -1))

        return TaskModel(name, init, logits,
                         lambda p, b: _xent(logits(p, b["x"]), b["y"]))

    if name == "lstm":
        steps = 8
        feat = dim // steps

        def init(key):
            k1, k2 = jax.random.split(key)
            h = hidden
            return {
                "wx": jax.random.normal(k1, (feat, 4 * h)) / jnp.sqrt(feat),
                "wh": jax.random.normal(k2, (h, 4 * h)) / jnp.sqrt(h),
                "b": jnp.zeros((4 * h,)),
                "head": _dense_stack(jax.random.fold_in(key, 7),
                                     [h, num_classes]),
            }

        def logits(p, x):
            b = x.shape[0]
            seq = x.reshape(b, steps, feat)
            h = hidden

            def cell(carry, xt):
                hprev, cprev = carry
                z = xt @ p["wx"] + hprev @ p["wh"] + p["b"]
                i, f, g, o = jnp.split(z, 4, axis=-1)
                c = jax.nn.sigmoid(f + 1.0) * cprev \
                    + jax.nn.sigmoid(i) * jnp.tanh(g)
                hn = jax.nn.sigmoid(o) * jnp.tanh(c)
                return (hn, c), None

            (hT, _), _ = jax.lax.scan(cell,
                                      (jnp.zeros((b, h)), jnp.zeros((b, h))),
                                      jnp.moveaxis(seq, 1, 0))
            return _mlp_apply(p["head"], hT)

        return TaskModel(name, init, logits,
                         lambda p, b: _xent(logits(p, b["x"]), b["y"]))

    if name == "lm":
        v, d, ff = LM_VOCAB, LM_WIDTH, LM_FF
        nl, nh, r = LM_LAYERS, LM_HEADS, LM_RANK
        hd = d // nh
        shapes = (("wq", (d, d)), ("wk", (d, d)), ("wv", (d, d)),
                  ("wo", (d, d)), ("w1", (d, ff)), ("w2", (ff, d)))

        def init(key):
            ke, kb, ka = jax.random.split(key, 3)
            base = {"embed": jax.random.normal(ke, (v, d)) * 0.02,
                    "layers": []}
            lora = []
            for i in range(nl):
                kbs = jax.random.split(jax.random.fold_in(kb, i),
                                       len(shapes))
                base["layers"].append(
                    {n: jax.random.normal(k, s) / jnp.sqrt(s[0])
                     for k, (n, s) in zip(kbs, shapes)})
                kas = jax.random.split(jax.random.fold_in(ka, i),
                                       len(shapes))
                # b zero-init: the adapter starts as an exact zero delta
                lora.append(
                    {n: {"a": jax.random.normal(k, (s[0], r))
                         / jnp.sqrt(s[0]),
                         "b": jnp.zeros((r, s[1]))}
                     for k, (n, s) in zip(kas, shapes)})
            return {"base": base, "lora": lora}

        def _rms(h):
            return h * jax.lax.rsqrt(
                jnp.mean(h * h, axis=-1, keepdims=True) + 1e-6)

        def _proj(h, bl, lo, n):
            return h @ bl[n] + (h @ lo[n]["a"]) @ lo[n]["b"]

        def logits(p, x):
            base, lora = p["base"], p["lora"]
            tok = x.astype(jnp.int32)
            b, s = tok.shape
            h = base["embed"][tok]                               # (B, S, D)
            mask = jnp.tril(jnp.ones((s, s), bool))
            for bl, lo in zip(base["layers"], lora):
                hn = _rms(h)
                q = _proj(hn, bl, lo, "wq").reshape(b, s, nh, hd)
                k = _proj(hn, bl, lo, "wk").reshape(b, s, nh, hd)
                vv = _proj(hn, bl, lo, "wv").reshape(b, s, nh, hd)
                att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(hd)
                att = jax.nn.softmax(
                    jnp.where(mask[None, None], att, -jnp.inf), axis=-1)
                o = jnp.einsum("bhqk,bkhd->bqhd", att, vv).reshape(b, s, d)
                h = h + _proj(o, bl, lo, "wo")
                h = h + _proj(jax.nn.relu(_proj(_rms(h), bl, lo, "w1")),
                              bl, lo, "w2")
            return _rms(h) @ base["embed"].T                     # tied head

        def loss(p, batch):
            tok = batch["x"].astype(jnp.int32)      # next-token CE; no "y"
            lg = logits(p, tok[:, :-1])
            tgt = tok[:, 1:]
            logz = jax.nn.logsumexp(lg, axis=-1)
            gold = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
            return jnp.mean(logz - gold)

        def accuracy_fn(p, x, y):
            tok = x.astype(jnp.int32)
            pred = jnp.argmax(logits(p, tok[:, :-1]), axis=-1)
            return jnp.mean((pred == tok[:, 1:]).astype(jnp.float32))

        return TaskModel(name, init, logits, loss,
                         split=lambda p: (p["base"], p["lora"]),
                         merge=lambda base, lora: {"base": base,
                                                   "lora": lora},
                         accuracy_fn=accuracy_fn)

    raise ValueError(f"unknown task model {name!r}")


TASK_MODELS = ("logistic", "svm", "fcn", "lstm", "cnn", "lm")
