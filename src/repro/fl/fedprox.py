"""FedProx local solver (Li et al. [9] — the weight-regularization family
the paper positions FedDif as complementary to, Sec. II-1).

Local objective:  F_i(w) + (μ/2)·‖w − w_global‖² — the proximal term tames
client drift under non-IID data.  Usable standalone (strategy="fedprox")
and composable with FedDif (strategy="feddif_prox"): the paper argues
weight regularization improves FL *internally* while diffusion improves it
*externally*, so the two should stack.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp

from repro.train import optimizer as opt_lib

Params = Any

__all__ = ["make_prox_local_update"]


@functools.lru_cache(maxsize=32)
def _jitted_prox_step(loss_fn: Callable, momentum: float, mu: float,
                      clip: float | None):
    opt = opt_lib.sgd(momentum=momentum)

    @jax.jit
    def step(params, anchor, mu_state, batch, lr):
        def obj(p):
            prox = sum(jnp.sum((a.astype(jnp.float32)
                                - b.astype(jnp.float32)) ** 2)
                       for a, b in zip(jax.tree.leaves(p),
                                       jax.tree.leaves(anchor)))
            return loss_fn(p, batch) + 0.5 * mu * prox

        loss, grads = jax.value_and_grad(obj)(params)
        if clip is not None:
            grads, _ = opt_lib.clip_by_global_norm(grads, clip)
        updates, new_state = opt.update(grads, {"mu": mu_state}, params, lr)
        return opt_lib.apply_updates(params, updates), new_state["mu"], loss

    return step


def make_prox_local_update(loss_fn: Callable, mu: float = 0.01,
                           momentum: float = 0.9,
                           clip: float | None = 10.0):
    """Returns ``local_update(params, batches, lr, anchor) -> (params, loss)``
    where ``anchor`` is the round's global model (defaults to the incoming
    params — i.e. proximal to the received model, the FedDif-compatible
    variant where the anchor travels with the hop)."""
    step = _jitted_prox_step(loss_fn, momentum, mu, clip)

    def local_update(params: Params, batches: Iterable[dict], lr: float,
                     anchor: Params | None = None):
        anchor = params if anchor is None else anchor
        mu_state = jax.tree.map(
            lambda p: jnp.zeros_like(p, jnp.float32), params)
        total, n = 0.0, 0
        for batch in batches:
            params, mu_state, loss = step(params, anchor, mu_state, batch,
                                          lr)
            total += float(loss)
            n += 1
        return params, total / max(n, 1)

    return local_update
