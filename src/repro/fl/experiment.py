"""High-level experiment harness: dataset → partition → run_federated.

One call reproduces one cell of the paper's figures/tables; the benchmark
scripts sweep it over α (Fig. 3), ε (Fig. 4), γ_min (Fig. 5), ML task
(Fig. 6 / Table I) and strategy (Table II).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.data.partitioner import dirichlet_partition
from repro.data.pipeline import make_client_loaders
from repro.data.synthetic import (ImageDataset, class_labels_for_lm,
                                  gaussian_image_dataset, lm_corpus)
from repro.fl.models import TASK_MODELS, build_task_model
from repro.fl.server import FLConfig, FLResult, run_federated

__all__ = ["ExperimentSpec", "run_experiment", "load_experiment_data",
           "spec_model_bits", "spec_adapter_bits"]


@dataclasses.dataclass
class ExperimentSpec:
    task: str = "fcn"                  # one of repro.fl.models.TASK_MODELS
    alpha: float = 1.0                 # Dirichlet concentration
    num_samples: int = 12_000
    num_classes: int = 10
    dim: int = 64                      # feature dim; seq_len for task="lm"
    test_frac: float = 0.2
    fl: FLConfig = dataclasses.field(default_factory=FLConfig)
    data_seed: int = 0
    adapter_hops: bool = True          # hop the trainable-adapter view when
                                       # the task has one (TaskModel.split);
                                       # full-params tasks are untouched
                                       # (identity view, bit-identical runs)

    def __post_init__(self):
        # Validate at construction — a bad task/dim otherwise surfaces as
        # a shape error deep inside the round loop.
        if self.task not in TASK_MODELS:
            raise ValueError(f"unknown task {self.task!r}; expected one of "
                             f"{TASK_MODELS}")
        if self.task == "cnn":
            side = int(self.dim ** 0.5)
            if side * side != self.dim:
                raise ValueError(f"task='cnn' needs a square feature dim "
                                 f"(got dim={self.dim})")
        if self.task == "lstm" and self.dim % 8 != 0:
            raise ValueError(f"task='lstm' needs dim divisible by 8 "
                             f"(got dim={self.dim})")


def load_experiment_data(spec: ExperimentSpec, with_loaders: bool = True):
    """Dataset → split → Dirichlet partition → loaders for one cell.

    The single definition of the ``data_seed`` RNG consumption order, shared
    by :func:`run_experiment`, the replicate engines and the sweep
    pre-planner — so a cell's DSIs (and therefore its plan-cache keys) are
    identical no matter which engine computes them.

    Returns ``(train, test, part, loaders)``.  ``with_loaders=False`` skips
    loader construction (loaders draw from their own seed, so the partition
    is unaffected) — the sweep pre-planner only needs ``part``.
    """
    rng = np.random.default_rng(spec.data_seed)
    if spec.task == "lm":
        # Token rows: spec.dim is the sequence length, one "sample" is one
        # document; labels are the dominant-token buckets that drive the
        # Dirichlet partition (non-IID unigram shards per client).
        from repro.fl.models import LM_VOCAB
        tokens = lm_corpus(spec.num_samples * spec.dim, vocab=LM_VOCAB,
                           seed=spec.data_seed)
        y = class_labels_for_lm(tokens, spec.num_classes, spec.dim)
        x = np.asarray(tokens[:len(y) * spec.dim]).reshape(len(y), spec.dim)
        ds = ImageDataset(x.astype(np.int32), y, spec.num_classes)
    else:
        ds = gaussian_image_dataset(spec.num_samples, spec.num_classes,
                                    spec.dim, seed=spec.data_seed)
    test, train = ds.split(spec.test_frac, rng)
    part = dirichlet_partition(train.y, spec.fl.num_clients, spec.alpha, rng)
    loaders = (make_client_loaders(train, part, spec.fl.batch_size,
                                   seed=spec.data_seed)
               if with_loaders else None)
    return train, test, part, loaders


def spec_model_bits(spec: ExperimentSpec) -> float:
    """S (Eq. 15) for a cell's task model without materializing weights —
    shapes come from ``jax.eval_shape`` on the model's init."""
    from repro.core.aggregation import model_bits
    model = build_task_model(spec.task, spec.dim, spec.num_classes)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return model_bits(shapes, spec.fl.bits_per_param)


def spec_adapter_bits(spec: ExperimentSpec) -> float:
    """S (Eq. 15) of one *D2D hop* for a cell — the companion of
    :func:`spec_model_bits` (which stays the full-model figure).

    The hop payload is the trainable-adapter view when the task has one and
    ``spec.adapter_hops`` is set, and it crosses the wire int8-packed
    (8 bits/element + one fp32 scale per row-block) when
    ``spec.fl.hop_quant == "int8"``; full-params fp32 cells return exactly
    :func:`spec_model_bits`."""
    from repro.core.aggregation import model_bits
    from repro.fl.adapters import packed_bits
    model = build_task_model(spec.task, spec.dim, spec.num_classes)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if spec.adapter_hops and model.split is not None:
        _, shapes = model.split(shapes)
    if spec.fl.hop_quant == "int8":
        return packed_bits(shapes)
    return model_bits(shapes, spec.fl.bits_per_param)


def run_experiment(spec: ExperimentSpec, plan_cache=None,
                   checkpoint_dir: str | None = None) -> FLResult:
    """Run one cell of a paper figure/table.

    ``plan_cache`` (a :class:`repro.core.diffusion.PlanCache`) is forwarded
    to the FL runtime; combined with ``spec.fl.topology_seed`` it lets the
    sweep orchestrator replay host-side diffusion plans across replicate
    seeds instead of re-running the auction loop per seed.
    ``spec.fl.executor`` selects the data plane (``"host"`` per-slot
    reference loop or ``"fleet"`` client-stacked vmap) — schedules and
    ledger charges are identical either way.

    ``checkpoint_dir`` + ``spec.fl.checkpoint_every > 0`` makes the cell
    durable: a :class:`~repro.fl.resume.RoundCheckpointer` serializes round
    state every R rounds (including the per-client loader shuffle cursors,
    so a resumed run replays the exact same batch order) and resumes from
    the latest readable checkpoint in that directory.
    """
    train, test, part, loaders = load_experiment_data(spec)
    model = build_task_model(spec.task, spec.dim, spec.num_classes)
    # The executors train/hop the view's payload tree: the trainable
    # adapter for split tasks, the full params (identity view — unwrapped
    # model.init/model.loss, bit-identical traces) otherwise.
    from repro.fl.adapters import make_adapter_view
    view = make_adapter_view(model, spec.fl, adapter_hops=spec.adapter_hops)

    checkpointer = None
    if checkpoint_dir is not None and spec.fl.checkpoint_every > 0:
        from repro.fl.resume import RoundCheckpointer

        def _capture():
            return {"loader_epochs": [ld.epochs_drawn for ld in loaders]}

        def _restore(extra):
            for ld, e in zip(loaders, extra["loader_epochs"]):
                ld.seek(int(e))

        checkpointer = RoundCheckpointer(checkpoint_dir,
                                         every=spec.fl.checkpoint_every,
                                         capture_extra=_capture,
                                         restore_extra=_restore)

    def client_epoch(i):
        return lambda: list(loaders[i].epoch())

    batches = [client_epoch(i) for i in range(spec.fl.num_clients)]

    @jax.jit
    def _eval(params):
        full = view.merge_fn(params)
        acc = model.accuracy(full, test.x, test.y)
        loss = model.loss(full, {"x": test.x, "y": test.y})
        return acc, loss

    def eval_fn(params):
        a, l = _eval(params)
        return float(a), float(l)

    value_fn = None
    if spec.fl.uncertainty_weight > 0.0:
        # Learning-value probe: a fixed 32-sample draw from each client's
        # shard (np.resize wraps small shards); the value is the global
        # model's mean predictive entropy on it, normalized to [0, 1] by
        # log of the class count.  High entropy = data the model is still
        # uncertain about = a shard worth routing models toward — the
        # signal the planner fuses into its bids (kernels.bid_value_fuse).
        import jax.numpy as jnp
        probe = np.stack([train.x[np.resize(idx, 32)]
                          for idx in part.indices])

        @jax.jit
        def _values(params):
            full = view.merge_fn(params)

            def one(x):
                lg = model.logits(full, x)
                logp = jax.nn.log_softmax(lg, axis=-1)
                ent = -jnp.sum(jnp.exp(logp) * logp, axis=-1)
                return jnp.mean(ent) / jnp.log(lg.shape[-1])

            return jax.vmap(one)(probe)

        def value_fn(params):
            return np.asarray(_values(params))

    return run_federated(view.init_fn, view.loss_fn, batches, part.dsi,
                         part.data_sizes, eval_fn, spec.fl,
                         plan_cache=plan_cache, checkpointer=checkpointer,
                         base_bits=view.base_bits, value_fn=value_fn)
