"""High-level experiment harness: dataset → partition → run_federated.

One call reproduces one cell of the paper's figures/tables; the benchmark
scripts sweep it over α (Fig. 3), ε (Fig. 4), γ_min (Fig. 5), ML task
(Fig. 6 / Table I) and strategy (Table II).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.data.partitioner import dirichlet_partition
from repro.data.pipeline import make_client_loaders
from repro.data.synthetic import gaussian_image_dataset
from repro.fl.models import build_task_model
from repro.fl.server import FLConfig, FLResult, run_federated

__all__ = ["ExperimentSpec", "run_experiment", "load_experiment_data",
           "spec_model_bits"]


@dataclasses.dataclass
class ExperimentSpec:
    task: str = "fcn"                  # logistic|svm|fcn|lstm|cnn
    alpha: float = 1.0                 # Dirichlet concentration
    num_samples: int = 12_000
    num_classes: int = 10
    dim: int = 64
    test_frac: float = 0.2
    fl: FLConfig = dataclasses.field(default_factory=FLConfig)
    data_seed: int = 0


def load_experiment_data(spec: ExperimentSpec, with_loaders: bool = True):
    """Dataset → split → Dirichlet partition → loaders for one cell.

    The single definition of the ``data_seed`` RNG consumption order, shared
    by :func:`run_experiment`, the replicate engines and the sweep
    pre-planner — so a cell's DSIs (and therefore its plan-cache keys) are
    identical no matter which engine computes them.

    Returns ``(train, test, part, loaders)``.  ``with_loaders=False`` skips
    loader construction (loaders draw from their own seed, so the partition
    is unaffected) — the sweep pre-planner only needs ``part``.
    """
    rng = np.random.default_rng(spec.data_seed)
    ds = gaussian_image_dataset(spec.num_samples, spec.num_classes, spec.dim,
                                seed=spec.data_seed)
    test, train = ds.split(spec.test_frac, rng)
    part = dirichlet_partition(train.y, spec.fl.num_clients, spec.alpha, rng)
    loaders = (make_client_loaders(train, part, spec.fl.batch_size,
                                   seed=spec.data_seed)
               if with_loaders else None)
    return train, test, part, loaders


def spec_model_bits(spec: ExperimentSpec) -> float:
    """S (Eq. 15) for a cell's task model without materializing weights —
    shapes come from ``jax.eval_shape`` on the model's init."""
    from repro.core.aggregation import model_bits
    model = build_task_model(spec.task, spec.dim, spec.num_classes)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return model_bits(shapes, spec.fl.bits_per_param)


def run_experiment(spec: ExperimentSpec, plan_cache=None,
                   checkpoint_dir: str | None = None) -> FLResult:
    """Run one cell of a paper figure/table.

    ``plan_cache`` (a :class:`repro.core.diffusion.PlanCache`) is forwarded
    to the FL runtime; combined with ``spec.fl.topology_seed`` it lets the
    sweep orchestrator replay host-side diffusion plans across replicate
    seeds instead of re-running the auction loop per seed.
    ``spec.fl.executor`` selects the data plane (``"host"`` per-slot
    reference loop or ``"fleet"`` client-stacked vmap) — schedules and
    ledger charges are identical either way.

    ``checkpoint_dir`` + ``spec.fl.checkpoint_every > 0`` makes the cell
    durable: a :class:`~repro.fl.resume.RoundCheckpointer` serializes round
    state every R rounds (including the per-client loader shuffle cursors,
    so a resumed run replays the exact same batch order) and resumes from
    the latest readable checkpoint in that directory.
    """
    train, test, part, loaders = load_experiment_data(spec)
    model = build_task_model(spec.task, spec.dim, spec.num_classes)

    checkpointer = None
    if checkpoint_dir is not None and spec.fl.checkpoint_every > 0:
        from repro.fl.resume import RoundCheckpointer

        def _capture():
            return {"loader_epochs": [ld.epochs_drawn for ld in loaders]}

        def _restore(extra):
            for ld, e in zip(loaders, extra["loader_epochs"]):
                ld.seek(int(e))

        checkpointer = RoundCheckpointer(checkpoint_dir,
                                         every=spec.fl.checkpoint_every,
                                         capture_extra=_capture,
                                         restore_extra=_restore)

    def client_epoch(i):
        return lambda: list(loaders[i].epoch())

    batches = [client_epoch(i) for i in range(spec.fl.num_clients)]

    @jax.jit
    def _eval(params):
        acc = model.accuracy(params, test.x, test.y)
        loss = model.loss(params, {"x": test.x, "y": test.y})
        return acc, loss

    def eval_fn(params):
        a, l = _eval(params)
        return float(a), float(l)

    return run_federated(model.init, model.loss, batches, part.dsi,
                         part.data_sizes, eval_fn, spec.fl,
                         plan_cache=plan_cache, checkpointer=checkpointer)
