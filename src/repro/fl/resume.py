"""Durable round-state checkpointing for ``run_federated`` — the resume seam.

Production FL fleets get preempted; the 6G FL surveys (arXiv 2111.07392,
2310.05269) treat client/server failure and partial progress as the defining
deployment constraints.  :class:`RoundCheckpointer` makes one experiment cell
preemption-proof: every ``FLConfig.checkpoint_every`` communication rounds it
serializes the **full** round state at the round boundary,

* the global model parameters (and, for persistent strategies like gossip /
  tthf, the per-slot state via the executor's ``capture_slots`` /
  ``adopt_slots`` hooks — each executor restores onto its own placement),
* the cumulative Eq.-15 :class:`~repro.channels.resources.ResourceLedger`,
* the accuracy / loss / diffusion-round / IID-distance histories,
* every RNG stream position: the model-seed generator's bit-generator state
  and the per-client data-shuffle cursors
  (:attr:`~repro.data.pipeline.ClientLoader.epochs_drawn`).  The control
  plane (positions / channel / plan draws) and churn streams are keyed
  ``[seed, t, tag]`` per round, so restarting the loop at round ``t``
  reproduces them exactly with no stored position,

through :mod:`repro.train.checkpoint` (atomic npz + metadata-JSON commit
marker).  A run resumed from any boundary is **bit-identical** to an
uninterrupted one: same params, same ledger, same curves — the property the
``tests/test_resume_orchestration.py`` fault-injection harness asserts for
all three executors.

:class:`Preempted` is the harness's in-process kill switch: a
``BaseException`` (like ``KeyboardInterrupt``) so the sweep orchestrator's
per-cell failure isolation — which catches ``Exception`` only — never
swallows a simulated (or real) preemption.
"""
from __future__ import annotations

import os
from typing import Any, Callable

import jax
import numpy as np

from repro.channels.resources import ResourceLedger
from repro.train.checkpoint import (load_metadata, restore_checkpoint,
                                    save_checkpoint, valid_steps)

__all__ = ["RoundCheckpointer", "Preempted", "RoundState"]

# FLConfig fields a checkpoint must agree on to be restorable: anything that
# alters the trajectory.  The cadence (checkpoint_every) is deliberately
# absent — changing it on resume is safe.  The resolved EngineSpec is
# guarded separately (``engine_fingerprint``): async-buffer state is only
# meaningful under the engine knobs that produced it.
_CONFIG_GUARD = ("strategy", "num_clients", "num_models", "rounds",
                 "local_epochs", "lr", "momentum", "batch_size", "epsilon",
                 "gamma_min", "metric", "stc_sparsity", "prox_mu", "seed",
                 "topology_seed", "executor", "planner", "churn_rate",
                 "allow_retraining", "underlay")


class Preempted(BaseException):
    """Simulated preemption raised at a round boundary (fault injection).

    Deliberately not an ``Exception``: cell-level failure isolation in the
    sweep work-queue must let preemptions propagate and kill the sweep, the
    same way SIGTERM would.
    """


class RoundState:
    """What a resumed ``run_federated`` gets back (plain attribute bag)."""

    def __init__(self, step: int, params: Any, slots: Any,
                 ledger: ResourceLedger, meta: dict,
                 buffer_tree: Any = None):
        self.step = step
        self.params = params
        self.slots = slots
        self.ledger = ledger
        self.acc_hist = [float(x) for x in meta["acc_hist"]]
        self.loss_hist = [float(x) for x in meta["loss_hist"]]
        self.dif_hist = [int(x) for x in meta["dif_hist"]]
        self.iid_hist = [float(x) for x in meta["iid_hist"]]
        self.round_wall = [float(x) for x in meta["round_wall"]]
        self.rng_state = meta["rng_state"]
        self.extra = meta.get("extra")
        # Async round plane: extra history curves and the mid-tick pending
        # buffer (stacked contribution pytree + JSON-able entry metadata).
        self.async_hist = meta.get("async_hist")
        self.buffer_meta = meta.get("buffer") or {"count": 0,
                                                  "virtual_s": 0.0,
                                                  "next_seq": 0}
        self.buffer_tree = buffer_tree


class RoundCheckpointer:
    """Serialize/restore ``run_federated`` round state every R rounds.

    Args:
      directory: per-cell-per-seed checkpoint directory.
      every: cadence R in communication rounds (>=1).
      capture_extra / restore_extra: caller-owned data-plane cursors — the
        experiment harness passes the per-client loader shuffle positions
        here, keeping ``run_federated`` agnostic of where batches come from.
      keep: how many round checkpoints to retain (older ones are pruned
        after a successful save; >=2 so a corrupt latest can fall back).
      fail_after_save: fault injection for the kill/resume test harness —
        after the checkpoint for this step is durably on disk, raise
        :class:`Preempted`.  Also a *class* attribute (default ``None``) so
        the fault-injection tests can arm every checkpointer a sweep
        constructs with one monkeypatch.
    """

    fail_after_save: int | None = None

    def __init__(self, directory: str, every: int = 1,
                 capture_extra: Callable[[], Any] | None = None,
                 restore_extra: Callable[[Any], None] | None = None,
                 keep: int = 2, fail_after_save: int | None = None):
        self.directory = directory
        self.every = max(1, int(every))
        self.capture_extra = capture_extra
        self.restore_extra = restore_extra
        self.keep = max(2, int(keep))
        if fail_after_save is not None:
            self.fail_after_save = fail_after_save

    # ------------------------------------------------------------- cadence

    def due(self, step: int, total_rounds: int) -> bool:
        """Save at round boundary ``step`` (= rounds completed)?  The final
        round never checkpoints — the finished result supersedes it."""
        return step < total_rounds and step % self.every == 0

    # ---------------------------------------------------------------- save

    def save(self, step: int, executor, params: Any, slots: Any,
             ledger: ResourceLedger, cfg, *, acc_hist, loss_hist, dif_hist,
             iid_hist, round_wall, rng: np.random.Generator,
             async_hist: dict | None = None, buffer_tree: Any = None,
             buffer_meta: dict | None = None) -> str:
        """Serialize one round boundary.

        ``async_hist`` / ``buffer_tree`` / ``buffer_meta`` are the buffered-
        async engine's additions: the virtual-clock curves and the pending
        contribution buffer (a stacked leading-axis pytree plus per-entry
        arrival/round/slot/weight metadata).  The buffer rides the same
        atomic npz + commit-marker protocol as params, so a kill between
        server ticks resumes with the exact mid-tick buffer state.
        """
        tree = {"params": jax.device_get(params)}
        saved_slots = executor.capture_slots(slots)
        if saved_slots is not None:
            tree["slots"] = saved_slots
        if buffer_tree is not None:
            tree["abuf"] = jax.device_get(buffer_tree)
        meta = {
            "config": {k: getattr(cfg, k) for k in _CONFIG_GUARD},
            "engine": _engine_fingerprint(cfg),
            "ledger": ledger.as_dict(),
            "acc_hist": [float(x) for x in acc_hist],
            "loss_hist": [float(x) for x in loss_hist],
            "dif_hist": [int(x) for x in dif_hist],
            "iid_hist": [float(x) for x in iid_hist],
            "round_wall": [float(x) for x in round_wall],
            "rng_state": _rng_state_jsonable(rng),
            "num_slots": (None if saved_slots is None
                          else executor.num_slots_of(saved_slots)),
            "has_slots": saved_slots is not None,
            "extra": (self.capture_extra()
                      if self.capture_extra is not None else None),
        }
        if async_hist is not None:
            meta["async_hist"] = {k: list(v) for k, v in async_hist.items()}
        if buffer_meta is not None:
            meta["buffer"] = buffer_meta
        path = save_checkpoint(self.directory, step, tree, metadata=meta)
        self._prune(step)
        if self.fail_after_save is not None and step == self.fail_after_save:
            raise Preempted(f"simulated preemption after round-{step} "
                            f"checkpoint in {self.directory!r}")
        return path

    def _prune(self, newest: int) -> None:
        steps = valid_steps(self.directory)
        for s in steps[:-self.keep]:
            for suffix in (".npz", ".json"):
                p = os.path.join(self.directory, f"ckpt_{s:08d}{suffix}")
                if os.path.exists(p):
                    os.remove(p)

    # ------------------------------------------------------------- restore

    def restore(self, executor, params_template: Any, cfg
                ) -> RoundState | None:
        """Latest readable round state, or ``None`` (fresh start).

        Walks checkpoints newest-first, skipping unreadable ones with a
        warning (see :func:`repro.train.checkpoint.restore_latest` for the
        fallback contract).  Raises ``ValueError`` if a readable checkpoint
        was written by an incompatible ``FLConfig``.
        """
        sds = lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype)  # noqa: E731
        import warnings
        for step in reversed(valid_steps(self.directory)):
            try:
                meta = load_metadata(self.directory, step)
            except Exception as e:                  # noqa: BLE001
                warnings.warn(
                    f"round checkpoint {step} metadata unreadable "
                    f"({type(e).__name__}: {e}); falling back",
                    RuntimeWarning, stacklevel=2)
                continue
            self._guard_config(meta, cfg)
            like = {"params": jax.tree.map(sds, params_template)}
            if meta["has_slots"]:
                like["slots"] = executor.slots_like(params_template,
                                                    int(meta["num_slots"]))
            nbuf = int((meta.get("buffer") or {}).get("count", 0))
            if nbuf > 0:
                # Pending async contributions: params-shaped trees stacked
                # on a leading entry axis.
                like["abuf"] = jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct((nbuf,) + x.shape,
                                                   x.dtype),
                    params_template)
            try:
                tree = restore_checkpoint(self.directory, step, like)
            except Exception as e:                  # noqa: BLE001
                warnings.warn(
                    f"round checkpoint {step} arrays unreadable "
                    f"({type(e).__name__}: {e}); falling back",
                    RuntimeWarning, stacklevel=2)
                continue
            slots = (executor.adopt_slots(tree["slots"])
                     if meta["has_slots"] else None)
            ledger = ResourceLedger(**meta["ledger"])
            state = RoundState(step, tree["params"], slots, ledger, meta,
                               buffer_tree=tree.get("abuf"))
            if self.restore_extra is not None and state.extra is not None:
                self.restore_extra(state.extra)
            return state
        return None

    @staticmethod
    def _guard_config(meta: dict, cfg) -> None:
        saved = meta.get("config", {})
        diffs = {k: (saved.get(k), getattr(cfg, k)) for k in _CONFIG_GUARD
                 if k in saved and saved[k] != getattr(cfg, k)}
        if "engine" in meta and meta["engine"] != _engine_fingerprint(cfg):
            diffs["engine"] = (meta["engine"], _engine_fingerprint(cfg))
        if diffs:
            raise ValueError(
                "refusing to resume: checkpoint was written by a different "
                f"config — mismatched fields (saved, current): {diffs}")

    @staticmethod
    def apply_rng_state(rng: np.random.Generator, state: dict) -> None:
        """Reposition the model-seed generator to its checkpointed state."""
        rng.bit_generator.state = _rng_state_from_jsonable(state)


def _engine_fingerprint(cfg) -> str:
    from repro.fl.engine import engine_fingerprint
    return engine_fingerprint(cfg)


def _rng_state_jsonable(rng: np.random.Generator) -> dict:
    # bit_generator.state is a nested dict of ints/str; numpy keeps the
    # 128-bit PCG64 state as Python ints, which JSON carries exactly.
    return rng.bit_generator.state


def _rng_state_from_jsonable(state: dict) -> dict:
    return state
