"""FL client: the LocalUpdate of Algorithm 2 (lines 31–37).

Generic over any trainable exposing ``loss(params, batch)`` — used both with
the paper's small task models (``repro.fl.models``) and the assigned LM
architectures (``repro.models.zoo.Model``).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Iterable

import jax

from repro.train import optimizer as opt_lib

Params = Any

__all__ = ["make_local_update", "local_update"]


@functools.lru_cache(maxsize=64)
def _jitted_step(loss_fn: Callable, momentum: float,
                 clip: float | None):
    opt = opt_lib.sgd(momentum=momentum)

    @jax.jit
    def step(params, mu, batch, lr):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch))(params)
        if clip is not None:
            grads, _ = opt_lib.clip_by_global_norm(grads, clip)
        updates, new_state = opt.update(grads, {"mu": mu}, params, lr)
        return opt_lib.apply_updates(params, updates), new_state["mu"], loss

    return step


def make_local_update(loss_fn: Callable, momentum: float = 0.9,
                      clip: float | None = 10.0):
    """Returns ``local_update(params, batches, lr) -> (params, mean_loss)``.

    Momentum is reset per local session, as each hop of the paper's
    diffusion restarts SGD on the receiving PUE (the BS only ships model
    parameters, not optimizer state, over PUSCH).
    """
    step = _jitted_step(loss_fn, momentum, clip)

    def local_update(params: Params, batches: Iterable[dict], lr: float):
        mu = jax.tree.map(lambda p: jax.numpy.zeros_like(
            p, jax.numpy.float32), params)
        total, n = 0.0, 0
        for batch in batches:
            params, mu, loss = step(params, mu, batch, lr)
            total += float(loss)
            n += 1
        return params, (total / max(n, 1))

    return local_update


def local_update(loss_fn: Callable, params: Params, batches: Iterable[dict],
                 lr: float = 0.01, momentum: float = 0.9) -> tuple[Params, float]:
    return make_local_update(loss_fn, momentum)(params, batches, lr)
