"""Buffered-async (FedBuff-style) round plane — the event-driven engine.

The sync planes are bulk-synchronous: every round is a barrier, so one
straggler stalls the fleet.  This module replays the **same**
:class:`~repro.core.schedule.RoundSchedule` IR through a deterministic
event queue instead:

1. **Dispatch.**  Each server tick ``t`` builds its round exactly like
   ``run_federated`` — same control-plane RNG streams, same scheduler, same
   churn, same ledger charging — then annotates the schedule with arrival
   times (:func:`~repro.core.schedule.annotate_arrivals`).  Per-slot compute
   durations come from data sizes x a lognormal per-round jitter x the
   client's persistent speed; D2D hop and uplink link times come from the
   **jnp channel twins** (Rayleigh gains → SNR → Eq.-14 spectral efficiency
   → seconds = bits / (γ · PRB_HZ)), keyed by ``fold_in``-derived PRNG
   streams so every draw is a pure function of ``(seed, t)`` — resumed runs
   redraw identical delays with no stored RNG position.
2. **Park.**  Diffusion hops whose payload would reach the carrier after
   ``AsyncSpec.hop_deadline_s`` are parked: the carrier keeps the late
   model but skips its training session, while the hop's wire events stay
   charged (Eq. 15) — stale airtime is still airtime.
3. **Buffer.**  The round's op work runs on an inner sync data plane
   (``HostExecutor`` or ``FleetExecutor`` via the ``run_ops``/``aggregate``
   split), and each aggregation contribution is pushed into a min-heap
   keyed ``(arrival_time, seq)``.
4. **Tick.**  The server aggregates the first **K** arrivals
   (``AsyncSpec.resolve_k``) with staleness-discounted weights
   ``w · alpha / (1 + s)^beta`` where ``s`` = ticks since the contribution
   was issued; the tick's virtual clock advances to the K-th arrival.
   Contributions older than ``max_staleness`` are dropped unaggregated.
   After the last dispatch round, drain ticks flush the remaining buffer.

**Degeneracy contract** (pinned by ``tests/test_async_plane.py``): with
K = everything, a zero delay model, and the discount off, every tick pops
the round's contributions in issue order with unit discount, so the
aggregation is the *same* ``agg.fedavg`` call the sync ``host`` executor
makes — params, ledger, and histories are bit-identical.

In front sits the population sampler (``AsyncSpec.population > 0``): each
tick draws its cohort of ``num_clients`` users from a simulated population
(:class:`~repro.fl.population.Population`), mapping users onto the
Dirichlet data shards — ``num_clients`` becomes cohort size, not world
size.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.channels.fading import ChannelModel
from repro.channels.resources import (GAMMA_FLOOR, PRB_HZ, ResourceLedger,
                                      spectral_efficiency_jax)
from repro.channels.topology import CellTopology
from repro.core import aggregation as agg
from repro.core.auction import AuctionConfig
from repro.core.diffusion import DiffusionPlanner, PlanCache
from repro.core.schedule import (ArrivalModel, WireEvent, annotate_arrivals,
                                 charge_schedule)
from repro.fl.client import make_local_update
from repro.fl.engine import AsyncSpec, EngineSpec, RunHistory, RunResult
from repro.fl.executors import make_executor
from repro.fl.population import Population
from repro.fl.schedulers import (PROX_STRATEGIES, SCHEDULERS, RoundContext,
                                 apply_round_churn)

Params = Any

__all__ = ["run_buffered_async", "ASYNC_COMPATIBLE_AGG"]

# Strategies the buffered-async plane can execute: non-persistent rounds
# aggregating raw params.  Persistent slot state (gossip / tthf) and
# stc_delta uplinks tie the aggregate to one barrier's slot snapshot — a
# buffered re-ordering has no meaning for them.
ASYNC_COMPATIBLE_AGG = "params"

# PRNG stream tags (folded into the per-round key) separating the async
# delay draws from each other; the numpy control plane uses its own
# [seed, t, tag] streams (churn 0xC4, population 0xA7).
_STREAM_COMPUTE = 1
_STREAM_D2D = 2


@dataclasses.dataclass(order=True)
class _Contribution:
    """One buffered aggregation contribution, heap-ordered by arrival."""
    arrival_s: float
    seq: int
    round: int = dataclasses.field(compare=False)
    slot: int = dataclasses.field(compare=False)
    weight: float = dataclasses.field(compare=False)
    tree: Any = dataclasses.field(compare=False, repr=False)


def _arrival_model(b: AsyncSpec, seed: int, t: int, pos: np.ndarray,
                   up_gamma: np.ndarray, channel: ChannelModel,
                   data_rows: np.ndarray, speed: np.ndarray,
                   hop_bits: float, model_bits: float,
                   interference: np.ndarray | float = 0.0) -> ArrivalModel:
    """Draw round ``t``'s delay world from the jnp channel twins.

    Pure in ``(seed, t)``: the key is ``fold_in(PRNGKey(seed), t)``, so the
    same round redraws the same delays across runs and across ``--resume``.
    ``delay_scale == 0`` short-circuits to the zero model (the sync-
    degenerate configuration) without consuming any keys.
    """
    n = len(pos)
    if b.delay_scale <= 0.0:
        return ArrivalModel.zeros(n)
    key = jax.random.fold_in(jax.random.PRNGKey(int(seed)), int(t))
    # Compute: rows x delay_scale seconds at unit speed, lognormal-jittered
    # per client per round, divided by the client's persistent speed.
    kz = jax.random.fold_in(key, _STREAM_COMPUTE)
    z = jax.random.normal(kz, (n,))
    sig = float(b.delay_sigma)
    jitter = jnp.exp(sig * z - 0.5 * sig * sig)
    train_s = (float(b.delay_scale) * jnp.asarray(data_rows, jnp.float32)
               * jitter / jnp.asarray(speed, jnp.float32))
    # Links: one Rayleigh draw over this round's geometry (Eq. 12 → Eq. 14),
    # seconds = payload bits / (γ · PRB_HZ) on one PRB.
    kd = jax.random.fold_in(key, _STREAM_D2D)
    dist = CellTopology.pairwise_distances_jax(
        jnp.asarray(pos, jnp.float32))
    gains = channel.sample_gains_jax(kd, jnp.maximum(dist, 1.0))
    # World interference enters the delay SINR exactly as it enters the
    # scheduler's rate SINR: per-receiver power broadcast over columns.
    # (Passed through unconverted: the scalar-0.0 static case must follow
    # the exact arithmetic of the pre-world default argument.)
    gamma_d2d = jnp.maximum(
        spectral_efficiency_jax(channel.snr_jax(gains, interference)),
        GAMMA_FLOOR)
    hop_s = float(hop_bits) / (gamma_d2d * PRB_HZ)
    uplink_s = float(model_bits) / (np.asarray(up_gamma, np.float64)
                                    * PRB_HZ)
    return ArrivalModel(train_s=np.asarray(train_s, np.float64),
                        hop_s=np.asarray(hop_s, np.float64),
                        uplink_s=np.asarray(uplink_s, np.float64))


def _discounted_fedavg(popped: list[_Contribution], tick: int,
                       b: AsyncSpec) -> tuple[Params, float]:
    """Aggregate one tick's arrivals with staleness-discounted weights.

    Weight normalization happens inside :func:`agg.fedavg` (float64 sum →
    float32 division), so discounted weights always renormalize to 1 —
    the property ``tests/test_async_plane.py`` pins.  Returns the new
    global and the tick's mean staleness.
    """
    staleness = [max(0, tick - c.round) for c in popped]
    weights = [c.weight * b.discount(s)
               for c, s in zip(popped, staleness)]
    if not sum(weights) > 0.0:
        # Zero-row Dirichlet shards train in zero seconds, so they can fill
        # an entire K-arrival tick with zero-weight contributions (the sync
        # barrier never sees this: it always aggregates the full cohort,
        # where they add exactly 0 to the Eq.-11 sums).  Leave the global
        # unchanged — bitwise what these contributions would contribute.
        return None, float(np.mean(staleness))
    trees = [c.tree for c in popped]
    return agg.fedavg(trees, weights), float(np.mean(staleness))


def run_buffered_async(init_fn: Callable, loss_fn: Callable,
                       client_batches: Sequence[Callable],
                       dsi: np.ndarray, data_sizes: np.ndarray,
                       eval_fn: Callable, cfg, espec: EngineSpec,
                       plan_cache: PlanCache | None = None,
                       checkpointer=None,
                       base_bits: float = 0.0,
                       value_fn: Callable | None = None) -> RunResult:
    """Event-driven counterpart of ``run_federated``'s round loop.

    Called by ``run_federated`` when the resolved engine mode is
    ``"async"`` — same arguments plus the resolved :class:`EngineSpec`.
    """
    from repro.channels.world import HostWorld, per_client_energy_j
    from repro.fl.server import STRATEGIES
    from repro.fl.schedulers import apply_energy_cap

    b = espec.buffered
    assert cfg.strategy in STRATEGIES, cfg.strategy
    n = int(cfg.num_clients)
    rng = np.random.default_rng(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)
    topology = CellTopology(num_pues=n)
    channel = ChannelModel()
    auction = AuctionConfig(gamma_min=cfg.gamma_min, metric=cfg.metric,
                            allow_retraining=cfg.allow_retraining)
    planner = DiffusionPlanner(topology, channel, auction,
                               epsilon=cfg.epsilon,
                               max_rounds=cfg.max_diffusion_rounds,
                               underlay=cfg.underlay, mode=espec.planner)
    if cfg.strategy in PROX_STRATEGIES:
        from repro.fl.fedprox import make_prox_local_update
        local_update = make_prox_local_update(loss_fn, cfg.prox_mu,
                                              cfg.momentum)
    else:
        local_update = make_local_update(loss_fn, cfg.momentum)
    # Same evolving world as the sync loop — the async plane's arrival
    # model reads its interference so delay SINRs and rate SINRs agree.
    world = HostWorld.create(getattr(cfg, "scenario", "static"), topology,
                             channel, n,
                             energy_budget_j=getattr(cfg, "energy_budget_j",
                                                     None))

    # Control-plane seed for delay/cohort draws: the topology seed when set
    # (plan-cache sharing across replicate seeds then stays valid — every
    # seed sees the same cohorts and delays), the model seed otherwise.
    ctrl_seed = (cfg.topology_seed if cfg.topology_seed is not None
                 else cfg.seed)

    # Population front end: slot c of the inner executor draws whatever
    # data shard the tick's cohort assigned it, through one mutable
    # indirection the per-slot batch closures read at call time.
    pop = None
    cohort = np.arange(n, dtype=np.int64)
    if b.population > 0:
        num_shards = len(client_batches)
        pop = Population(int(b.population), num_shards, seed=int(ctrl_seed),
                         avail_alpha=b.avail_alpha, avail_beta=b.avail_beta,
                         speed_sigma=b.speed_sigma)
        batches_view = [
            (lambda c=c: client_batches[int(cohort[c])]())
            for c in range(n)]
    else:
        batches_view = list(client_batches[:n])

    inner_name = espec.inner_data_plane(n)
    inner = make_executor(inner_name, loss_fn, local_update, batches_view,
                          cfg)
    ledger = ResourceLedger()
    global_params = init_fn(key)
    model_bits = agg.model_bits(global_params, cfg.bits_per_param)
    if cfg.hop_quant == "int8":
        from repro.fl.adapters import packed_bits
        hop_bits = packed_bits(global_params)
    else:
        hop_bits = model_bits
    auction.model_bits = hop_bits

    hist = RunHistory()
    pending: list[_Contribution] = []
    seq = 0
    vtime = 0.0
    start_t = 0

    if checkpointer is not None:
        state = checkpointer.restore(inner, global_params, cfg)
        if state is not None:
            start_t = state.step
            global_params = state.params
            ledger = state.ledger
            hist = RunHistory(
                accuracy=state.acc_hist, loss=state.loss_hist,
                diffusion_rounds=state.dif_hist,
                iid_distance=state.iid_hist,
                round_wall_s=state.round_wall,
                **(state.async_hist or {}))
            checkpointer.apply_rng_state(rng, state.rng_state)
            vtime = float(state.buffer_meta["virtual_s"])
            seq = int(state.buffer_meta["next_seq"])
            pending = _unpack_buffer(state.buffer_tree, state.buffer_meta)
            heapq.heapify(pending)
            # Replay the world up to the restored round (same per-round RNG
            # streams as the live run, so mobile positions resume exactly).
            if cfg.topology_seed is not None:
                for tt in range(start_t):
                    world.advance_round(
                        np.random.default_rng([cfg.topology_seed, tt]))

    def eval_due(t: int) -> bool:
        return (t + 1) % cfg.eval_every == 0 or t == cfg.rounds - 1

    def server_tick(t: int, num_new: int) -> None:
        """Aggregate the first K arrivals; advance the virtual clock."""
        nonlocal global_params, vtime
        if not pending:
            return
        k = b.resolve_k(num_new if num_new > 0 else len(pending))
        k = min(k, len(pending))
        popped: list[_Contribution] = []
        dropped = 0
        while pending and len(popped) < k:
            c = heapq.heappop(pending)
            if b.max_staleness is not None \
                    and t - c.round > b.max_staleness:
                dropped += 1
                continue
            popped.append(c)
        if not popped:
            return
        vtime = max(vtime, popped[-1].arrival_s)
        new_params, mean_stale = _discounted_fedavg(popped, t, b)
        if new_params is not None:
            global_params = new_params
        hist.virtual_s.append(float(vtime))
        hist.arrivals.append(len(popped))
        hist.staleness.append(mean_stale)

    for t in range(start_t, cfg.rounds):
        t_exec = time.time()
        if pop is not None:
            draw = pop.sample_cohort(t, n)
            cohort[:] = draw.shards
            speed = draw.speed
        else:
            speed = np.ones(n)
        dsi_t = np.asarray(dsi)[cohort]
        sizes_t = np.asarray(data_sizes)[cohort]

        # --- control plane: identical streams to the sync loop -----------
        if cfg.topology_seed is not None:
            ctrl_rng = np.random.default_rng([cfg.topology_seed, t])
        else:
            ctrl_rng = rng
        pos = world.advance_round(ctrl_rng)
        up_gamma = np.maximum(world.uplink_gamma(ctrl_rng), GAMMA_FLOOR)
        learning_value = None
        if value_fn is not None \
                and getattr(cfg, "uncertainty_weight", 0.0) > 0.0:
            learning_value = np.asarray(value_fn(global_params), np.float64)
        ctx = RoundContext(cfg=cfg, t=t, dsi=dsi_t, data_sizes=sizes_t,
                           pos=pos, rng=ctrl_rng, up_gamma=up_gamma,
                           topology=topology, channel=channel,
                           planner=planner, model_bits=model_bits,
                           param_template=global_params,
                           plan_cache=plan_cache, hop_bits=hop_bits,
                           world=world, interference=world.interference(),
                           learning_value=learning_value)
        schedule = SCHEDULERS[cfg.strategy](ctx)
        if schedule.persistent or schedule.agg_mode != ASYNC_COMPATIBLE_AGG:
            raise ValueError(
                f"strategy {cfg.strategy!r} needs persistent slot state or "
                f"agg_mode={schedule.agg_mode!r}; the buffered-async engine "
                f"supports non-persistent params-aggregation strategies "
                f"(feddif / fedavg / fedswap / d2d_random_walk / prox "
                f"variants) — run it on a sync engine instead")
        if t == 0 and base_bits > 0.0:
            schedule.wire.append(WireEvent("downlink", float(base_bits),
                                           float(np.median(up_gamma)), n))
        schedule = apply_round_churn(ctx, schedule)
        if world.has_energy_cap:
            schedule = apply_energy_cap(ctx, schedule, world.depleted())

        # --- arrival annotation + Eq.-15 charging ------------------------
        model = _arrival_model(b, ctrl_seed, t, pos, up_gamma, channel,
                               sizes_t, speed, hop_bits, model_bits,
                               interference=world.interference())
        schedule, arrival_s, parked = annotate_arrivals(
            schedule, model, hop_deadline_s=b.hop_deadline_s)
        charge_schedule(ledger, schedule)
        if world.has_energy_cap:
            world.charge_energy(per_client_energy_j(schedule, n, PRB_HZ))

        # --- dispatch: inner op replay, contributions into the heap ------
        slots = inner.run_ops(schedule, global_params, None)
        for slot, w in schedule.agg:
            heapq.heappush(pending, _Contribution(
                arrival_s=vtime + float(arrival_s[slot]), seq=seq,
                round=t, slot=int(slot), weight=float(w),
                tree=inner.slot_state(slots, int(slot))))
            seq += 1

        # --- server tick -------------------------------------------------
        server_tick(t, num_new=len(schedule.agg))
        jax.block_until_ready(global_params)
        hist.round_wall_s.append(time.time() - t_exec)
        hist.diffusion_rounds.append(schedule.diffusion_rounds)
        hist.iid_distance.append(schedule.mean_iid)
        hist.parked_hops.append(parked)

        if eval_due(t):
            a, l = eval_fn(global_params)
            hist.accuracy.append(float(a))
            hist.loss.append(float(l))

        if checkpointer is not None and checkpointer.due(t + 1, cfg.rounds):
            btree, bmeta = _pack_buffer(pending, vtime, seq)
            checkpointer.save(
                t + 1, inner, global_params, None, ledger, cfg,
                acc_hist=hist.accuracy, loss_hist=hist.loss,
                dif_hist=hist.diffusion_rounds, iid_hist=hist.iid_distance,
                round_wall=hist.round_wall_s, rng=rng,
                async_hist={"virtual_s": hist.virtual_s,
                            "arrivals": hist.arrivals,
                            "staleness": hist.staleness,
                            "parked_hops": hist.parked_hops},
                buffer_tree=btree, buffer_meta=bmeta)

    # Drain: flush contributions still buffered after the last dispatch
    # round — K at a time, evaluating after each tick so the curves keep
    # tracking the virtual clock.  Empty immediately in the degenerate
    # (barrier) configuration.
    t = cfg.rounds
    while pending:
        server_tick(t, num_new=0)
        a, l = eval_fn(global_params)
        hist.accuracy.append(float(a))
        hist.loss.append(float(l))
        t += 1

    return RunResult(params=global_params, ledger=ledger, history=hist,
                     engine=espec, config=cfg)


# ------------------------------------------------------------------ buffer
# serialization — the mid-tick state the commit-marker protocol must cover.

def _pack_buffer(pending: list[_Contribution], vtime: float, next_seq: int
                 ) -> tuple[Any, dict]:
    """Stack the pending contributions into one leading-axis pytree (the
    npz payload) plus a JSON-able meta dict.  Heap order is recovered on
    restore from the (arrival, seq) keys."""
    entries = sorted(pending)
    meta = {"count": len(entries),
            "virtual_s": float(vtime),
            "next_seq": int(next_seq),
            "arrival_s": [float(c.arrival_s) for c in entries],
            "seq": [int(c.seq) for c in entries],
            "round": [int(c.round) for c in entries],
            "slot": [int(c.slot) for c in entries],
            "weight": [float(c.weight) for c in entries]}
    if not entries:
        return None, meta
    host = [jax.device_get(c.tree) for c in entries]
    stacked = jax.tree.map(lambda *xs: np.stack(xs), *host)
    return stacked, meta


def _unpack_buffer(buffer_tree: Any, meta: dict) -> list[_Contribution]:
    count = int(meta.get("count", 0))
    if count == 0:
        return []
    out = []
    for i in range(count):
        tree = jax.tree.map(lambda x: jnp.asarray(x[i]), buffer_tree)
        out.append(_Contribution(
            arrival_s=float(meta["arrival_s"][i]), seq=int(meta["seq"][i]),
            round=int(meta["round"][i]), slot=int(meta["slot"][i]),
            weight=float(meta["weight"][i]), tree=tree))
    return out
