"""Client population sampling — cohorts drawn from 10^5–10^6 simulated users.

The sync planes treat ``FLConfig.num_clients`` as the *world* size: every
client exists, trains, and aggregates each round.  Production cross-device
FL (and the paper's 6G setting) is the opposite regime — a huge population
of intermittently-available devices, of which each round only sees a small
cohort.  :class:`Population` models that front end for the buffered-async
engine (``AsyncSpec.population > 0``):

* Each of ``size`` users carries a **persistent** availability weight
  (Beta(``avail_alpha``, ``avail_beta``)) and a persistent lognormal
  compute speed (heterogeneous hardware, ``speed_sigma``), drawn once from
  a ``[seed, _POP_STREAM]``-keyed stream at construction.
* :meth:`sample_cohort` draws tick ``t``'s cohort of ``k`` users
  *without replacement*, availability-weighted, via the
  Efraimidis–Spirakis exponential-key trick — one vectorized pass over the
  population, deterministic in ``(seed, t)`` alone (same stateless
  ``default_rng([seed, t, tag])`` idiom as the churn stream), so resumed
  runs redraw identical cohorts with no stored RNG position.
* A user's *data shard* is ``user % num_shards``: the Dirichlet partition
  stays the world of distinct data distributions, and the population maps
  many users onto it (users sharing a shard are devices holding similarly
  distributed data).  ``num_clients`` thereby becomes cohort size, not
  world size.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["Population", "CohortDraw"]

# Stream tags keeping the population draws out of every other [seed, t]
# consumer's stream (churn uses 0xC4 — see repro.fl.schedulers).
_POP_STREAM = 0x9E
_COHORT_STREAM = 0xA7


@dataclasses.dataclass(frozen=True)
class CohortDraw:
    """One tick's cohort: global user ids, their data shards and speeds."""
    t: int
    users: np.ndarray       # (k,) int64 — population indices
    shards: np.ndarray      # (k,) int64 — data-partition shard per user
    speed: np.ndarray       # (k,) float64 — persistent compute speed ~ 1.0


class Population:
    """A fixed simulated user population with heterogeneous availability."""

    def __init__(self, size: int, num_shards: int, seed: int = 0,
                 avail_alpha: float = 2.0, avail_beta: float = 2.0,
                 speed_sigma: float = 0.5):
        assert size >= num_shards >= 1, (size, num_shards)
        self.size = int(size)
        self.num_shards = int(num_shards)
        self.seed = int(seed)
        rng = np.random.default_rng([self.seed, _POP_STREAM])
        # Persistent per-user traits: availability in (0, 1] (the sampling
        # weight) and a mean-1 lognormal compute speed.
        self.availability = np.maximum(
            rng.beta(float(avail_alpha), float(avail_beta), self.size),
            1e-9)
        z = rng.standard_normal(self.size)
        s = float(speed_sigma)
        self.speed = np.exp(s * z - 0.5 * s * s)

    def shard_of(self, users: np.ndarray) -> np.ndarray:
        return np.asarray(users, np.int64) % self.num_shards

    def sample_cohort(self, t: int, k: int) -> CohortDraw:
        """Draw tick ``t``'s availability-weighted cohort of ``k`` users.

        Weighted sampling without replacement (Efraimidis–Spirakis): each
        user draws an exponential key ``E / w`` and the ``k`` smallest keys
        win — one vectorized O(size) pass, exactly reproducible from
        ``(seed, t)``.
        """
        assert 1 <= k <= self.size, (k, self.size)
        rng = np.random.default_rng([self.seed, int(t), _COHORT_STREAM])
        keys = rng.exponential(size=self.size) / self.availability
        if k == self.size:
            users = np.arange(self.size, dtype=np.int64)
        else:
            part = np.argpartition(keys, k)[:k]
            users = part[np.argsort(keys[part], kind="stable")].astype(
                np.int64)
        return CohortDraw(t=int(t), users=users, shards=self.shard_of(users),
                          speed=self.speed[users])
