"""The adapter hop plane: frozen-base / trainable-adapter views + int8 wire.

FedDif's hop payload does not have to be the model.  A :class:`AdapterView`
splits a task's parameters into a frozen base (broadcast once, charged on
the round-0 downlink) and a trainable adapter pytree (LoRA factors for the
"lm" task) that is the *only* state the executors train, diffuse, mix
(Eq. 10/11) and aggregate.  Tasks without a split (``TaskModel.split is
None`` — every CNN/MLP sweep) degenerate to the identity view: the exact
``model.init``/``model.loss`` objects pass through unwrapped, so full-params
runs are bit-identical to the pre-adapter code path.

On the wire, a hop payload is additionally packed to int8 when
``FLConfig.hop_quant == "int8"``: the flattened adapter is cut into
QUANT_BLOCK-element row-blocks and each block moves as int8 codes plus one
fp32 absmax scale (``kernels/quant.py``).  :func:`packed_bits` is the
Eq.-15 payload size S of that format — 8·block + 32 bits per row-block —
charged per D2D hop by the schedulers via ``spec_adapter_bits``.

Every executor applies exactly one pack→unpack roundtrip per PermuteOp to
every slot (the roundtrip is what the receiving device would decode), so
host / fleet / sharded runs stay numerically identical: per-row packing
commutes with the row gathers/ring shifts that implement the move.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import model_bits
from repro.kernels import ops as kernel_ops
from repro.kernels.quant import QUANT_BLOCK

Params = Any

__all__ = ["AdapterView", "make_adapter_view", "packed_bits", "pack_rows",
           "unpack_rows", "quant_roundtrip_rows", "quant_roundtrip_tree",
           "quant_roundtrip_slot", "QUANT_BLOCK"]


def pack_rows(flat: jax.Array, *, block: int = QUANT_BLOCK,
              implementation: str = "auto"):
    """(C, F) fp32 client-stacked flat params → ((C, Fp) int8 codes,
    (C, Fp/block) fp32 scales), Fp = F padded up to a block multiple.
    Per client row the layout matches :func:`quant_roundtrip_slot`, so a
    packed row is the same wire bytes no matter which executor sends it."""
    c, f = flat.shape
    fp = -(-f // block) * block
    if fp != f:
        flat = jnp.pad(flat, ((0, 0), (0, fp - f)))
    r = fp // block
    q, s = kernel_ops.quant_pack(
        flat.astype(jnp.float32).reshape(c * r, block),
        implementation=implementation)
    return q.reshape(c, fp), s.reshape(c, r)


def unpack_rows(q: jax.Array, scales: jax.Array, f: int, *,
                implementation: str = "auto") -> jax.Array:
    """Inverse of :func:`pack_rows`; ``f`` is the unpadded feature count."""
    c, fp = q.shape
    r = scales.shape[1]
    x = kernel_ops.quant_unpack(q.reshape(c * r, fp // r),
                                scales.reshape(c * r),
                                implementation=implementation)
    return x.reshape(c, fp)[:, :f]


def quant_roundtrip_rows(flat: jax.Array, *, block: int = QUANT_BLOCK,
                         implementation: str = "auto") -> jax.Array:
    """pack→unpack of a (C, F) block: what the hop destination decodes."""
    q, s = pack_rows(flat, block=block, implementation=implementation)
    return unpack_rows(q, s, flat.shape[1], implementation=implementation)


def quant_roundtrip_tree(params: Params, *,
                         implementation: str = "auto") -> Params:
    """Roundtrip a client-stacked pytree per client row (FleetExecutor)."""
    from repro.kernels.diffusion import stack_ravel, stack_unravel
    flat, spec = stack_ravel(params)
    return stack_unravel(quant_roundtrip_rows(flat,
                                              implementation=implementation),
                         spec)


def quant_roundtrip_slot(params: Params, *,
                         implementation: str = "auto") -> Params:
    """Roundtrip one unstacked slot tree (HostExecutor).  Flattens in
    ``stack_ravel``'s leaf-concat order so the row-block boundaries — and
    therefore the decoded values — coincide with the stacked executors'."""
    leaves, treedef = jax.tree.flatten(params)
    flat = jnp.concatenate([x.reshape(1, -1).astype(jnp.float32)
                            for x in leaves], axis=1)
    out = quant_roundtrip_rows(flat, implementation=implementation)[0]
    new, off = [], 0
    for x in leaves:
        n = int(np.prod(x.shape))
        new.append(out[off:off + n].reshape(x.shape).astype(x.dtype))
        off += n
    return jax.tree.unflatten(treedef, new)


def packed_bits(template: Params, *, block: int = QUANT_BLOCK) -> float:
    """S for one int8-packed hop (Eq. 15 numerator): 8 bits per padded
    element plus one fp32 scale per row-block.  ``template`` may hold
    arrays or ShapeDtypeStructs."""
    f = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(template))
    rows = -(-f // block)
    return float(rows * (8 * block + 32))


@dataclasses.dataclass(frozen=True)
class AdapterView:
    """What ``run_federated`` sees of a task: init/loss over the *hop
    payload* tree, a merge back to full params for eval, and the one-time
    base broadcast charge (0.0 when the view is the identity)."""
    init_fn: Callable[[jax.Array], Params]
    loss_fn: Callable[[Params, dict], jax.Array]
    merge_fn: Callable[[Params], Params]
    base_bits: float
    base: Params | None


def make_adapter_view(model, fl_cfg, adapter_hops: bool = True) -> AdapterView:
    """Build the view ``run_federated`` trains/hops over.

    Full-params tasks (``model.split is None``) or ``adapter_hops=False``
    return the identity view with the *unwrapped* ``model.init`` /
    ``model.loss`` — bit-identical traces to the pre-adapter code path.
    Otherwise the base is fixed from the run seed (every client would
    derive the same base from the round-0 broadcast), the hop payload is
    ``split(init)[1]``, and the loss closes over the frozen base."""
    if not adapter_hops or model.split is None:
        return AdapterView(model.init, model.loss, lambda p: p, 0.0, None)
    base, _ = model.split(model.init(jax.random.PRNGKey(fl_cfg.seed)))

    def init_fn(key):
        return model.split(model.init(key))[1]

    def loss_fn(adapter, batch):
        return model.loss(model.merge(base, adapter), batch)

    def merge_fn(adapter):
        return model.merge(base, adapter)

    return AdapterView(init_fn, loss_fn, merge_fn,
                       model_bits(base, fl_cfg.bits_per_param), base)
