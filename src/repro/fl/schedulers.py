"""Per-strategy schedulers: one communication round -> :class:`RoundSchedule`.

Every Table-II strategy is a *scheduler* — a pure function from the round's
control-plane inputs (partition DSIs, wireless draw, QoS knobs) to a
:class:`~repro.core.schedule.RoundSchedule` — and nothing else.  Training and
parameter movement happen in an executor (``repro.fl.executors``), ledger
charging in :func:`~repro.core.schedule.charge_schedule`.  Adding a strategy
therefore means: write one ``schedule_*`` function, register it in
:data:`SCHEDULERS` — both executors, the ledger, the sweep registry and the
benchmarks pick it up with no further plumbing.

Determinism contract: a scheduler consumes ``ctx.rng`` in exactly the order
the paper's round would (positions → gains → matching draws), so host and
fleet executions of one config share one schedule, and plans stay cacheable
across replicate seeds (``FLConfig.topology_seed``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.channels.fading import ChannelModel
from repro.channels.resources import GAMMA_FLOOR, spectral_efficiency
from repro.channels.topology import CellTopology
from repro.core.diffusion import DiffusionPlanner, PlanCache, feddif_cache_key
from repro.core.dol import DiffusionState, iid_distance
from repro.core.schedule import (MixOp, PermuteOp, RoundSchedule, TrainOp,
                                 WireEvent, apply_churn,
                                 complete_round_permutation)
from repro.fl.compression import compressed_bits

__all__ = ["RoundContext", "SCHEDULERS", "PROX_STRATEGIES", "GAMMA_FLOOR",
           "apply_round_churn", "apply_energy_cap"]

# Strategies whose local solver is the FedProx proximal step.
PROX_STRATEGIES = ("fedprox", "feddif_prox")


@dataclasses.dataclass
class RoundContext:
    """Everything a scheduler may consult for one communication round ``t``.

    ``topology`` / ``channel`` / ``planner`` are built once per experiment in
    ``run_federated`` and shared by every round (the topology is *not*
    re-instantiated per strategy round).  ``param_template`` is the current
    global params, used only for *shapes* (compressed-bits accounting) —
    schedulers never read parameter values.
    """
    cfg: "FLConfig"                      # noqa: F821 — import cycle
    t: int
    dsi: np.ndarray
    data_sizes: np.ndarray
    pos: np.ndarray
    rng: np.random.Generator
    up_gamma: np.ndarray
    topology: CellTopology
    channel: ChannelModel
    planner: DiffusionPlanner
    model_bits: float
    param_template: object
    plan_cache: PlanCache | None = None
    # Per-hop D2D payload bits when the wire format differs from fp32
    # params (int8-packed adapter hops, FLConfig.hop_quant); None charges
    # model_bits.  Up/downlinks always charge model_bits.
    hop_bits: float | None = None
    # The round's wireless world (channels/world.HostWorld).  ``interference``
    # is its per-receiver co-channel power — scalar 0.0 outside multicell, so
    # the static SNR arithmetic is bit-identical to the pre-world path.
    world: object | None = None
    interference: np.ndarray | float = 0.0
    # Per-client learning value in [0, 1] (None when the signal is off);
    # fused into the FedDif bids with FLConfig.uncertainty_weight.
    learning_value: np.ndarray | None = None
    _dist: np.ndarray | None = dataclasses.field(default=None, repr=False)

    def d2d_bits(self) -> float:
        """Eq.-15 payload size S of one D2D hop under the active wire
        format (``repro.fl.adapters.packed_bits`` for int8 hops)."""
        return self.model_bits if self.hop_bits is None else self.hop_bits

    def pair_distances(self) -> np.ndarray:
        """(N, N) distance matrix for this round's positions, computed once
        (fedswap / random-walk draw gains many times per round over it)."""
        if self._dist is None:
            self._dist = self.topology.pairwise_distances(self.pos)
        return self._dist


def _mean_partition_iid(ctx: RoundContext) -> float:
    return float(np.mean(iid_distance(np.asarray(ctx.dsi), ctx.cfg.metric)))


def _downlink(ctx: RoundContext, bits: float | None = None) -> WireEvent:
    return WireEvent("downlink", ctx.model_bits if bits is None else bits,
                     float(np.median(ctx.up_gamma)), ctx.cfg.num_clients)


def _uplink(ctx: RoundContext, client: int,
            bits: float | None = None) -> WireEvent:
    return WireEvent("uplink", ctx.model_bits if bits is None else bits,
                     float(ctx.up_gamma[client]), src=int(client))


def _pair_gamma(ctx: RoundContext) -> np.ndarray:
    """One D2D channel draw over the round's positions (Sec. III-D).

    ``ctx.interference`` folds the world's per-receiver co-channel power
    into the SINR; its (n,) form broadcasts over the receiver (column)
    axis of the (n, n) link matrix."""
    gains = ctx.channel.sample_gains(ctx.pair_distances(), ctx.rng)
    return spectral_efficiency(ctx.channel.snr(gains, ctx.interference))


# Stream tag separating the churn draw from every other [seed, t] consumer.
_CHURN_STREAM = 0xC4


def apply_round_churn(ctx: RoundContext,
                      schedule: RoundSchedule) -> RoundSchedule:
    """Draw this round's churn/straggler mask and apply it to the schedule.

    Lives with the schedulers because it extends the determinism contract:
    the mask comes from a **dedicated** RNG stream keyed on
    ``[topology_seed (or seed), t, _CHURN_STREAM]`` — *not* from the tail
    of ``ctx.rng``, whose post-scheduler position depends on plan-cache
    hits and on the planner mode (a cache hit skips the channel draws a
    miss consumes).  A given config therefore drops the same clients in
    round ``t`` no matter which executor/planner/engine runs it or what
    the shared cache already contains; ``churn_rate=0`` draws nothing and
    existing trajectories are bit-identical.  Each client independently
    drops with probability ``FLConfig.churn_rate``; see
    :func:`~repro.core.schedule.apply_churn` for the dropped-client
    semantics (no training, zero aggregation weight, wire still charged).
    """
    rate = float(getattr(ctx.cfg, "churn_rate", 0.0))
    if rate <= 0.0:
        return schedule
    seed = (ctx.cfg.topology_seed if ctx.cfg.topology_seed is not None
            else ctx.cfg.seed)
    rng = np.random.default_rng([seed, ctx.t, _CHURN_STREAM])
    drop = rng.random(ctx.cfg.num_clients) < rate
    return apply_churn(schedule, drop)


def apply_energy_cap(ctx: RoundContext, schedule: RoundSchedule,
                     depleted: np.ndarray) -> RoundSchedule:
    """Drop clients whose TX-energy budget was spent in *prior* rounds.

    The ``energy_capped`` scenario's enforcement point: depletion reuses the
    churn semantics (:func:`~repro.core.schedule.apply_churn` — no training,
    zero aggregation weight, already-scheduled wire still charges, exactly
    like a battery dying mid-round).  The mask is deterministic (a pure
    function of past schedules), so no RNG stream is consumed and
    un-capped runs are untouched."""
    depleted = np.asarray(depleted, dtype=bool)
    if not depleted.any():
        return schedule
    return apply_churn(schedule, depleted)


# ----------------------------------------------------------------- schedulers

def schedule_fedavg(ctx: RoundContext) -> RoundSchedule:
    """FedAvg [1] (and FedProx [9] — same schedule, proximal local solver):
    broadcast, local update everywhere, weighted uplink aggregation."""
    n = ctx.cfg.num_clients
    wire = [_downlink(ctx)]
    wire += [_uplink(ctx, i) for i in range(n)]
    return RoundSchedule(
        num_slots=n,
        ops=[TrainOp(np.ones(n, dtype=bool))],
        wire=wire,
        agg=[(i, float(ctx.data_sizes[i])) for i in range(n)],
        mean_iid=_mean_partition_iid(ctx))


def schedule_stc(ctx: RoundContext) -> RoundSchedule:
    """STC [41]: full-model downlink, sparse-ternary-compressed delta uplink
    (Table II's compression baseline)."""
    n = ctx.cfg.num_clients
    up_bits = compressed_bits(ctx.param_template, ctx.cfg.stc_sparsity)
    wire = [_downlink(ctx)]
    wire += [_uplink(ctx, i, up_bits) for i in range(n)]
    return RoundSchedule(
        num_slots=n,
        ops=[TrainOp(np.ones(n, dtype=bool))],
        wire=wire,
        agg=[(i, float(ctx.data_sizes[i])) for i in range(n)],
        agg_mode="stc_delta",
        stc_sparsity=ctx.cfg.stc_sparsity,
        mean_iid=_mean_partition_iid(ctx))


def schedule_feddif(ctx: RoundContext) -> RoundSchedule:
    """FedDif (Algorithm 2): initial training by the holders, then the
    auction-planned diffusion rounds, then chain-weighted aggregation.
    ``feddif_stc`` ships STC-compressed deltas on every hop; ``feddif_prox``
    swaps the local solver (the schedule is identical)."""
    cfg = ctx.cfg
    n, m = cfg.num_clients, cfg.num_models
    compress = cfg.strategy == "feddif_stc"
    hop_bits = (compressed_bits(ctx.param_template, cfg.stc_sparsity)
                if compress else ctx.d2d_bits())

    state = DiffusionState.init(m, n, ctx.dsi.shape[1])
    init_mask = np.zeros(n, dtype=bool)
    for mi in range(m):
        holder = int(state.holder[mi])
        init_mask[holder] = True
        state.record_training(mi, holder, ctx.dsi[holder],
                              float(ctx.data_sizes[holder]))
    ops: list = [TrainOp(init_mask)]
    wire: list = [_downlink(ctx)]

    cache_key = None
    if ctx.plan_cache is not None and cfg.topology_seed is not None:
        cache_key = feddif_cache_key(cfg, ctx.t, ctx.dsi, ctx.data_sizes,
                                     ctx.d2d_bits(), ctx.planner.auction,
                                     values=ctx.learning_value)
    # World-model plan inputs: per-receiver interference (multicell), the
    # within-round WorldState + substep for mobile, and the learning-value
    # signal.  All default to the off/static values, keeping the pre-world
    # call bit-identical.
    planner_world = (ctx.world.planner_world()
                     if ctx.world is not None else None)
    step_m = (ctx.world.cfg.step_m
              if planner_world is not None else 0.0)
    plan = ctx.planner.plan_communication_round(
        state, ctx.dsi, ctx.data_sizes, ctx.rng, positions=ctx.pos,
        cache=ctx.plan_cache, cache_key=cache_key,
        interference=ctx.interference, values=ctx.learning_value,
        value_weight=float(getattr(cfg, "uncertainty_weight", 0.0)),
        world=planner_world, step_m=step_m)

    slot_of_model = np.arange(m) % max(n, 1)
    for k in range(plan.num_rounds):
        hops = plan.hops_in_round(k)
        for h in hops:
            wire.append(WireEvent("d2d", hop_bits,
                                  max(h.gamma, GAMMA_FLOOR), src=int(h.src)))
        src_of_dst, mask, slot_of_model = complete_round_permutation(
            [(h.model, h.dst) for h in hops], slot_of_model, n)
        ops.append(PermuteOp(src_of_dst, mask, compress=compress))

    for mi in range(m):
        wire.append(_uplink(ctx, int(state.holder[mi])))
    return RoundSchedule(
        num_slots=n,
        ops=ops,
        wire=wire,
        agg=[(int(slot_of_model[mi]), float(state.chain_size[mi]))
             for mi in range(m)],
        stc_sparsity=cfg.stc_sparsity,
        diffusion_rounds=plan.num_rounds,
        mean_iid=float(np.mean(plan.final_iid_distance)))


def schedule_fedswap(ctx: RoundContext) -> RoundSchedule:
    """FedSwap [21]: random full swaps until every model visited every PUE
    (full diffusion, no auction)."""
    cfg = ctx.cfg
    n = cfg.num_clients
    holder = np.arange(n)
    visited = np.eye(n, dtype=bool)
    slot_of_model = np.arange(n)
    ops: list = [TrainOp(np.ones(n, dtype=bool))]
    wire: list = [_downlink(ctx)]
    swaps = 0
    while not visited.all():
        perm = ctx.rng.permutation(n)
        gamma = _pair_gamma(ctx)
        hops, mask = [], np.zeros(n, dtype=bool)
        for mi in range(n):
            src, dst = int(holder[mi]), int(perm[mi])
            if src == dst:
                continue
            wire.append(WireEvent("d2d", ctx.d2d_bits(),
                                  max(float(gamma[src, dst]), GAMMA_FLOOR),
                                  src=src))
            holder[mi] = dst
            hops.append((mi, dst))
            if not visited[mi, dst]:
                mask[dst] = True
                visited[mi, dst] = True
        src_of_dst, _, slot_of_model = complete_round_permutation(
            hops, slot_of_model, n)
        ops.append(PermuteOp(src_of_dst, mask))
        swaps += 1
        if swaps > 4 * n:
            break
    for mi in range(n):
        wire.append(_uplink(ctx, int(holder[mi])))
    return RoundSchedule(
        num_slots=n,
        ops=ops,
        wire=wire,
        agg=[(int(slot_of_model[mi]), float(ctx.data_sizes[mi]))
             for mi in range(n)],
        diffusion_rounds=swaps)


def schedule_d2d_random_walk(ctx: RoundContext) -> RoundSchedule:
    """Auction-free diffusion ablation: models take random feasible D2D hops
    (same mobility as FedDif, zero planning — the Table-II gap to ``feddif``
    is what the auction buys).

    Host semantics allow several models on one PUE, so hops inside one walk
    round may collide on a destination; they are serialized into dst-unique
    *waves* (in model order) for the slot-bijection executors.
    """
    cfg = ctx.cfg
    n, m = cfg.num_clients, cfg.num_models
    holder = np.arange(m) % n
    visited = np.zeros((m, n), dtype=bool)
    init_mask = np.zeros(n, dtype=bool)
    for mi in range(m):
        h = int(holder[mi])
        init_mask[h] = True
        visited[mi, h] = True
    ops: list = [TrainOp(init_mask)]
    wire: list = [_downlink(ctx)]
    slot_of_model = np.arange(m) % max(n, 1)
    hops_done = 0
    for _ in range(cfg.random_walk_hops):
        gamma = _pair_gamma(ctx)
        round_hops: list[tuple[int, int]] = []
        for mi in range(m):
            src = int(holder[mi])
            cand = [j for j in range(n)
                    if j != src and not visited[mi, j]
                    and gamma[src, j] >= cfg.gamma_min]
            if not cand:
                continue
            dst = int(ctx.rng.choice(cand))
            wire.append(WireEvent("d2d", ctx.d2d_bits(),
                                  max(float(gamma[src, dst]), GAMMA_FLOOR),
                                  src=src))
            holder[mi] = dst
            visited[mi, dst] = True
            round_hops.append((mi, dst))
        if not round_hops:
            break
        hops_done += 1
        # Serialize dst collisions into waves, preserving model order.
        waves: list[list[tuple[int, int]]] = []
        for model, dst in round_hops:
            for wave in waves:
                if all(d != dst for _, d in wave):
                    wave.append((model, dst))
                    break
            else:
                waves.append([(model, dst)])
        for wave in waves:
            src_of_dst, mask, slot_of_model = complete_round_permutation(
                wave, slot_of_model, n)
            ops.append(PermuteOp(src_of_dst, mask))
    for mi in range(m):
        wire.append(_uplink(ctx, int(holder[mi])))
    # Chain weights and DoL follow Eq. (2): each model's mixture of the DSIs
    # it visited, weighted by client data size.
    sizes = np.asarray(ctx.data_sizes, np.float64)
    chain_sizes = visited @ sizes
    dol = (visited * sizes[None, :]) @ np.asarray(ctx.dsi)
    dol = dol / np.maximum(chain_sizes[:, None], 1e-9)
    return RoundSchedule(
        num_slots=n,
        ops=ops,
        wire=wire,
        agg=[(int(slot_of_model[mi]), float(chain_sizes[mi]))
             for mi in range(m)],
        diffusion_rounds=hops_done,
        mean_iid=float(np.mean(np.asarray(
            iid_distance(dol, cfg.metric)))))


def schedule_tthf(ctx: RoundContext) -> RoundSchedule:
    """TT-HF-like [22]: local updates + intra-cluster D2D consensus each
    round; global aggregation (uplink + broadcast reset) only every
    ``tthf_global_period`` rounds."""
    cfg = ctx.cfg
    n, cs = cfg.num_clients, cfg.tthf_cluster_size
    clusters = [list(range(i, min(i + cs, n))) for i in range(0, n, cs)]
    gamma = _pair_gamma(ctx)
    ops: list = [TrainOp(np.ones(n, dtype=bool))]
    wire: list = []
    groups = []
    for cl in clusters:
        head = cl[0]
        for i in cl[1:]:
            wire.append(WireEvent("d2d", ctx.model_bits,
                                  max(float(gamma[i, head]), GAMMA_FLOOR),
                                  src=i))
        groups.append((tuple(cl), tuple(float(ctx.data_sizes[i])
                                        for i in cl)))
    ops.append(MixOp(tuple(groups)))
    if (ctx.t + 1) % cfg.tthf_global_period == 0:
        for cl in clusters:
            wire.append(_uplink(ctx, cl[0]))
        wire.append(_downlink(ctx))
        ops.append(MixOp(((tuple(range(n)),
                           tuple(float(s) for s in ctx.data_sizes)),)))
    return RoundSchedule(
        num_slots=n,
        ops=ops,
        wire=wire,
        agg=[(i, float(ctx.data_sizes[i])) for i in range(n)],
        persistent=True)


def schedule_gossip(ctx: RoundContext) -> RoundSchedule:
    """D-PSGD-style gossip (Appendix C Scenario 1): train locally, average
    with one random neighbour over D2D — fully decentralized, no BS."""
    cfg = ctx.cfg
    n = cfg.num_clients
    gamma = _pair_gamma(ctx)
    perm = ctx.rng.permutation(n)
    wire: list = []
    groups = []
    for a in range(0, n - 1, 2):
        i, j = int(perm[a]), int(perm[a + 1])
        wire.append(WireEvent("d2d", ctx.model_bits,
                              max(float(gamma[i, j]), GAMMA_FLOOR), src=i))
        wire.append(WireEvent("d2d", ctx.model_bits,
                              max(float(gamma[j, i]), GAMMA_FLOOR), src=j))
        groups.append(((i, j), (float(ctx.data_sizes[i]),
                                float(ctx.data_sizes[j]))))
    return RoundSchedule(
        num_slots=n,
        ops=[TrainOp(np.ones(n, dtype=bool)), MixOp(tuple(groups))],
        wire=wire,
        agg=[(i, float(ctx.data_sizes[i])) for i in range(n)],
        persistent=True,
        diffusion_rounds=1)


SCHEDULERS: dict[str, Callable[[RoundContext], RoundSchedule]] = {
    "feddif": schedule_feddif,
    "feddif_stc": schedule_feddif,
    "feddif_prox": schedule_feddif,
    "fedavg": schedule_fedavg,
    "fedprox": schedule_fedavg,
    "stc": schedule_stc,
    "fedswap": schedule_fedswap,
    "tthf": schedule_tthf,
    "gossip": schedule_gossip,
    "d2d_random_walk": schedule_d2d_random_walk,
}
