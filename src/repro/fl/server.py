"""FL server runtime: FedDif (Algorithm 2) plus every comparison strategy of
Sec. VI — FedAvg [1], FedSwap [21] (full diffusion, no auction), STC [41]
(compressed uplink), TT-HF-like [22] (semi-decentralized cluster averaging),
D-PSGD-style gossip (fully decentralized; Appendix C Scenario 1), and a
``d2d_random_walk`` ablation (auction-free diffusion: models hop to random
feasible neighbours, isolating FedDif's *planning* gain from its *mobility*
gain on Table II's strategy axis).

The strategy seam
-----------------
``run_federated`` is the single entry point; ``cfg.strategy`` selects a
per-communication-round function ``_round_<name>``.  Every round function
receives the same ingredients — the current global (or persistent per-client)
params, a ``local_update`` closure, per-client batch thunks, the Dirichlet
partition's DSI/data-size arrays, the wireless draw of the round
(positions + uplink spectral efficiencies), and the shared
:class:`ResourceLedger` — and returns the next global params plus its
strategy-specific diffusion/IID bookkeeping.  Adding a strategy therefore
means: append its name to :data:`STRATEGIES`, write one ``_round_*``
function, and dispatch it in the round loop; the experiment harness
(``repro.fl.experiment``), the sweep registry (``repro.experiments``) and the
benchmarks pick it up by name with no further plumbing.

The runtime is model-agnostic: pass any ``loss_fn(params, batch)`` +
``init_fn(key)`` + per-client batch iterators.  Communication is charged to a
:class:`ResourceLedger` through the simulated wireless channel (Sec. III-D),
reproducing the paper's sub-frame / transmitted-model metrics.

Control-plane determinism: when ``cfg.topology_seed`` is set, each round's
positions / channel draws come from a fresh ``default_rng([topology_seed, t])``
stream, decoupled from the model-init seed.  Diffusion plans then depend only
on (topology_seed, round, data partition, planner knobs), which lets a
:class:`~repro.core.diffusion.PlanCache` passed to ``run_federated`` replan
once per sweep cell and replay the plan across replicate seeds.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Any, Callable, Sequence

import jax
import numpy as np

from repro.channels.fading import ChannelModel
from repro.channels.resources import ResourceLedger, spectral_efficiency
from repro.channels.topology import CellTopology
from repro.core import aggregation as agg
from repro.core.auction import AuctionConfig
from repro.core.diffusion import DiffusionPlanner, PlanCache, plan_cache_key
from repro.core.dol import DiffusionState, iid_distance
from repro.fl.client import make_local_update
from repro.fl.compression import compressed_bits, stc_compress

Params = Any

__all__ = ["FLConfig", "FLResult", "run_federated"]

STRATEGIES = ("feddif", "fedavg", "fedswap", "stc", "tthf", "gossip",
              "feddif_stc", "fedprox", "feddif_prox", "d2d_random_walk")


@dataclasses.dataclass
class FLConfig:
    strategy: str = "feddif"
    num_clients: int = 10
    num_models: int = 10               # M (FedDif trains M ≤ N models)
    rounds: int = 30                   # T communication rounds
    local_epochs: int = 1
    lr: float = 0.01
    momentum: float = 0.9
    batch_size: int = 16
    epsilon: float = 0.04              # min tolerable IID distance
    gamma_min: float = 1.0             # min tolerable QoS (bit/s/Hz)
    metric: str = "w1_norm"
    diffusion_ratio: float = 1.0       # fraction of PUEs allowed to diffuse
    stc_sparsity: float = 0.01
    prox_mu: float = 0.01              # FedProx proximal coefficient
    tthf_cluster_size: int = 5
    tthf_global_period: int = 4
    bits_per_param: int = 32
    seed: int = 0
    topology_seed: int | None = None   # decouple wireless draw from model seed
    random_walk_hops: int = 3          # hops/round for d2d_random_walk
    max_diffusion_rounds: int | None = None
    eval_every: int = 1
    allow_retraining: bool = False   # Appendix C-D (drops constraint 18c)
    underlay: bool = False           # Appendix C-F (D2D reuses CUE PRBs)


@dataclasses.dataclass
class FLResult:
    accuracy: list[float]
    loss: list[float]
    ledger: ResourceLedger
    diffusion_rounds: list[int]
    iid_distance: list[float]
    config: FLConfig
    final_params: Params = None

    def rounds_to_accuracy(self, target: float) -> int | None:
        for i, a in enumerate(self.accuracy):
            if a >= target:
                return i + 1
        return None


def _uplink_gamma(channel: ChannelModel, pos: np.ndarray,
                  rng: np.random.Generator) -> np.ndarray:
    """Spectral efficiency of each user's link to the BS at the origin."""
    d = np.linalg.norm(pos, axis=-1)
    gains = channel.sample_gains(np.maximum(d, 1.0), rng)
    return spectral_efficiency(channel.snr(gains))


def run_federated(init_fn: Callable, loss_fn: Callable,
                  client_batches: Sequence[Callable[[], list[dict]]],
                  dsi: np.ndarray, data_sizes: np.ndarray,
                  eval_fn: Callable[[Params], tuple[float, float]],
                  cfg: FLConfig,
                  plan_cache: PlanCache | None = None) -> FLResult:
    """Run one FL experiment.

    Args:
      init_fn: key -> params.
      loss_fn: (params, batch) -> scalar.
      client_batches: per client, a callable returning one local epoch of
        batches.
      dsi / data_sizes: from the Dirichlet partitioner.
      eval_fn: params -> (accuracy, loss) on held-out data.
      cfg: experiment configuration.
      plan_cache: optional :class:`PlanCache` for FedDif strategies; only
        consulted when ``cfg.topology_seed`` is set (otherwise the wireless
        draw depends on ``cfg.seed`` and plans are not shareable).
    """
    assert cfg.strategy in STRATEGIES, cfg.strategy
    n, m = cfg.num_clients, cfg.num_models
    rng = np.random.default_rng(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)
    topology = CellTopology(num_pues=n)
    channel = ChannelModel()
    auction = AuctionConfig(gamma_min=cfg.gamma_min, metric=cfg.metric,
                            allow_retraining=cfg.allow_retraining)
    planner = DiffusionPlanner(topology, channel, auction,
                               epsilon=cfg.epsilon,
                               max_rounds=cfg.max_diffusion_rounds,
                               underlay=cfg.underlay)
    if cfg.strategy in ("fedprox", "feddif_prox"):
        # proximal local solver (anchor = the received model's weights)
        from repro.fl.fedprox import make_prox_local_update
        local_update = make_prox_local_update(loss_fn, cfg.prox_mu,
                                              cfg.momentum)
    else:
        local_update = make_local_update(loss_fn, cfg.momentum)
    ledger = ResourceLedger()

    global_params = init_fn(key)
    model_bits = agg.model_bits(global_params, cfg.bits_per_param)
    auction.model_bits = model_bits

    acc_hist, loss_hist, dif_hist, iid_hist = [], [], [], []

    # gossip / tthf keep per-client params persistently
    persistent = ([copy.deepcopy(global_params) for _ in range(n)]
                  if cfg.strategy in ("gossip", "tthf") else None)

    for t in range(cfg.rounds):
        # Control-plane stream: per-round and model-seed-independent when
        # topology_seed is set, so diffusion plans are cacheable across seeds.
        if cfg.topology_seed is not None:
            ctrl_rng = np.random.default_rng([cfg.topology_seed, t])
        else:
            ctrl_rng = rng
        pos = topology.sample_positions(ctrl_rng, n)
        up_gamma = np.maximum(_uplink_gamma(channel, pos, ctrl_rng), 0.05)

        if cfg.strategy in ("feddif", "feddif_stc", "feddif_prox"):
            cache_key = None
            if plan_cache is not None and cfg.topology_seed is not None:
                cache_key = plan_cache_key(
                    cfg.topology_seed, t, dsi, data_sizes, cfg.epsilon,
                    cfg.gamma_min, cfg.metric,
                    extra=(n, m, model_bits, cfg.max_diffusion_rounds,
                           cfg.allow_retraining, cfg.underlay))
            k_rounds, iid_now = _round_feddif(
                global_params, local_update, client_batches, dsi, data_sizes,
                planner, ledger, model_bits, pos, ctrl_rng, cfg, up_gamma,
                plan_cache=plan_cache, cache_key=cache_key)
            global_params = k_rounds.pop("agg")
            dif_hist.append(k_rounds["rounds"])
            iid_hist.append(iid_now)
        elif cfg.strategy in ("fedavg", "fedprox"):
            global_params = _round_fedavg(
                global_params, local_update, client_batches, data_sizes,
                ledger, model_bits, up_gamma, cfg)
            dif_hist.append(0)
            iid_hist.append(float(np.mean(iid_distance(
                np.asarray(dsi), cfg.metric))))
        elif cfg.strategy == "stc":
            global_params = _round_stc(
                global_params, local_update, client_batches, data_sizes,
                ledger, up_gamma, cfg)
            dif_hist.append(0)
            iid_hist.append(float(np.mean(iid_distance(
                np.asarray(dsi), cfg.metric))))
        elif cfg.strategy == "fedswap":
            global_params, k_sw = _round_fedswap(
                global_params, local_update, client_batches, data_sizes,
                ledger, model_bits, pos, ctrl_rng, channel, cfg, up_gamma)
            dif_hist.append(k_sw)
            iid_hist.append(0.0)
        elif cfg.strategy == "tthf":
            global_params = _round_tthf(
                persistent, local_update, client_batches, data_sizes,
                ledger, model_bits, pos, ctrl_rng, channel, cfg, up_gamma, t)
            dif_hist.append(0)
            iid_hist.append(0.0)
        elif cfg.strategy == "gossip":
            persistent = _round_gossip(
                persistent, local_update, client_batches, data_sizes,
                ledger, model_bits, pos, ctrl_rng, channel, cfg)
            global_params = agg.fedavg(persistent, list(data_sizes))
            dif_hist.append(1)
            iid_hist.append(0.0)
        elif cfg.strategy == "d2d_random_walk":
            global_params, k_walk, iid_now = _round_d2d_random_walk(
                global_params, local_update, client_batches, dsi, data_sizes,
                ledger, model_bits, pos, ctrl_rng, channel, cfg, up_gamma)
            dif_hist.append(k_walk)
            iid_hist.append(iid_now)

        if (t + 1) % cfg.eval_every == 0 or t == cfg.rounds - 1:
            a, l = eval_fn(global_params)
            acc_hist.append(float(a))
            loss_hist.append(float(l))

    return FLResult(accuracy=acc_hist, loss=loss_hist, ledger=ledger,
                    diffusion_rounds=dif_hist, iid_distance=iid_hist,
                    config=cfg, final_params=global_params)


# ------------------------------------------------------------------ rounds

def _round_feddif(global_params, local_update, client_batches, dsi,
                  data_sizes, planner: DiffusionPlanner,
                  ledger: ResourceLedger, model_bits, pos, rng, cfg,
                  up_gamma, plan_cache: PlanCache | None = None,
                  cache_key: tuple | None = None):
    n, m = cfg.num_clients, cfg.num_models
    # BS clones the global model to M local models and broadcasts.
    models = [copy.deepcopy(global_params) for _ in range(m)]
    ledger.charge_downlink(model_bits, float(np.median(up_gamma)), n)
    state = DiffusionState.init(m, n, dsi.shape[1])

    # Initial training by the initial holders (Algorithm 2 lines 9–13).
    for mi in range(m):
        holder = int(state.holder[mi])
        models[mi], _ = local_update(models[mi], client_batches[holder](),
                                     cfg.lr)
        state.record_training(mi, holder, dsi[holder],
                              float(data_sizes[holder]))

    # Diffusion rounds (plan + execute).  The cache key (when given) captures
    # every plan input, so a hit replays the stored plan and post-state.
    plan = planner.plan_communication_round(state, dsi, data_sizes, rng,
                                            positions=pos, cache=plan_cache,
                                            cache_key=cache_key)
    for k in range(plan.num_rounds):
        for hop in plan.hops_in_round(k):
            bits = model_bits
            if cfg.strategy == "feddif_stc":
                # STC compresses the hop's DELTA against the round-start
                # global model (which every PUE holds from the broadcast);
                # the receiver reconstructs global + ternarized delta.
                delta = jax.tree.map(lambda a, b: a - b,
                                     models[hop.model], global_params)
                cdelta = stc_compress(delta, cfg.stc_sparsity)
                models[hop.model] = jax.tree.map(lambda g, d: g + d,
                                                 global_params, cdelta)
                bits = compressed_bits(delta, cfg.stc_sparsity)
            ledger.charge_d2d(bits, max(hop.gamma, 0.05))
            models[hop.model], _ = local_update(
                models[hop.model], client_batches[hop.dst](), cfg.lr)

    # Uplink + aggregation (Eq. 11), weighted by chain data size.
    for mi in range(m):
        holder = int(state.holder[mi])
        ledger.charge_uplink(model_bits, float(up_gamma[holder]))
    weights = [float(state.chain_size[mi]) for mi in range(m)]
    out = agg.fedavg(models, weights)
    return {"agg": out, "rounds": plan.num_rounds}, \
        float(np.mean(plan.final_iid_distance))


def _round_fedavg(global_params, local_update, client_batches, data_sizes,
                  ledger, model_bits, up_gamma, cfg):
    n = cfg.num_clients
    ledger.charge_downlink(model_bits, float(np.median(up_gamma)), n)
    locals_ = []
    for i in range(n):
        p, _ = local_update(copy.deepcopy(global_params),
                            client_batches[i](), cfg.lr)
        locals_.append(p)
        ledger.charge_uplink(model_bits, float(up_gamma[i]))
    return agg.fedavg(locals_, list(data_sizes))


def _round_stc(global_params, local_update, client_batches, data_sizes,
               ledger, up_gamma, cfg):
    n = cfg.num_clients
    full_bits = agg.model_bits(global_params, cfg.bits_per_param)
    ledger.charge_downlink(full_bits, float(np.median(up_gamma)), n)
    deltas = []
    for i in range(n):
        p, _ = local_update(copy.deepcopy(global_params),
                            client_batches[i](), cfg.lr)
        delta = jax.tree.map(lambda a, b: a - b, p, global_params)
        cdelta = stc_compress(delta, cfg.stc_sparsity)
        deltas.append(cdelta)
        ledger.charge_uplink(compressed_bits(delta, cfg.stc_sparsity),
                             float(up_gamma[i]))
    mean_delta = agg.fedavg(deltas, list(data_sizes))
    return jax.tree.map(lambda g, d: g + d, global_params, mean_delta)


def _round_fedswap(global_params, local_update, client_batches, data_sizes,
                   ledger, model_bits, pos, rng, channel, cfg, up_gamma):
    """FedSwap [21]: every round, models do a random full swap across all
    PUEs until each model visited every client (full diffusion)."""
    n = cfg.num_clients
    ledger.charge_downlink(model_bits, float(np.median(up_gamma)), n)
    models = [copy.deepcopy(global_params) for _ in range(n)]
    holder = np.arange(n)
    dist = CellTopology(num_pues=n).pairwise_distances(pos)
    visited = np.eye(n, dtype=bool)
    for mi in range(n):
        models[mi], _ = local_update(models[mi], client_batches[mi](),
                                     cfg.lr)
    swaps = 0
    while not visited.all():
        perm = rng.permutation(n)
        gains = channel.sample_gains(dist, rng)
        gamma = spectral_efficiency(channel.snr(gains))
        for mi in range(n):
            src, dst = int(holder[mi]), int(perm[mi])
            if src == dst:
                continue
            ledger.charge_d2d(model_bits, max(float(gamma[src, dst]), 0.05))
            holder[mi] = dst
            if not visited[mi, dst]:
                models[mi], _ = local_update(models[mi],
                                             client_batches[dst](), cfg.lr)
                visited[mi, dst] = True
        swaps += 1
        if swaps > 4 * n:
            break
    for mi in range(n):
        ledger.charge_uplink(model_bits, float(up_gamma[int(holder[mi])]))
    return agg.fedavg(models, list(data_sizes)), swaps


def _round_d2d_random_walk(global_params, local_update, client_batches, dsi,
                           data_sizes, ledger, model_bits, pos, rng, channel,
                           cfg, up_gamma):
    """Auction-free diffusion baseline (Table II's third D2D point).

    Models take ``cfg.random_walk_hops`` random D2D hops per communication
    round: each hop moves a model to a uniformly random unvisited neighbour
    whose link clears γ_min, and the receiver trains it.  Same mobility
    pattern as FedDif, zero planning — the accuracy/bandwidth gap to FedDif
    measures what the auction itself buys.
    """
    n, m = cfg.num_clients, cfg.num_models
    ledger.charge_downlink(model_bits, float(np.median(up_gamma)), n)
    models = [copy.deepcopy(global_params) for _ in range(m)]
    holder = np.arange(m) % n
    visited = np.zeros((m, n), dtype=bool)
    for mi in range(m):
        h = int(holder[mi])
        models[mi], _ = local_update(models[mi], client_batches[h](), cfg.lr)
        visited[mi, h] = True
    dist = CellTopology(num_pues=n).pairwise_distances(pos)
    hops_done = 0
    for _ in range(cfg.random_walk_hops):
        gains = channel.sample_gains(dist, rng)
        gamma = spectral_efficiency(channel.snr(gains))
        moved = False
        for mi in range(m):
            src = int(holder[mi])
            cand = [j for j in range(n)
                    if j != src and not visited[mi, j]
                    and gamma[src, j] >= cfg.gamma_min]
            if not cand:
                continue
            dst = int(rng.choice(cand))
            ledger.charge_d2d(model_bits, max(float(gamma[src, dst]), 0.05))
            models[mi], _ = local_update(models[mi], client_batches[dst](),
                                         cfg.lr)
            holder[mi] = dst
            visited[mi, dst] = True
            moved = True
        if not moved:
            break
        hops_done += 1
    for mi in range(m):
        ledger.charge_uplink(model_bits, float(up_gamma[int(holder[mi])]))
    # Chain weights and DoL follow Eq. (2): each model's mixture of the DSIs
    # it visited, weighted by client data size.
    chain_sizes = visited @ np.asarray(data_sizes, np.float64)
    dol = (visited * np.asarray(data_sizes)[None, :]) @ np.asarray(dsi)
    dol = dol / np.maximum(chain_sizes[:, None], 1e-9)
    mean_iid = float(np.mean(np.asarray(iid_distance(dol, cfg.metric))))
    out = agg.fedavg(models, [float(w) for w in chain_sizes])
    return out, hops_done, mean_iid


def _round_tthf(params, local_update, client_batches, data_sizes,
                ledger, model_bits, pos, rng, channel, cfg, up_gamma, t):
    """TT-HF-like [22]: local updates + intra-cluster D2D averaging each
    round; global aggregation only every ``tthf_global_period`` rounds.
    ``params`` is the persistent per-client parameter list (mutated)."""
    n = cfg.num_clients
    cs = cfg.tthf_cluster_size
    clusters = [list(range(i, min(i + cs, n))) for i in range(0, n, cs)]
    dist = CellTopology(num_pues=n).pairwise_distances(pos)
    gains = channel.sample_gains(dist, rng)
    gamma = spectral_efficiency(channel.snr(gains))
    for i in range(n):
        params[i], _ = local_update(params[i], client_batches[i](), cfg.lr)
    # intra-cluster consensus averaging (each member sends to a head)
    for cl in clusters:
        head = cl[0]
        for i in cl[1:]:
            ledger.charge_d2d(model_bits, max(float(gamma[i, head]), 0.05))
        avg = agg.fedavg([params[i] for i in cl],
                         [float(data_sizes[i]) for i in cl])
        for i in cl:
            params[i] = copy.deepcopy(avg)
    if (t + 1) % cfg.tthf_global_period == 0:
        for cl in clusters:
            ledger.charge_uplink(model_bits, float(up_gamma[cl[0]]))
        ledger.charge_downlink(model_bits, float(np.median(up_gamma)), n)
        g = agg.fedavg(params, list(data_sizes))
        for i in range(n):
            params[i] = copy.deepcopy(g)
        return g
    return agg.fedavg(params, list(data_sizes))


def _round_gossip(gossip_params, local_update, client_batches, data_sizes,
                  ledger, model_bits, pos, rng, channel, cfg):
    """D-PSGD-style gossip: train locally, then average with one random
    neighbor over D2D (fully decentralized — no BS)."""
    n = cfg.num_clients
    dist = CellTopology(num_pues=n).pairwise_distances(pos)
    gains = channel.sample_gains(dist, rng)
    gamma = spectral_efficiency(channel.snr(gains))
    for i in range(n):
        gossip_params[i], _ = local_update(gossip_params[i],
                                           client_batches[i](), cfg.lr)
    perm = rng.permutation(n)
    for a in range(0, n - 1, 2):
        i, j = int(perm[a]), int(perm[a + 1])
        ledger.charge_d2d(model_bits, max(float(gamma[i, j]), 0.05))
        ledger.charge_d2d(model_bits, max(float(gamma[j, i]), 0.05))
        avg = agg.fedavg([gossip_params[i], gossip_params[j]],
                         [float(data_sizes[i]), float(data_sizes[j])])
        gossip_params[i] = copy.deepcopy(avg)
        gossip_params[j] = copy.deepcopy(avg)
    return gossip_params
