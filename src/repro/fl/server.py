"""FL server runtime: FedDif (Algorithm 2) plus every comparison strategy of
Sec. VI — FedAvg [1], FedSwap [21] (full diffusion, no auction), STC [41]
(compressed uplink), TT-HF-like [22] (semi-decentralized cluster averaging),
D-PSGD-style gossip (fully decentralized; Appendix C Scenario 1), and a
``d2d_random_walk`` ablation (auction-free diffusion: models hop to random
feasible neighbours, isolating FedDif's *planning* gain from its *mobility*
gain on Table II's strategy axis).

The RoundSchedule / Executor seam
---------------------------------
``run_federated`` is the single entry point.  Each communication round runs
in three strategy-agnostic stages, mirroring the paper's PUCCH/PUSCH split:

1. **schedule** — ``repro.fl.schedulers.SCHEDULERS[cfg.strategy]`` turns the
   round's control-plane inputs (partition DSIs, wireless draw, QoS knobs)
   into a pure :class:`~repro.core.schedule.RoundSchedule`: slot-level
   train/permute/mix ops, wire events, aggregation weights.   [PUCCH]
2. **charge** — :func:`~repro.core.schedule.charge_schedule` replays the wire
   events into the :class:`ResourceLedger` (Sec. III-D metrics), identically
   for every executor.
3. **execute** — the executor selected by ``cfg.executor`` runs the ops:
   ``"host"`` on a per-slot pytree list (the reference semantics), ``"fleet"``
   on one client-stacked pytree via vmapped/jitted fedshard steps, and
   ``"sharded"`` with that client axis sharded over a ``("clients",)`` mesh
   (shard_map sessions, collective hops/aggregation — the large-N plane).
   When ``cfg.churn_rate > 0``, a per-round dropout mask is applied to the
   schedule first (``apply_round_churn``): dropped clients neither train nor
   carry aggregation weight, while their wire events still charge. [PUSCH]

Adding a strategy therefore means: append its name to :data:`STRATEGIES` and
write one scheduler in ``repro.fl.schedulers`` — both executors, the ledger,
the experiment harness (``repro.fl.experiment``), the sweep registry
(``repro.experiments``) and the benchmarks pick it up by name with no
further plumbing.

The runtime is model-agnostic: pass any ``loss_fn(params, batch)`` +
``init_fn(key)`` + per-client batch iterators.

Control-plane determinism: when ``cfg.topology_seed`` is set, each round's
positions / channel draws come from a fresh ``default_rng([topology_seed, t])``
stream, decoupled from the model-init seed.  Diffusion plans then depend only
on (topology_seed, round, data partition, planner knobs), which lets a
:class:`~repro.core.diffusion.PlanCache` passed to ``run_federated`` replan
once per sweep cell and replay the plan across replicate seeds.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Sequence

import jax
import numpy as np

from repro.channels.fading import ChannelModel
from repro.channels.resources import (GAMMA_FLOOR, PRB_HZ, ResourceLedger,
                                      spectral_efficiency)
from repro.channels.topology import CellTopology
from repro.channels.world import SCENARIOS, HostWorld, per_client_energy_j
from repro.core import aggregation as agg
from repro.core.auction import AuctionConfig
from repro.core.diffusion import PLANNER_MODES, DiffusionPlanner, PlanCache
from repro.core.schedule import WireEvent, charge_schedule
from repro.fl.client import make_local_update
from repro.fl.engine import (EngineSpec, RunHistory, RunResult,
                             resolve_engine)
from repro.fl.executors import EXECUTORS, make_executor
from repro.fl.schedulers import (PROX_STRATEGIES, SCHEDULERS, RoundContext,
                                 apply_energy_cap, apply_round_churn)

Params = Any

__all__ = ["FLConfig", "FLResult", "RunResult", "EngineSpec",
           "run_federated", "STRATEGIES", "HOP_QUANTS"]

STRATEGIES = ("feddif", "fedavg", "fedswap", "stc", "tthf", "gossip",
              "feddif_stc", "fedprox", "feddif_prox", "d2d_random_walk")

HOP_QUANTS = ("none", "int8")


@dataclasses.dataclass
class FLConfig:
    strategy: str = "feddif"
    num_clients: int = 10
    num_models: int = 10               # M (FedDif trains M ≤ N models)
    rounds: int = 30                   # T communication rounds
    local_epochs: int = 1
    lr: float = 0.01
    momentum: float = 0.9
    batch_size: int = 16
    epsilon: float = 0.04              # min tolerable IID distance
    gamma_min: float = 1.0             # min tolerable QoS (bit/s/Hz)
    metric: str = "w1_norm"
    diffusion_ratio: float = 1.0       # fraction of PUEs allowed to diffuse
    stc_sparsity: float = 0.01
    prox_mu: float = 0.01              # FedProx proximal coefficient
    tthf_cluster_size: int = 5
    tthf_global_period: int = 4
    bits_per_param: int = 32
    seed: int = 0
    topology_seed: int | None = None   # decouple wireless draw from model seed
    random_walk_hops: int = 3          # hops/round for d2d_random_walk
    max_diffusion_rounds: int | None = None
    eval_every: int = 1
    executor: str = "host"           # "host" (reference) | "fleet" (stacked)
                                     # | "sharded" (client-sharded mesh)
    shard_microbatch: int = 32       # clients per device microbatch when
                                     # executor="sharded" (caps memory)
    mesh_model_axis: int = 1         # requested "model" axis size of the 2-D
                                     # ("clients","model") FL mesh — hops
                                     # feature-shard over it (make_fl_mesh)
    shard_overlap: str = "auto"      # "auto"|"on"|"off": fused round plane
                                     # with double-buffered hop/train stages
                                     # ("on") vs the op-by-op legacy plane
                                     # ("off"); "auto" = fused at large N
                                     # (executors.FUSED_MIN_CLIENTS) where
                                     # per-op dispatch dominates, op-by-op
                                     # below it and while profiling phases
    shard_hop_transport: str = "auto"  # fused-plane hop collective:
                                     # "gather" (one all_gather per hop, the
                                     # fast path while the gathered stack
                                     # fits memory) | "ring" (per-shift
                                     # ppermute, O(block) memory) | "auto"
                                     # = gather under the byte budget
    profile_phases: bool = False     # per-round train/hop/mix wall-clock
                                     # breakdown (forces the op-by-op plane —
                                     # a fused round cannot be sub-timed)
    churn_rate: float = 0.0          # per-round P(client drops out) — see
                                     # schedulers.apply_round_churn
    scenario: str = "static"         # wireless world evolution
                                     # (channels/world.SCENARIOS): "static" |
                                     # "mobile" (random waypoint) |
                                     # "multicell" (SINR handoff + inter-cell
                                     # interference) | "energy_capped"
                                     # (finite TX budgets).  "static" is
                                     # bit-identical to the pre-world runtime.
    uncertainty_weight: float = 0.0  # learning-value bid fusion weight w:
                                     # the planner's bids become
                                     # bids·(1 + w·value); 0.0 = off, the
                                     # exact pre-value auction
    energy_budget_j: float | None = None
                                     # per-client TX energy budget (J) when
                                     # scenario="energy_capped"; None = the
                                     # scenario default.  Depleted clients
                                     # drop out via churn semantics.
    planner: str = "host"            # control plane: "host" numpy oracle |
                                     # "jax" jitted/batched device planner
    allow_retraining: bool = False   # Appendix C-D (drops constraint 18c)
    underlay: bool = False           # Appendix C-F (D2D reuses CUE PRBs)
    checkpoint_every: int = 0        # durable round-state cadence R; 0 = off
                                     # (see repro.fl.resume.RoundCheckpointer)
    hop_quant: str = "none"          # D2D hop payload wire format: "none"
                                     # (fp32) | "int8" (per-row-block absmax
                                     # pack, kernels/quant.py).  Applies to
                                     # PermuteOp diffusion hops (feddif /
                                     # fedswap / d2d_random_walk); MixOp-
                                     # based exchanges (tthf, gossip) and
                                     # up/downlinks stay fp32.  Composes
                                     # numerically with feddif_stc, whose
                                     # ledger keeps the STC accounting.
    engine: "EngineSpec | str | None" = None
                                     # The typed engine selection
                                     # (repro.fl.engine): an EngineSpec, or
                                     # an ENGINE_PRESETS name ("host",
                                     # "fleet", "sharded", "auto", "async",
                                     # "async_barrier").  When set it WINS
                                     # over the legacy string kwargs above
                                     # (executor / planner / shard_*), which
                                     # keep working through the one-release
                                     # EngineSpec.from_config deprecation
                                     # shim.


# Legacy alias, one release: ``run_federated`` now returns the structured
# :class:`repro.fl.engine.RunResult` (params, ledger, history, engine), whose
# properties reproduce the old flat FLResult surface (``accuracy``, ``loss``,
# ``final_params``, ``round_wall_s``, ``phase_s``, ``rounds_to_accuracy``).
FLResult = RunResult


def _uplink_gamma(channel: ChannelModel, pos: np.ndarray,
                  rng: np.random.Generator) -> np.ndarray:
    """Spectral efficiency of each user's link to the BS at the origin."""
    d = np.linalg.norm(pos, axis=-1)
    gains = channel.sample_gains(np.maximum(d, 1.0), rng)
    return spectral_efficiency(channel.snr(gains))


def run_federated(init_fn: Callable, loss_fn: Callable,
                  client_batches: Sequence[Callable[[], list[dict]]],
                  dsi: np.ndarray, data_sizes: np.ndarray,
                  eval_fn: Callable[[Params], tuple[float, float]],
                  cfg: FLConfig,
                  plan_cache: PlanCache | None = None,
                  checkpointer=None, base_bits: float = 0.0,
                  value_fn: Callable[[Params], np.ndarray] | None = None
                  ) -> FLResult:
    """Run one FL experiment.

    Args:
      init_fn: key -> params.
      loss_fn: (params, batch) -> scalar.
      client_batches: per client, a callable returning one local epoch of
        batches.
      dsi / data_sizes: from the Dirichlet partitioner.
      eval_fn: params -> (accuracy, loss) on held-out data.
      cfg: experiment configuration; ``cfg.executor`` selects the data plane
        (``"host"`` reference loop or ``"fleet"`` client-stacked vmap).
      plan_cache: optional :class:`PlanCache` for FedDif strategies; only
        consulted when ``cfg.topology_seed`` is set (otherwise the wireless
        draw depends on ``cfg.seed`` and plans are not shareable).
      checkpointer: optional :class:`~repro.fl.resume.RoundCheckpointer`.
        When set, full round state is serialized every
        ``checkpointer.every`` rounds and, if a readable checkpoint exists
        in its directory, the loop resumes from it bit-identically.
      base_bits: serialized size of the frozen base under an adapter view
        (``repro.fl.adapters``).  Charged once as a round-0 downlink
        broadcast; 0.0 (full-params runs) charges nothing.
      value_fn: optional ``params -> (N,) learning value in [0, 1]``
        (``fl/experiment.py`` builds a predictive-uncertainty probe).  Only
        consulted when ``cfg.uncertainty_weight > 0``; the values fuse into
        the FedDif auction bids via ``kernels.ops.bid_value_fuse``.
    """
    assert cfg.strategy in STRATEGIES, cfg.strategy
    assert cfg.hop_quant in HOP_QUANTS, cfg.hop_quant
    assert cfg.scenario in SCENARIOS, cfg.scenario
    if cfg.num_models > cfg.num_clients:
        # The paper trains M ≤ N models (one PUE trains one model per round,
        # constraint 18d); the slot-per-client executors require it too.
        raise ValueError(
            f"num_models={cfg.num_models} > num_clients={cfg.num_clients}; "
            f"FedDif requires M ≤ N (set num_models <= num_clients)")
    # Engine resolution — the ONLY place an execution plane is selected.
    espec = resolve_engine(cfg)
    assert espec.planner in PLANNER_MODES, espec.planner
    if espec.mode == "async":
        from repro.fl.async_plane import run_buffered_async
        return run_buffered_async(init_fn, loss_fn, client_batches, dsi,
                                  data_sizes, eval_fn, cfg, espec,
                                  plan_cache=plan_cache,
                                  checkpointer=checkpointer,
                                  base_bits=base_bits, value_fn=value_fn)
    assert espec.mode in EXECUTORS, espec.mode
    # Materialize the resolved spec onto the config the executor reads, so
    # an explicit EngineSpec wins over stale legacy fields.
    cfg_exec = dataclasses.replace(
        cfg, executor=espec.mode, planner=espec.planner,
        shard_overlap=espec.shard_overlap,
        shard_hop_transport=espec.shard_hop_transport,
        shard_microbatch=espec.shard_microbatch,
        mesh_model_axis=espec.mesh_model_axis)
    n = cfg.num_clients
    rng = np.random.default_rng(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)
    topology = CellTopology(num_pues=n)
    channel = ChannelModel()
    auction = AuctionConfig(gamma_min=cfg.gamma_min, metric=cfg.metric,
                            allow_retraining=cfg.allow_retraining)
    planner = DiffusionPlanner(topology, channel, auction,
                               epsilon=cfg.epsilon,
                               max_rounds=cfg.max_diffusion_rounds,
                               underlay=cfg.underlay, mode=espec.planner)
    if cfg.strategy in PROX_STRATEGIES:
        # proximal local solver (anchor = the received model's weights)
        from repro.fl.fedprox import make_prox_local_update
        local_update = make_prox_local_update(loss_fn, cfg.prox_mu,
                                              cfg.momentum)
    else:
        local_update = make_local_update(loss_fn, cfg.momentum)
    executor = make_executor(espec.mode, loss_fn, local_update,
                             client_batches, cfg_exec)
    ledger = ResourceLedger()
    # The evolving wireless world.  Static consumes exactly the draws the
    # pre-world control plane did, so the whole run is bit-identical; the
    # other scenarios add mobility / handoff / energy on the same streams.
    world = HostWorld.create(cfg.scenario, topology, channel, n,
                             energy_budget_j=cfg.energy_budget_j)

    global_params = init_fn(key)
    model_bits = agg.model_bits(global_params, cfg.bits_per_param)
    # What one D2D hop actually moves: the int8-packed wire size under
    # hop_quant, the fp32 payload otherwise.  The auction prices hops
    # (Eq. 15) at this figure; up/downlinks keep charging model_bits.
    if cfg.hop_quant == "int8":
        from repro.fl.adapters import packed_bits
        hop_bits = packed_bits(global_params)
    else:
        hop_bits = model_bits
    auction.model_bits = hop_bits

    acc_hist, loss_hist, dif_hist, iid_hist = [], [], [], []
    round_wall: list[float] = []
    phase_hist: list[dict] = []
    slots = None            # persistent per-slot state (gossip / tthf)
    start_t = 0

    if checkpointer is not None:
        state = checkpointer.restore(executor, global_params, cfg)
        if state is not None:
            start_t = state.step
            global_params = state.params
            slots = state.slots
            ledger = state.ledger
            acc_hist, loss_hist = state.acc_hist, state.loss_hist
            dif_hist, iid_hist = state.dif_hist, state.iid_hist
            round_wall = state.round_wall
            checkpointer.apply_rng_state(rng, state.rng_state)
            if start_t and cfg.topology_seed is not None:
                # Rebuild the world's round-t state: mobility / handoff
                # trajectories are pure functions of the per-round control
                # streams, which are independent of ``rng``, so replaying
                # them is exact.  (Per-client *energy* spent in replayed
                # rounds is not recharged — energy_capped runs should
                # checkpoint at rounds=cadence boundaries they can afford;
                # with topology_seed unset a mobile world restarts.)
                for tt in range(start_t):
                    world.advance_round(
                        np.random.default_rng([cfg.topology_seed, tt]))

    for t in range(start_t, cfg.rounds):
        # Control-plane stream: per-round and model-seed-independent when
        # topology_seed is set, so diffusion plans are cacheable across seeds.
        if cfg.topology_seed is not None:
            ctrl_rng = np.random.default_rng([cfg.topology_seed, t])
        else:
            ctrl_rng = rng
        pos = world.advance_round(ctrl_rng)
        up_gamma = np.maximum(world.uplink_gamma(ctrl_rng), GAMMA_FLOOR)
        learning_value = None
        if value_fn is not None and cfg.uncertainty_weight > 0.0:
            learning_value = np.asarray(value_fn(global_params), np.float64)

        t_plan = time.time()
        ctx = RoundContext(cfg=cfg, t=t, dsi=dsi, data_sizes=data_sizes,
                           pos=pos, rng=ctrl_rng, up_gamma=up_gamma,
                           topology=topology, channel=channel,
                           planner=planner, model_bits=model_bits,
                           param_template=global_params,
                           plan_cache=plan_cache, hop_bits=hop_bits,
                           world=world, interference=world.interference(),
                           learning_value=learning_value)
        schedule = SCHEDULERS[cfg.strategy](ctx)
        if t == 0 and base_bits > 0.0:
            # One-time frozen-base broadcast (adapter view): every round-t
            # state derives from base + hopped adapter, so the base ships
            # once on the round-0 downlink, strategy-independent.
            schedule.wire.append(WireEvent("downlink", float(base_bits),
                                           float(np.median(up_gamma)), n))
        schedule = apply_round_churn(ctx, schedule)
        if world.has_energy_cap:
            schedule = apply_energy_cap(ctx, schedule, world.depleted())
        charge_schedule(ledger, schedule)
        if world.has_energy_cap:
            world.charge_energy(per_client_energy_j(schedule, n, PRB_HZ))
        plan_s = time.time() - t_plan
        t_exec = time.time()
        global_params, slots = executor.run_round(schedule, global_params,
                                                  slots)
        jax.block_until_ready(global_params)
        round_wall.append(time.time() - t_exec)
        if cfg.profile_phases:
            phases = dict(getattr(executor, "pop_phase_times",
                                  lambda: {})())
            phases["plan"] = plan_s
            phase_hist.append(phases)
        dif_hist.append(schedule.diffusion_rounds)
        iid_hist.append(schedule.mean_iid)

        if (t + 1) % cfg.eval_every == 0 or t == cfg.rounds - 1:
            a, l = eval_fn(global_params)
            acc_hist.append(float(a))
            loss_hist.append(float(l))

        if checkpointer is not None and checkpointer.due(t + 1, cfg.rounds):
            checkpointer.save(t + 1, executor, global_params, slots, ledger,
                              cfg, acc_hist=acc_hist, loss_hist=loss_hist,
                              dif_hist=dif_hist, iid_hist=iid_hist,
                              round_wall=round_wall, rng=rng)

    hist = RunHistory(accuracy=acc_hist, loss=loss_hist,
                      diffusion_rounds=dif_hist, iid_distance=iid_hist,
                      round_wall_s=round_wall, phase_s=phase_hist)
    return RunResult(params=global_params, ledger=ledger, history=hist,
                     engine=espec, config=cfg)
