"""Sparse Ternary Compression (STC) — Sattler et al. [41], the paper's
model-compression baseline (Table II).

STC sends, per tensor: the indices of the top-``p`` fraction of entries by
magnitude and a single magnitude ``μ`` (the mean of the selected magnitudes),
with signs — i.e. the tensor is approximated by ``μ·(sign ∘ top-k mask)``.

``compressed_bits`` follows the paper's accounting: Golomb-ish index cost
≈ ``k·(log2(n/k)+2)`` bits + 1 sign bit per kept entry + 32 bits for μ.

The host path lives here; the TPU Pallas kernel is
``repro.kernels.stc_compress`` (same semantics, validated against this).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["stc_compress_leaf", "stc_compress", "compressed_bits"]


def stc_compress_leaf(x: jax.Array, sparsity: float = 0.01) -> jax.Array:
    """Ternarize one tensor, keeping the top-``sparsity`` fraction."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    k = max(1, int(n * sparsity))
    mag = jnp.abs(flat)
    topv, topi = jax.lax.top_k(mag, k)
    mu = jnp.mean(topv)
    out = jnp.zeros_like(flat)
    out = out.at[topi].set(jnp.sign(flat[topi]) * mu)
    return out.reshape(x.shape).astype(x.dtype)


def stc_compress(tree: Any, sparsity: float = 0.01) -> Any:
    return jax.tree.map(lambda x: stc_compress_leaf(x, sparsity), tree)


def compressed_bits(tree: Any, sparsity: float = 0.01) -> float:
    total = 0.0
    for leaf in jax.tree.leaves(tree):
        n = int(np.prod(leaf.shape))
        k = max(1, int(n * sparsity))
        total += k * (math.log2(max(n / k, 2.0)) + 2.0) + k + 32.0
    return total
