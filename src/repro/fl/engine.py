"""EngineSpec / RunResult — the typed engine-selection and result API.

Engine selection used to be string sprawl across ``FLConfig``: ``executor``
(+ the sharded plane's ``shard_overlap`` / ``shard_hop_transport`` /
``shard_microbatch`` / ``mesh_model_axis``), ``planner``, and the
orchestrator's ``_pick_executor`` heuristic on top.  The buffered-async
plane (PR 9) would have added a fourth ad-hoc knob family.  This module
collapses all of it into one frozen :class:`EngineSpec`:

* ``EngineSpec`` is the **single selection authority**: every runtime entry
  point (``run_federated``, the sweep orchestrator, the benches) resolves
  its engine through :func:`resolve_engine` and nothing else constructs an
  engine from raw strings.
* Legacy ``FLConfig`` string kwargs keep working through
  :meth:`EngineSpec.from_config` — a deprecation shim that warns **once**
  per process and maps the old fields onto a spec.
* :meth:`EngineSpec.auto` absorbs ``orchestrator._pick_executor``: the
  measured sharded/fleet crossover lives here, next to the thing it picks.
* Named :data:`ENGINE_PRESETS` ("host", "fleet", "sharded", "async", …) are
  what ``launch/sweep --engine`` and ``benchmarks/run.py --engine`` accept,
  and what ``FLConfig.engine`` stores when given a string.

:class:`RunResult` is the structured return of ``run_federated``: params,
ledger, a :class:`RunHistory` of per-round curves, and the engine actually
used.  The legacy ``FLResult`` flat attributes (``accuracy``, ``loss``,
``final_params``, …) are preserved as properties, and positional unpacking
``params, ledger, history = result`` works via ``__iter__`` for one release.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any

__all__ = ["AsyncSpec", "EngineSpec", "ENGINE_PRESETS", "resolve_engine",
           "engine_fingerprint", "RunHistory", "RunResult",
           "SHARDED_CROSSOVER_N"]

# Measured fleet/sharded crossover (benchmarks/run.py fleet_scaling on the
# 2-device CPU mesh): below this N the collective rendezvous overhead of the
# sharded plane exceeds its parallelism win.  EngineSpec.auto() downgrades
# sharded requests under it — the heuristic formerly in
# ``orchestrator._pick_executor``.
SHARDED_CROSSOVER_N = 64

#: Execution planes run_federated can dispatch to.
ENGINE_MODES = ("host", "fleet", "sharded", "async", "auto")


@dataclasses.dataclass(frozen=True)
class AsyncSpec:
    """Knobs of the buffered-async (FedBuff-style) round plane.

    The **defaults are degenerate on purpose**: ``buffer_k=None`` +
    ``buffer_frac=None`` aggregates every arrival of the round (a barrier),
    ``delay_scale=0`` makes every arrival instantaneous, and
    ``staleness_beta=0`` turns the discount off — so
    ``EngineSpec(mode="async")`` with stock knobs reproduces the sync
    ``host`` executor bit-identically (the degeneracy contract
    ``tests/test_async_plane.py`` pins).

    Attributes:
      buffer_k: aggregate the first K arrivals per server tick.  ``None``
        defers to ``buffer_frac``; both ``None`` means K = all of the
        round's contributions (sync barrier).
      buffer_frac: K as a fraction of the round's contribution count
        (``K = max(1, round(frac * M))``); only read when ``buffer_k`` is
        ``None``.
      staleness_alpha / staleness_beta: the FedBuff-style discount applied
        to a contribution aggregated ``s`` server ticks after it was
        issued: ``alpha / (1 + s) ** beta``.  ``beta=0`` disables it
        (``alpha`` then scales all weights uniformly and cancels in the
        normalized Eq.-11 mean).
      max_staleness: drop (never aggregate) contributions older than this
        many ticks; ``None`` keeps everything buffered.
      delay_scale: seconds of local-training time per data row at unit
        client speed.  ``0.0`` disables the whole delay model — compute
        *and* link delays are exactly zero and every round's arrivals are
        simultaneous.
      delay_sigma: sigma of the lognormal per-client compute jitter
        (``exp(sigma * Z)``, Z ~ N(0,1) per client per round).
      hop_deadline_s: park diffusion hops whose payload would arrive at
        the carrier later than this (the stale carrier still receives the
        model — it just skips the training session; the wire event stays
        charged, Eq. 15).  ``None`` never parks.
      population: size of the simulated user population the cohort is drawn
        from each tick (``fl/population.py``).  ``0`` disables sampling —
        ``num_clients`` is the world size, as in the sync planes.  When
        set, ``num_clients`` becomes the *cohort* size.
      avail_alpha / avail_beta: Beta-distribution shape of per-user
        availability (the sampling weight) across the population.
      speed_sigma: sigma of the *persistent* lognormal per-user compute
        speed across the population (heterogeneous hardware); drawn once
        per user, not per round.
    """
    buffer_k: int | None = None
    buffer_frac: float | None = None
    staleness_alpha: float = 1.0
    staleness_beta: float = 0.0
    max_staleness: int | None = None
    delay_scale: float = 0.0
    delay_sigma: float = 0.0
    hop_deadline_s: float | None = None
    population: int = 0
    avail_alpha: float = 2.0
    avail_beta: float = 2.0
    speed_sigma: float = 0.5

    def discount(self, staleness) -> float:
        """Staleness weight multiplier ``alpha / (1 + s) ** beta``."""
        return float(self.staleness_alpha
                     / (1.0 + float(staleness)) ** self.staleness_beta)

    def resolve_k(self, num_contributions: int) -> int:
        """K for a tick with ``num_contributions`` fresh contributions."""
        if self.buffer_k is not None:
            return max(1, min(int(self.buffer_k), num_contributions))
        if self.buffer_frac is not None:
            return max(1, min(int(round(self.buffer_frac
                                        * num_contributions)),
                              num_contributions))
        return num_contributions

    def validate(self) -> None:
        assert self.buffer_k is None or self.buffer_k >= 1, self.buffer_k
        assert self.buffer_frac is None or 0.0 < self.buffer_frac <= 1.0, \
            self.buffer_frac
        assert self.staleness_alpha > 0.0, self.staleness_alpha
        assert self.staleness_beta >= 0.0, self.staleness_beta
        assert self.delay_scale >= 0.0, self.delay_scale
        assert self.population >= 0, self.population


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """The typed engine selection — everything that picks an execution plane.

    Attributes:
      mode: "host" | "fleet" | "sharded" | "async" | "auto" ("auto" resolves
        by fleet size and device count, see :meth:`auto`).
      planner: "host" | "jax" control plane (``core.diffusion``).
      data_plane: the async plane's *inner* op executor ("auto" | "host" |
        "fleet") — the buffered-async engine replays each round's schedule
        ops through it, then re-orders the aggregation by arrival.
      shard_overlap / shard_hop_transport / shard_microbatch /
        mesh_model_axis: the sharded plane's knobs, verbatim from the old
        ``FLConfig`` fields.
      buffered: the :class:`AsyncSpec` knobs (read when ``mode="async"``).
    """
    mode: str = "host"
    planner: str = "host"
    data_plane: str = "auto"
    shard_overlap: str = "auto"
    shard_hop_transport: str = "auto"
    shard_microbatch: int = 32
    mesh_model_axis: int = 1
    buffered: AsyncSpec = dataclasses.field(default_factory=AsyncSpec)

    # --------------------------------------------------------- validation

    def validate(self) -> None:
        assert self.mode in ENGINE_MODES, self.mode
        assert self.planner in ("host", "jax"), self.planner
        assert self.data_plane in ("auto", "host", "fleet"), self.data_plane
        assert self.shard_overlap in ("auto", "on", "off"), self.shard_overlap
        assert self.shard_hop_transport in ("auto", "ring", "gather"), \
            self.shard_hop_transport
        self.buffered.validate()

    # --------------------------------------------------------- resolution

    def auto(self, num_clients: int) -> "EngineSpec":
        """Resolve "auto" and downgrade infeasible sharded requests.

        Absorbs ``orchestrator._pick_executor``: a sharded engine below the
        measured :data:`SHARDED_CROSSOVER_N` (or on a single device, where
        the mesh degenerates anyway) downgrades to the fleet plane;
        ``mode="auto"`` picks sharded above the crossover on a multi-device
        runtime and fleet otherwise.  Idempotent; never changes an explicit
        host/fleet/async request.
        """
        import jax
        mode = self.mode
        multi = jax.device_count() > 1
        if mode == "auto":
            mode = ("sharded" if multi and num_clients >= SHARDED_CROSSOVER_N
                    else "fleet")
        if mode == "sharded" and num_clients < SHARDED_CROSSOVER_N:
            mode = "fleet"
        return self if mode == self.mode \
            else dataclasses.replace(self, mode=mode)

    def inner_data_plane(self, num_clients: int) -> str:
        """The async plane's inner op executor, "auto" resolved by size."""
        if self.data_plane != "auto":
            return self.data_plane
        return "fleet" if num_clients >= SHARDED_CROSSOVER_N else "host"

    def describe(self) -> str:
        """Stable one-line fingerprint (checkpoint config guard, records)."""
        b = self.buffered
        base = (f"{self.mode}/planner={self.planner}"
                f"/overlap={self.shard_overlap}"
                f"/transport={self.shard_hop_transport}"
                f"/mb={self.shard_microbatch}/km={self.mesh_model_axis}")
        if self.mode != "async":
            return base
        return (base + f"/data={self.data_plane}/k={b.buffer_k}"
                f"/frac={b.buffer_frac}/a={b.staleness_alpha}"
                f"/b={b.staleness_beta}/smax={b.max_staleness}"
                f"/ds={b.delay_scale}/sig={b.delay_sigma}"
                f"/ddl={b.hop_deadline_s}/pop={b.population}"
                f"/av={b.avail_alpha},{b.avail_beta}"
                f"/spd={b.speed_sigma}")

    # ------------------------------------------------------ legacy mapping

    @classmethod
    def from_config(cls, cfg) -> "EngineSpec":
        """Deprecation shim: map the legacy ``FLConfig`` string kwargs onto
        a spec.  Warns once per process when any legacy engine field is
        set away from its default (the new spelling is
        ``FLConfig(engine=EngineSpec(...))`` or a preset name)."""
        spec = cls(mode=str(getattr(cfg, "executor", "host")),
                   planner=str(getattr(cfg, "planner", "host")),
                   shard_overlap=str(getattr(cfg, "shard_overlap", "auto")),
                   shard_hop_transport=str(getattr(cfg, "shard_hop_transport",
                                                   "auto")),
                   shard_microbatch=int(getattr(cfg, "shard_microbatch", 32)),
                   mesh_model_axis=int(getattr(cfg, "mesh_model_axis", 1)))
        global _WARNED_LEGACY
        if not _WARNED_LEGACY and spec != cls():
            _WARNED_LEGACY = True
            warnings.warn(
                "engine selection via FLConfig string kwargs (executor=, "
                "planner=, shard_*=) is deprecated; pass "
                "FLConfig(engine=EngineSpec(...)) or a preset name "
                "(engine='fleet') instead — the legacy kwargs keep working "
                "for one release through this shim",
                DeprecationWarning, stacklevel=3)
        return spec

    @classmethod
    def preset(cls, name: str) -> "EngineSpec":
        try:
            return ENGINE_PRESETS[name]
        except KeyError:
            raise ValueError(
                f"unknown engine preset {name!r}; expected one of "
                f"{sorted(ENGINE_PRESETS)}") from None


#: Named engine presets — what ``--engine`` flags and ``FLConfig.engine``
#: strings resolve to.  "async" is the headline buffered-async
#: configuration: half-buffer ticks, staleness discount on, lognormal
#: compute stragglers and channel-drawn link delays.
ENGINE_PRESETS: dict[str, EngineSpec] = {
    "host": EngineSpec(mode="host"),
    "fleet": EngineSpec(mode="fleet"),
    "sharded": EngineSpec(mode="sharded"),
    "auto": EngineSpec(mode="auto"),
    "async": EngineSpec(mode="async", buffered=AsyncSpec(
        buffer_frac=0.5, staleness_beta=0.5,
        delay_scale=0.01, delay_sigma=1.0)),
    # Barrier-on-the-event-queue: the async machinery with K = everything
    # and the same delay model — the sync comparison arm of fig_async /
    # the async_throughput bench (tick time = slowest arrival).
    "async_barrier": EngineSpec(mode="async", buffered=AsyncSpec(
        delay_scale=0.01, delay_sigma=1.0)),
}

_WARNED_LEGACY = False


def resolve_engine(cfg) -> EngineSpec:
    """THE engine-selection authority: ``FLConfig`` -> :class:`EngineSpec`.

    ``cfg.engine`` wins when set (an :class:`EngineSpec`, or a preset name);
    otherwise the legacy string kwargs map through the deprecation shim.
    ``mode="auto"`` resolves against ``cfg.num_clients``.
    """
    eng = getattr(cfg, "engine", None)
    if eng is None:
        spec = EngineSpec.from_config(cfg)
    elif isinstance(eng, str):
        spec = EngineSpec.preset(eng)
    elif isinstance(eng, EngineSpec):
        spec = eng
    else:
        raise TypeError(f"FLConfig.engine must be an EngineSpec or a preset "
                        f"name, got {type(eng).__name__}")
    if spec.mode == "auto":
        spec = spec.auto(int(getattr(cfg, "num_clients", 0)))
    spec.validate()
    return spec


def engine_fingerprint(cfg) -> str:
    """Resolved-engine fingerprint for the checkpoint config guard."""
    return resolve_engine(cfg).describe()


# --------------------------------------------------------------------------
# RunResult — the structured return of run_federated
# --------------------------------------------------------------------------

@dataclasses.dataclass
class RunHistory:
    """Per-round curves of one run.  The async plane fills the last four."""
    accuracy: list = dataclasses.field(default_factory=list)
    loss: list = dataclasses.field(default_factory=list)
    diffusion_rounds: list = dataclasses.field(default_factory=list)
    iid_distance: list = dataclasses.field(default_factory=list)
    round_wall_s: list = dataclasses.field(default_factory=list)
    phase_s: list = dataclasses.field(default_factory=list)
    # --- async round plane only (empty under the sync engines) ---
    virtual_s: list = dataclasses.field(default_factory=list)   # tick clock
    arrivals: list = dataclasses.field(default_factory=list)    # agg'd per tick
    staleness: list = dataclasses.field(default_factory=list)   # mean per tick
    parked_hops: list = dataclasses.field(default_factory=list)  # per round


@dataclasses.dataclass
class RunResult:
    """What ``run_federated`` returns: the structured (params, ledger,
    history) triple plus the engine actually used.

    Backwards compatibility (one release): the flat ``FLResult`` attributes
    are properties over ``history``, and ``params, ledger, history = result``
    unpacks via ``__iter__``.
    """
    params: Any
    ledger: Any
    history: RunHistory
    engine: EngineSpec | None = None
    config: Any = None

    def __iter__(self):
        yield self.params
        yield self.ledger
        yield self.history

    # ------------------------------------------- legacy FLResult surface

    @property
    def final_params(self):
        return self.params

    @property
    def accuracy(self) -> list:
        return self.history.accuracy

    @property
    def loss(self) -> list:
        return self.history.loss

    @property
    def diffusion_rounds(self) -> list:
        return self.history.diffusion_rounds

    @property
    def iid_distance(self) -> list:
        return self.history.iid_distance

    @property
    def round_wall_s(self) -> list:
        return self.history.round_wall_s

    @property
    def phase_s(self) -> list:
        return self.history.phase_s

    def rounds_to_accuracy(self, target: float) -> int | None:
        for i, a in enumerate(self.history.accuracy):
            if a >= target:
                return i + 1
        return None

    def time_to_accuracy(self, target: float) -> float | None:
        """Virtual seconds to reach ``target`` accuracy (async plane; falls
        back to the round index when no virtual clock was recorded)."""
        r = self.rounds_to_accuracy(target)
        if r is None:
            return None
        if self.history.virtual_s:
            return float(self.history.virtual_s[min(
                r - 1, len(self.history.virtual_s) - 1)])
        return float(r)

    @classmethod
    def from_histories(cls, *, accuracy, loss, ledger, diffusion_rounds,
                       iid_distance, config=None, final_params=None,
                       round_wall_s=(), phase_s=(), engine=None,
                       **async_hist) -> "RunResult":
        """Build a result from the flat legacy field spelling (replication
        engines, tests)."""
        hist = RunHistory(accuracy=list(accuracy), loss=list(loss),
                          diffusion_rounds=list(diffusion_rounds),
                          iid_distance=list(iid_distance),
                          round_wall_s=list(round_wall_s),
                          phase_s=list(phase_s), **async_hist)
        return cls(params=final_params, ledger=ledger, history=hist,
                   engine=engine, config=config)
