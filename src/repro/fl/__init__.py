from repro.fl.models import TaskModel, build_task_model, TASK_MODELS
from repro.fl.client import make_local_update, local_update
from repro.fl.compression import stc_compress, compressed_bits
from repro.fl.adapters import AdapterView, make_adapter_view, packed_bits
from repro.fl.server import (FLConfig, FLResult, run_federated, STRATEGIES,
                             HOP_QUANTS)
from repro.fl.engine import (AsyncSpec, EngineSpec, ENGINE_PRESETS,
                             RunHistory, RunResult, resolve_engine)
from repro.fl.population import Population, CohortDraw
from repro.fl.schedulers import SCHEDULERS, RoundContext
from repro.fl.executors import (EXECUTORS, FleetExecutor, HostExecutor,
                                ShardedFleetExecutor)
from repro.fl.fedprox import make_prox_local_update
from repro.fl.experiment import (ExperimentSpec, run_experiment,
                                 spec_adapter_bits, spec_model_bits)
