"""Executors: run one :class:`~repro.core.schedule.RoundSchedule` on params.

Two data planes consume the same schedule object:

* :class:`HostExecutor` — the reference semantics.  One parameter pytree per
  client slot, local updates through ``repro.fl.client`` /
  ``repro.fl.fedprox`` exactly as the original per-strategy loops did
  (same per-client batch draws, same jitted step, same aggregation order),
  so refactored strategies reproduce their pre-schedule trajectories.

* :class:`FleetExecutor` — the client-stacked fast path.  All slots live on
  one pytree with a leading client axis; a local "session" (one epoch of
  batches, momentum restarted, per-slot gradient clipping) is a jitted
  ``vmap`` over that axis, a diffusion hop is
  :func:`~repro.distributed.fedshard.diffuse_params`, STC hops use
  :func:`~repro.distributed.fedshard.masked_stc_compress`, and Eq.-11
  aggregation is one weighted ``tensordot``.  Clients with shorter epochs
  are padded and masked out per step, so the math per client matches the
  host loop; the win is dispatch count — O(max-epoch) jitted calls per op
  instead of O(Σ client batches) — which is what lets sweeps scale past
  paper-sized fleets.

Ledger charging lives in neither: :func:`~repro.core.schedule
.charge_schedule` replays the schedule's wire events, so both executors
report identical communication metrics by construction.
"""
from __future__ import annotations

import copy
import functools
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as agg
from repro.core.schedule import MixOp, PermuteOp, RoundSchedule, TrainOp
from repro.distributed.fedshard import diffuse_params, masked_stc_compress
from repro.fl.compression import stc_compress
from repro.fl.schedulers import PROX_STRATEGIES
from repro.train import optimizer as opt_lib

Params = Any

__all__ = ["HostExecutor", "FleetExecutor", "make_executor", "EXECUTORS"]

EXECUTORS = ("host", "fleet")


def _tree_sub(a, b):
    return jax.tree.map(lambda x, y: x - y, a, b)


def _tree_add(a, b):
    return jax.tree.map(lambda x, y: x + y, a, b)


class HostExecutor:
    """Per-slot pytree-list execution — the bit-for-bit reference path."""

    def __init__(self, local_update: Callable,
                 client_batches: Sequence[Callable], cfg):
        self.local_update = local_update
        self.client_batches = client_batches
        self.cfg = cfg

    def _train(self, slots: list, mask: np.ndarray) -> None:
        for c in np.flatnonzero(mask):
            slots[c], _ = self.local_update(
                slots[c], self.client_batches[c](), self.cfg.lr)

    def run_round(self, sched: RoundSchedule, global_params: Params,
                  slots: list | None) -> tuple[Params, list | None]:
        c_slots = sched.num_slots
        if not sched.persistent or slots is None:
            slots = [copy.deepcopy(global_params) for _ in range(c_slots)]
        ref = global_params
        for op in sched.ops:
            if isinstance(op, TrainOp):
                self._train(slots, op.train_mask)
            elif isinstance(op, PermuteOp):
                if op.compress:
                    for s in np.flatnonzero(op.compress_src_mask()):
                        delta = stc_compress(_tree_sub(slots[s], ref),
                                             sched.stc_sparsity)
                        slots[s] = _tree_add(ref, delta)
                slots = [slots[int(op.src_of_dst[c])] for c in range(c_slots)]
                self._train(slots, op.train_mask)
            elif isinstance(op, MixOp):
                for members, weights in op.groups:
                    avg = agg.fedavg([slots[i] for i in members],
                                     list(weights))
                    for i in members:
                        slots[i] = avg
            else:
                raise TypeError(f"unknown op {type(op).__name__}")
        weights = [w for _, w in sched.agg]
        if sched.agg_mode == "stc_delta":
            deltas = [stc_compress(_tree_sub(slots[s], ref),
                                   sched.stc_sparsity) for s, _ in sched.agg]
            new_global = _tree_add(ref, agg.fedavg(deltas, weights))
        else:
            new_global = agg.fedavg([slots[s] for s, _ in sched.agg], weights)
        return new_global, (slots if sched.persistent else None)


class FleetExecutor:
    """Client-stacked execution: one pytree, leading client axis, jitted."""

    def __init__(self, loss_fn: Callable,
                 client_batches: Sequence[Callable], cfg,
                 clip: float | None = 10.0):
        self.loss_fn = loss_fn
        self.client_batches = client_batches
        self.cfg = cfg
        self.prox = cfg.strategy in PROX_STRATEGIES
        opt = opt_lib.sgd(momentum=cfg.momentum)
        mu = float(cfg.prox_mu)

        def one(p, mom, batch, active, anchor):
            def obj(q):
                loss = loss_fn(q, batch)
                if self.prox:
                    prox = sum(jnp.sum((a.astype(jnp.float32)
                                        - b.astype(jnp.float32)) ** 2)
                               for a, b in zip(jax.tree.leaves(q),
                                               jax.tree.leaves(anchor)))
                    loss = loss + 0.5 * mu * prox
                return loss

            loss, grads = jax.value_and_grad(obj)(p)
            if clip is not None:
                grads, _ = opt_lib.clip_by_global_norm(grads, clip)
            updates, new_state = opt.update(grads, {"mu": mom}, p, cfg.lr)
            p2 = opt_lib.apply_updates(p, updates)
            sel = functools.partial(jnp.where, active)
            return (jax.tree.map(sel, p2, p),
                    jax.tree.map(sel, new_state["mu"], mom), loss)

        self._step = jax.jit(jax.vmap(one))

    # ---------------------------------------------------------------- batches

    def _draw_session(self, mask: np.ndarray):
        """Draw one local epoch per *masked* slot (preserving each client's
        host-side batch stream), pad to the longest epoch, stack per step.

        Returns ``(steps, actives)``: per padded step, a client-stacked batch
        dict and the (C,) bool mask of slots genuinely training that step.
        """
        per_slot = [list(self.client_batches[c]()) if mask[c] else []
                    for c in range(len(mask))]
        nb = max((len(b) for b in per_slot), default=0)
        if nb == 0:
            return [], []
        template = jax.tree.map(
            np.zeros_like, next(b[0] for b in per_slot if b))
        steps, actives = [], []
        for k in range(nb):
            rows = [b[k] if k < len(b) else template for b in per_slot]
            steps.append(jax.tree.map(
                lambda *xs: jnp.asarray(np.stack(xs)), *rows))
            actives.append(jnp.asarray(
                np.array([k < len(b) for b in per_slot])))
        return steps, actives

    def _session(self, params: Params, mask: np.ndarray) -> Params:
        """One local-update session at every masked slot (vmapped epoch)."""
        if not mask.any():
            return params
        steps, actives = self._draw_session(mask)
        mom = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        anchor = params      # prox anchor = the received model (host default)
        for batch, active in zip(steps, actives):
            params, mom, _ = self._step(params, mom, batch, active, anchor)
        return params

    # ------------------------------------------------------------------ round

    def run_round(self, sched: RoundSchedule, global_params: Params,
                  slots: Params | None) -> tuple[Params, Params | None]:
        c_slots = sched.num_slots
        if sched.persistent and slots is not None:
            params = slots
        else:
            params = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (c_slots,) + x.shape),
                global_params)
        ref = global_params
        for op in sched.ops:
            if isinstance(op, TrainOp):
                params = self._session(params, op.train_mask)
            elif isinstance(op, PermuteOp):
                if op.compress:
                    params = masked_stc_compress(
                        params, ref, jnp.asarray(op.compress_src_mask()),
                        sched.stc_sparsity)
                params = diffuse_params(params,
                                        jnp.asarray(op.src_of_dst))
                params = self._session(params, op.train_mask)
            elif isinstance(op, MixOp):
                w = jnp.asarray(op.matrix(c_slots))
                params = jax.tree.map(
                    lambda x: jnp.einsum(
                        "ij,j...->i...", w,
                        x.astype(jnp.float32)).astype(x.dtype), params)
            else:
                raise TypeError(f"unknown op {type(op).__name__}")
        wvec = sched.slot_weights()
        w = jnp.asarray((wvec / wvec.sum()).astype(np.float32))
        if sched.agg_mode == "stc_delta":
            payload = masked_stc_compress(
                params, ref, jnp.asarray(wvec > 0), sched.stc_sparsity)
        else:
            payload = params
        new_global = jax.tree.map(
            lambda x: jnp.tensordot(w, x.astype(jnp.float32),
                                    axes=(0, 0)).astype(x.dtype), payload)
        return new_global, (params if sched.persistent else None)


def make_executor(name: str, loss_fn: Callable, local_update: Callable,
                  client_batches: Sequence[Callable], cfg):
    """Build the executor selected by ``FLConfig.executor``."""
    if name == "host":
        return HostExecutor(local_update, client_batches, cfg)
    if name == "fleet":
        return FleetExecutor(loss_fn, client_batches, cfg)
    raise ValueError(f"unknown executor {name!r}; expected one of "
                     f"{EXECUTORS}")
