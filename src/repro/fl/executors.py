"""Executors: run one :class:`~repro.core.schedule.RoundSchedule` on params.

Two data planes consume the same schedule object:

* :class:`HostExecutor` — the reference semantics.  One parameter pytree per
  client slot, local updates through ``repro.fl.client`` /
  ``repro.fl.fedprox`` exactly as the original per-strategy loops did
  (same per-client batch draws, same jitted step, same aggregation order),
  so refactored strategies reproduce their pre-schedule trajectories.

* :class:`FleetExecutor` — the client-stacked fast path.  All slots live on
  one pytree with a leading client axis; a local "session" (one epoch of
  batches, momentum restarted, per-slot gradient clipping) is a jitted
  ``vmap`` over that axis, a diffusion hop is
  :func:`~repro.distributed.fedshard.diffuse_params`, STC hops use
  :func:`~repro.distributed.fedshard.masked_stc_compress`, and Eq.-11
  aggregation is one weighted ``tensordot``.  Clients with shorter epochs
  are padded and masked out per step, so the math per client matches the
  host loop; the win is dispatch count — O(max-epoch) jitted calls per op
  instead of O(Σ client batches) — which is what lets sweeps scale past
  paper-sized fleets.

* :class:`ShardedFleetExecutor` — the large-N plane, on the 2-D
  ``("clients", "model")`` mesh of :func:`repro.launch.mesh.make_fl_mesh`.
  The stacked pytree's leading client axis is *sharded* over the combined
  mesh axis (:func:`repro.distributed.sharding.fl_stacked_specs`), padded
  with zero-weighted slots when N does not divide the mesh, and runs in one
  of two shard_map planes selected by ``FLConfig.shard_overlap``:

  - the **op-by-op plane** (``shard_overlap="off"``, and the plane phase
    profiling runs on): one compiled collective per schedule op — sessions
    are ``shard_map``-ped with the per-shard block microbatched (``lax.map``
    over chunks of ``FLConfig.shard_microbatch`` clients) so N=256–4096
    fleets fit in memory, a :class:`~repro.core.schedule.PermuteOp` is a
    ring-shift-decomposed permutation collective (static routing tables +
    per-shift ``lax.ppermute``; with a model axis the flattened parameter
    block is first feature-split over ``"model"`` via ``all_to_all`` so
    each shift moves only F/km bytes per link), a
    :class:`~repro.core.schedule.MixOp` is Wᵀ-partials + ``psum_scatter``,
    and Eq.-11 aggregation is a masked ``psum`` over the combined axis.

  - the **fused round plane** (``"on"``; ``"auto"`` resolves to it): the
    whole round — broadcast, sessions, STC hops, permutes, mixes,
    aggregation — is ONE jitted shard_map program per round signature.
    Hop k's ring shifts are issued per *double-buffered chunk*: the send
    buffers of chunk j+1 depend only on pre-hop state, so their collectives
    can overlap chunk j's training compute (async collectives where the
    backend supports them; on CPU the win is dispatch count — a handful of
    device calls per round instead of O(hops × steps)).

  On a 1-device mesh both planes degenerate to the fleet program.

Under ``FLConfig.hop_quant == "int8"`` every PermuteOp payload crosses the
wire int8-packed (``repro.fl.adapters``): each executor applies exactly one
pack→unpack roundtrip per hop to every slot — the host roundtrips slot
trees, the fleet roundtrips the stacked pytree, and the sharded planes move
the packed codes + scales through the very ring/gather collectives that
implement the hop.  Per-row packing commutes with row movement, so the
three placements stay numerically identical.

Ledger charging lives in none of them: :func:`~repro.core.schedule
.charge_schedule` replays the schedule's wire events, so all executors
report identical communication metrics by construction.
"""
from __future__ import annotations

import copy
import functools
import time
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import aggregation as agg
from repro.core.schedule import MixOp, PermuteOp, RoundSchedule, TrainOp
from repro.distributed.fedshard import diffuse_params, masked_stc_compress
from repro.distributed.sharding import CLIENT_AXIS, FL_AXES, MODEL_AXIS
from repro.fl.adapters import (pack_rows, quant_roundtrip_rows,
                               quant_roundtrip_slot, quant_roundtrip_tree,
                               unpack_rows)
from repro.fl.compression import stc_compress
from repro.fl.schedulers import PROX_STRATEGIES
from repro.kernels import ops as kernel_ops
from repro.kernels.diffusion import stack_ravel, stack_unravel
from repro.train import optimizer as opt_lib

Params = Any

__all__ = ["HostExecutor", "FleetExecutor", "ShardedFleetExecutor",
           "make_executor", "EXECUTORS"]

EXECUTORS = ("host", "fleet", "sharded")


def _tree_sub(a, b):
    return jax.tree.map(lambda x, y: x - y, a, b)


def _tree_add(a, b):
    return jax.tree.map(lambda x, y: x + y, a, b)


class HostExecutor:
    """Per-slot pytree-list execution — the bit-for-bit reference path."""

    def __init__(self, local_update: Callable,
                 client_batches: Sequence[Callable], cfg):
        self.local_update = local_update
        self.client_batches = client_batches
        self.cfg = cfg
        self.quant = str(getattr(cfg, "hop_quant", "none")) == "int8"

    def _train(self, slots: list, mask: np.ndarray) -> None:
        for c in np.flatnonzero(mask):
            slots[c], _ = self.local_update(
                slots[c], self.client_batches[c](), self.cfg.lr)

    # ------------------------------------------------- round-state capture
    # Persistent strategies (gossip, tthf) carry per-slot state across
    # communication rounds; the resume seam (repro.fl.resume) round-trips it
    # through these three hooks so a checkpoint taken under any executor
    # restores onto the same executor bit-identically.

    def capture_slots(self, slots: list | None):
        """Host-resident copy of the persistent slot state (or ``None``)."""
        return None if slots is None else jax.device_get(slots)

    def slots_like(self, global_params: Params, num_slots: int):
        """Shape/dtype template matching :meth:`capture_slots` output."""
        leaf = lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype)  # noqa: E731
        return [jax.tree.map(leaf, global_params) for _ in range(num_slots)]

    def num_slots_of(self, saved) -> int:
        """Slot count of a :meth:`capture_slots` capture (host: outer list).

        The executor is authoritative here — the capture's pytree structure
        alone is ambiguous (a model whose params are themselves a list looks
        like a host slot-list)."""
        return len(saved)

    def adopt_slots(self, saved):
        """Executor-native placement of a captured slot tree."""
        return saved

    def run_ops(self, sched: RoundSchedule, global_params: Params,
                slots: list | None) -> list:
        """Replay the schedule's op list; return the post-op slot state.

        The first half of :meth:`run_round` — the buffered-async plane runs
        it per round, then defers :meth:`aggregate` to arrival order."""
        c_slots = sched.num_slots
        if not sched.persistent or slots is None:
            slots = [copy.deepcopy(global_params) for _ in range(c_slots)]
        ref = global_params
        for op in sched.ops:
            if isinstance(op, TrainOp):
                self._train(slots, op.train_mask)
            elif isinstance(op, PermuteOp):
                if op.compress:
                    for s in np.flatnonzero(op.compress_src_mask()):
                        delta = stc_compress(_tree_sub(slots[s], ref),
                                             sched.stc_sparsity)
                        slots[s] = _tree_add(ref, delta)
                if self.quant:
                    # int8 wire: what each destination decodes is the
                    # pack→unpack of the payload (hop is a bijection, so
                    # every slot moves and is roundtripped exactly once).
                    slots = [quant_roundtrip_slot(s) for s in slots]
                slots = [slots[int(op.src_of_dst[c])] for c in range(c_slots)]
                self._train(slots, op.train_mask)
            elif isinstance(op, MixOp):
                for members, weights in op.groups:
                    avg = agg.fedavg([slots[i] for i in members],
                                     list(weights))
                    for i in members:
                        slots[i] = avg
            else:
                raise TypeError(f"unknown op {type(op).__name__}")
        return slots

    def slot_state(self, slots: list, slot: int) -> Params:
        """The post-op payload of one slot (host: its pytree)."""
        return slots[slot]

    def aggregate(self, sched: RoundSchedule, slots: list,
                  ref: Params) -> Params:
        """Eq. (11) over the schedule's ``agg`` entries, in entry order."""
        weights = [w for _, w in sched.agg]
        if sched.agg_mode == "stc_delta":
            deltas = [stc_compress(_tree_sub(slots[s], ref),
                                   sched.stc_sparsity) for s, _ in sched.agg]
            return _tree_add(ref, agg.fedavg(deltas, weights))
        return agg.fedavg([slots[s] for s, _ in sched.agg], weights)

    def run_round(self, sched: RoundSchedule, global_params: Params,
                  slots: list | None) -> tuple[Params, list | None]:
        slots = self.run_ops(sched, global_params, slots)
        new_global = self.aggregate(sched, slots, global_params)
        return new_global, (slots if sched.persistent else None)


class FleetExecutor:
    """Client-stacked execution: one pytree, leading client axis, jitted."""

    def __init__(self, loss_fn: Callable,
                 client_batches: Sequence[Callable], cfg,
                 clip: float | None = 10.0):
        self.loss_fn = loss_fn
        self.client_batches = client_batches
        self.cfg = cfg
        self.quant = str(getattr(cfg, "hop_quant", "none")) == "int8"
        self.prox = cfg.strategy in PROX_STRATEGIES
        opt = opt_lib.sgd(momentum=cfg.momentum)
        mu = float(cfg.prox_mu)

        def one(p, mom, batch, active, anchor):
            def obj(q):
                loss = loss_fn(q, batch)
                if self.prox:
                    prox = sum(jnp.sum((a.astype(jnp.float32)
                                        - b.astype(jnp.float32)) ** 2)
                               for a, b in zip(jax.tree.leaves(q),
                                               jax.tree.leaves(anchor)))
                    loss = loss + 0.5 * mu * prox
                return loss

            loss, grads = jax.value_and_grad(obj)(p)
            if clip is not None:
                grads, _ = opt_lib.clip_by_global_norm(grads, clip)
            updates, new_state = opt.update(grads, {"mu": mom}, p, cfg.lr)
            p2 = opt_lib.apply_updates(p, updates)
            sel = functools.partial(jnp.where, active)
            return (jax.tree.map(sel, p2, p),
                    jax.tree.map(sel, new_state["mu"], mom), loss)

        self._one = one          # per-client step; ShardedFleetExecutor remaps
        self._step = jax.jit(jax.vmap(one))
        self.profile = bool(getattr(cfg, "profile_phases", False))
        self._phase: dict = {}

    # ------------------------------------------------------- phase profiling

    def _timed(self, phase: str, fn, *args):
        """Run a round primitive; under ``cfg.profile_phases`` sync the
        device and charge the wall-clock to ``phase`` (train /
        hop_collective / mix — "plan" is added by the server)."""
        if not self.profile:
            return fn(*args)
        t0 = time.time()
        out = fn(*args)
        jax.block_until_ready(out)
        self._phase[phase] = self._phase.get(phase, 0.0) + time.time() - t0
        return out

    def pop_phase_times(self) -> dict:
        """Return and reset the per-round phase accumulator."""
        out, self._phase = self._phase, {}
        return out

    # ---------------------------------------------------------------- batches

    def _draw_session(self, mask: np.ndarray):
        """Draw one local epoch per *masked* slot (preserving each client's
        host-side batch stream), pad to the longest epoch, stack per step.

        Returns ``(steps, actives)``: per padded step, a client-stacked batch
        dict and the (C,) bool mask of slots genuinely training that step.
        """
        per_slot = [list(self.client_batches[c]()) if mask[c] else []
                    for c in range(len(mask))]
        nb = max((len(b) for b in per_slot), default=0)
        if nb == 0:
            return [], []
        template = jax.tree.map(
            np.zeros_like, next(b[0] for b in per_slot if b))
        steps, actives = [], []
        for k in range(nb):
            rows = [b[k] if k < len(b) else template for b in per_slot]
            steps.append(jax.tree.map(
                lambda *xs: jnp.asarray(np.stack(xs)), *rows))
            actives.append(jnp.asarray(
                np.array([k < len(b) for b in per_slot])))
        return steps, actives

    def _session(self, params: Params, mask: np.ndarray) -> Params:
        """One local-update session at every masked slot (vmapped epoch)."""
        if not mask.any():
            return params
        steps, actives = self._draw_session(mask)
        mom = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        anchor = params      # prox anchor = the received model (host default)
        for batch, active in zip(steps, actives):
            params, mom, _ = self._step(params, mom, batch, active, anchor)
        return params

    # ------------------------------------------------- round-state capture

    def capture_slots(self, slots: Params | None):
        return None if slots is None else jax.device_get(slots)

    def slots_like(self, global_params: Params, num_slots: int):
        return jax.tree.map(
            lambda x: jax.ShapeDtypeStruct((num_slots,) + x.shape, x.dtype),
            global_params)

    def num_slots_of(self, saved) -> int:
        """Slot count of a capture (fleet: the stacked leading axis)."""
        return int(jax.tree.leaves(saved)[0].shape[0])

    def adopt_slots(self, saved):
        return jax.tree.map(jnp.asarray, saved)

    # ----------------------------------------------- overridable primitives
    # One round structure (run_round below), two placements:
    # ShardedFleetExecutor overrides exactly these five hooks with its
    # collective twins, so a new op kind or agg mode is added in one place.

    def _broadcast(self, global_params: Params, num_slots: int) -> Params:
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (num_slots,) + x.shape),
            global_params)

    def _permute(self, params: Params, op: PermuteOp) -> Params:
        if self.quant:
            # int8 wire: roundtrip the stacked payload per client row, then
            # move the decoded rows (packing commutes with row gathers).
            params = quant_roundtrip_tree(params)
        return diffuse_params(params, jnp.asarray(op.src_of_dst))

    def _mix(self, params: Params, op: MixOp, num_slots: int) -> Params:
        # Eq. (10) through the kernel data plane: the fused single-HBM-pass
        # Pallas kernel on TPU / under REPRO_KERNELS_IMPL, the per-leaf
        # einsum chain on the XLA reference path.
        w = jnp.asarray(op.matrix(num_slots), jnp.float32)
        return kernel_ops.mix_aggregate_tree(params, w)

    def _masked_stc(self, params: Params, ref: Params, mask: np.ndarray,
                    sparsity: float) -> Params:
        return masked_stc_compress(params, ref, jnp.asarray(mask), sparsity)

    def _aggregate(self, payload: Params, w: jax.Array) -> Params:
        # Eq. (11): aggregation is the same kernel with one output row.
        return kernel_ops.mix_aggregate_tree(
            payload, w.astype(jnp.float32).reshape(1, -1), collapse=True)

    # ------------------------------------------------------------------ round

    def run_ops(self, sched: RoundSchedule, global_params: Params,
                slots: Params | None) -> Params:
        """Replay the op list on the client-stacked pytree (first half of
        :meth:`run_round` — see :meth:`HostExecutor.run_ops`)."""
        c_slots = sched.num_slots
        if sched.persistent and slots is not None:
            params = slots
        else:
            params = self._timed("hop_collective", self._broadcast,
                                 global_params, c_slots)
        ref = global_params
        for op in sched.ops:
            if isinstance(op, TrainOp):
                params = self._timed("train", self._session, params,
                                     op.train_mask)
            elif isinstance(op, PermuteOp):
                if op.compress:
                    params = self._timed("hop_collective", self._masked_stc,
                                         params, ref, op.compress_src_mask(),
                                         sched.stc_sparsity)
                params = self._timed("hop_collective", self._permute,
                                     params, op)
                params = self._timed("train", self._session, params,
                                     op.train_mask)
            elif isinstance(op, MixOp):
                params = self._timed("mix", self._mix, params, op, c_slots)
            else:
                raise TypeError(f"unknown op {type(op).__name__}")
        return params

    def slot_state(self, params: Params, slot: int) -> Params:
        """The post-op payload of one slot (fleet: its stacked-axis row)."""
        return jax.tree.map(lambda x: x[slot], params)

    def aggregate(self, sched: RoundSchedule, params: Params,
                  ref: Params) -> Params:
        wvec = sched.slot_weights()
        w = jnp.asarray((wvec / wvec.sum()).astype(np.float32))
        if sched.agg_mode == "stc_delta":
            payload = self._timed("hop_collective", self._masked_stc,
                                  params, ref, wvec > 0, sched.stc_sparsity)
        else:
            payload = params
        return self._timed("mix", self._aggregate, payload, w)

    def run_round(self, sched: RoundSchedule, global_params: Params,
                  slots: Params | None) -> tuple[Params, Params | None]:
        params = self.run_ops(sched, global_params, slots)
        new_global = self.aggregate(sched, params, global_params)
        return new_global, (params if sched.persistent else None)


def _permutation_tables(src_of_dst: np.ndarray, num_shards: int
                        ) -> tuple[np.ndarray, np.ndarray]:
    """Static routing tables for a slot bijection on a ``num_shards`` mesh.

    The global permutation ``new[c] = old[src_of_dst[c]]`` is decomposed into
    ``num_shards`` ring shifts: rows moving from shard ``s`` to shard
    ``(s + shift) % K`` travel together in one ``ppermute`` step.  Returns

    * ``send[s, shift, i]`` — local row index the *source* shard ``s`` packs
      at buffer position ``i`` for shift ``shift`` (0-padded), and
    * ``recv[d, shift, i]`` — local row index where the *destination* shard
      ``d`` scatters buffer position ``i`` (padded with ``n_local``, a trash
      row dropped after the scatter).

    Packing order ``i`` is shared between the two tables because a
    ``(shift, src)`` pair determines the destination shard uniquely.  The
    tables are data, not code: one compiled collective serves every
    permutation of a round without retracing.
    """
    perm = np.asarray(src_of_dst, np.int64)
    c = perm.shape[0]
    k = num_shards
    assert c % k == 0, (c, k)
    nl = c // k
    send = np.zeros((k, k, nl), np.int32)
    recv = np.full((k, k, nl), nl, np.int32)
    fill = np.zeros((k, k), np.int32)
    for dst in range(c):
        src = int(perm[dst])
        s, d = src // nl, dst // nl
        shift = (d - s) % k
        i = int(fill[shift, s])
        fill[shift, s] = i + 1
        send[s, shift, i] = src % nl
        recv[d, shift, i] = dst % nl
    return send, recv


def _chunked_permutation_tables(src_of_dst: np.ndarray, num_shards: int,
                                num_chunks: int
                                ) -> tuple[np.ndarray, np.ndarray]:
    """:func:`_permutation_tables` split by *destination chunk* — the
    double-buffered stage tables of the fused round plane.

    The local rows of every destination shard are cut into ``num_chunks``
    contiguous chunks of ``mb = n_local / num_chunks`` rows; the rows
    landing in chunk ``j`` travel in their own per-shift buffers, so chunk
    ``j+1``'s collectives depend only on the *pre-hop* state and can be
    issued while chunk ``j`` trains.  Returns

    * ``send[s, j, shift, i]`` — local row the source shard ``s`` packs at
      position ``i`` of the (chunk ``j``, ``shift``) buffer (0-padded), and
    * ``recv[d, j, shift, i]`` — *chunk-relative* row where destination
      ``d`` scatters position ``i`` (padded with ``mb``, a trash row).

    A ``(shift, src, chunk)`` triple determines the destination shard, so
    the packing order is shared exactly as in the unchunked tables; a
    buffer never overflows ``mb`` because chunk ``j`` only has ``mb`` rows.
    """
    perm = np.asarray(src_of_dst, np.int64)
    c = perm.shape[0]
    k = num_shards
    assert c % k == 0, (c, k)
    nl = c // k
    assert nl % num_chunks == 0, (nl, num_chunks)
    mb = nl // num_chunks
    send = np.zeros((k, num_chunks, k, mb), np.int32)
    recv = np.full((k, num_chunks, k, mb), mb, np.int32)
    fill = np.zeros((k, num_chunks, k), np.int32)
    for dst in range(c):
        src = int(perm[dst])
        s, d = src // nl, dst // nl
        r = dst % nl
        j = r // mb
        shift = (d - s) % k
        i = int(fill[s, j, shift])
        fill[s, j, shift] = i + 1
        send[s, j, shift, i] = src % nl
        recv[d, j, shift, i] = r - j * mb
    return send, recv


class ShardedFleetExecutor(FleetExecutor):
    """Client-sharded execution over the 2-D ``("clients", "model")`` mesh.

    Same math as :class:`FleetExecutor` (it reuses the per-client step and
    the host-side batch streams verbatim); the difference is placement and
    program shape:

    * **Layout.**  The leading client axis of every leaf is sharded over the
      *combined* mesh axes; N is padded to ``c_pad`` (next multiple of the
      mesh size) with zero-weighted padding slots — identity rows in mix
      matrices, identity extensions of hop permutations, ``False`` training
      masks — so padding never leaks into real slots and no divisibility is
      required of N.  During a hop with ``km > 1`` the flattened parameter
      block is feature-split over ``"model"`` (``all_to_all``), each
      ``"clients"``-ring ``ppermute`` then moves F/km bytes per link, and
      the inverse ``all_to_all`` restores the train layout.

    * **Planes.**  ``FLConfig.shard_overlap`` picks between the inherited
      op-by-op round loop (one compiled collective per schedule op; the
      plane phase profiling must run on) and the fused round plane: the
      whole round is ONE jitted shard_map program per round *signature*
      (op kinds + step counts + compress/agg flags + hop transport), with
      each hop's ring shifts issued per double-buffered destination chunk
      so chunk j+1's collectives — which depend only on pre-hop state —
      overlap chunk j's training compute.

    * **Hop transport.**  ``FLConfig.shard_hop_transport`` picks the fused
      plane's hop collective: ``"gather"`` (one tiled ``all_gather`` over
      the combined axes + local row-take — a single rendezvous per hop)
      or ``"ring"`` (kc ``ppermute`` shifts, O(block) memory, double
      buffered).  ``"auto"`` takes gather while the gathered ``(c_pad, F)``
      stack fits ``GATHER_BUDGET_BYTES`` per device and rings past it.

    * **Signature stability.**  Every distinct round signature is a fresh
      trace + XLA compile of the whole-round program — at N ≥ 1024 that
      retrace dominated the round wall-clock, because both the diffusion
      wave count and the ragged epoch lengths vary per round.  The fused
      plane therefore normalizes the signature: all session step counts
      pad to a running maximum (padded steps carry all-``False`` active
      masks and are skipped at runtime by a ``lax.cond`` that sits outside
      the vmap), and each run of hop segments pads to a multiple of
      ``FUSED_WAVE_BUCKET`` with identity no-op waves (identity routing,
      nothing trains, zero wire charge).  Padding is executor-internal —
      exactly like the ``c_pad`` slot padding, it never touches real
      slots, so ledger and parameter parity are preserved bit-identically.
    """

    def __init__(self, loss_fn: Callable,
                 client_batches: Sequence[Callable], cfg,
                 clip: float | None = 10.0, mesh=None):
        super().__init__(loss_fn, client_batches, cfg, clip)
        from repro.launch.mesh import make_fl_mesh
        c = cfg.num_clients
        if mesh is None:
            mesh = make_fl_mesh(c, model=int(getattr(cfg,
                                                     "mesh_model_axis", 1)))
        self.mesh = mesh
        shape = dict(mesh.shape)
        self.kc = int(shape[CLIENT_AXIS])
        self.km = int(shape.get(MODEL_AXIS, 1))
        # A caller-supplied 1-D ("clients",) mesh still works: the model
        # axis degenerates and every spec collapses to P(("clients",)).
        self._axes = FL_AXES if MODEL_AXIS in shape else (CLIENT_AXIS,)
        self.k = self.kc * self.km
        self.c = c
        self.c_pad = -(-c // self.k) * self.k
        self.nl = self.c_pad // self.k        # train-layout rows per device
        self.nl_hop = self.c_pad // self.kc   # hop-layout rows per ring slot
        mb_cap = max(1, int(getattr(cfg, "shard_microbatch", 32)))
        self.mb = max(b for b in range(1, min(mb_cap, self.nl) + 1)
                      if self.nl % b == 0)
        self.nchunks = self.nl // self.mb
        # Fused-plane double buffering: two destination chunks per hop when
        # the local block splits evenly.  Chunk j+1's send gathers read only
        # pre-hop state, so its collectives can issue while chunk j trains.
        self.fused_chunks = 2 if (self.km == 1 and self.nl % 2 == 0) else 1
        self.fused_mb = self.nl // self.fused_chunks
        mode = str(getattr(cfg, "shard_overlap", "auto"))
        assert mode in ("auto", "on", "off"), mode
        # Phase profiling needs per-op dispatch boundaries, and below
        # FUSED_MIN_CLIENTS the fused program's compile cost and round-
        # signature sensitivity outweigh the dispatch it saves — "auto"
        # therefore takes the fused plane only for large unprofiled fleets.
        self.overlap = mode == "on" or (mode == "auto" and not self.profile
                                        and c >= self.FUSED_MIN_CLIENTS)
        transport = str(getattr(cfg, "shard_hop_transport", "auto"))
        assert transport in ("auto", "ring", "gather"), transport
        self._transport_req = transport
        self._transport: str | None = None     # resolved on first fused round
        self._stc_cache: dict = {}
        self._fused_cache: dict = {}
        # Fused-plane signature normalization (see class docstring): the
        # running per-segment step maximum, and a zero batch template for
        # the cond-skipped padding steps (set on the first drawn step).
        self._nb_pad = 0
        self._batch_template = None
        self._build()

    # Largest gathered flat client stack (c_pad × F × 4 bytes) the "auto"
    # hop transport will materialize per device; beyond it hops fall back to
    # the O(block)-memory ring shifts.
    GATHER_BUDGET_BYTES = 1 << 30

    # Hop runs pad to a multiple of this many waves with identity no-op
    # segments, bounding the signature space (and hence trace + compile
    # count) while a no-op wave costs one skipped hop at runtime.
    FUSED_WAVE_BUCKET = 4

    # Smallest fleet for which ``shard_overlap="auto"`` takes the fused
    # round plane: below it per-op dispatch is cheap relative to the round
    # and the whole-round program only adds compile latency.
    FUSED_MIN_CLIENTS = 256

    def _hop_transport(self, params) -> str:
        """Resolve the fused-plane hop collective for this model size.

        ``"gather"`` moves each hop with ONE tiled ``all_gather`` over the
        combined mesh axes plus a local row-take — a single collective
        rendezvous per hop, the fast path whenever the gathered
        ``(c_pad, F)`` stack fits :data:`GATHER_BUDGET_BYTES` per device.
        ``"ring"`` is the per-shift ``ppermute`` decomposition (double
        buffered when ``km == 1``): kc rendezvous per hop but O(block)
        memory — the large-model path.
        """
        if self._transport is None:
            if self._transport_req != "auto":
                self._transport = self._transport_req
            else:
                # params: the GLOBAL (unstacked) pytree — F is its flat size.
                f = sum(int(np.prod(x.shape))
                        for x in jax.tree.leaves(params))
                gathered = 4 * self.c_pad * f
                self._transport = ("gather"
                                   if gathered <= self.GATHER_BUDGET_BYTES
                                   else "ring")
        return self._transport

    # -------------------------------------------------------- slot padding

    def _pad_mask(self, mask) -> np.ndarray:
        m = np.zeros(self.c_pad, dtype=bool)
        m[:self.c] = np.asarray(mask, dtype=bool)
        return m

    def _pad_perm(self, src_of_dst) -> np.ndarray:
        p = np.arange(self.c_pad, dtype=np.int64)
        p[:self.c] = np.asarray(src_of_dst, dtype=np.int64)
        return p

    def _pad_matrix(self, w: np.ndarray) -> np.ndarray:
        # Identity on the padding block: padded slots keep their content
        # and contribute weight 0 to every real slot's mixture.
        out = np.eye(self.c_pad, dtype=np.float32)
        out[:self.c, :self.c] = w
        return out

    def _pad_weights(self, w) -> np.ndarray:
        out = np.zeros(self.c_pad, dtype=np.float32)
        out[:self.c] = np.asarray(w, dtype=np.float32)
        return out

    # ------------------------------------------------------- compiled planes

    def _shmap(self, f, in_specs, out_specs):
        return jax.jit(shard_map(f, mesh=self.mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False))

    def _build(self) -> None:
        axes = self._axes
        pc = P(axes)
        kc, km = self.kc, self.km
        nl, nl_hop = self.nl, self.nl_hop
        nchunks, mb = self.nchunks, self.mb
        D, mbh = self.fused_chunks, self.fused_mb
        vstep = jax.vmap(self._one)

        def chunked_session_step(p, mom, batch, active, anchor):
            # Local block of nl clients, trained in nchunks microbatches so
            # activations/grads are O(mb) per device, not O(N).
            args = (p, mom, batch, active, anchor)
            if nchunks == 1:
                return vstep(*args)
            split = jax.tree.map(
                lambda x: x.reshape((nchunks, mb) + x.shape[1:]), args)
            out = jax.lax.map(lambda a: vstep(*a), split)
            return jax.tree.map(
                lambda x: x.reshape((-1,) + x.shape[2:]), out)

        # Overrides FleetExecutor._step: _session() is inherited unchanged.
        self._step = self._shmap(chunked_session_step,
                                 in_specs=(pc, pc, pc, pc, pc),
                                 out_specs=(pc, pc, pc))

        def session_local(params, steps):
            # Fused-plane session body: same math as FleetExecutor._session
            # but running *inside* shard_map on the local block.  Steps
            # whose active mask is empty on this device — signature
            # padding, ragged epochs — are skipped by a real branch: the
            # lax.cond sits outside the vmap, so a padded step costs one
            # predicate, not a training step.
            if not steps:
                return params
            mom = jax.tree.map(
                lambda p: jnp.zeros_like(p, jnp.float32), params)
            anchor = params
            for batch, active in steps:
                def run(carry, batch=batch, active=active):
                    p, m = carry
                    p2, m2, _ = chunked_session_step(p, m, batch, active,
                                                     anchor)
                    return p2, m2
                params, mom = jax.lax.cond(jnp.any(active), run,
                                           lambda carry: carry,
                                           (params, mom))
            return params

        self._local_session = session_local

        def shift_rows(x, send, recv):
            # x: (nl_hop, F) hop-layout rows; send/recv: (kc, nl_hop) local
            # routing tables.  kc ring shifts, trash row nl_hop for padding.
            out = jnp.zeros((nl_hop + 1,) + x.shape[1:], x.dtype)
            for shift in range(kc):
                buf = jnp.take(x, send[shift], axis=0)
                if shift:
                    buf = jax.lax.ppermute(
                        buf, CLIENT_AXIS,
                        [(s, (s + shift) % kc) for s in range(kc)])
                out = out.at[recv[shift]].set(buf)
            return out[:nl_hop]

        quant = self.quant

        def permute_local(params, send_all, recv_all):
            # Routing tables travel replicated ((kc, kc, nl_hop)); each ring
            # slot selects its row by mesh position.
            ic = jax.lax.axis_index(CLIENT_AXIS)
            send, recv = send_all[ic], recv_all[ic]
            if km == 1:
                if quant:
                    # int8 wire: ring-shift the packed codes and their
                    # scales instead of fp32 rows, decode at the
                    # destination (shift_rows is dtype-generic).
                    flat, spec = stack_ravel(params)
                    q, s = pack_rows(flat)
                    q = shift_rows(q, send, recv)
                    s = shift_rows(s, send, recv)
                    return stack_unravel(unpack_rows(q, s, flat.shape[1]),
                                         spec)
                return jax.tree.map(
                    lambda x: shift_rows(x, send, recv), params)
            # Hop layout: feature-split every leaf over "model" so one ring
            # shift moves F/km bytes per link.  After the all_to_all the
            # device holds the *contiguous* client rows of its ring slot
            # (row blocks concatenate in model-axis order, and the combined
            # linear device order is ic·km + im), which is exactly the
            # contiguity _permutation_tables assumes.
            flat, spec = stack_ravel(params)
            if quant:
                # A km-way feature split cuts across quantization row-
                # blocks, so the packed wire needs km == 1 (or the gather
                # transport, which moves whole rows); here the payload is
                # decoded locally — numerically identical hop, fp32 moves.
                flat = quant_roundtrip_rows(flat)
            f = flat.shape[1]
            fpad = (-f) % km
            if fpad:
                flat = jnp.pad(flat, ((0, 0), (0, fpad)))
            x = jax.lax.all_to_all(flat, MODEL_AXIS, split_axis=1,
                                   concat_axis=0, tiled=True)
            y = shift_rows(x, send, recv)
            y = jax.lax.all_to_all(y, MODEL_AXIS, split_axis=0,
                                   concat_axis=1, tiled=True)
            return stack_unravel(y[:, :f], spec)

        self._local_permute = permute_local
        self._sh_permute = self._shmap(permute_local,
                                       in_specs=(pc, P(), P()), out_specs=pc)

        def gather_permute_local(params, perm):
            # One-collective hop: tiled all_gather over the combined axes
            # reassembles the (c_pad, F) flat stack in global slot order
            # (device linear index ic·km + im matches the concatenation
            # order), then each device takes its own destination rows.  One
            # rendezvous per hop vs the ring's kc — the fast transport while
            # the gathered stack fits GATHER_BUDGET_BYTES.
            flat, spec = stack_ravel(params)
            d = jax.lax.axis_index(CLIENT_AXIS)
            if km > 1:
                d = d * km + jax.lax.axis_index(MODEL_AXIS)
            rows = jax.lax.dynamic_slice_in_dim(perm, d * nl, nl)
            if quant:
                # int8 wire: gather the packed codes + scales (whole client
                # rows, so blocks stay intact at any km), decode the taken
                # destination rows.
                q, s = pack_rows(flat)
                fq = jax.lax.all_gather(q, axes, axis=0, tiled=True)
                fs = jax.lax.all_gather(s, axes, axis=0, tiled=True)
                return stack_unravel(
                    unpack_rows(jnp.take(fq, rows, axis=0),
                                jnp.take(fs, rows, axis=0), flat.shape[1]),
                    spec)
            full = jax.lax.all_gather(flat, axes, axis=0, tiled=True)
            return stack_unravel(jnp.take(full, rows, axis=0), spec)

        self._local_permute_gather = gather_permute_local

        def chunked_permute_session(params, send_all, recv_all, steps):
            # Double-buffered fused hop (km == 1): rows are routed per
            # *destination chunk*; chunk j's scatter+train consumes only its
            # own buffers while chunk j+1's gathers read the pre-hop flat
            # block, so the backend can overlap j+1's collectives with j's
            # compute.  Concatenating the trained chunks restores slot order.
            ic = jax.lax.axis_index(CLIENT_AXIS)
            send, recv = send_all[ic], recv_all[ic]     # (D, kc, mbh)
            flat, spec = stack_ravel(params)
            if quant:
                # int8 wire: pack the pre-hop block once; each chunk then
                # routes its slice of codes + scales through the same
                # double-buffered shifts and decodes on arrival.
                qf, sf = pack_rows(flat)
            chunks = []
            for j in range(D):
                if quant:
                    outq = jnp.zeros((mbh + 1, qf.shape[1]), qf.dtype)
                    outs = jnp.zeros((mbh + 1, sf.shape[1]), sf.dtype)
                    for shift in range(kc):
                        bq = jnp.take(qf, send[j, shift], axis=0)
                        bs = jnp.take(sf, send[j, shift], axis=0)
                        if shift:
                            links = [(s, (s + shift) % kc)
                                     for s in range(kc)]
                            bq = jax.lax.ppermute(bq, CLIENT_AXIS, links)
                            bs = jax.lax.ppermute(bs, CLIENT_AXIS, links)
                        outq = outq.at[recv[j, shift]].set(bq)
                        outs = outs.at[recv[j, shift]].set(bs)
                    chunk = stack_unravel(
                        unpack_rows(outq[:mbh], outs[:mbh], flat.shape[1]),
                        spec)
                else:
                    out = jnp.zeros((mbh + 1, flat.shape[1]), flat.dtype)
                    for shift in range(kc):
                        buf = jnp.take(flat, send[j, shift], axis=0)
                        if shift:
                            buf = jax.lax.ppermute(
                                buf, CLIENT_AXIS,
                                [(s, (s + shift) % kc) for s in range(kc)])
                        out = out.at[recv[j, shift]].set(buf)
                    chunk = stack_unravel(out[:mbh], spec)
                if steps:
                    mom = jax.tree.map(
                        lambda p: jnp.zeros_like(p, jnp.float32), chunk)
                    anchor = chunk
                    for batch, active in steps:
                        bch = jax.tree.map(
                            lambda x: x[j * mbh:(j + 1) * mbh], batch)
                        act = active[j * mbh:(j + 1) * mbh]

                        def run(carry, bch=bch, act=act, anchor=anchor):
                            p, m = carry
                            p2, m2, _ = vstep(p, m, bch, act, anchor)
                            return p2, m2
                        chunk, mom = jax.lax.cond(
                            jnp.any(act), run, lambda carry: carry,
                            (chunk, mom))
                chunks.append(chunk)
            return jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *chunks)

        self._local_permute_session = chunked_permute_session

        def mix_local(params, wt_local):
            # wt_local: this device's (nl, C_pad) block of Wᵀ — the kernel
            # data plane computes the partial products over local source
            # slots ((C_pad, ...) fp32 per leaf: partials stay fp32 across
            # the collective), then psum_scatter reduces them back to
            # owners.  Scattering over "clients" then "model" lands row
            # block (ic·km + im)·nl — the combined-order train layout.
            part = kernel_ops.mix_aggregate_tree(params, wt_local.T,
                                                 keep_float32=True)

            def scatter(x, orig):
                out = jax.lax.psum_scatter(x, CLIENT_AXIS,
                                           scatter_dimension=0, tiled=True)
                if km > 1:
                    out = jax.lax.psum_scatter(out, MODEL_AXIS,
                                               scatter_dimension=0,
                                               tiled=True)
                return out.astype(orig.dtype)
            return jax.tree.map(scatter, part, params)

        self._local_mix = mix_local
        self._sh_mix = self._shmap(mix_local, in_specs=(pc, pc),
                                   out_specs=pc)

        def agg_local(payload, w_local):
            # Eq. (11) as a masked psum over the combined axes: dropped,
            # churned and padding slots carry zero weight, so their rows
            # contribute nothing to the reduction.
            part = kernel_ops.mix_aggregate_tree(
                payload, w_local.reshape(1, -1), collapse=True,
                keep_float32=True)

            def reduce(x, orig):
                return jax.lax.psum(x, axes).astype(orig.dtype)
            return jax.tree.map(reduce, part, payload)

        self._local_agg = agg_local
        self._sh_agg = self._shmap(agg_local, in_specs=(pc, pc),
                                   out_specs=P())

        def bcast_local(g):
            return jax.tree.map(
                lambda x: jnp.broadcast_to(x, (nl,) + x.shape), g)

        self._local_bcast = bcast_local
        self._sh_bcast = self._shmap(bcast_local, in_specs=P(), out_specs=pc)

    def _sh_stc(self, sparsity: float):
        fn = self._stc_cache.get(sparsity)
        if fn is None:
            pc = P(self._axes)

            def stc_tree(params, ref, mask):
                return masked_stc_compress(params, ref, mask, sparsity)
            fn = self._shmap(stc_tree, in_specs=(pc, P(), pc), out_specs=pc)
            self._stc_cache[sparsity] = fn
        return fn

    # ------------------------- primitive overrides (round loop inherited)

    def capture_slots(self, slots: Params | None):
        # Padding slots are an executor-internal placement detail — strip
        # them so checkpoints are executor-portable.
        if slots is None:
            return None
        host = jax.device_get(slots)
        if self.c_pad == self.c:
            return host
        return jax.tree.map(lambda x: x[:self.c], host)

    def adopt_slots(self, saved):
        # Restored slot state must land client-sharded (zero-filled padding
        # rows) — the shard_map planes expect the leading axis on the mesh.
        sh = jax.sharding.NamedSharding(self.mesh, P(self._axes))

        def place(x):
            x = np.asarray(x)
            if self.c_pad != self.c:
                pad = np.zeros((self.c_pad - self.c,) + x.shape[1:],
                               x.dtype)
                x = np.concatenate([x, pad], axis=0)
            return jax.device_put(jnp.asarray(x), sh)
        return jax.tree.map(place, saved)

    def _broadcast(self, global_params: Params, num_slots: int) -> Params:
        return self._sh_bcast(global_params)

    def _session(self, params: Params, mask: np.ndarray) -> Params:
        return super()._session(params, self._pad_mask(mask))

    def _permute(self, params: Params, op: PermuteOp) -> Params:
        send, recv = _permutation_tables(self._pad_perm(op.src_of_dst),
                                         self.kc)
        return self._sh_permute(params, jnp.asarray(send),
                                jnp.asarray(recv))

    def _mix(self, params: Params, op: MixOp, num_slots: int) -> Params:
        wt = np.ascontiguousarray(
            self._pad_matrix(op.matrix(num_slots)).T)
        return self._sh_mix(params, jnp.asarray(wt))

    def _masked_stc(self, params: Params, ref: Params, mask: np.ndarray,
                    sparsity: float) -> Params:
        return self._sh_stc(sparsity)(params, ref,
                                      jnp.asarray(self._pad_mask(mask)))

    def _aggregate(self, payload: Params, w: jax.Array) -> Params:
        return self._sh_agg(payload,
                            jnp.asarray(self._pad_weights(np.asarray(w))))

    # ------------------------------------------------------ fused round plane

    def _build_fused(self, segs: tuple, persistent_in: bool,
                     stc_delta: bool, sparsity: float, transport: str):
        pc = P(self._axes)
        km, D = self.km, self.fused_chunks
        session = self._local_session
        gather = transport == "gather"

        in_specs: list = [P()]                   # global params (replicated)
        if persistent_in:
            in_specs.append(pc)                  # carried slot state
        for seg in segs:
            if seg[0] == "train":
                in_specs += [pc, pc] * seg[1]    # (batch, active) per step
            elif seg[0] == "perm":
                if seg[2]:
                    in_specs.append(pc)          # compress-source mask
                # gather: padded permutation; ring: send/recv routing
                # tables — replicated either way
                in_specs += [P()] if gather else [P(), P()]
                in_specs += [pc, pc] * seg[1]
            else:                                # mix
                in_specs.append(pc)              # Wᵀ row block
        if stc_delta:
            in_specs.append(pc)                  # agg compress mask
        in_specs.append(pc)                      # agg weights

        def fused(g, *rest):
            it = iter(rest)
            params = next(it) if persistent_in else self._local_bcast(g)
            ref = g
            for seg in segs:
                if seg[0] == "train":
                    steps = [(next(it), next(it)) for _ in range(seg[1])]
                    params = session(params, steps)
                elif seg[0] == "perm":
                    cmask = next(it) if seg[2] else None
                    route = (next(it),) if gather else (next(it), next(it))
                    steps = [(next(it), next(it)) for _ in range(seg[1])]
                    if cmask is not None:
                        params = masked_stc_compress(params, ref, cmask,
                                                     sparsity)
                    if gather:
                        params = self._local_permute_gather(params, *route)
                        params = session(params, steps)
                    elif km == 1 and D > 1:
                        params = self._local_permute_session(
                            params, *route, steps)
                    else:
                        params = self._local_permute(params, *route)
                        params = session(params, steps)
                else:
                    params = self._local_mix(params, next(it))
            wmask = next(it) if stc_delta else None
            w_local = next(it)
            payload = (masked_stc_compress(params, ref, wmask, sparsity)
                       if stc_delta else params)
            return self._local_agg(payload, w_local), params

        return self._shmap(fused, in_specs=tuple(in_specs),
                           out_specs=(P(), pc))

    def _run_round_fused(self, sched: RoundSchedule, global_params: Params,
                         slots: Params | None
                         ) -> tuple[Params, Params | None]:
        persistent_in = bool(sched.persistent and slots is not None)
        transport = self._hop_transport(global_params)
        # Pass 1 — draw every session in schedule order (batch-stream
        # parity with the op-by-op loop) and settle the round's uniform
        # step count before any segment is emitted: padding to a running
        # max mid-walk would leave earlier segments shorter and the
        # signature ragged again.
        drawn: list = []
        for op in sched.ops:
            if isinstance(op, (TrainOp, PermuteOp)):
                steps, actives = self._draw_session(
                    self._pad_mask(op.train_mask))
                if steps and self._batch_template is None:
                    self._batch_template = jax.tree.map(jnp.zeros_like,
                                                        steps[0])
                self._nb_pad = max(self._nb_pad, len(steps))
                drawn.append((op, list(zip(steps, actives))))
            elif isinstance(op, MixOp):
                drawn.append((op, None))
            else:
                raise TypeError(f"unknown op {type(op).__name__}")

        nb = self._nb_pad
        dead = jnp.zeros(self.c_pad, dtype=bool)

        def pad_steps(pairs):
            pairs += [(self._batch_template, dead)] * (nb - len(pairs))
            return pairs

        def route_args(perm):
            if transport == "gather":
                return [jnp.asarray(perm)]
            if self.km == 1 and self.fused_chunks > 1:
                send, recv = _chunked_permutation_tables(
                    perm, self.kc, self.fused_chunks)
            else:
                send, recv = _permutation_tables(perm, self.kc)
            return [jnp.asarray(send), jnp.asarray(recv)]

        # Pass 2 — emit segments, bucketing every hop run (see docstring).
        segs: list = []
        args: list = []
        pend = 0                 # open hop-run length
        pend_compress = False

        def close_run():
            nonlocal pend
            npad = (-pend) % self.FUSED_WAVE_BUCKET if pend else 0
            for _ in range(npad):
                if pend_compress:
                    args.append(dead)
                args.extend(route_args(np.arange(self.c_pad,
                                                 dtype=np.int64)))
                for _ in range(nb):
                    args.extend((self._batch_template, dead))
                segs.append(("perm", nb, pend_compress))
            pend = 0

        for op, pairs in drawn:
            if isinstance(op, TrainOp):
                close_run()
                pairs = pad_steps(pairs)
                segs.append(("train", len(pairs)))
                for b, a in pairs:
                    args.extend((b, a))
            elif isinstance(op, PermuteOp):
                compress = bool(op.compress)
                if pend and compress != pend_compress:
                    close_run()
                pend_compress = compress
                pend += 1
                if compress:
                    args.append(jnp.asarray(
                        self._pad_mask(op.compress_src_mask())))
                args.extend(route_args(self._pad_perm(op.src_of_dst)))
                pairs = pad_steps(pairs)
                segs.append(("perm", len(pairs), compress))
                for b, a in pairs:
                    args.extend((b, a))
            else:
                close_run()
                segs.append(("mix",))
                wt = np.ascontiguousarray(
                    self._pad_matrix(op.matrix(sched.num_slots)).T)
                args.append(jnp.asarray(wt))
        close_run()
        wvec = sched.slot_weights()
        stc_delta = sched.agg_mode == "stc_delta"
        if stc_delta:
            args.append(jnp.asarray(self._pad_mask(wvec > 0)))
        args.append(jnp.asarray(self._pad_weights(
            (wvec / wvec.sum()).astype(np.float32))))

        key = (tuple(segs), persistent_in, stc_delta,
               float(sched.stc_sparsity), transport)
        fn = self._fused_cache.get(key)
        if fn is None:
            fn = self._build_fused(tuple(segs), persistent_in, stc_delta,
                                   float(sched.stc_sparsity), transport)
            self._fused_cache[key] = fn
        if persistent_in:
            new_global, params = fn(global_params, slots, *args)
        else:
            new_global, params = fn(global_params, *args)
        return new_global, (params if sched.persistent else None)

    def run_round(self, sched: RoundSchedule, global_params: Params,
                  slots: Params | None) -> tuple[Params, Params | None]:
        # The mesh/tables were built for cfg.num_clients slots.
        assert sched.num_slots == self.cfg.num_clients, \
            (sched.num_slots, self.cfg.num_clients)
        if self.overlap and not self.profile:
            return self._run_round_fused(sched, global_params, slots)
        return super().run_round(sched, global_params, slots)


def make_executor(name: str, loss_fn: Callable, local_update: Callable,
                  client_batches: Sequence[Callable], cfg):
    """Build the executor selected by ``FLConfig.executor``."""
    if name == "host":
        return HostExecutor(local_update, client_batches, cfg)
    if name == "fleet":
        return FleetExecutor(loss_fn, client_batches, cfg)
    if name == "sharded":
        return ShardedFleetExecutor(loss_fn, client_batches, cfg)
    raise ValueError(f"unknown executor {name!r}; expected one of "
                     f"{EXECUTORS}")
